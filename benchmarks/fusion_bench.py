"""Fused-segment JIT engine vs per-instruction interpreter.

Repeated-execution workload (the JMLC/HPO serving shape): a
`PreparedScript` scoring pipeline invoked many times with fresh inputs.
The interpreter dispatches ~a dozen eager jnp calls per invocation with
a `block_until_ready` barrier each; the fused engine replays a handful
of cached XLA executables. Also checks numerical parity and that
reuse-cache hit counts are identical across modes on a grid-search
workload.

Appends a trajectory entry to ``benchmarks/BENCH_fusion.json``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from .common import emit, timed

BENCH_JSON = os.path.join(os.path.dirname(__file__), "BENCH_fusion.json")


def _pipeline(x, w):
    from repro.core import ops
    z = x @ w
    p = ops.sigmoid(z)
    err = p - 0.5
    g = ops.xtv(x, err * 2.0) + 1e-3 * w
    loss = ops.sum_(err * err)
    stats = ops.cbind(ops.colSums(err), ops.colMaxs(err))
    return loss, g, stats


def _build_script(fuse: bool, rows: int, cols: int):
    from repro.core import LineageRuntime, PreparedScript
    rt = LineageRuntime(fuse=fuse)
    return PreparedScript(_pipeline, [(rows, cols), (cols, 1)],
                          runtime=rt), rt


def _scoring_loop(ps, xs, ws, calls: int):
    out = None
    for i in range(calls):
        out = ps(xs[i % len(xs)], ws[i % len(ws)])
    return out


def _reuse_hits(fuse: bool, xn, yn, lambdas) -> tuple:
    from repro.core import LineageRuntime, ReuseCache, input_tensor, ops
    rt = LineageRuntime(cache=ReuseCache(), fuse=fuse)
    x, y = input_tensor("fbX", xn), input_tensor("fby", yn)
    for lam in lambdas:
        n = x.shape[1]
        beta = ops.solve(ops.gram(x) + float(lam) * ops.eye(n),
                         ops.xtv(x, y))
        rt.evaluate([beta])
    return rt.cache.stats.probes, rt.cache.stats.hits


def main(rows: int = 2000, cols: int = 64, calls: int = 50,
         repeats: int = 3) -> dict:
    rng = np.random.default_rng(7)
    xs = [rng.normal(size=(rows, cols)) for _ in range(4)]
    ws = [rng.normal(size=(cols, 1)) for _ in range(4)]

    # JMLC shape: compile once, invoke many — the script is prepared
    # outside the timed loop, replay cost is what matters.
    ps_fused, _ = _build_script(True, rows, cols)
    ps_interp, _ = _build_script(False, rows, cols)
    t_fused = timed(lambda: _scoring_loop(ps_fused, xs, ws, calls),
                    repeats=repeats, warmup=1)
    t_interp = timed(lambda: _scoring_loop(ps_interp, xs, ws, calls),
                     repeats=repeats, warmup=1)

    out_f = _scoring_loop(ps_fused, xs, ws, 4)
    out_i = _scoring_loop(ps_interp, xs, ws, 4)
    parity = max(float(np.max(np.abs(a - b)))
                 for a, b in zip(out_f, out_i))
    assert parity < 1e-9, f"fusion changed results (max abs err {parity})"

    xn = rng.normal(size=(rows // 4, cols))
    yn = rng.normal(size=(rows // 4, 1))
    hits_f = _reuse_hits(True, xn, yn, (0.1, 1.0, 10.0))
    hits_i = _reuse_hits(False, xn, yn, (0.1, 1.0, 10.0))
    assert hits_f == hits_i, \
        f"fusion changed reuse behaviour: {hits_f} vs {hits_i}"

    speedup = t_interp / max(t_fused, 1e-12)
    emit("fused_vs_interpreted", t_fused / calls,
         f"interp_us={t_interp / calls * 1e6:.1f};speedup={speedup:.2f}x")

    entry = dict(
        benchmark="fused_vs_interpreted",
        workload=f"prepared_script_scoring_loop({rows}x{cols}, "
                 f"{calls} calls)",
        fused_us_per_call=round(t_fused / calls * 1e6, 1),
        interpreted_us_per_call=round(t_interp / calls * 1e6, 1),
        speedup=round(speedup, 2),
        parity_max_abs_err=parity,
        reuse_probes_hits_fused=list(hits_f),
        reuse_probes_hits_interpreted=list(hits_i),
        ts=time.strftime("%Y-%m-%dT%H:%M:%S"),
    )
    trajectory = []
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                trajectory = json.load(f)
        except Exception:
            trajectory = []
    trajectory.append(entry)
    with open(BENCH_JSON, "w") as f:
        json.dump(trajectory, f, indent=2)
    return entry


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    print("name,us_per_call,derived")
    print(json.dumps(main(), indent=2))
