"""Compile-time format assignment + sparse execution.

Covers the sparsity-aware fused engine: the format-assignment pass
(dense/bcoo pinned from propagated estimates), dense/sparse kernel
parity across the registry at several densities, the block-sparse
Pallas SpMM kernels (interpret mode), sparse-size cache accounting, and
property tests that sparsity estimates stay in [0, 1] through rewrites.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (LineageRuntime, ReuseCache, input_tensor, ops)
from repro.core import backend
from repro.core.compiler import compile_plan
from repro.core.dag import SPARSE_THRESHOLD
from repro.core.rewrites import run_rewrites

needs_sparse = pytest.mark.skipif(not backend.HAS_SPARSE,
                                  reason="jax.experimental.sparse absent")


def _sparse_mat(rng, m, n, density):
    return rng.normal(size=(m, n)) * (rng.random((m, n)) < density)


# ---------------------------------------------------------------------------
# format assignment
# ---------------------------------------------------------------------------

@needs_sparse
class TestFormatAssignment:
    def test_sparse_leaf_assigned_bcoo(self, rng):
        x = input_tensor("Xs", _sparse_mat(rng, 128, 64, 0.05))
        plan = compile_plan([ops.gram(x)])
        fmts = plan.formats_for(True)
        assert fmts[x.node.uid] == backend.BCOO
        # gram of a sparse matrix produces a dense result (only
        # non-dense assignments are recorded in the mapping)
        (gram_ins,) = [i for i in plan.instructions
                       if i.node.op == "gram"]
        assert fmts.get(gram_ins.out_id, backend.DENSE) == backend.DENSE

    def test_dense_or_small_leaves_stay_dense(self, rng):
        dense_leaf = input_tensor("Xd", rng.normal(size=(128, 64)))
        small_leaf = input_tensor("Xt", _sparse_mat(rng, 8, 8, 0.05))
        plan = compile_plan([ops.sum_(ops.gram(dense_leaf))
                             + ops.sum_(ops.gram(small_leaf))])
        fmts = plan.formats_for(True)
        assert fmts.get(dense_leaf.node.uid, backend.DENSE) == backend.DENSE
        # < min numel
        assert fmts.get(small_leaf.node.uid, backend.DENSE) == backend.DENSE
        # nothing qualified for bcoo: the mapping is empty, so all-dense
        # plans share jit executables across sparse_inputs modes
        assert fmts == {}

    def test_sparse_disabled_means_empty_mapping(self, rng):
        x = input_tensor("Xs", _sparse_mat(rng, 128, 64, 0.05))
        plan = compile_plan([ops.gram(x)])
        assert plan.formats_for(False) == {}

    def test_structure_preserving_ops_keep_bcoo(self, rng):
        x = input_tensor("Xs", _sparse_mat(rng, 128, 64, 0.05))
        expr = ops.abs_(-(x.T)) * 2.0        # t, neg, abs, scalar mul
        plan = compile_plan([ops.sum_(expr)], opt_level=0)
        fmts = plan.formats_for(True)
        by_op = {}
        for ins in plan.instructions:
            by_op.setdefault(ins.node.op, fmts.get(ins.out_id,
                                                   backend.DENSE))
        assert by_op["t"] == backend.BCOO
        assert by_op["neg"] == backend.BCOO
        assert by_op["abs"] == backend.BCOO
        assert by_op["mul"] == backend.BCOO   # bcoo * scalar
        assert by_op["sum"] == backend.DENSE  # densify boundary

    def test_non_scalar_mul_densifies(self, rng):
        x = input_tensor("Xs", _sparse_mat(rng, 128, 64, 0.05))
        w = input_tensor("W", rng.normal(size=(128, 64)))
        plan = compile_plan([ops.sum_(x * w)], opt_level=0)
        fmts = plan.formats_for(True)
        (mul_ins,) = [i for i in plan.instructions if i.node.op == "mul"]
        assert fmts.get(mul_ins.out_id, backend.DENSE) == backend.DENSE

    def test_explain_annotates_formats(self, rng):
        x = input_tensor("Xs", _sparse_mat(rng, 128, 64, 0.05))
        txt = compile_plan([ops.gram(-x)]).explain(sparse=True)
        assert ":bcoo" in txt and "fmt=bcoo" in txt
        assert ":bcoo" not in compile_plan([ops.gram(-x)]).explain()

    def test_threshold_shared_with_cost_model(self):
        from repro.core import costmodel
        assert backend.SPARSE_THRESHOLD is SPARSE_THRESHOLD
        assert costmodel.SPARSE_THRESHOLD is SPARSE_THRESHOLD


# ---------------------------------------------------------------------------
# dense/sparse kernel parity across the registry
# ---------------------------------------------------------------------------

def _registry_pipeline(x, y):
    """Touches matmul/gram/xtv/add/mul + slice/cbind/rbind densify
    boundaries and unary/aggregate kernels."""
    g = ops.gram(x)                       # bcoo -> dense
    b = ops.xtv(x, y)                     # bcoo,dense -> dense
    z = x @ (b * 0.5)                     # bcoo matmul dense
    s = ops.abs_(-x) * 2.0                # stays bcoo
    sl = x[4:60, 1:33]                    # densify boundary
    cat = ops.cbind(ops.colSums(z), ops.colMaxs(z))
    stacked = ops.rbind(sl, sl)
    return [ops.sum_(g), ops.sum_(b), ops.sum_(z), ops.sum_(s),
            ops.sum_(stacked), cat, ops.sqrt(ops.abs_(g)) + g * g]


@needs_sparse
class TestDenseSparseParity:
    @pytest.mark.parametrize("density", [0.01, 0.05, 0.2])
    @pytest.mark.parametrize("fuse", [True, False])
    def test_registry_parity(self, rng, density, fuse):
        xn = _sparse_mat(rng, 128, 64, density)
        yn = rng.normal(size=(128, 1))
        x, y = input_tensor("X", xn), input_tensor("y", yn)
        exprs = _registry_pipeline(x, y)
        dense_out = LineageRuntime(fuse=True,
                                   sparse_inputs=False).evaluate(exprs)
        got = LineageRuntime(fuse=fuse,
                             sparse_inputs=True).evaluate(exprs)
        for a, b in zip(got, dense_out):
            np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-10)

    def test_sparse_plan_fuses(self, rng):
        xn = _sparse_mat(rng, 128, 64, 0.05)
        yn = rng.normal(size=(128, 1))
        x, y = input_tensor("X", xn), input_tensor("y", yn)
        rt = LineageRuntime(fuse=True, sparse_inputs=True)
        rt.evaluate(_registry_pipeline(x, y))
        # the whole sparse pipeline ran as a handful of fused segments,
        # not one dispatch per instruction
        assert rt.stats.segments < rt.stats.instructions / 2

    def test_sparse_reuse_hits_match_interpreter(self, rng):
        xn = _sparse_mat(rng, 256, 64, 0.05)
        yn = rng.normal(size=(256, 1))
        stats = {}
        for fuse in (True, False):
            rt = LineageRuntime(cache=ReuseCache(), fuse=fuse,
                                sparse_inputs=True)
            x, y = input_tensor("X", xn), input_tensor("y", yn)
            for lam in (0.1, 1.0, 10.0):
                beta = ops.solve(ops.gram(x) + float(lam) * ops.eye(64),
                                 ops.xtv(x, y))
                out = rt.evaluate([beta])[0]
            stats[fuse] = (rt.cache.stats.probes, rt.cache.stats.hits,
                           rt.cache.stats.misses)
            assert rt.cache.stats.hits >= 4  # gram+xtv per extra lambda
        assert stats[True] == stats[False]
        ref = np.linalg.solve(xn.T @ xn + 10.0 * np.eye(64), xn.T @ yn)
        np.testing.assert_allclose(out, ref, rtol=1e-8, atol=1e-9)


# ---------------------------------------------------------------------------
# block-sparse Pallas kernels (interpret mode)
# ---------------------------------------------------------------------------

class TestSpmmKernels:
    def test_block_mask(self, rng):
        from repro.kernels.spmm import ops as sops, ref
        x = np.zeros((32, 32))
        x[0, 0] = 1.0
        x[20, 30] = 2.0
        got = np.asarray(sops.block_mask(np.asarray(x), 16, 16))
        np.testing.assert_array_equal(got, ref.block_mask(x, 16, 16))
        assert got[0, 0] == 1 and got[1, 1] == 1
        assert got[0, 1] == 0 and got[1, 0] == 0

    def test_gram_block_sparse_matches_ref(self, rng):
        from repro.kernels.spmm import ops as sops, ref
        x = _sparse_mat(rng, 64, 32, 0.1).astype(np.float32)
        got = np.asarray(sops.gram_dense_masked(x, bm=16, bn=16,
                                                interpret=True))
        np.testing.assert_allclose(got, ref.gram(x), rtol=1e-4, atol=1e-4)

    def test_spmm_block_sparse_matches_ref(self, rng):
        from repro.kernels.spmm import ops as sops, ref
        x = _sparse_mat(rng, 64, 32, 0.1).astype(np.float32)
        w = rng.normal(size=(32, 8)).astype(np.float32)
        got = np.asarray(sops.spmm_dense_masked(x, w, bm=16, bk=16,
                                                interpret=True))
        np.testing.assert_allclose(got, ref.spmm(x, w), rtol=1e-4,
                                   atol=1e-4)

    def test_xtv_block_sparse_matches_ref(self, rng):
        from repro.kernels.spmm import ops as sops, ref
        x = _sparse_mat(rng, 64, 32, 0.1).astype(np.float32)
        v = rng.normal(size=(64, 1)).astype(np.float32)
        got = np.asarray(sops.xtv_dense_masked(x, v, bm=16, bn=16,
                                               interpret=True))
        np.testing.assert_allclose(got, ref.xtv(x, v), rtol=1e-4,
                                   atol=1e-4)

    def test_zero_blocks_are_skipped_exactly(self, rng):
        # block-aligned sparsity: only one block column populated;
        # result must equal the dense gram bit-for-bit in the populated
        # block and zero elsewhere
        from repro.kernels.spmm import ops as sops
        x = np.zeros((64, 32), dtype=np.float32)
        x[:, :16] = rng.normal(size=(64, 16)).astype(np.float32)
        got = np.asarray(sops.gram_dense_masked(x, bm=16, bn=16,
                                                interpret=True))
        np.testing.assert_allclose(got, x.T @ x, rtol=1e-4, atol=1e-4)
        assert np.all(got[16:, 16:] == 0.0)


# ---------------------------------------------------------------------------
# sparse cache accounting (reuse.nbytes)
# ---------------------------------------------------------------------------

@needs_sparse
class TestSparseCacheAccounting:
    def test_bcoo_nbytes_is_sparse_size(self, rng):
        from jax.experimental import sparse as jsparse
        from repro.core.reuse import nbytes
        xn = _sparse_mat(rng, 256, 256, 0.02)
        xb = jsparse.BCOO.fromdense(np.asarray(xn))
        got = nbytes(xb)
        expect = int(xb.data.nbytes) + int(xb.indices.nbytes)
        assert got == expect
        assert 64 < got < xn.nbytes  # not the stub, not the dense size

    def test_nbytes_fallbacks(self):
        from repro.core.reuse import nbytes
        assert nbytes(np.zeros((4, 4))) == 128

        class SizeOnly:
            size, dtype = 10, np.dtype(np.float64)
        assert nbytes(SizeOnly()) == 80
        assert nbytes(object()) == 64

    def test_prepared_script_formats_are_declared_not_guessed(self, rng):
        # placeholder leaves are zeros; without a declaration the
        # format pass must NOT pin them to BCOO
        from repro.core import PreparedScript
        rt = LineageRuntime(fuse=True, sparse_inputs=True)
        ps = PreparedScript(lambda a: ops.gram(a), [(128, 64)],
                            runtime=rt)
        fmts = ps.plan.formats_for(True)
        assert fmts == {}  # dense by default
        xn = rng.normal(size=(128, 64))
        np.testing.assert_allclose(ps(xn)[0], xn.T @ xn, rtol=1e-10)
        # with a declared density the leaf is pinned bcoo and results
        # still match
        rt2 = LineageRuntime(fuse=True, sparse_inputs=True)
        ps2 = PreparedScript(lambda a: ops.gram(a), [(128, 64)],
                             runtime=rt2, arg_sparsities=[0.05])
        assert backend.BCOO in ps2.plan.formats_for(True).values()
        xs = _sparse_mat(rng, 128, 64, 0.05)
        np.testing.assert_allclose(ps2(xs)[0], xs.T @ xs, rtol=1e-10)

    def test_fresh_sparse_batches_share_warm_executables(self, rng):
        # nse is part of the BCOO aval: without power-of-two nse
        # bucketing in backend.sparsify, every batch with a distinct
        # nnz would re-trace and recompile its segments
        from repro.core import PreparedScript, clear_jit_cache
        clear_jit_cache()
        rt = LineageRuntime(fuse=True, sparse_inputs=True)
        ps = PreparedScript(lambda a: ops.gram(a), [(256, 64)],
                            runtime=rt, arg_sparsities=[0.05])
        batches = [_sparse_mat(rng, 256, 64, 0.05) for _ in range(4)]
        nnzs = {np.count_nonzero(b) for b in batches}
        assert len(nnzs) > 1  # genuinely distinct nnz per batch
        out = ps(batches[0])[0]
        np.testing.assert_allclose(out, batches[0].T @ batches[0],
                                   rtol=1e-10)
        trace_after_first = rt.stats.trace_time
        hits_before = rt.stats.jit_cache_hits
        for b in batches[1:]:
            np.testing.assert_allclose(ps(b)[0], b.T @ b, rtol=1e-10)
        assert rt.stats.trace_time == trace_after_first  # no re-trace
        assert rt.stats.jit_cache_hits >= hits_before + 3

    def test_inplace_mutation_seen_by_sparse_bind(self, rng):
        # leaf conversion must never serve a stale BCOO after the bound
        # array is mutated in place (regression guard: no identity- or
        # sampled-fingerprint-keyed bind memo)
        from repro.core import PreparedScript
        rt = LineageRuntime(fuse=True, sparse_inputs=True)
        ps = PreparedScript(lambda a: ops.sum_(a), [(128, 64)],
                            runtime=rt, arg_sparsities=[0.05])
        x = _sparse_mat(rng, 128, 64, 0.05)
        first = ps(x)[0]
        x *= 3.0
        np.testing.assert_allclose(ps(x)[0], first * 3.0, rtol=1e-12)

    def test_cache_hit_coerced_to_assigned_format(self, rng):
        # a cache shared across sparse_inputs modes returns values in
        # the other mode's physical format; the runtime must coerce at
        # the probe boundary instead of feeding a dense array to a
        # sparse kernel (or vice versa)
        from repro.core.reuse import ReuseCache as RC
        xn = _sparse_mat(rng, 2048, 128, 0.05)
        cache = RC()
        expr_of = lambda t: ops.sum_(ops.gram(ops.abs_(t)))
        x = input_tensor("Xc", xn)
        ref = LineageRuntime(fuse=True,
                             sparse_inputs=False).evaluate([expr_of(x)])[0]
        for first, second in ((False, True), (True, False)):
            cache.clear()
            r1 = LineageRuntime(cache=cache, sparse_inputs=first)
            r1.evaluate([expr_of(x)])
            r2 = LineageRuntime(cache=cache, sparse_inputs=second)
            out = r2.evaluate([expr_of(x)])[0]
            assert r2.cache.stats.hits > 0  # the cross-format hit
            np.testing.assert_allclose(out, ref, rtol=1e-9)

    def test_cached_sparse_intermediate_accounted_sparse(self, rng):
        # a reused BCOO value must charge the pool its sparse size
        xn = _sparse_mat(rng, 256, 64, 0.02)
        x = input_tensor("X", xn)
        rt = LineageRuntime(cache=ReuseCache(), fuse=True,
                            sparse_inputs=True)
        # t(x) stays bcoo and is expensive enough to probe via bytes?
        # gram is the reliable probe; its entry is dense. Check pool
        # bookkeeping consistency instead: bytes_cached equals the sum
        # of entry sizes as computed by nbytes.
        rt.evaluate([ops.gram(x)])
        from repro.core.reuse import nbytes
        assert rt.cache.stats.bytes_cached == \
            sum(e.size for e in rt.cache.entries.values())
        assert all(e.size == nbytes(e.value)
                   for e in rt.cache.entries.values())


# ---------------------------------------------------------------------------
# property: sparsity estimates stay in [0, 1] through rewrites
# ---------------------------------------------------------------------------

def _walk(nodes):
    seen, out = set(), []

    def rec(n):
        if n.uid in seen:
            return
        seen.add(n.uid)
        out.append(n)
        for i in n.inputs:
            rec(i)

    for n in nodes:
        rec(n)
    return out


@st.composite
def sparse_expr_strategy(draw):
    density = draw(st.floats(0.0, 1.0))
    seed = draw(st.integers(0, 2 ** 16))
    steps = draw(st.lists(
        st.sampled_from(["neg", "abs", "sqrtabs", "mulself", "addself",
                         "scale", "gramlike", "slice", "cat"]),
        min_size=1, max_size=5))
    return density, seed, steps


def _build_sparse(x, steps):
    cur = x
    for s in steps:
        if s == "neg":
            cur = -cur
        elif s == "abs":
            cur = ops.abs_(cur)
        elif s == "sqrtabs":
            cur = ops.sqrt(ops.abs_(cur))
        elif s == "mulself":
            cur = cur * cur
        elif s == "addself":
            cur = cur + cur
        elif s == "scale":
            cur = cur * 3.0
        elif s == "gramlike":
            cur = cur.T @ cur
        elif s == "slice":
            cur = cur[: max(2, cur.shape[0] // 2)]
        elif s == "cat":
            cur = ops.rbind(cur, cur)
    return cur


@settings(max_examples=30, deadline=None)
@given(sparse_expr_strategy())
def test_sparsity_estimates_stay_in_unit_interval(params):
    density, seed, steps = params
    rng = np.random.default_rng(seed)
    xn = rng.normal(size=(12, 12)) * (rng.random((12, 12)) < density)
    x = input_tensor("Xp", xn)
    expr = _build_sparse(x, steps)
    for reuse in (False, True):
        roots = run_rewrites([expr.node], reuse_enabled=reuse,
                             opt_level=2)
        for node in _walk(roots):
            assert 0.0 <= node.sparsity <= 1.0, (node.op, node.sparsity)
