"""Architecture registry: every assigned config selectable via --arch.

Exact hyperparameters from the assignment sheet (sources in brackets in
each module docstring)."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = [
    "rwkv6_3b",
    "llama3_2_3b",
    "phi3_medium_14b",
    "llama3_2_1b",
    "qwen3_0_6b",
    "jamba_v0_1_52b",
    "deepseek_v2_236b",
    "deepseek_moe_16b",
    "musicgen_large",
    "llama3_2_vision_90b",
    # extras (not on the assignment sheet)
    "lm_100m",      # example end-to-end training target
    "paper_hpo",    # the paper's own workload scale knobs
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
_ALIAS.update({
    "rwkv6-3b": "rwkv6_3b",
    "llama3.2-3b": "llama3_2_3b",
    "phi3-medium-14b": "phi3_medium_14b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen3-0.6b": "qwen3_0_6b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "musicgen-large": "musicgen_large",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
})

ASSIGNED = [a for a in ARCHS if a not in ("lm_100m", "paper_hpo")]


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIAS.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG
