"""Quickstart: the declarative DSL, lineage tracing, and reuse.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import (LineageRuntime, ReuseCache, input_tensor,
                        lineage_trace, ops)
from repro.core.compiler import compile_plan


def main():
    rng = np.random.default_rng(0)
    xn = rng.normal(size=(5000, 64))
    yn = xn @ rng.normal(size=(64, 1)) + 0.01 * rng.normal(size=(5000, 1))

    # 1. declarative expressions build a lazy HOP DAG — nothing runs yet
    X = input_tensor("X", xn)
    y = input_tensor("y", yn)
    beta = ops.solve(X.T @ X + 0.1 * ops.eye(64), X.T @ y)

    # 2. the compiler fuses t(X)@X into the gram (tsmm) operator
    plan = compile_plan([beta])
    print("== compiled plan ==")
    print(plan.explain(), "\n")

    # 3. execute with a lineage-reuse cache: sweep λ, X^T X computed ONCE
    rt = LineageRuntime(cache=ReuseCache())
    for lam in (0.01, 0.1, 1.0, 10.0):
        b = rt.evaluate([ops.solve(X.T @ X + lam * ops.eye(64),
                                   X.T @ y)])[0]
        resid = float(np.linalg.norm(xn @ b - yn))
        print(f"lambda={lam:6.2f}  |resid|={resid:9.4f}")
    print("\ncache:", rt.cache.stats.as_dict())
    print("runtime:", rt.stats.as_dict())

    # 4. every value carries its lineage (reproducibility / versioning)
    print("\n== lineage trace of beta ==")
    print(lineage_trace(beta))


if __name__ == "__main__":
    main()
