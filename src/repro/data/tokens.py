"""Deterministic, resumable, sharded LM token pipeline.

Each (host, data-shard) draws disjoint slices of a seeded synthetic
stream; iteration state is just (seed, step), so restart-after-failure
replays exactly (the lineage story of §4.1 applied to data: the batch at
step t is a pure function of the pipeline lineage).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from .synthetic import gen_tokens


@dataclass
class TokenPipeline:
    vocab: int
    batch: int                 # per-shard batch size
    seq_len: int
    seed: int = 0
    shard: int = 0
    n_shards: int = 1
    n_codebooks: int = 0
    step: int = 0              # resumable position

    def batch_at(self, step: int) -> dict:
        """Pure function (seed, shard, step) -> batch."""
        rng_seed = (self.seed * 1_000_003 + self.shard * 7919 + step) \
            % (2 ** 31)
        need = self.batch * (self.seq_len + 1)
        stream = gen_tokens(need, self.vocab, seed=rng_seed,
                            n_codebooks=self.n_codebooks)
        if self.n_codebooks:
            stream = stream.reshape(self.batch, self.seq_len + 1,
                                    self.n_codebooks)
            return {"tokens": stream[:, :-1], "labels": stream[:, 1:]}
        stream = stream.reshape(self.batch, self.seq_len + 1)
        return {"tokens": stream[:, :-1], "labels": stream[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.batch_at(self.step)
            self.step += 1

    def state(self) -> dict:
        return {"seed": self.seed, "shard": self.shard, "step": self.step}

    @classmethod
    def restore(cls, state: dict, **kw) -> "TokenPipeline":
        return cls(seed=state["seed"], shard=state["shard"],
                   step=state["step"], **kw)
