"""Heterogeneous tensors (SystemDS §3.3).

`DataTensor` is the DataTensorBlock analogue: a 2-D+ array where the
second dimension carries a schema; internally it is composed of
homogeneous columns (numpy arrays; string columns stay host-side as
object arrays — TPU adaptation note DESIGN.md §2b).

`transformencode` / `transformapply` are the feature-transform builtins
(recode, dummycode, binning, standardization) that bridge heterogeneous
data into the dense LA world (SystemDS §4.2), emitting plain matrices
consumable by the DSL / models.

The paper's fixed-size n-dimensional blocking scheme (1024², 128³, 32⁴,
16⁵, 8⁶, 8⁷ — §3.3 "Distributed Tensors") is provided as `block_shape` +
`reblock` for local tiles; at cluster scale GSPMD replaces manual RDD
blocking (see DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import numpy as np

VALID_TYPES = ("f64", "f32", "i64", "i32", "bool", "str")
_NP = {"f64": np.float64, "f32": np.float32, "i64": np.int64,
       "i32": np.int32, "bool": np.bool_, "str": object}


# ---------------------------------------------------------------------------
# Schema detection (§4.2 "schema detection" builtin)
# ---------------------------------------------------------------------------

def detect_value_type(col: np.ndarray) -> str:
    """Semantic type detection heuristic for a raw (string-ish) column."""
    vals = [v for v in col.ravel() if v is not None and str(v) != ""]
    if not vals:
        return "str"
    def _is(f):
        try:
            for v in vals[:256]:
                f(str(v))
            return True
        except ValueError:
            return False
    sv = [str(v).strip().lower() for v in vals[:256]]
    if all(v in ("true", "false", "t", "f", "0", "1") for v in sv):
        return "bool"
    if _is(int):
        mx = max(abs(int(str(v))) for v in vals[:256])
        return "i32" if mx < 2 ** 31 else "i64"
    if _is(float):
        return "f64"
    return "str"


@dataclass
class DataTensor:
    """Heterogeneous 2-D tensor: one schema'd dimension (columns)."""

    names: list[str]
    types: list[str]
    columns: list[np.ndarray]  # each 1-D, len == nrows

    def __post_init__(self):
        assert len(self.names) == len(self.types) == len(self.columns)
        for t in self.types:
            assert t in VALID_TYPES, t
        n = self.nrows
        for c in self.columns:
            assert len(c) == n

    # -- construction -------------------------------------------------------
    @classmethod
    def from_dict(cls, data: dict[str, Sequence], types: Optional[dict] = None
                  ) -> "DataTensor":
        names, tps, cols = [], [], []
        for k, v in data.items():
            arr = np.asarray(v, dtype=object) \
                if (types or {}).get(k) == "str" else np.asarray(v)
            t = (types or {}).get(k)
            if t is None:
                if arr.dtype == object or arr.dtype.kind in "US":
                    t = detect_value_type(arr.astype(object))
                elif arr.dtype.kind == "b":
                    t = "bool"
                elif arr.dtype.kind in "iu":
                    t = "i64" if arr.dtype.itemsize > 4 else "i32"
                else:
                    t = "f64" if arr.dtype.itemsize > 4 else "f32"
            if t != "str":
                arr = arr.astype(_NP[t])
            else:
                arr = arr.astype(object)
            names.append(k); tps.append(t); cols.append(arr)
        return cls(names, tps, cols)

    @classmethod
    def from_frame(cls, frame: np.ndarray, names: Optional[list[str]] = None
                   ) -> "DataTensor":
        """Raw 2-D object array -> typed DataTensor via schema detection."""
        ncol = frame.shape[1]
        names = names or [f"c{i}" for i in range(ncol)]
        data, types = {}, {}
        for i, nm in enumerate(names):
            col = frame[:, i].astype(object)
            t = detect_value_type(col)
            types[nm] = t
            if t == "bool":
                data[nm] = np.array(
                    [str(v).strip().lower() in ("true", "t", "1")
                     for v in col])
            elif t != "str":
                data[nm] = np.array([_NP[t](str(v)) if str(v) != "" else
                                     (np.nan if t.startswith("f") else 0)
                                     for v in col], dtype=_NP[t])
            else:
                data[nm] = col
        return cls.from_dict(data, types)

    # -- access ---------------------------------------------------------------
    @property
    def nrows(self) -> int:
        return len(self.columns[0]) if self.columns else 0

    @property
    def ncols(self) -> int:
        return len(self.names)

    @property
    def shape(self) -> tuple[int, int]:
        return (self.nrows, self.ncols)

    @property
    def schema(self) -> list[tuple[str, str]]:
        return list(zip(self.names, self.types))

    def column(self, name: str) -> np.ndarray:
        return self.columns[self.names.index(name)]

    def select_rows(self, idx) -> "DataTensor":
        return DataTensor(self.names[:], self.types[:],
                          [c[idx] for c in self.columns])

    def numeric_matrix(self, dtype=np.float64) -> np.ndarray:
        """All non-string columns as a dense matrix (NaNs preserved)."""
        cols = [c.astype(dtype) for c, t in zip(self.columns, self.types)
                if t != "str"]
        return np.stack(cols, axis=1) if cols else np.zeros((self.nrows, 0))


# ---------------------------------------------------------------------------
# transformencode / transformapply (§4.2)
# ---------------------------------------------------------------------------

@dataclass
class TransformMeta:
    spec: dict[str, str]
    recode_maps: dict[str, dict[Any, int]] = field(default_factory=dict)
    bins: dict[str, np.ndarray] = field(default_factory=dict)
    centers: dict[str, float] = field(default_factory=dict)
    scales: dict[str, float] = field(default_factory=dict)
    out_names: list[str] = field(default_factory=list)


def transformencode(dt: DataTensor, spec: dict[str, str]
                    ) -> tuple[np.ndarray, TransformMeta]:
    """Fit + apply feature transforms; returns (X, meta)."""
    meta = TransformMeta(spec=dict(spec))
    for name in dt.names:
        how = spec.get(name, "passthrough")
        col = dt.column(name)
        if how == "recode" or (how == "dummycode"):
            vals = sorted({v for v in col.tolist()}, key=lambda v: str(v))
            meta.recode_maps[name] = {v: i for i, v in enumerate(vals)}
        elif how.startswith("bin"):
            k = int(how.split(":")[1]) if ":" in how else 10
            c = col.astype(np.float64)
            qs = np.nanquantile(c, np.linspace(0, 1, k + 1)[1:-1])
            meta.bins[name] = np.unique(qs)
        elif how == "scale":
            c = col.astype(np.float64)
            meta.centers[name] = float(np.nanmean(c))
            meta.scales[name] = float(np.nanstd(c) or 1.0)
    x = transformapply(dt, meta)
    return x, meta


def transformapply(dt: DataTensor, meta: TransformMeta) -> np.ndarray:
    outs, names = [], []
    for name, typ in zip(dt.names, dt.types):
        how = meta.spec.get(name, "passthrough")
        col = dt.column(name)
        if how == "drop":
            continue
        if how == "recode":
            m = meta.recode_maps[name]
            outs.append(np.array([m.get(v, -1) for v in col.tolist()],
                                 dtype=np.float64)[:, None])
            names.append(name)
        elif how == "dummycode":
            m = meta.recode_maps[name]
            k = len(m)
            codes = np.array([m.get(v, -1) for v in col.tolist()])
            oh = np.zeros((len(col), k))
            valid = codes >= 0
            oh[np.arange(len(col))[valid], codes[valid]] = 1.0
            outs.append(oh)
            names.extend(f"{name}={v}" for v in m)
        elif how.startswith("bin"):
            edges = meta.bins[name]
            outs.append(np.digitize(col.astype(np.float64), edges
                                    ).astype(np.float64)[:, None])
            names.append(name)
        elif how == "scale":
            c = col.astype(np.float64)
            outs.append(((c - meta.centers[name]) / meta.scales[name]
                         )[:, None])
            names.append(name)
        else:  # passthrough
            if typ == "str":
                raise ValueError(f"string column {name!r} needs an encoder")
            outs.append(col.astype(np.float64)[:, None])
            names.append(name)
    meta.out_names = names
    return np.concatenate(outs, axis=1) if outs else \
        np.zeros((dt.nrows, 0))


# ---------------------------------------------------------------------------
# n-D fixed-size blocking scheme (§3.3) — local tile math
# ---------------------------------------------------------------------------

_BLOCK_EDGE = {1: 1024 * 1024, 2: 1024, 3: 128, 4: 32, 5: 16, 6: 8, 7: 8}


def block_shape(rank: int) -> tuple[int, ...]:
    """Exponentially decreasing edge lengths: 1024², 128³, 32⁴, 16⁵, 8⁶, 8⁷."""
    edge = _BLOCK_EDGE.get(rank)
    if edge is None:
        raise ValueError(f"unsupported rank {rank}")
    return (edge,) * rank


def reblock(arr: np.ndarray, target_rank: int) -> dict[tuple, np.ndarray]:
    """Split an array into the fixed-size blocks of `target_rank`'s scheme.

    Mirrors the paper's local conversion example: a 1024² matrix block
    splits into 64 × 128² sub-blocks when joining with a 3-D tensor.
    """
    bs = block_shape(target_rank)[: arr.ndim]
    grid = [range(0, s, b) for s, b in zip(arr.shape, bs)]
    out: dict[tuple, np.ndarray] = {}
    import itertools as it
    for starts in it.product(*grid):
        key = tuple(s // b for s, b in zip(starts, bs))
        sl = tuple(slice(s, min(s + b, d))
                   for s, b, d in zip(starts, bs, arr.shape))
        out[key] = arr[sl]
    return out
