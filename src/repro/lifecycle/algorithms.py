"""Classical ML algorithm builtins (SystemDS algorithm-library breadth, L3).

Batch 1st/2nd-order algorithms written on the DSL — the hot linear
algebra runs through the lineage runtime (and thus the gram kernel +
reuse cache); light control flow stays in the host control program.

Like the regression builtins, these are placement-neutral (§3.3): pass
a `repro.core.federated_input` leaf as X and the compiler's placement
pass federates the plan — e.g. `pca` over a federated X lowers the
centering to a broadcast `fed_map`, the covariance to `fed_gram`, and
the projection to `fed_mv`; only column-sized aggregates leave the
sites (see `tests/test_fed_placement.py::TestFederatedParity`).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import ops
from repro.core.dag import LTensor, input_tensor
from repro.core.runtime import LineageRuntime, get_runtime


def _rt(runtime):
    return runtime or get_runtime()


def pca(X: LTensor, k: int, runtime: Optional[LineageRuntime] = None
        ) -> tuple[np.ndarray, np.ndarray]:
    """PCA via eigen-decomposition of the covariance (gram of centered X).

    Returns (components [d, k], projected [n, k])."""
    rt = _rt(runtime)
    n = X.shape[0]
    Xc = X - ops.colMeans(X)
    cov_t = ops.gram(Xc) * (1.0 / (n - 1))
    cov = rt.evaluate([cov_t])[0]
    evals, evecs = np.linalg.eigh(cov)
    order = np.argsort(evals)[::-1][:k]
    comps = evecs[:, order]
    proj_t = Xc @ input_tensor("pca_comps", comps)
    return comps, rt.evaluate([proj_t])[0]


def kmeans(X: LTensor, k: int, max_iter: int = 50, seed: int = 0,
           tol: float = 1e-6, runtime: Optional[LineageRuntime] = None
           ) -> tuple[np.ndarray, np.ndarray]:
    """Lloyd's algorithm; distance algebra in the DSL, argmin in CP.

    dist(i,j) = ||x_i||² - 2 x_i·c_j + ||c_j||² — the cross term is a
    matmul, reusing the distributed backend for large n."""
    rt = _rt(runtime)
    n, d = X.shape
    x_sq = ops.rowSums(X * X)
    rng = np.random.default_rng(seed)
    x_np = rt.evaluate([X])[0]
    centers = x_np[rng.choice(n, size=k, replace=False)]
    assign = np.zeros(n, dtype=np.int64)
    for _ in range(max_iter):
        C = input_tensor("kmC", centers)
        cross_t = X @ C.T
        c_sq_t = ops.rowSums(C * C)
        cross, c_sq, xs = rt.evaluate([cross_t, c_sq_t, x_sq])
        dist = xs + c_sq.T - 2.0 * cross
        new_assign = dist.argmin(axis=1)
        onehot = np.zeros((n, k))
        onehot[np.arange(n), new_assign] = 1.0
        A = input_tensor("kmA", onehot)
        sums_t = ops.xtv(A, X)                 # A^T X: per-cluster sums
        counts_t = ops.colSums(A)
        sums, counts = rt.evaluate([sums_t, counts_t])
        counts = np.maximum(counts.T, 1.0)
        new_centers = sums / counts
        shift = float(np.abs(new_centers - centers).max())
        centers, assign = new_centers, new_assign
        if shift < tol:
            break
    return centers, assign


def l2svm(X: LTensor, y: LTensor, reg: float = 1.0, max_iter: int = 100,
          tol: float = 1e-9, runtime: Optional[LineageRuntime] = None
          ) -> np.ndarray:
    """L2-regularized squared-hinge SVM (DML l2svm): Newton-ish steps with
    line search; labels in {-1, +1}."""
    rt = _rt(runtime)
    n, d = X.shape
    w = np.zeros((d, 1))
    g_old = None
    s = None
    for it in range(max_iter):
        wt = input_tensor("svm_w", w)
        out_t = y * (X @ wt)
        hinge_t = ops.maximum(1.0 - out_t, 0.0)
        grad_t = reg * wt - ops.xtv(X, y * hinge_t)
        grad = rt.evaluate([grad_t])[0]
        gnorm = float((grad * grad).sum())
        if gnorm < tol:
            break
        if s is None:
            s = -grad
        else:
            beta_fr = gnorm / max(g_old, 1e-30)
            s = -grad + beta_fr * s
        g_old = gnorm
        # exact line search on the quadratic upper bound
        st = input_tensor("svm_s", s)
        Xs_t = X @ st
        hinge_v, Xs_v, out_v = rt.evaluate([hinge_t, Xs_t, out_t])
        active = (hinge_v > 0).astype(np.float64)
        denom = reg * float((s * s).sum()) + float(
            (active * (y_np_cache(y, rt) * Xs_v) ** 2).sum())
        num = -float((grad * s).sum())
        step = num / max(denom, 1e-30)
        w = w + step * s
    return w


_y_cache: dict[int, np.ndarray] = {}


def y_np_cache(y: LTensor, rt: LineageRuntime) -> np.ndarray:
    got = _y_cache.get(y.node.uid)
    if got is None:
        got = rt.evaluate([y])[0]
        _y_cache[y.node.uid] = got
    return got


def mlogreg(X: LTensor, y_onehot: LTensor, reg: float = 1e-4,
            lr: float = 0.5, max_iter: int = 200,
            runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """Multinomial logistic regression via gradient descent in the DSL."""
    rt = _rt(runtime)
    n, d = X.shape
    k = y_onehot.shape[1]
    W = np.zeros((d, k))
    for _ in range(max_iter):
        Wt = input_tensor("mlr_W", W)
        logits = X @ Wt
        emax = ops.colMaxs(logits.T).T          # rowMaxs via transpose
        ex = ops.exp(logits - emax)
        p = ex / ops.rowSums(ex)
        grad_t = ops.xtv(X, p - y_onehot) * (1.0 / n) + reg * Wt
        grad = rt.evaluate([grad_t])[0]
        W = W - lr * grad
        if float(np.abs(grad).max()) < 1e-7:
            break
    return W
