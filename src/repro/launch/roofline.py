"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

    compute term    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory term     = HLO_bytes_per_device / HBM_BW
    collective term = ring-model wire bytes per chip / ICI_BW

FLOPs/bytes come from `repro.launch.hlocost` (while-loop trip counts
included — XLA's own cost_analysis counts scan bodies once, verified).
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) + attention window
term; the ratio MODEL_FLOPS / HLO_FLOPs flags remat/redundancy waste.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (~per-direction per link)


@dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    kind: str                      # train | prefill | decode
    # per-device measured (hlocost)
    hlo_flops: float = 0.0
    hlo_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_raw_bytes: float = 0.0
    per_collective: dict = field(default_factory=dict)
    by_group_size: dict = field(default_factory=dict)
    unknown_trips: int = 0
    # xla raw (body-once) for cross-reference
    xla_flops: float = 0.0
    xla_bytes: float = 0.0
    # memory analysis (per device)
    arg_bytes: int = 0
    out_bytes: int = 0
    temp_bytes: int = 0
    # analytic
    model_flops: float = 0.0       # useful flops per device per step
    tokens: int = 0
    compile_seconds: float = 0.0

    # -- derived ---------------------------------------------------------
    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_wire_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / modeled step time (perfect overlap)."""
        t_star = self.model_flops / PEAK_FLOPS
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        return t_star / t_step if t_step > 0 else 0.0

    @property
    def flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 roofline_fraction=self.roofline_fraction,
                 flops_ratio=self.flops_ratio)
        return d


def model_flops_per_device(cfg, kind: str, batch: int, seq: int,
                           n_devices: int) -> tuple[float, int]:
    """Analytic useful FLOPs per device per step + tokens processed.

    train: 6·N_active·D (fwd 2 + bwd 4) + attention 12·B·S²·H·hd·L_attn/2
    prefill: 2·N_active·D + attention 4·B·S²·H·hd·L_attn/2
    decode: 2·N_active·B + attention 4·B·S·H·hd·L_attn (one token)."""
    n_active = cfg.active_params()
    hd = cfg.head_dim
    # attention layer count
    kinds = cfg.layer_kinds() * cfg.n_periods()
    n_attn = sum(1 for k in kinds if k.startswith("attn")) \
        + cfg.first_dense_layers
    n_mamba = sum(1 for k in kinds if k.startswith("mamba"))
    if kind == "train":
        tokens = batch * seq
        base = 6.0 * n_active * tokens
        attn = 12.0 * batch * seq * seq * cfg.n_heads * hd * n_attn / 2
        ssm = 18.0 * batch * seq * cfg.d_inner * cfg.d_state * n_mamba \
            if n_mamba else 0.0
        if cfg.ssm_type == "rwkv6":
            # chunked linear attention: ≈ 2·(C + 2·dh)·d per token fwd
            ssm = 6.0 * batch * seq * cfg.d_model \
                * (cfg.rwkv_chunk + 2 * cfg.rwkv_head_dim) * cfg.n_layers
        total = base + attn + ssm
    elif kind == "prefill":
        tokens = batch * seq
        total = 2.0 * n_active * tokens \
            + 4.0 * batch * seq * seq * cfg.n_heads * hd * n_attn / 2
    else:  # decode: one new token, attends over the full cache
        tokens = batch
        total = 2.0 * n_active * batch \
            + 4.0 * batch * seq * cfg.n_heads * hd * n_attn
    return total / n_devices, tokens


def format_table(cells: list[RooflineCell]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':9s} {'kind':7s} "
           f"{'t_comp(ms)':>10s} {'t_mem(ms)':>10s} {'t_coll(ms)':>10s} "
           f"{'bound':>9s} {'MODEL/HLO':>9s} {'roofline%':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for c in cells:
        lines.append(
            f"{c.arch:22s} {c.shape:12s} {c.mesh:9s} {c.kind:7s} "
            f"{c.t_compute*1e3:10.3f} {c.t_memory*1e3:10.3f} "
            f"{c.t_collective*1e3:10.3f} {c.bottleneck:>9s} "
            f"{c.flops_ratio:9.3f} {c.roofline_fraction*100:8.1f}%")
    return "\n".join(lines)


def save_cells(cells: list[RooflineCell], path: str) -> None:
    with open(path, "w") as f:
        json.dump([c.to_dict() for c in cells], f, indent=1)


def load_cells(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)
