"""Deploy a trained lmDS model behind `repro.serving.ModelServer`.

The lifecycle's deployment stage: train offline with the lmDS builtin,
compile the scoring expression ONCE into a `PreparedScript`, then serve
it — the server AOT-warms every power-of-two vmap bucket at deploy
time (pinned in the jit cache) and coalesces concurrent requests onto
those warm executables, so the request path never compiles.

Contrast with examples/serve_lm.py, which drives the transformer
prefill/decode token loop (`repro.launch.serve`); this example serves a
compiled lifecycle *plan*.

    PYTHONPATH=src python examples/serve_plan.py
"""
import sys

sys.path.insert(0, "src")

import threading
import time

import numpy as np

from repro.core import LineageRuntime, input_tensor, ops
from repro.core.runtime import PreparedScript
from repro.lifecycle.regression import lmDS
from repro.serving import ModelServer

N_FEATURES = 64
N_REQUESTS = 64


def main():
    rng = np.random.default_rng(0)

    # 1. train offline: closed-form linear regression (lmDS builtin)
    xn = rng.normal(size=(20000, N_FEATURES))
    yn = xn @ rng.normal(size=(N_FEATURES, 1)) \
        + 0.01 * rng.normal(size=(20000, 1))
    rt = LineageRuntime()
    beta = lmDS(input_tensor("X", xn), input_tensor("y", yn),
                reg=1e-3, runtime=rt)
    print(f"trained lmDS model: beta {beta.shape}")

    # 2. compile the scoring expression once — one feature row in,
    #    one prediction out
    B = input_tensor("beta", beta)

    def scoring(x):
        return ops.matmul(x, B)

    script = PreparedScript(scoring, [(1, N_FEATURES)], runtime=rt)

    # 3. deploy: warm + pin the serving buckets, start the coalescer
    server = ModelServer(script, max_batch=16, max_wait_us=2000.0,
                         runtime=rt)
    server.deploy()
    print(server.explain().splitlines()[0])

    # 4. score concurrent requests; each call is an ordinary blocking
    #    function call — coalescing happens behind the queue
    lat_us = [0.0] * N_REQUESTS
    preds = [None] * N_REQUESTS
    rows = [rng.normal(size=(1, N_FEATURES)) for _ in range(N_REQUESTS)]

    def client(i):
        t0 = time.perf_counter()
        preds[i], = server.score(rows[i])
        lat_us[i] = (time.perf_counter() - t0) * 1e6

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(N_REQUESTS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    # 5. parity + the serving meter (retraces MUST be 0: all compiles
    #    happened at deploy)
    for i in range(N_REQUESTS):
        ref, = script(rows[i])
        assert (preds[i] == ref).all(), f"request {i} diverged"
    p50, p99 = np.percentile(lat_us, [50, 99])
    print(f"{N_REQUESTS} concurrent requests: "
          f"p50={p50:.0f}us p99={p99:.0f}us")
    stats = rt.stats.serving.as_dict()
    print("serving:", stats)
    assert stats["retraces"] == 0, "hot path recompiled!"
    server.shutdown()
    print("all predictions bitwise-match solo PreparedScript calls")


if __name__ == "__main__":
    main()
