"""Lineage tracing + reuse cache behaviour (paper §4.1)."""
import numpy as np
import pytest

from repro.core import (LineageRuntime, PreparedScript, ReuseCache,
                        evaluate, input_tensor, lineage_trace, ops)
from repro.core.compiler import compile_plan


def _data(rng, n=200, d=10):
    x = rng.normal(size=(n, d))
    y = rng.normal(size=(n, 1))
    return x, y


class TestLineageHash:
    def test_same_computation_same_hash(self, rng):
        xn, _ = _data(rng)
        x = input_tensor("X", xn)
        a = ops.gram(x)
        b = ops.gram(x)
        lin = {}
        from repro.core.dag import LEAVES
        assert a.node.lhash(LEAVES.lineage) == b.node.lhash(LEAVES.lineage)

    def test_different_data_different_hash(self, rng):
        from repro.core.dag import LEAVES
        x1 = input_tensor("X", rng.normal(size=(10, 4)))
        x2 = input_tensor("X", rng.normal(size=(10, 4)))
        assert ops.gram(x1).node.lhash(LEAVES.lineage) != \
            ops.gram(x2).node.lhash(LEAVES.lineage)

    def test_literals_distinguish(self, rng):
        from repro.core.dag import LEAVES
        x = input_tensor("X", rng.normal(size=(10, 4)))
        a = ops.gram(x) + 0.1 * ops.eye(4)
        b = ops.gram(x) + 0.2 * ops.eye(4)
        assert a.node.lhash(LEAVES.lineage) != b.node.lhash(LEAVES.lineage)

    def test_shape_in_hash(self):
        from repro.core.dag import LEAVES
        assert ops.eye(3).node.lhash(LEAVES.lineage) != \
            ops.eye(5).node.lhash(LEAVES.lineage)

    def test_seed_traced(self):
        from repro.core.dag import LEAVES
        a = ops.rand((5, 5), seed=1)
        b = ops.rand((5, 5), seed=2)
        c = ops.rand((5, 5), seed=1)
        assert a.node.lhash(LEAVES.lineage) != b.node.lhash(LEAVES.lineage)
        assert a.node.lhash(LEAVES.lineage) == c.node.lhash(LEAVES.lineage)


class TestFullReuse:
    def test_gram_reused_across_lambdas(self, rng):
        xn, yn = _data(rng)
        x, y = input_tensor("X", xn), input_tensor("y", yn)
        rt = LineageRuntime(cache=ReuseCache())
        for lam in (0.1, 1.0, 10.0):
            beta = ops.solve(ops.gram(x) + lam * ops.eye(10), ops.xtv(x, y))
            out = rt.evaluate([beta])[0]
            ref = np.linalg.solve(xn.T @ xn + lam * np.eye(10), xn.T @ yn)
            np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-8)
        # gram + xtv hit twice each (2nd and 3rd lambda)
        assert rt.cache.stats.hits >= 4
        assert rt.stats.reused >= 4

    def test_reuse_returns_identical_values(self, rng):
        xn, _ = _data(rng)
        x = input_tensor("X", xn)
        rt = LineageRuntime(cache=ReuseCache())
        g1 = rt.evaluate([ops.gram(x)])[0]
        g2 = rt.evaluate([ops.gram(x)])[0]
        np.testing.assert_array_equal(g1, g2)

    def test_no_cache_no_reuse(self, rng):
        xn, _ = _data(rng)
        x = input_tensor("X", xn)
        rt = LineageRuntime(cache=None)
        rt.evaluate([ops.gram(x)])
        rt.evaluate([ops.gram(x)])
        assert rt.stats.reused == 0


class TestPartialReuse:
    def test_cv_fold_decomposition(self, rng):
        """gram(rbind(folds)) decomposes; per-fold grams reused."""
        folds = [input_tensor(f"f{i}", rng.normal(size=(40, 6)))
                 for i in range(5)]
        rt = LineageRuntime(cache=ReuseCache())
        # two different leave-one-out subsets share 3 folds
        g1 = rt.evaluate([ops.gram(ops.rbind(*folds[:4]))])[0]
        before = rt.cache.stats.hits
        g2 = rt.evaluate([ops.gram(ops.rbind(*folds[1:]))])[0]
        assert rt.cache.stats.hits - before >= 3  # folds 1,2,3 reused
        from repro.core.dag import LEAVES
        stack = np.concatenate([LEAVES.values[f.node.uid]
                                for f in folds[1:]])
        np.testing.assert_allclose(g2, stack.T @ stack, rtol=1e-6)

    def test_steplm_cbind_decomposition(self, rng):
        """gram(cbind(X, c)) reuses gram(X)."""
        xn = rng.normal(size=(100, 8))
        cn = rng.normal(size=(100, 1))
        x, c = input_tensor("X", xn), input_tensor("c", cn)
        rt = LineageRuntime(cache=ReuseCache())
        rt.evaluate([ops.gram(x)])
        before = rt.cache.stats.hits
        g = rt.evaluate([ops.gram(ops.cbind(x, c))])[0]
        assert rt.cache.stats.hits > before
        full = np.concatenate([xn, cn], axis=1)
        np.testing.assert_allclose(g, full.T @ full, rtol=1e-6, atol=1e-7)


class TestEviction:
    def test_budget_respected(self, rng):
        cache = ReuseCache(budget_bytes=1 << 16)
        rt = LineageRuntime(cache=cache)
        for i in range(20):
            x = input_tensor(f"X{i}", rng.normal(size=(64, 64)))
            rt.evaluate([ops.gram(x)])
        assert cache.stats.bytes_cached <= 1 << 16
        assert cache.stats.evictions > 0

    def test_lru_policy(self, rng):
        cache = ReuseCache(budget_bytes=1 << 16, policy="lru")
        rt = LineageRuntime(cache=cache)
        for i in range(20):
            x = input_tensor(f"Y{i}", rng.normal(size=(64, 64)))
            rt.evaluate([ops.gram(x)])
        assert cache.stats.bytes_cached <= 1 << 16


class TestPreparedScript:
    def test_recompile_free_reexecution(self, rng):
        ps = PreparedScript(
            lambda a, b: ops.solve(ops.gram(a) + 0.1 * ops.eye(6),
                                   ops.xtv(a, b)),
            [(50, 6), (50, 1)])
        for seed in range(3):
            r = np.random.default_rng(seed)
            xn, yn = r.normal(size=(50, 6)), r.normal(size=(50, 1))
            out = ps(xn, yn)[0]
            ref = np.linalg.solve(xn.T @ xn + 0.1 * np.eye(6), xn.T @ yn)
            np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-8)

    def test_lineage_distinguishes_inputs(self, rng):
        rt = LineageRuntime(cache=ReuseCache())
        ps = PreparedScript(lambda a: ops.gram(a), [(32, 4)], runtime=rt)
        x1 = rng.normal(size=(32, 4))
        x2 = rng.normal(size=(32, 4))
        g1 = ps(x1)[0]
        g2 = ps(x2)[0]  # must NOT hit x1's cache entry
        np.testing.assert_allclose(g2, x2.T @ x2, rtol=1e-6)
        g1b = ps(x1)[0]  # this SHOULD hit
        np.testing.assert_array_equal(g1, g1b)
        assert rt.cache.stats.hits >= 1


def test_lineage_trace_format(rng):
    x = input_tensor("X", rng.normal(size=(10, 3)))
    beta = ops.solve(ops.gram(x) + 0.1 * ops.eye(3),
                     ops.xtv(x, input_tensor("y", rng.normal(size=(10, 1)))))
    trace = lineage_trace(beta)
    assert "L·input X:" in trace
    assert "L·gram" in trace and "L·solve" in trace
    # deduplicated: each node appears once
    assert trace.count("L·gram") == 1
