"""Reference implementations for the block-sparse SpMM kernel family."""
from __future__ import annotations

import numpy as np


def block_mask(x: np.ndarray, bm: int, bn: int) -> np.ndarray:
    """int32 per-block nonzero counts of a (padded) dense matrix."""
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0, (x.shape, bm, bn)
    blocks = np.asarray(x).reshape(m // bm, bm, n // bn, bn)
    return np.count_nonzero(blocks, axis=(1, 3)).astype(np.int32)


def gram(x: np.ndarray) -> np.ndarray:
    return np.asarray(x).T @ np.asarray(x)


def spmm(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    return np.asarray(x) @ np.asarray(w)


def xtv(x: np.ndarray, v: np.ndarray) -> np.ndarray:
    return np.asarray(x).T @ np.asarray(v)
