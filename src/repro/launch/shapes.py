"""Assigned input shapes × per-arch input_specs (ShapeDtypeStruct
stand-ins: weak-type-correct, shardable, no device allocation).

Shapes (LM family, seq_len × global_batch):
  train_4k     seq 4,096  batch 256   (training)
  prefill_32k  seq 32,768 batch 32    (inference prefill)
  decode_32k   seq 32,768 batch 128   (one token, KV cache of 32k)
  long_500k    seq 524,288 batch 1    (long-context decode; only for
                                       sub-quadratic archs: ssm/hybrid)

``decode_*``/``long_*`` lower `decode_step` (serve_step), not train_step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import Model

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, batch=1),
}

# pure full-attention archs skip long_500k (no sub-quadratic path);
# ssm / hybrid run it (recurrent state decode / tiny KV slice).
LONG_OK_FAMILIES = ("ssm", "hybrid")


def cell_supported(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.family not in LONG_OK_FAMILIES:
        return False, (f"{cfg.name} is pure full-attention; long_500k "
                       "needs sub-quadratic attention (DESIGN.md §6)")
    return True, ""


def _tokens_sds(cfg: ModelConfig, batch: int, seq: int):
    shape = (batch, seq, cfg.n_codebooks) if cfg.n_codebooks \
        else (batch, seq)
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape_name: str, model: Model) -> dict:
    """Returns {'kind', 'args': tuple of ShapeDtypeStruct pytrees, ...}."""
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq_len"]
    kind = info["kind"]
    if kind == "train":
        batch = {"tokens": _tokens_sds(cfg, B, S),
                 "labels": _tokens_sds(cfg, B, S)}
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        return {"kind": kind, "batch": batch, "B": B, "S": S}
    if kind == "prefill":
        out = {"kind": kind, "tokens": _tokens_sds(cfg, B, S),
               "B": B, "S": S}
        if cfg.family == "vlm":
            out["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        return out
    # decode: one new token against a cache of length S
    caches = model.cache_shapes(B, S)
    return {"kind": kind, "token": _tokens_sds(cfg, B, 1),
            "caches": caches, "cur_len": jax.ShapeDtypeStruct((), jnp.int32),
            "B": B, "S": S}
