"""Per-architecture smoke tests (reduced configs) + decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import build_model


def _setup(arch, rng, seq=64, batch=2):
    cfg = get_config(arch).reduced()
    if cfg.family == "vlm":
        cfg = cfg.with_(n_image_tokens=16)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if cfg.n_codebooks:
        tokens = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq, cfg.n_codebooks)),
            jnp.int32)
    else:
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                             jnp.int32)
    batch_d = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch_d["image_embeds"] = jnp.asarray(
            rng.normal(size=(batch, 16, cfg.d_model)), jnp.float32)
    return cfg, model, params, batch_d


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch, rng):
    """One forward + one train step on CPU: shapes + finiteness."""
    from repro.launch.steps import init_train_state, make_train_step
    cfg, model, params, batch = _setup(arch, rng)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), arch
    assert 0 < float(loss) < 20

    from repro.optim.adamw import adamw_init
    opt = adamw_init(params)
    step = make_train_step(model, lr=1e-3)
    new_params, new_opt, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(new_opt.step) == 1
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[1]
    l1 = jax.tree_util.tree_leaves(new_params)[1]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_prefill(arch, rng):
    """Teacher-forced decode must reproduce prefill logits: prefill the
    first n tokens, decode the rest one-by-one; final-position logits
    must match a full prefill of the whole sequence."""
    cfg, model, params, batch = _setup(arch, rng, seq=24)
    tokens = batch["tokens"]
    img = batch.get("image_embeds")
    n0 = 16
    total = tokens.shape[1]

    logits_full, _ = model.prefill(params, tokens, max_len=total,
                                   image_embeds=img)
    logits, caches = model.prefill(params, tokens[:, :n0], max_len=total,
                                   image_embeds=img)
    for t in range(n0, total):
        nxt = tokens[:, t:t + 1]
        logits, caches = model.decode_step(params, nxt, caches,
                                           jnp.int32(t))
    a = np.asarray(logits, np.float32)
    b = np.asarray(logits_full, np.float32)
    # recurrent archs accumulate small fp differences across steps
    tol = 2e-2 if cfg.family in ("ssm", "hybrid") else 5e-3
    np.testing.assert_allclose(a, b, rtol=tol, atol=tol)


def test_param_counts_hit_nameplates():
    expected = {
        "rwkv6_3b": 3.1, "llama3_2_3b": 3.6, "phi3_medium_14b": 14.7,
        "llama3_2_1b": 1.5, "qwen3_0_6b": 0.75, "jamba_v0_1_52b": 51.6,
        "deepseek_v2_236b": 235.7, "deepseek_moe_16b": 16.4,
        "musicgen_large": 3.25, "llama3_2_vision_90b": 87.7,
    }
    for arch, want_b in expected.items():
        n = build_model(get_config(arch)).n_params() / 1e9
        assert abs(n - want_b) / want_b < 0.02, (arch, n, want_b)


def test_active_params_moe():
    assert abs(get_config("deepseek_v2_236b").active_params() / 1e9
               - 21.4) < 0.5
    assert abs(get_config("jamba_v0_1_52b").active_params() / 1e9
               - 12.0) < 0.5


def test_moe_dispatch_matches_dense_oracle(rng):
    from repro.models import moe as moe_mod
    cfg = get_config("deepseek_moe_16b").reduced()
    key = jax.random.PRNGKey(1)
    p = moe_mod.moe_init(key, cfg)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    out1, aux1 = moe_mod.moe_forward_local(p, cfg, x)
    out2, aux2 = moe_mod.moe_forward_dense_fallback(p, cfg, x)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_mla_decode_matches_expanded(rng):
    """Absorbed MLA decode == train-path attention at the same position."""
    cfg = get_config("deepseek_v2_236b").reduced()
    from repro.models import mla
    p = mla.mla_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.1, jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out_full, cache = mla.mla_forward(p, cfg, x, positions)
    ckv, kr = cache
    pad = S + 4
    ckv = jnp.pad(ckv, ((0, 0), (0, pad - S), (0, 0)))
    kr = jnp.pad(kr, ((0, 0), (0, pad - S), (0, 0)))
    # decode the last token against the cache of the first S-1
    out_step, _ = mla.mla_decode(
        p, cfg, x[:, S - 1:S],
        (ckv.at[:, S - 1:].set(0), kr.at[:, S - 1:].set(0)),
        jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(out_step[:, 0]),
                               np.asarray(out_full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_musicgen_delay_pattern():
    from repro.data.frontends import encodec_tokens
    toks = encodec_tokens(1, 16, 64, n_books=4, seed=3)
    assert toks.shape == (1, 16, 4)
    assert (toks[0, :3, 3] == 0).all()  # book 3 delayed by 3


def test_long_context_skip_rule():
    from repro.launch.shapes import cell_supported
    ok, why = cell_supported(get_config("llama3_2_3b"), "long_500k")
    assert not ok and "full-attention" in why
    ok, _ = cell_supported(get_config("rwkv6_3b"), "long_500k")
    assert ok
    ok, _ = cell_supported(get_config("jamba_v0_1_52b"), "long_500k")
    assert ok
