"""Synthetic data generators (paper §5: dense/sparse regression inputs;
LM token streams for the model zoo)."""
from __future__ import annotations

import numpy as np


def gen_regression(rows: int, cols: int, *, sparsity: float = 1.0,
                   noise: float = 0.01, seed: int = 7
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (X, y, beta_true). sparsity = nnz/#cells like the paper."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(rows, cols))
    if sparsity < 1.0:
        mask = rng.random((rows, cols)) < sparsity
        x = np.where(mask, x, 0.0)
    beta = rng.normal(size=(cols, 1))
    y = x @ beta + noise * rng.normal(size=(rows, 1))
    return x, y, beta


def gen_tokens(n_tokens: int, vocab: int, *, seed: int = 0,
               n_codebooks: int = 0) -> np.ndarray:
    """Markov-ish synthetic token stream (not uniform — so training can
    actually reduce loss)."""
    rng = np.random.default_rng(seed)
    # zipf-like unigram + short-range repetition
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    shape = (n_tokens, n_codebooks) if n_codebooks else (n_tokens,)
    base = rng.choice(vocab, size=shape, p=probs)
    rep = rng.random(shape[:1]) < 0.3          # 30% repeat prev token
    out = base.copy()
    out[1:][rep[1:]] = out[:-1][rep[1:]]
    return out.astype(np.int32)
