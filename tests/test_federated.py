"""Federated tensors + instructions vs dense oracles (paper §4.3, Ex. 2)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.federated import (FederatedTensor, LocalSite,
                                  federated_lmds)


@pytest.fixture
def fed(rng):
    x = rng.normal(size=(97, 8))   # deliberately ragged row count
    return x, FederatedTensor.partition_rows(x, 4)


class TestFederatedOps:
    def test_mv(self, fed, rng):
        x, f = fed
        v = rng.normal(size=(8, 1))
        np.testing.assert_allclose(f.fed_mv(v), x @ v, rtol=1e-10)

    def test_vm(self, fed, rng):
        x, f = fed
        v = rng.normal(size=(97, 1))
        np.testing.assert_allclose(f.fed_vm(v), v.T @ x, rtol=1e-10)

    def test_gram(self, fed):
        x, f = fed
        np.testing.assert_allclose(f.fed_gram(), x.T @ x, rtol=1e-10)

    def test_xtv(self, fed, rng):
        x, f = fed
        y = rng.normal(size=(97, 1))
        np.testing.assert_allclose(f.fed_xtv(y), x.T @ y, rtol=1e-10)

    def test_colsums(self, fed):
        x, f = fed
        np.testing.assert_allclose(f.fed_colsums(),
                                   x.sum(axis=0, keepdims=True))


class TestExchangeAccounting:
    def test_gram_exchange_is_data_independent(self, rng):
        """The paper's point: only n×n aggregates leave the sites."""
        for rows in (100, 1000):
            x = rng.normal(size=(rows, 8))
            f = FederatedTensor.partition_rows(x, 4)
            f.fed_gram()
            assert f.log.from_sites == 4 * 8 * 8 * 8  # 4 sites × n² f64
            assert f.log.to_sites == 0                # data never moves

    def test_vm_sends_only_slices(self, rng):
        x = rng.normal(size=(100, 8))
        f = FederatedTensor.partition_rows(x, 4)
        v = rng.normal(size=(100, 1))
        f.fed_vm(v)
        assert f.log.to_sites == 100 * 8  # the full vector split once

    def test_mv_broadcast_cost(self, rng):
        x = rng.normal(size=(100, 8))
        f = FederatedTensor.partition_rows(x, 4)
        f.fed_mv(rng.normal(size=(8, 1)))
        assert f.log.to_sites == 4 * 8 * 8  # v broadcast to 4 sites


class TestFederatedLmDS:
    def test_matches_centralized(self, rng):
        x = rng.normal(size=(200, 6))
        y = x @ rng.normal(size=(6, 1)) + 0.01 * rng.normal(size=(200, 1))
        f = FederatedTensor.partition_rows(x, 3)
        b = federated_lmds(f, y, reg=1e-6)
        ref = np.linalg.solve(x.T @ x + 1e-6 * np.eye(6), x.T @ y)
        np.testing.assert_allclose(b, ref, rtol=1e-8)

    def test_intercept(self, rng):
        x = rng.normal(size=(120, 4))
        y = rng.normal(size=(120, 1))
        b = federated_lmds(FederatedTensor.partition_rows(x, 2), y,
                           intercept=True)
        assert b.shape == (5, 1)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(10, 200), st.integers(1, 12),
       st.integers(0, 10 ** 6))
def test_partition_invariance_property(n_sites, rows, cols, seed):
    """Federated results must not depend on the partitioning."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(rows, cols))
    f1 = FederatedTensor.partition_rows(x, min(n_sites, rows))
    f2 = FederatedTensor.partition_rows(x, 1)
    np.testing.assert_allclose(f1.fed_gram(), f2.fed_gram(), rtol=1e-8,
                               atol=1e-9)
    v = rng.normal(size=(cols, 1))
    np.testing.assert_allclose(f1.fed_mv(v), f2.fed_mv(v), rtol=1e-8,
                               atol=1e-9)


def test_fedavg_trainer_converges(rng):
    """Relaxed-sync FedAvg reaches a reasonable regression loss and
    compression reduces wire bytes 4x."""
    import jax.numpy as jnp
    from repro.distributed.fedavg import FedAvgTrainer

    w_true = rng.normal(size=(64, 1))

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    def make_batch(site, step):
        r = np.random.default_rng(100 * site + step)
        x = r.normal(size=(96, 64))
        return {"x": jnp.asarray(x),
                "y": jnp.asarray(x @ w_true + 0.01 * r.normal(size=(96, 1)))}

    results = {}
    for compress in (False, True):
        tr = FedAvgTrainer(loss_fn=loss_fn, n_sites=3, sync_every=4,
                           lr=5e-2, compress_int8=compress)
        tr.init({"w": jnp.zeros((64, 1))})
        for step in range(100):
            for s in range(3):
                tr.local_step(s, make_batch(s, step))
            tr.maybe_sync()
        err = float(np.abs(np.asarray(tr.anchor["w"]) - w_true).max())
        results[compress] = (err, tr.bytes_exchanged)
    assert results[False][0] < 0.35
    assert results[True][0] < 0.45           # int8 a bit noisier
    assert results[True][1] < 0.3 * results[False][1]
