"""Data cleaning / preparation builtins (SystemDS §4.2).

Vectorized implementations over the DSL: masking turns missing-value
imputation and outlier handling into sequences of full matrix operations
("masking allows data slicing and missing value imputation ... via
sequences of full matrix operations", §4.2), which keeps them inside the
compiler's optimization scope and trivially distributable.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import ops
from repro.core.dag import LTensor, input_tensor
from repro.core.runtime import LineageRuntime, get_runtime


def _rt(runtime):
    return runtime or get_runtime()


def isnan_mask(X: LTensor) -> LTensor:
    """1.0 where NaN (NaN != NaN)."""
    return X._bin(X, "ne")


def scale_matrix(X: LTensor, center: bool = True, scale: bool = True,
                 runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """z-score standardization (DML `scale`)."""
    out = X
    if center:
        out = out - ops.colMeans(out)
    if scale:
        out = out / ops.sqrt(ops.colVars(X))
    return _rt(runtime).evaluate([out])[0]


def impute_by_mean(X: LTensor, runtime: Optional[LineageRuntime] = None
                   ) -> np.ndarray:
    """Replace NaNs by per-column means of observed values (mask algebra)."""
    mask = isnan_mask(X)                      # 1 where missing
    x0 = ops.replace_nan(X, 0.0)
    obs = X.shape[0] - ops.colSums(mask)      # observed count per column
    mu = ops.colSums(x0) / ops.maximum(obs, 1.0)
    out = x0 + mask * mu
    return _rt(runtime).evaluate([out])[0]


def impute_by_median(X: LTensor, runtime: Optional[LineageRuntime] = None
                     ) -> np.ndarray:
    """Median imputation. The median is an `ops.quantile` *host-op
    node* (sort-based order statistics run in the control program, like
    SystemDS's quantiles) rather than an `evaluate()` round trip — the
    whole pipeline stays one plan, so lineage (and therefore downstream
    reuse) flows through it."""
    med = ops.quantile(X, 0.5)
    out = ops.where(isnan_mask(X), med, X)
    return _rt(runtime).evaluate([out])[0]


def mice_lite(X: LTensor, n_iter: int = 3, reg: float = 1e-3,
              runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """Chained-equation imputation (mice, §4.2 ref [71]) via mask algebra.

    Each round regresses every incomplete column on the others over the
    *observed* rows (row mask folded into the normal equations:
    gram(M⊙X) and (M⊙X)^T y — full matrix ops, no gather/scatter), then
    rewrites only the missing entries.
    """
    rt = _rt(runtime)
    x_np = rt.evaluate([X])[0] if isinstance(X, LTensor) else np.asarray(X)
    miss = np.isnan(x_np)
    # init: mean imputation
    mu = np.nanmean(x_np, axis=0, keepdims=True)
    cur = np.where(miss, mu, x_np)
    n, d = cur.shape
    for _ in range(n_iter):
        for j in range(d):
            mj = miss[:, j]
            if not mj.any() or mj.all():
                continue
            others = [k for k in range(d) if k != j]
            Xo = input_tensor("miceX", cur[:, others])
            yj = input_tensor("micey", cur[:, j:j + 1])
            w = input_tensor("micew", (~mj).astype(np.float64)[:, None])
            Xw = Xo * w                      # zero out unobserved rows
            yw = yj * w
            A = ops.gram(Xw) + reg * ops.eye(d - 1)
            b = ops.xtv(Xw, yw)
            beta_t = ops.solve(A, b)
            pred_t = Xo @ beta_t
            pred = rt.evaluate([pred_t])[0]
            cur[mj, j] = pred[mj, 0]
    return cur


def outlier_by_iqr(X: LTensor, k: float = 1.5, repair: str = "nan",
                   runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """Flag/repair values outside [Q1 - k·IQR, Q3 + k·IQR] per column.

    Quantiles are host-op nodes; the mask algebra stays in the DSL, so
    the whole repair is one plan with unbroken lineage."""
    X = X if isinstance(X, LTensor) else input_tensor("iqrX", np.asarray(X))
    q1 = ops.quantile(X, 0.25)
    q3 = ops.quantile(X, 0.75)
    iqr = q3 - q1
    lo, hi = q1 - k * iqr, q3 + k * iqr
    bad = (X < lo)._bin(X > hi, "or")
    if repair == "nan":
        out = ops.where(bad, float("nan"), X)
    elif repair == "clip":
        out = ops.minimum(ops.maximum(X, lo), hi)
    else:  # repair == "flag"
        out = bad
    # comparison kernels produce 0/1 f32 matrices; callers of this
    # builtin always got f64 back
    return np.asarray(_rt(runtime).evaluate([out])[0], dtype=np.float64)


def outlier_by_sd(X: LTensor, k: float = 3.0, repair: str = "nan",
                  runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """Flag/repair values beyond k standard deviations (DSL mask algebra)."""
    rt = _rt(runtime)
    mu = ops.colMeans(X)
    sd = ops.sqrt(ops.colVars(X))
    dev = ops.abs_(X - mu)
    bad = dev > (k * sd)
    x_np, bad_np = rt.evaluate([X, bad])
    if repair == "nan":
        return np.where(bad_np != 0, np.nan, x_np)
    if repair == "clip":
        mu_np, sd_np = rt.evaluate([mu, sd])
        return np.clip(x_np, mu_np - k * sd_np, mu_np + k * sd_np)
    return bad_np


def winsorize(X: LTensor, lower: float = 0.05, upper: float = 0.95,
              runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """Clamp each column to its [lower, upper] quantiles.

    One plan, one evaluation: both quantiles are host-op nodes feeding
    the clamp directly (previously this evaluated X, re-entered the DSL
    with fresh leaves, and evaluated again — severing lineage and
    computing X twice)."""
    X = X if isinstance(X, LTensor) else input_tensor("winsX", np.asarray(X))
    out = ops.minimum(ops.maximum(X, ops.quantile(X, lower)),
                      ops.quantile(X, upper))
    return _rt(runtime).evaluate([out])[0]
