"""Pallas TPU kernel for the mamba selective scan (hardware-aware scan).

Grid = (B, n_channel_blocks, n_time_chunks), time innermost. The
(bd, ds) state block stays in f32 VMEM scratch across the time sweep —
the VMEM analogue of Mamba's CUDA shared-memory scan (DESIGN.md §2) —
while a fori_loop walks the tc steps of each chunk with pure VPU ops.

Channels are independent, so the channel-block grid axis parallelizes
across cores; d_state (16) rides the lane dimension.

VMEM per cell ≈ tc·bd·4·2 (x, dt) + tc·ds·4·2 (B, C) + bd·ds·4 (state)
≈ 1.1 MB at tc = 256, bd = 512, ds = 16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BD = 512
DEFAULT_TC = 256


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return jax.ShapeDtypeStruct(shape, dtype)


def _ssm_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, dskip_ref, h0_ref,
                y_ref, hout_ref, h_scr, *, tc: int, n_t: int):
    t_blk = pl.program_id(2)

    @pl.when(t_blk == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    x = x_ref[0].astype(jnp.float32)                  # (tc, bd)
    dt = dt_ref[0].astype(jnp.float32)                # (tc, bd)
    A = a_ref[...].astype(jnp.float32)                # (bd, ds)
    Bv = b_ref[0].astype(jnp.float32)                 # (tc, ds)
    Cv = c_ref[0].astype(jnp.float32)                 # (tc, ds)
    dskip = dskip_ref[...].astype(jnp.float32)        # (1, bd)

    def step(t, carry):
        h, y = carry
        dA = jnp.exp(dt[t][:, None] * A)              # (bd, ds)
        h = dA * h + (dt[t] * x[t])[:, None] * Bv[t][None, :]
        y_t = jnp.sum(h * Cv[t][None, :], axis=1)     # (bd,)
        y = y.at[t].set(y_t)
        return h, y

    h, y = jax.lax.fori_loop(
        0, tc, step, (h_scr[...], jnp.zeros((tc, x.shape[1]), jnp.float32)))
    h_scr[...] = h
    y_ref[0] = (y + x * dskip).astype(y_ref.dtype)

    @pl.when(t_blk == n_t - 1)
    def _flush():
        hout_ref[0] = h


@functools.partial(jax.jit, static_argnames=("bd", "tc", "interpret"))
def ssm_scan_pallas(x, dt, A, B, C, D_skip, h0, *, bd: int = DEFAULT_BD,
                    tc: int = DEFAULT_TC, interpret: bool = False):
    """x, dt: (Bt, S, di); A: (di, ds); B, C: (Bt, S, ds); h0: (Bt, di, ds)."""
    Bt, S, di = x.shape
    ds = A.shape[1]
    bd = min(bd, di)
    tc = min(tc, S)
    assert di % bd == 0 and S % tc == 0, (di, bd, S, tc)
    n_t = S // tc
    grid = (Bt, di // bd, n_t)
    y, h_out = pl.pallas_call(
        functools.partial(_ssm_kernel, tc=tc, n_t=n_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tc, bd), lambda b, d, t: (b, t, d)),   # x
            pl.BlockSpec((1, tc, bd), lambda b, d, t: (b, t, d)),   # dt
            pl.BlockSpec((bd, ds), lambda b, d, t: (d, 0)),         # A
            pl.BlockSpec((1, tc, ds), lambda b, d, t: (b, t, 0)),   # B
            pl.BlockSpec((1, tc, ds), lambda b, d, t: (b, t, 0)),   # C
            pl.BlockSpec((1, bd), lambda b, d, t: (0, d)),          # D_skip
            pl.BlockSpec((1, bd, ds), lambda b, d, t: (b, d, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((1, tc, bd), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, bd, ds), lambda b, d, t: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, S, di), jnp.float32),
            jax.ShapeDtypeStruct((Bt, di, ds), jnp.float32),
        ],
        scratch_shapes=[_vmem((bd, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D_skip[None, :], h0)
    return y, h_out
