"""Pallas TPU flash attention (causal, GQA via kv index mapping).

Grid = (B·Hq, n_q_blocks, n_kv_blocks), kv innermost. Online softmax
state (running max m, denominator l, accumulator acc) lives in VMEM
scratch and persists across the kv sweep; the output tile is written at
the last visible kv block. Causally invisible blocks are skipped with
pl.when (no MXU work — compiled FLOPs ≈ S²/2 like the algorithm's
ideal).

BlockSpecs: q (1, bq, hd) indexed (h, i); k/v (1, bk, hd) indexed
(h // G, j) — the GQA group shares one kv stream, so kv tiles are
fetched HBM→VMEM once per group sweep. Default (bq, bk) = (512, 512):
VMEM ≈ bq·hd·2 + 2·bk·hd·2 + bq·bk·4 + bq·hd·4 ≈ 1.9 MB at hd=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _vmem(shape, dtype):
    """VMEM scratch allocation (TPU memory space; interpret-mode safe)."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return jax.ShapeDtypeStruct(shape, dtype)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, bq: int, bk: int, n_kv: int, causal: bool):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level causal visibility: kv block j visible iff j*bk <= i*bq+bq-1
    visible = (j * bk <= i * bq + bq - 1) if causal else True

    @pl.when(visible)
    def _compute():
        q = q_ref[0]                                   # (bq, hd)
        k = k_ref[0]                                   # (bk, hd)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new

    @pl.when(j == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_pallas(q, k, v, *, causal: bool = True, bq: int = DEFAULT_BQ,
                 bk: int = DEFAULT_BK, interpret: bool = False):
    """q: (BH, Sq, hd); k/v: (BHkv, Sk, hd); BH = BHkv · G."""
    BH, Sq, hd = q.shape
    BHkv, Sk, _ = k.shape
    G = BH // BHkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    n_kv = Sk // bk
    scale = float(1.0 / np.sqrt(hd))
    grid = (BH, Sq // bq, n_kv)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk,
                          n_kv=n_kv, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j, G=G: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            _vmem((bq,), jnp.float32),
            _vmem((bq,), jnp.float32),
            _vmem((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
