"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048, 4 codebooks with
the delay interleaving pattern. The EnCodec frontend is a STUB:
input_specs() provides the (B, S, 4) codebook token ids directly.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
    rope_theta=10000.0,
)
