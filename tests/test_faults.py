"""Fault-tolerant execution (ISSUE 10): deterministic seeded fault
injection (`repro.core.faults`), retry/timeout/backoff, and graceful
degradation across the federated, streaming, and serving paths.

Determinism contract: every injected fault is a pure function of
(kind, call index, seed), so a faulted run is exactly reproducible —
the parity tests assert the degraded result matches the clean run to
1e-12 (bitwise in practice: degradation re-executes the SAME jit-cached
executable) and the recovery counters exactly. `stragglers` is the one
nondeterministic counter (wall-clock through the median+MAD monitor)
and is deliberately excluded from exact assertions.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import costmodel, faults, ops
from repro.core.dag import input_tensor
from repro.core.faults import (
    DeadlineExceededError,
    InjectedFault,
    ServerClosedError,
    SiteFailedError,
    parse_spec,
)
from repro.core.federated import FederatedTensor
from repro.core.reuse import ReuseCache
from repro.core.runtime import LineageRuntime, PreparedScript
from repro.data.csv_io import read_csv_chunks, write_csv
from repro.distributed.fault import StepMonitor
from repro.lifecycle import lmDS_federated
from repro.lifecycle.regression import lmDS
from repro.serving import ModelServer, ScoreFuture

D = 16


def _counters(rt):
    """The deterministic counter tuple (everything but stragglers)."""
    f = rt.stats.faults
    return dict(injected=f.injected, retries=f.retries,
                timeouts=f.timeouts, degradations=f.degradations,
                shed=f.shed, restarts=f.restarts)


def _fed_run(x, y, spec=None, intercept=True, sites=4):
    rt = LineageRuntime()
    fed = FederatedTensor.partition_rows(x, sites)
    with faults.inject(spec) as plan:
        w = lmDS_federated(fed, y, intercept=intercept, runtime=rt)
    return np.asarray(w), rt, plan


@pytest.fixture
def fed_data(rng):
    return rng.normal(size=(200, 6)), rng.normal(size=(200, 1))


# ---------------------------------------------------------------------------
# Spec parsing / plan semantics
# ---------------------------------------------------------------------------

class TestSpecAndPlan:
    def test_parse_spec_round_trip(self):
        plan = parse_spec(
            "seed=42;site_rpc@1,3;site_slow:p=0.1:delay=0.02;"
            "site_dead:site=2")
        assert plan.seed == 42
        kinds = [r.kind for r in plan.rules]
        assert kinds == ["site_rpc", "site_slow", "site_dead"]
        assert plan.rules[0].at == frozenset({1, 3})
        assert plan.rules[1].params["delay"] == pytest.approx(0.02)
        assert plan.rules[2].params["site"] == 2

    def test_parse_spec_rejects_unknown_kind(self):
        with pytest.raises(ValueError, match="bogus"):
            parse_spec("bogus@1")

    def test_indexed_firing_is_positional(self):
        plan = parse_spec("seed=7;site_rpc@2")
        hits = [plan.check("site_rpc", site=0) is not None
                for _ in range(4)]
        assert hits == [False, False, True, False]
        assert plan.fired["site_rpc"] == 1
        assert plan.calls["site_rpc"] == 4

    def test_probability_draws_are_seeded(self):
        # same seed -> same firing pattern; different seed -> (almost
        # surely) different pattern at p=0.5 over 64 calls
        def pattern(seed):
            plan = parse_spec(f"seed={seed};chunk_io:p=0.5")
            return [plan.check("chunk_io") is not None
                    for _ in range(64)]
        assert pattern(1) == pattern(1)
        assert pattern(1) != pattern(2)

    def test_inject_stack_overrides_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_FAULT_SPEC", "site_rpc@0")
        env_plan = faults.active_plan()
        assert env_plan is not None
        with faults.inject(None):            # explicit clean run
            assert faults.active_plan() is None
        with faults.inject("seed=1;chunk_io@0") as p:
            assert faults.active_plan() is p
        assert faults.active_plan() is env_plan

    def test_policy_kill_switch(self, monkeypatch, fed_data):
        monkeypatch.setenv("REPRO_FAULT_POLICY", "off")
        assert not faults.policy_enabled()
        x, y = fed_data
        w0, rt0, _ = _fed_run(x, y)
        # injection entries are no-ops with the policy off
        w1, rt1, plan = _fed_run(x, y, "seed=1;site_rpc@0,1")
        assert not plan.fired
        assert np.abs(w1 - w0).max() == 0.0
        assert rt1.stats.faults.injected == 0


# ---------------------------------------------------------------------------
# Federated: retry, timeout, degradation ladders
# ---------------------------------------------------------------------------

class TestFederatedRecovery:
    def test_transient_rpc_faults_heal_by_retry(self, fed_data):
        x, y = fed_data
        w0, _, _ = _fed_run(x, y)
        w1, rt, plan = _fed_run(x, y, "seed=3;site_rpc@0,1")
        assert np.abs(w1 - w0).max() < 1e-12
        assert _counters(rt) == dict(injected=2, retries=2, timeouts=0,
                                     degradations=0, shed=0, restarts=0)
        assert plan.fired == {"site_rpc": 2}
        assert rt.stats.faults.backoff_s > 0.0

    def test_dead_site_degrades_to_recompute(self, fed_data):
        # 1 dead site of 4 plus 2 transient RPC failures: every fed
        # instruction exhausts retries against site 2, then collects
        # its partition and recomputes locally through the SAME
        # jit-cached executable -> bitwise parity with the clean run
        x, y = fed_data
        w0, _, _ = _fed_run(x, y)
        spec = "seed=11;site_dead:site=2;site_rpc@0,9"
        w1, rt, plan = _fed_run(x, y, spec)
        assert np.abs(w1 - w0).max() < 1e-12
        # 3 fed instructions (fed_map, fed_gram, fed_xtv): dead site
        # burns 3 attempts each (9 injected minus one call where the
        # positional site_rpc rule fired first), transient rules add 2
        assert _counters(rt) == dict(injected=10, retries=7, timeouts=0,
                                     degradations=3, shed=0, restarts=0)
        assert plan.fired == {"site_rpc": 2, "site_dead": 8}

    def test_faulted_run_is_deterministic(self, fed_data):
        x, y = fed_data
        spec = "seed=11;site_dead:site=2;site_rpc@0,9"
        w1, rt1, p1 = _fed_run(x, y, spec)
        w2, rt2, p2 = _fed_run(x, y, spec)
        assert np.abs(w1 - w2).max() == 0.0
        assert _counters(rt1) == _counters(rt2)
        assert dict(p1.fired) == dict(p2.fired)

    def test_slow_site_times_out_then_degrades(self, monkeypatch,
                                               fed_data):
        # every call to site 1 sleeps past the timeout; the attempt-
        # boundary timeout discards the (late) result, retries, then
        # degrades. site_slow never raises -> injected stays 0.
        monkeypatch.setenv("REPRO_FED_TIMEOUT_S", "0.01")
        x, y = fed_data
        w0, _, _ = _fed_run(x, y, intercept=False)
        w1, rt, plan = _fed_run(
            x, y, "site_slow:p=1:site=1:delay=0.05", intercept=False)
        assert np.abs(w1 - w0).max() < 1e-12
        assert _counters(rt) == dict(injected=0, retries=4, timeouts=6,
                                     degradations=2, shed=0, restarts=0)
        assert plan.fired == {"site_slow": 6}

    def test_lost_data_plane_is_fatal(self, fed_data):
        # site_lost means the partition itself is gone: no degradation
        # rung remains and the failure surfaces with site + instruction
        x, y = fed_data
        with pytest.raises(SiteFailedError) as ei:
            _fed_run(x, y, "seed=1;site_lost:site=1")
        assert ei.value.site == 1
        assert "site 1" in str(ei.value)
        assert ei.value.instruction     # names the fed instruction

    def test_control_plane_surfaces_in_stats(self, fed_data):
        x, y = fed_data
        _, rt, _ = _fed_run(x, y, "seed=11;site_dead:site=2")
        d = rt.stats.as_dict()["faults"]
        assert d["degradations"] == 3
        assert d["incidents"] >= d["injected"] + d["degradations"]
        assert "site_p50_us" in d and "site_p99_us" in d
        # heartbeats: the 3 surviving sites beat on every successful
        # RPC; the dead site never does
        assert d["sites_seen"] == 3
        assert d["dead_sites"] == []    # dead-man switch is 60s

    def test_combined_faults_acceptance(self, rng, tmp_path):
        # the acceptance scenario: site failures + chunk IO errors +
        # one compile failure in ONE seeded run — 1e-12 parity with
        # the clean run, identical counters on every rerun. The jit
        # cache is cleared per run so compile-call indices (and hence
        # the compile@0 firing) are reproducible within one process.
        from repro.core.jit_cache import clear_jit_cache
        xh = rng.normal(size=(208, 7))
        yh = rng.normal(size=(208, 1))
        path = str(tmp_path / "d.csv")
        write_csv(path, np.hstack([xh, yh]))

        def run(spec):
            clear_jit_cache()
            rt = LineageRuntime()
            with faults.inject(spec) as plan:
                parts = [c for _, c in read_csv_chunks(
                    path, 64, chunk_bytes=1 << 12,
                    fault_log=rt.stats.faults)]
                data = np.vstack(parts)
                fed = FederatedTensor.partition_rows(data[:, :-1], 4)
                w = lmDS_federated(fed, data[:, -1:], intercept=True,
                                   runtime=rt)
            return (np.asarray(w), rt,
                    dict(plan.fired) if plan else {})

        spec = ("seed=13;site_dead:site=3;site_rpc@2;"
                "chunk_io@0,1;compile@0")
        w0, _, _ = run(None)
        w1, rt1, fired1 = run(spec)
        w2, rt2, fired2 = run(spec)
        assert np.abs(w1 - w0).max() < 1e-12
        assert np.abs(w1 - w2).max() == 0.0
        assert _counters(rt1) == _counters(rt2) == dict(
            injected=13, retries=10, timeouts=0, degradations=3,
            shed=0, restarts=0)
        assert fired1 == fired2 == {"chunk_io": 2, "compile": 1,
                                    "site_rpc": 1, "site_dead": 9}

    def test_clean_run_has_no_fault_section(self, fed_data):
        x, y = fed_data
        rt = LineageRuntime()
        fed = FederatedTensor.partition_rows(x, 4)
        with faults.inject(None):
            lmDS_federated(fed, y, intercept=True, runtime=rt)
        assert _counters(rt) == dict(injected=0, retries=0, timeouts=0,
                                     degradations=0, shed=0, restarts=0)


# ---------------------------------------------------------------------------
# Compile failures: interpreter fallback
# ---------------------------------------------------------------------------

class TestCompileFallback:
    def test_segment_summary_names_ops(self, rng):
        from repro.core.compiler import compile_plan
        xh = rng.normal(size=(32, 4))
        X = input_tensor("X", xh)
        plan = compile_plan([ops.gram(X)], reuse_enabled=False)
        seg = plan.segments_for(False)[0]
        s = seg.summary()
        assert s.startswith("segment#") and "gram" in s and "ins=" in s

    def test_compile_fault_falls_back_to_interpreter(self, rng):
        # unique shape so the segment is a guaranteed jit-cache miss;
        # faulted run FIRST (the fallback does not populate the cache)
        xh = rng.normal(size=(61, 9))
        yh = rng.normal(size=(61, 1))

        def run(spec):
            rt = LineageRuntime(cache=ReuseCache(), fuse=True)
            with faults.inject(spec):
                w = lmDS(input_tensor("X", xh), input_tensor("y", yh),
                         reg=1e-3, runtime=rt)
            return np.asarray(w), rt

        w1, rt1 = run("seed=1;compile@0")
        w0, _ = run(None)
        assert np.abs(w1 - w0).max() < 1e-12
        assert _counters(rt1) == dict(injected=1, retries=0, timeouts=0,
                                      degradations=1, shed=0, restarts=0)


# ---------------------------------------------------------------------------
# Streaming: chunk IO retry + prefetch-worker death
# ---------------------------------------------------------------------------

BUDGET = 1 << 16


class TestStreamingRecovery:
    def test_csv_read_retries_transient_io(self, rng, tmp_path):
        xh = rng.normal(size=(300, 4))
        path = str(tmp_path / "x.csv")
        write_csv(path, xh)
        flog = faults.FaultLog()
        # two injected IO errors on the first byte-window read, healed
        # by backoff retry (max_retries=2 -> third attempt lands)
        with faults.inject("seed=5;chunk_io@0,1"):
            chunks = list(read_csv_chunks(path, 64, chunk_bytes=1 << 12,
                                          fault_log=flog))
        clean = list(read_csv_chunks(path, 64, chunk_bytes=1 << 12))
        assert len(chunks) == len(clean)
        for (o1, c1), (o0, c0) in zip(chunks, clean):
            assert o1 == o0 and (c1 == c0).all()
        assert flog.injected == 2 and flog.retries == 2
        assert flog.backoff_s > 0.0

    def test_csv_read_exhausts_retries(self, rng, tmp_path):
        xh = rng.normal(size=(50, 3))
        path = str(tmp_path / "x.csv")
        write_csv(path, xh)
        with faults.inject("seed=5;chunk_io@0,1,2"):
            with pytest.raises(InjectedFault):
                list(read_csv_chunks(path, 16))

    def test_streamed_lmds_parity_with_io_faults(self, rng, tmp_path,
                                                 monkeypatch):
        # the satellite scenario: streamed lmDS whose ingestion takes 2
        # injected chunk IO errors — byte-identical data after retry,
        # chunked execution, 1e-12 parity with the clean run
        monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", BUDGET)
        xh = rng.normal(size=(4096, 8))
        yh = rng.normal(size=(4096, 1))
        path = str(tmp_path / "x.csv")
        write_csv(path, np.hstack([xh, yh]))

        def ingest(spec, flog):
            with faults.inject(spec):
                parts = [c for _, c in read_csv_chunks(
                    path, 512, chunk_bytes=1 << 14, fault_log=flog)]
            return np.vstack(parts)

        flog = faults.FaultLog()
        data1 = ingest("seed=9;chunk_io@0,1", flog)
        data0 = ingest(None, faults.FaultLog())
        assert (data1 == data0).all()
        assert flog.injected == 2 and flog.retries == 2

        def fit(data):
            rt = LineageRuntime(cache=ReuseCache(), fuse=True)
            with faults.inject(None):
                w = lmDS(input_tensor("X", data[:, :-1]),
                         input_tensor("y", data[:, -1:]),
                         reg=1e-3, runtime=rt)
            assert rt.stats.streaming.chunks > 1   # actually streamed
            return np.asarray(w)

        assert np.abs(fit(data1) - fit(data0)).max() < 1e-12

    def test_prefetch_worker_death_degrades_to_sync(self, rng,
                                                    monkeypatch):
        # kill the chunk-prefetch worker mid-stream: the consumer
        # drains in-flight work and finishes the tail synchronously
        # (injection-free), same chunks, bitwise result
        monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", BUDGET)
        monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "2")
        xh = rng.normal(size=(4096, 8))
        yh = rng.normal(size=(4096, 1))

        def run(spec):
            rt = LineageRuntime(cache=ReuseCache(), fuse=True)
            with faults.inject(spec):
                w = lmDS(input_tensor("X", xh), input_tensor("y", yh),
                         reg=1e-3, runtime=rt)
            return np.asarray(w), rt

        w0, rt0 = run(None)
        w1, rt1 = run("seed=2;chunk_io@1")
        assert np.abs(w1 - w0).max() < 1e-12
        assert rt1.stats.streaming.chunks == rt0.stats.streaming.chunks
        f = rt1.stats.faults
        assert f.injected == 1 and f.degradations == 1


# ---------------------------------------------------------------------------
# Serving: deadlines, supervisor, terminal errors
# ---------------------------------------------------------------------------

def _script(rng, rt):
    W = input_tensor("fltW", rng.normal(size=(D, 1)))
    return PreparedScript(lambda x: (ops.matmul(x, W),), [(1, D)],
                          runtime=rt)


class TestServingFaults:
    def test_deadline_shed_before_dispatch(self, rng):
        rt = LineageRuntime()
        script = _script(rng, rt)
        srv = ModelServer(script, runtime=rt, max_batch=8,
                          adaptive=False, max_wait_us=5e4)
        with faults.inject("seed=1"), srv:
            fut = srv.submit(rng.normal(size=(1, D)), deadline_us=1.0)
            with pytest.raises(DeadlineExceededError):
                fut.result(timeout=5.0)
        assert rt.stats.faults.shed == 1

    def test_supervisor_restarts_coalescer_in_thread(self, rng):
        rt = LineageRuntime()
        script = _script(rng, rt)
        x = rng.normal(size=(1, D))
        before = set(threading.enumerate())
        with faults.inject("seed=1;serving_dispatch@0"):
            with ModelServer(script, runtime=rt, max_batch=8,
                             max_wait_us=500.0) as srv:
                # first dispatch crashes in the pop->dispatch window:
                # exactly that batch fails, the loop restarts in-thread
                with pytest.raises(InjectedFault):
                    srv.score(x, timeout=5.0)
                got, = srv.score(x, timeout=5.0)
        ref, = script(x)
        assert (got == ref).all()
        assert rt.stats.faults.restarts == 1
        assert set(threading.enumerate()) == before

    @pytest.mark.filterwarnings(
        "ignore::pytest.PytestUnhandledThreadExceptionWarning")
    def test_dead_dispatcher_surfaces_not_hangs(self, rng):
        # a persistent (non-injected) poison kills the dispatcher once
        # the restart budget is spent; waiters get ServerClosedError
        # instead of hanging, and shutdown delivers terminal errors to
        # anything still queued
        rt = LineageRuntime()
        script = _script(rng, rt)
        srv = ModelServer(script, runtime=rt, max_batch=8,
                          max_wait_us=500.0).deploy()
        srv.max_restarts = 2
        srv._budget_s = None            # poisons every coalesce pass
        fut = srv.submit(rng.normal(size=(1, D)))
        with pytest.raises((ServerClosedError, TypeError)):
            fut.result(timeout=5.0)
        # the thread is dead now; a late submit stays queued until
        # shutdown hands it the terminal error
        deadline = time.monotonic() + 5.0
        while srv._dispatcher_alive() and time.monotonic() < deadline:
            time.sleep(0.01)
        assert not srv._dispatcher_alive()
        late = srv.submit(rng.normal(size=(1, D)))
        srv.shutdown()
        with pytest.raises(ServerClosedError):
            late.result(timeout=1.0)

    def test_result_timeout(self):
        fut = ScoreFuture([np.zeros((1, D))])
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            fut.result(timeout=0.1)
        assert time.monotonic() - t0 < 2.0

    def test_score_timeout_kwarg(self, rng):
        rt = LineageRuntime()
        script = _script(rng, rt)
        with ModelServer(script, runtime=rt).deploy() as srv:
            got, = srv.score(rng.normal(size=(1, D)), timeout=5.0)
            assert got.shape == (1, 1)

    def test_dispatch_latencies_metered(self, rng):
        rt = LineageRuntime()
        script = _script(rng, rt)
        with faults.inject(None), \
                ModelServer(script, runtime=rt).deploy() as srv:
            srv.score(rng.normal(size=(1, D)), timeout=5.0)
        d = rt.stats.faults
        assert d.dispatch_monitor.times   # dispatch went through the
        assert "dispatch_p50_us" in d.as_dict()   # rescued monitor


# ---------------------------------------------------------------------------
# Thread hygiene: repeated crash/recover cycles leak nothing
# ---------------------------------------------------------------------------

class TestThreadHygiene:
    def test_serving_crash_cycles_leak_no_threads(self, rng):
        rt = LineageRuntime()
        script = _script(rng, rt)
        x = rng.normal(size=(1, D))
        before = set(threading.enumerate())
        with ModelServer(script, runtime=rt, max_batch=8,
                         max_wait_us=500.0) as srv:
            for i in range(4):
                with faults.inject(f"seed={i};serving_dispatch@0"):
                    with pytest.raises(InjectedFault):
                        srv.score(x, timeout=5.0)
                with faults.inject(None):
                    got, = srv.score(x, timeout=5.0)
                    assert got.shape == (1, 1)
        assert rt.stats.faults.restarts == 4
        assert set(threading.enumerate()) == before

    def test_streaming_crash_cycles_leak_no_threads(self, rng,
                                                    monkeypatch):
        monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", BUDGET)
        monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "2")
        xh = rng.normal(size=(4096, 8))
        yh = rng.normal(size=(4096, 1))
        before = set(threading.enumerate())
        ws = []
        for i in range(3):
            rt = LineageRuntime(cache=ReuseCache(), fuse=True)
            with faults.inject(f"seed={i};chunk_io@1"):
                ws.append(np.asarray(
                    lmDS(input_tensor("X", xh), input_tensor("y", yh),
                         reg=1e-3, runtime=rt)))
            assert rt.stats.faults.degradations == 1
        assert np.abs(ws[0] - ws[1]).max() == 0.0
        assert np.abs(ws[0] - ws[2]).max() == 0.0
        assert set(threading.enumerate()) == before


# ---------------------------------------------------------------------------
# Rescued control plane: StepMonitor bounds
# ---------------------------------------------------------------------------

class TestStepMonitorBounds:
    def test_history_stays_bounded(self):
        m = StepMonitor(max_history=16)
        for i in range(200):
            m.record(i, 0.001)
        assert len(m.times) < 2 * 16
        p50, p99 = m.p50_p99()
        assert p50 == pytest.approx(0.001)

    def test_straggler_still_flagged_after_trim(self):
        m = StepMonitor(max_history=16)
        for i in range(100):
            m.record(i, 1.0)
        assert m.record(100, 10.0)
        assert m.incidents[-1]["step"] == 100
