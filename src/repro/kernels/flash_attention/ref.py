"""Pure-jnp oracle for flash attention (GQA, causal)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q: (B, Sq, Hq, hd); k/v: (B, Sk, Hkv, hd); Hq % Hkv == 0."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd) * (float(1.0 / np.sqrt(hd)))
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    if causal:
        mask = (jnp.arange(Sq)[:, None] >= jnp.arange(k.shape[1])[None, :])
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(B, Sq, Hq, v.shape[-1])
