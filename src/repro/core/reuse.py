"""Lineage-based reuse cache (SystemDS §4.1, "Reuse of Intermediates").

Intermediates are identified by their lineage hash (hash of the lineage
DAG). Before executing an instruction, the runtime probes the cache for
*full reuse*; *partial reuse* is realized by the compensation-plan
rewrites in `repro.core.rewrites.distribute_for_reuse`, which decompose
operators (gram/xtv over rbind/cbind) so their pieces become cache hits.

Eviction follows SystemDS's cost-and-size heuristic: keep entries with
high (compute-cost / byte), weighted by recency (LRU decay).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

# Below this compute cost (seconds) an intermediate is not worth caching.
MIN_CACHE_COST_S = 20e-6
# Below this size we always cache (scalars/metadata are free to keep).
ALWAYS_CACHE_BYTES = 1 << 12


def nbytes(value) -> int:
    if hasattr(value, "nbytes"):
        return int(value.nbytes)
    data = getattr(value, "data", None)  # BCOO
    if data is not None and hasattr(data, "nbytes"):
        return int(data.nbytes) + int(value.indices.nbytes)
    return 64


@dataclass
class CacheEntry:
    value: Any
    size: int
    cost: float          # seconds it took to compute
    last_used: float
    hits: int = 0


@dataclass
class ReuseStats:
    probes: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_cached: int = 0
    time_saved: float = 0.0   # Σ cost of hit entries

    def as_dict(self) -> dict:
        return dict(probes=self.probes, hits=self.hits, misses=self.misses,
                    evictions=self.evictions, bytes=self.bytes_cached,
                    time_saved_s=round(self.time_saved, 6))


class ReuseCache:
    """Lineage-hash keyed intermediate cache with cost/size eviction."""

    def __init__(self, budget_bytes: int = 4 << 30,
                 policy: str = "costsize"):
        assert policy in ("costsize", "lru")
        self.budget = int(budget_bytes)
        self.policy = policy
        self.entries: dict[str, CacheEntry] = {}
        self.stats = ReuseStats()

    # -- interface ----------------------------------------------------------
    def probe(self, lhash: str) -> Optional[Any]:
        self.stats.probes += 1
        e = self.entries.get(lhash)
        if e is None:
            self.stats.misses += 1
            return None
        e.hits += 1
        e.last_used = time.perf_counter()
        self.stats.hits += 1
        self.stats.time_saved += e.cost
        return e.value

    def put(self, lhash: str, value: Any, cost: float) -> None:
        size = nbytes(value)
        if cost < MIN_CACHE_COST_S and size > ALWAYS_CACHE_BYTES:
            return  # not worth the pool space
        if size > self.budget:
            return
        if lhash in self.entries:
            return
        self._make_room(size)
        self.entries[lhash] = CacheEntry(value=value, size=size, cost=cost,
                                         last_used=time.perf_counter())
        self.stats.bytes_cached += size

    def clear(self) -> None:
        self.entries.clear()
        self.stats.bytes_cached = 0

    # -- eviction -------------------------------------------------------------
    def _score(self, e: CacheEntry, now: float) -> float:
        if self.policy == "lru":
            return -(now - e.last_used)
        # costsize: value density (seconds saved per byte), light recency decay
        age = now - e.last_used
        return (e.cost / max(e.size, 1)) / (1.0 + 0.01 * age)

    def _make_room(self, need: int) -> None:
        if self.stats.bytes_cached + need <= self.budget:
            return
        now = time.perf_counter()
        victims = sorted(self.entries.items(),
                         key=lambda kv: self._score(kv[1], now))
        for key, e in victims:
            if self.stats.bytes_cached + need <= self.budget:
                break
            del self.entries[key]
            self.stats.bytes_cached -= e.size
            self.stats.evictions += 1
