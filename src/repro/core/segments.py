"""Segmentation pass: instruction stream -> maximal fusable segments.

The devito-DLE-style lowering stage of the compiler (SystemDS codegen
analogue): the topologically ordered instruction list produced by
`compile_plan` is partitioned into *segments*, each of which lowers to
one pure Python closure over the `repro.core.backend` kernel registry
and is compiled once by `jax.jit` (see `repro.core.jit_cache`), so XLA
fuses the whole segment and replay skips per-op dispatch entirely.

Segment boundaries are forced by:

  * reuse-probe points — with an active `ReuseCache`, instructions whose
    compile-time cost estimate clears the cache's worth-keeping
    threshold (`Instruction.probe`, see `repro.core.costmodel`) end
    their segment so the probed value stays observable; everything
    between probes fuses, so HPO/CV loops run multi-instruction
    segments with reuse hit behaviour identical to the per-instruction
    interpreter (which gates its probes on the same flag)
  * execution-target changes — heavy `local`, `distributed`, and
    `federated` instructions never share a segment (scalar generators
    are target-neutral and join either side). Placement-aware
    segmentation falls out of this: a federated plan interleaves
    jit-fused local segments with single-instruction `federated`
    segments, and each `fed_*` instruction's *per-site* work is itself
    compiled through the kernel registry + jit cache as per-site
    sub-segments (`repro.core.federated.LocalSite.execute`)
  * shard-exec flips — instructions lowered for the device mesh
    (`placement='sharded'` values, `shard_*` reduces, `reshard`
    boundaries) never share a segment with legacy memory-based
    `distributed` instructions; a maximal shard-exec run lowers to ONE
    `shard_map`-wrapped closure (`build_sharded_segment_fn`), so the
    whole chain — elementwise riders, per-shard partial reduce, psum —
    fuses into a single collective-carrying executable
  * chunked-target runs — instructions lowered for out-of-core
    streaming (`placement='chunked'` prefixes and the `chunk_*` partial
    aggregates) group into `chunked` segments via the ordinary
    target-change rule; the runtime dispatches one warm executable per
    row chunk and sums the partials, with the `combine` boundary (a
    local instruction) closing the streaming scope
  * non-traceable ops — anything in `backend.NON_TRACEABLE_OPS` (the
    `fed_*` site-orchestration ops, `collect` exchange boundaries, and
    host ops like `quantile`) runs in its own segment, outside any jit
    trace; the runtime executes those eagerly on the host path
  * config-variance flips — for batched plans (`repro.core.batching`),
    instructions whose value carries the batch axis (`variant_uids`)
    never share a segment with config-invariant ones: the invariant
    prefix compiles to ordinary executables (shared with single-config
    plans via the jit cache) and is computed ONCE per grid, while
    variant segments are wrapped in `jax.vmap` by the runtime

Each segment carries a *canonical structural key*: `dag.structural_key`
computed with segment inputs pre-seeded positionally, so two segments
that perform the same computation hash identically even when their node
uids differ. `PreparedScript` re-invocations and HPO/CV loops therefore
hit warm compiled executables in the global jit cache instead of
re-tracing.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import TYPE_CHECKING, Optional

from . import backend
from .dag import Node, structural_key

if TYPE_CHECKING:  # avoid circular import; Plan imports this lazily
    from .compiler import Instruction, Plan


@dataclass
class Segment:
    """A maximal fusable run of instructions."""

    index: int
    instructions: list
    input_uids: tuple[int, ...]   # external values read (leaves or earlier
                                  # segment outputs), first-use order
    output_uids: tuple[int, ...]  # values that must be observable outside
                                  # (plan outputs + cross-segment uses)
    output_nodes: tuple[Node, ...]
    frees: tuple[int, ...]        # uids dead after this segment
    target: str                   # 'local' | 'distributed' | 'federated'
    key: str                      # canonical structural hash
    variant: bool = False         # carries the config batch axis (vmapped)
    sharded: bool = False         # shard-exec lane: lowered via shard_map
                                  # over the device mesh's data axis
    chunked: bool = False         # streaming lane: the runtime dispatches
                                  # this executable once per row chunk and
                                  # sums the partial aggregates

    @property
    def fused(self) -> bool:
        return len(self.instructions) > 1

    def donatable_positions(self) -> tuple[int, ...]:
        """Argument positions that are donation *candidates*: inputs
        whose uid this segment frees — i.e. it is their compile-time
        last consumer, so after dispatch nothing in the plan can read
        them again. The structural half of the `donate_argnums`
        decision; the runtime intersects it with run-time ownership
        (only buffers produced by traced execution this run and not
        referenced by the reuse cache may actually be donated — see
        `LineageRuntime._donation_mask`).
        """
        dead = set(self.frees)
        return tuple(i for i, u in enumerate(self.input_uids)
                     if u in dead)

    def summary(self) -> str:
        """One-line human identity of this segment — used by the fault
        policy's degradation warnings and `CompileFailedError` context,
        where the structural hash alone tells an operator nothing."""
        ops = [ins.node.op for ins in self.instructions]
        shown = ",".join(ops[:6]) + (",…" if len(ops) > 6 else "")
        lanes = "".join(tag for flag, tag in
                        ((self.variant, "+vmap"), (self.sharded, "+shard"),
                         (self.chunked, "+chunk")) if flag)
        return (f"segment#{self.index}[{self.target}{lanes}] "
                f"ops={shown} ins={len(self.instructions)}")


def _target_neutral(ins) -> bool:
    """Scalar generators (literals, folded constants) cost nothing on any
    target; letting them join either side keeps heavy runs contiguous."""
    return not ins.input_ids and ins.node.shape == ()


def _shard_exec(ins) -> bool:
    """Instruction executes on the device mesh (inside `shard_map`):
    either its value keeps the row-sharded placement or it is one of the
    explicit shard-exec ops (per-shard reduce + psum, reshard)."""
    return (ins.node.placement == "sharded"
            or ins.node.op in backend.SHARD_EXEC_OPS)


def _segment_key(instructions, input_uids, output_positions,
                 target: str) -> str:
    """Uid-independent structural hash of the segment's computation.

    External inputs are seeded into the `structural_key` memo by
    position, truncating recursion at the segment boundary; interior
    nodes (including generators/literals) hash by op/attrs/shape/dtype.
    `output_positions` (indices of exported instructions) must be part
    of the key: two segments with identical bodies but different output
    sets compile to different executables. Input shapes/dtypes are
    deliberately excluded — the jit cache adds the concrete argument
    signature at lookup time.
    """
    memo = {uid: f"@in{i}" for i, uid in enumerate(input_uids)}
    body = ";".join(structural_key(ins.node, memo) for ins in instructions)
    outs = ",".join(str(p) for p in output_positions)
    return hashlib.sha1(
        f"seg1|{target}|{body}|outs={outs}".encode()).hexdigest()


def segment_plan(plan: "Plan", reuse_active: bool,
                 variant_uids: Optional[frozenset[int]] = None
                 ) -> list[Segment]:
    """Partition `plan.instructions` into segments (pure, static).

    `variant_uids` (batched plans only) forces boundaries where the
    config-variance of adjacent instructions differs — target-neutral
    scalar generators still join either side; inside a vmapped segment
    they trace unbatched, so letting them ride along costs nothing."""
    def is_var(ins) -> bool:
        return variant_uids is not None and ins.out_id in variant_uids

    groups: list[list] = []
    group_targets: list[str] = []
    group_variant: list[bool] = []
    group_sharded: list[bool] = []
    cur_target: Optional[str] = None  # None while the group is all-neutral
    cur_variant: Optional[bool] = None
    cur_sharded: Optional[bool] = None
    for ins in plan.instructions:
        neutral = _target_neutral(ins)
        start_new = (
            not groups
            # a probe point must be segment-final so its value is
            # observable for cache probe/put: break after it — except in
            # the chunked lane, where the streaming executor probes and
            # populates every probe-flagged segment OUTPUT itself (a
            # break there would force the chunked prefix to materialize
            # between two streaming scopes, defeating out-of-core)
            or (reuse_active and groups[-1][-1].probe
                and groups[-1][-1].target != "chunked")
            or groups[-1][-1].node.op in backend.NON_TRACEABLE_OPS
            or ins.node.op in backend.NON_TRACEABLE_OPS
            or (not neutral and cur_target is not None
                and ins.target != cur_target)
            or (not neutral and cur_variant is not None
                and is_var(ins) != cur_variant)
            # shard-exec instructions never fuse with legacy memory-based
            # 'distributed' instructions: the former lower via shard_map,
            # the latter via plain jit over big arrays
            or (not neutral and cur_sharded is not None
                and _shard_exec(ins) != cur_sharded))
        if start_new:
            groups.append([ins])
            group_targets.append(ins.target)
            group_variant.append(is_var(ins))
            group_sharded.append(_shard_exec(ins))
            cur_target = None if neutral else ins.target
            cur_variant = None if neutral else is_var(ins)
            cur_sharded = None if neutral else _shard_exec(ins)
        else:
            groups[-1].append(ins)
            if not neutral and cur_target is None:
                cur_target = ins.target
                group_targets[-1] = ins.target
            if not neutral and cur_variant is None:
                cur_variant = is_var(ins)
            if not neutral and cur_sharded is None:
                cur_sharded = _shard_exec(ins)
                group_sharded[-1] = _shard_exec(ins)
            if is_var(ins):
                group_variant[-1] = True

    consumer_segs: dict[int, set[int]] = {}
    for si, group in enumerate(groups):
        for ins in group:
            for uid in ins.input_ids:
                consumer_segs.setdefault(uid, set()).add(si)

    out_ids = set(plan.output_ids)
    segments: list[Segment] = []
    for si, group in enumerate(groups):
        in_group = {ins.out_id for ins in group}
        input_uids: list[int] = []
        seen_in: set[int] = set()
        for ins in group:
            for uid in ins.input_ids:
                if uid not in in_group and uid not in seen_in:
                    seen_in.add(uid)
                    input_uids.append(uid)
        consumed_elsewhere = {uid for uid, segs in consumer_segs.items()
                              if segs - {si}}
        output_uids, output_nodes, output_positions = [], [], []
        for pos, ins in enumerate(group):
            if ins.out_id in out_ids or ins.out_id in consumed_elsewhere:
                output_uids.append(ins.out_id)
                output_nodes.append(ins.node)
                output_positions.append(pos)
        frees: list[int] = []
        seen_f: set[int] = set()
        for ins in group:
            for uid in ins.last_use_of:
                # purely segment-internal values never materialize in the
                # runtime environment, so freeing them is a no-op; only
                # report frees of externally visible values
                if uid in in_group and uid not in output_uids:
                    continue
                if uid not in seen_f:
                    seen_f.add(uid)
                    frees.append(uid)
        segments.append(Segment(
            index=si, instructions=list(group),
            input_uids=tuple(input_uids),
            output_uids=tuple(output_uids),
            output_nodes=tuple(output_nodes),
            frees=tuple(frees),
            target=group_targets[si],
            key=_segment_key(group, input_uids, output_positions,
                             group_targets[si]
                             + ("+sh" if group_sharded[si] else "")),
            variant=group_variant[si],
            sharded=group_sharded[si],
            # target-change boundaries already isolate the streaming
            # lane; the flag routes the group to the streaming executor
            chunked=group_targets[si] == "chunked"))
    return segments


def build_segment_fn(seg: Segment, formats: Optional[dict] = None,
                     drop_output: Optional[int] = None,
                     unshard: bool = False):
    """Lower a segment to one pure closure over the kernel registry.

    The result takes the segment's external inputs positionally (order of
    `seg.input_uids`) and returns the tuple of `seg.output_uids` values.
    Kernel variants are selected from the compile-time format assignment
    (`formats`: uid -> 'dense'|'bcoo'); BCOO values flow through the
    trace as pytrees, so the closure is jit-traceable whenever every
    kernel in the segment is.

    `drop_output` builds the *compensation* variant used on a reuse-cache
    hit in a multi-output segment: the given uid (the probe-final value,
    served from the cache) is removed from the outputs and every
    instruction not needed for the remaining ones is dead-code
    eliminated — the closure computes exactly what the per-instruction
    interpreter would after the same hit.

    `unshard` builds the local-equivalent variant of a sharded segment
    (mesh unavailable at runtime): shard-exec kernels are swapped for
    their single-device base ops (`backend.SHARD_BASE_OPS`; `reshard`
    becomes identity), so the closure computes the same global values
    without any collective.
    """
    fmts = formats or {}
    out_uids = tuple(u for u in seg.output_uids if u != drop_output)
    instructions = seg.instructions
    if drop_output is not None:
        needed = set(out_uids)
        keep = []
        for ins in reversed(seg.instructions):
            if ins.out_id in needed:
                keep.append(ins)
                needed.update(ins.input_ids)
        instructions = keep[::-1]
    steps = [(ins.out_id, ins.input_ids,
              backend.kernel_for_node(
                  ins.node,
                  in_fmts=tuple(fmts.get(u, backend.DENSE)
                                for u in ins.input_ids),
                  out_fmt=fmts.get(ins.out_id, backend.DENSE),
                  unshard=unshard))
             for ins in instructions]
    in_pos = {uid: i for i, uid in enumerate(seg.input_uids)}

    def run(*args):
        env: dict[int, object] = {}
        for out_id, input_ids, kern in steps:
            env[out_id] = kern(*[env[u] if u in env else args[in_pos[u]]
                                 for u in input_ids])
        return tuple(env[u] for u in out_uids)

    return run


def build_batched_segment_fn(seg: Segment, formats: Optional[dict],
                             batched_uids: frozenset,
                             drop_output: Optional[int] = None):
    """Lower a config-variant segment to one `jax.vmap`-wrapped closure.

    Inputs carrying the batch axis (batched leaves and earlier variant
    segment outputs — `batched_uids`) map over axis 0; config-invariant
    inputs broadcast (`in_axes=None`), so the prefix's gram/xtv is
    traced once and shared across the whole batch inside the executable.
    Outputs mirror the same split. The result is jit-compiled through
    the ordinary jit cache (with a vmap-tagged key, see the runtime).
    """
    import jax
    fn = build_segment_fn(seg, formats, drop_output=drop_output)
    out_uids = tuple(u for u in seg.output_uids if u != drop_output)
    in_axes = tuple(0 if u in batched_uids else None
                    for u in seg.input_uids)
    out_axes = tuple(0 if u in batched_uids else None for u in out_uids)
    return jax.vmap(fn, in_axes=in_axes, out_axes=out_axes)


def shard_specs(seg: Segment) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """Per-boundary shard_map specs of a sharded segment.

    Returns ('s'/'r' tag per external input, same per output). Input
    tags come from the consumers' compile-time `sin` attrs (written by
    `compiler.lower_distributed`): 's' = split on the mesh's data axis
    (leading dim), 'r' = replicated. Untouched inputs (only consumed by
    ops without a `sin`, e.g. literals feeding a neutral rider) default
    to replicated. Output tags follow the value's placement: a
    `sharded` output leaves the segment still row-split; everything
    else (psum-reduced values, reshard results) is replicated.
    """
    tags: dict[int, str] = {}
    for ins in seg.instructions:
        sin = ins.node.attr("sin")
        if not sin:
            continue
        for uid, tag in zip(ins.input_ids, sin):
            prev = tags.setdefault(uid, tag)
            if prev != tag:
                raise ValueError(
                    f"conflicting shard specs for value %{uid} in "
                    f"segment {seg.index}: {prev!r} vs {tag!r}")
    in_tags = tuple(tags.get(u, "r") for u in seg.input_uids)
    out_tags = tuple("s" if n.placement == "sharded" else "r"
                     for n in seg.output_nodes)
    return in_tags, out_tags


def build_sharded_segment_fn(seg: Segment, formats: Optional[dict],
                             mesh, drop_output: Optional[int] = None):
    """Lower a shard-exec segment to one `shard_map`-wrapped closure.

    The segment body is the ordinary fused closure; `shard_map` runs it
    per device along the mesh's `data` axis with in/out specs derived
    from the compile-time `sin` tags ('s' -> rows split on the data
    axis, 'r' -> replicated). Collectives (`jax.lax.psum` inside the
    shard-reduce kernels, `all_gather` inside `reshard`) are the only
    cross-shard communication — exactly the exchanges the cost model
    priced when it accepted the lowering. `check_rep=False`: psum
    outputs are replicated by construction.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.mesh import DATA_AXIS
    fn = build_segment_fn(seg, formats, drop_output=drop_output)
    in_tags, out_tags = shard_specs(seg)
    if drop_output is not None:
        out_tags = tuple(t for u, t in zip(seg.output_uids, out_tags)
                         if u != drop_output)
    in_specs = tuple(P(DATA_AXIS) if t == "s" else P() for t in in_tags)
    out_specs = tuple(P(DATA_AXIS) if t == "s" else P() for t in out_tags)
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def build_config_sharded_segment_fn(seg: Segment, formats: Optional[dict],
                                    batched_uids: frozenset, mesh,
                                    drop_output: Optional[int] = None):
    """Lower a config-variant segment to shard_map-over-`config` around
    the vmapped closure: the bucket axis is split across the mesh's
    `config` axis (each device vmaps over bucket/c configs), while
    config-invariant inputs broadcast replicated. No collectives — the
    configs are embarrassingly parallel; the stacked outputs reassemble
    along axis 0 via the out specs.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.mesh import CONFIG_AXIS
    fn = build_batched_segment_fn(seg, formats, batched_uids,
                                  drop_output=drop_output)
    out_uids = tuple(u for u in seg.output_uids if u != drop_output)
    in_specs = tuple(P(CONFIG_AXIS) if u in batched_uids else P()
                     for u in seg.input_uids)
    out_specs = tuple(P(CONFIG_AXIS) if u in batched_uids else P()
                      for u in out_uids)
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)
