"""Substrate: optimizer, schedules, data pipeline, csv io, checkpointing,
fault handling."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.data.csv_io import make_reader, read_csv, write_csv
from repro.data.synthetic import gen_regression, gen_tokens
from repro.data.tokens import TokenPipeline
from repro.distributed.compress import (compress_tree, dequantize,
                                        init_error_state, quantize_int8)
from repro.distributed.fault import HeartbeatTracker, StepMonitor
from repro.optim.adamw import (accumulate_grads, adamw_init, adamw_update,
                               clip_by_global_norm)
from repro.optim.schedules import warmup_cosine


class TestAdamW:
    def test_matches_reference(self, rng):
        p = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
        g = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
        st_ = adamw_init(p)
        new_p, st2, m = adamw_update(g, st_, p, lr=0.1, b1=0.9, b2=0.95,
                                     weight_decay=0.0, max_grad_norm=None)
        # reference: first step -> mhat = g, vhat = g², delta = g/|g|+eps
        ref = np.asarray(p["w"]) - 0.1 * np.asarray(g["w"]) / (
            np.abs(np.asarray(g["w"])) + 1e-8)
        np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)

    def test_weight_decay(self):
        p = {"w": jnp.ones((2,), jnp.float32)}
        g = {"w": jnp.zeros((2,), jnp.float32)}
        new_p, _, _ = adamw_update(g, adamw_init(p), p, lr=0.1,
                                   weight_decay=0.5, max_grad_norm=None)
        np.testing.assert_allclose(np.asarray(new_p["w"]), 0.95)

    def test_clip(self, rng):
        g = {"w": jnp.asarray(rng.normal(size=(100,)) * 100, jnp.float32)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        total = float(jnp.sqrt(jnp.sum(jnp.square(clipped["w"]))))
        assert abs(total - 1.0) < 1e-4

    def test_accumulate_grads(self, rng):
        p = {"w": jnp.asarray(rng.normal(size=(3, 2)), jnp.float32)}

        def loss_fn(params, mb):
            return jnp.mean((mb["x"] @ params["w"]) ** 2), {}

        mbs = {"x": jnp.asarray(rng.normal(size=(4, 5, 3)), jnp.float32)}
        loss, grads = accumulate_grads(loss_fn, p, mbs)
        # equals full-batch gradient
        full = {"x": mbs["x"].reshape(20, 3)}
        (ref_loss, _), ref_g = jax.value_and_grad(loss_fn, has_aux=True)(
            p, full)
        np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(grads["w"]),
                                   np.asarray(ref_g["w"]), rtol=1e-5)


def test_warmup_cosine():
    lr0 = float(warmup_cosine(0, peak_lr=1.0, warmup_steps=10,
                              total_steps=100))
    lr10 = float(warmup_cosine(10, peak_lr=1.0, warmup_steps=10,
                               total_steps=100))
    lr100 = float(warmup_cosine(100, peak_lr=1.0, warmup_steps=10,
                                total_steps=100))
    assert lr0 == 0.0 and abs(lr10 - 1.0) < 1e-6 and lr100 <= 0.11


class TestTokenPipeline:
    def test_deterministic_and_resumable(self):
        p1 = TokenPipeline(vocab=100, batch=2, seq_len=16, seed=3)
        b5 = p1.batch_at(5)
        p2 = TokenPipeline.restore({"seed": 3, "shard": 0, "step": 5},
                                   vocab=100, batch=2, seq_len=16)
        b5b = next(iter(p2))
        np.testing.assert_array_equal(b5["tokens"], b5b["tokens"])

    def test_shards_disjoint_streams(self):
        a = TokenPipeline(vocab=100, batch=2, seq_len=16, shard=0,
                          n_shards=2).batch_at(0)
        b = TokenPipeline(vocab=100, batch=2, seq_len=16, shard=1,
                          n_shards=2).batch_at(0)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_labels_are_shifted_tokens(self):
        b = TokenPipeline(vocab=50, batch=1, seq_len=8).batch_at(0)
        assert b["tokens"].shape == b["labels"].shape == (1, 8)


class TestCsvIO:
    def test_roundtrip(self, rng, tmp_path):
        x = rng.normal(size=(50, 4))
        path = str(tmp_path / "x.csv")
        nbytes = write_csv(path, x)
        assert nbytes > 0
        back = read_csv(path)
        np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-7)

    def test_generated_reader(self, tmp_path):
        path = str(tmp_path / "t.csv")
        with open(path, "w") as f:
            f.write("1,2.5,foo\n2,3.5,bar\n")
        reader = make_reader({"delimiter": ",", "columns": [
            ("a", "i64"), ("b", "f64"), ("c", "str")]})
        cols = reader(path)
        assert cols["a"].tolist() == [1, 2]
        assert cols["c"].tolist() == ["foo", "bar"]
        assert "def _generated_reader" in reader.__source__


class TestCheckpoint:
    def _tree(self, rng):
        return {"a": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32),
                "nested": {"b": jnp.arange(5)}}

    def test_save_restore_roundtrip(self, rng, tmp_path):
        tree = self._tree(rng)
        store.save(str(tmp_path), 10, tree, lineage={"run": "test"})
        back, manifest = store.restore(str(tmp_path), tree)
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.asarray(tree["a"]))
        assert manifest["step"] == 10
        assert manifest["lineage"]["run"] == "test"

    def test_latest_and_cleanup(self, rng, tmp_path):
        tree = self._tree(rng)
        for s in (1, 2, 3, 4, 5):
            store.save(str(tmp_path), s, tree, keep_last=2)
        assert store.latest_step(str(tmp_path)) == 5
        assert len(os.listdir(tmp_path)) == 2

    def test_restart_exactness(self, rng, tmp_path):
        """Interrupted training == uninterrupted (lineage exactness)."""
        from repro.configs import get_config
        from repro.data.tokens import TokenPipeline
        from repro.launch.steps import init_train_state, make_train_step
        from repro.models import build_model
        cfg = get_config("lm_100m").with_(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab_size=128, loss_chunk=16, attn_chunk=32)
        model = build_model(cfg)
        pipe = TokenPipeline(vocab=128, batch=2, seq_len=32, seed=0)
        step_fn = jax.jit(make_train_step(model, lr=1e-3))

        def run(n_steps, params, opt):
            for s in range(n_steps[0], n_steps[1]):
                batch = {k: jnp.asarray(v)
                         for k, v in pipe.batch_at(s).items()}
                params, opt, _ = step_fn(params, opt, batch)
            return params, opt

        p0, o0 = init_train_state(model, jax.random.PRNGKey(0))
        pa, oa = run((0, 6), p0, o0)

        # interrupted at 3 with checkpoint + restore
        p1, o1 = run((0, 3), p0, o0)
        store.save(str(tmp_path), 3, {"p": p1, "o": o1})
        back, _ = store.restore(str(tmp_path), {"p": p1, "o": o1})
        pb, ob = run((3, 6), back["p"], back["o"])

        for la, lb in zip(jax.tree_util.tree_leaves(pa),
                          jax.tree_util.tree_leaves(pb)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


class TestCompression:
    def test_quantize_roundtrip_small_error(self, rng):
        g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
        q, scale, err = quantize_int8(g, jnp.zeros_like(g))
        back = dequantize(q, scale)
        assert float(jnp.abs(back + err - g).max()) < 1e-6  # exact with EF
        assert q.dtype == jnp.int8

    def test_error_feedback_unbiased(self, rng):
        """Mean of compressed grads converges to mean of true grads."""
        errs = jnp.zeros((64,))
        total_true, total_sent = jnp.zeros((64,)), jnp.zeros((64,))
        for i in range(50):
            g = jnp.asarray(np.random.default_rng(i).normal(size=(64,)),
                            jnp.float32) * 0.01
            q, s, errs = quantize_int8(g, errs)
            total_sent = total_sent + dequantize(q, s)
            total_true = total_true + g
        resid = float(jnp.abs(total_true - total_sent).max())
        assert resid < 1e-3  # bounded by one step's quantization error


class TestFault:
    def test_straggler_detection(self):
        mon = StepMonitor()
        for s in range(30):
            assert not mon.record(s, 0.1 + 0.001 * (s % 3))
        assert mon.record(30, 0.5)       # 5× median -> straggler
        assert len(mon.incidents) == 1

    def test_heartbeat(self):
        hb = HeartbeatTracker(timeout_s=10)
        hb.beat("host0", now=0.0)
        hb.beat("host1", now=5.0)
        assert hb.dead_hosts(now=12.0) == ["host0"]
