"""HLO-text cost analyzer: trip counts, collectives, cross-validation
against XLA's cost_analysis on unrolled programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlocost import analyze


def _flops(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return comp, analyze(comp.as_text())


def _xla_cost(comp) -> dict:
    # cost_analysis() returns a per-device list on some jax versions
    ca = comp.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


X = jax.ShapeDtypeStruct((128, 128), jnp.float32)
MATMUL_FLOPS = 2 * 128 ** 3


class TestTripCounts:
    def test_single_matches_xla(self):
        comp, mine = _flops(lambda x: x @ x, X)
        assert abs(mine.flops - _xla_cost(comp)["flops"]) \
            / mine.flops < 0.05

    def test_unrolled_matches_xla(self):
        def f(x):
            for _ in range(4):
                x = x @ x
            return x
        comp, mine = _flops(f, X)
        assert abs(mine.flops - _xla_cost(comp)["flops"]) \
            / mine.flops < 0.05

    def test_scan_multiplied(self):
        def f(x):
            y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                                length=7)
            return y
        _, mine = _flops(f, X)
        assert abs(mine.flops - 7 * MATMUL_FLOPS) / mine.flops < 0.05
        assert mine.unknown_trip_counts == 0

    def test_nested_scan_multiplied(self):
        def f(x):
            def outer(c, _):
                y, _ = jax.lax.scan(lambda d, __: (d @ d, None), c, None,
                                    length=3)
                return y, None
            y, _ = jax.lax.scan(outer, x, None, length=5)
            return y
        _, mine = _flops(f, X)
        assert abs(mine.flops - 15 * MATMUL_FLOPS) / mine.flops < 0.05

    def test_scan_equals_unrolled(self):
        def scan_fn(x):
            y, _ = jax.lax.scan(lambda c, _: (jnp.tanh(c @ c), None), x,
                                None, length=4)
            return y

        def unroll_fn(x):
            for _ in range(4):
                x = jnp.tanh(x @ x)
            return x
        _, m_scan = _flops(scan_fn, X)
        _, m_unroll = _flops(unroll_fn, X)
        assert abs(m_scan.flops - m_unroll.flops) / m_unroll.flops < 0.1


class TestCollectives:
    def _sharded_program(self):
        import subprocess
        import sys
        # collectives need >1 device -> subprocess with forced devices
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
try:
    from jax.sharding import AxisType          # jax >= 0.5
    mesh_kw = dict(axis_types=(AxisType.Auto,))
except ImportError:
    mesh_kw = {}
from repro.launch.hlocost import analyze
mesh = jax.make_mesh((8,), ("d",), **mesh_kw)
xs = jax.ShapeDtypeStruct((1024, 512), jnp.float32)
ws = jax.ShapeDtypeStruct((512, 256), jnp.float32)
with mesh:
    comp = jax.jit(lambda x, w: x @ w,
        in_shardings=(NamedSharding(mesh, P(None, "d")),
                      NamedSharding(mesh, P("d", None))),
        out_shardings=NamedSharding(mesh, P(None, None))).lower(xs, ws).compile()
t = analyze(comp.as_text(), 8)
# contraction sharded -> all-reduce of the (1024, 256) f32 output
expected_payload = 1024 * 256 * 4
assert abs(t.collective_raw_bytes - expected_payload) / expected_payload < 0.05, t.collective_raw_bytes
assert abs(t.collective_wire_bytes - 2 * 7 / 8 * expected_payload) / expected_payload < 0.05
assert t.per_collective.get("all-reduce", 0) > 0
print("OK")
"""
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True,
                             cwd="/root/repo")
        assert "OK" in out.stdout, out.stdout + out.stderr

    def test_allreduce_bytes(self):
        self._sharded_program()


class TestBytes:
    def test_bytes_scale_with_tensor_size(self):
        _, small = _flops(lambda x: jnp.tanh(x) + 1.0, X)
        big_x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
        _, big = _flops(lambda x: jnp.tanh(x) + 1.0, big_x)
        assert 10 < big.bytes / small.bytes < 22  # ~16x elements
