"""Distribution: sharding rules, mini multi-device dry-run, EP-vs-local
MoE equivalence (subprocess with forced device count)."""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=REPO, timeout=560)
    assert out.returncode == 0 and "OK" in out.stdout, \
        out.stdout[-2000:] + out.stderr[-4000:]
    return out.stdout


class TestShardingRules:
    def test_param_specs_shapes(self):
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.distributed.sharding import param_specs
        from repro.launch.mesh import make_mesh
        # 1-device mesh named like production: rules apply, sizes=1 so
        # every axis divides — checks rule/path matching only
        mesh = make_mesh((1, 1), ("data", "model"))
        from repro.models import build_model
        model = build_model(get_config("qwen3-0.6b").reduced())
        shapes = model.param_shapes()
        specs = param_specs(shapes, mesh)
        flat = jax.tree_util.tree_leaves_with_path(specs)
        byname = {"/".join(str(getattr(k, "key", k)) for k in path): spec
                  for path, spec in flat}
        wq = [v for k, v in byname.items() if k.endswith("attn/wq")][0]
        assert wq == P(None, "data", "model")  # leading period axis
        head = [v for k, v in byname.items() if k.endswith("head/w")][0]
        assert head == P("data", "model")

    def test_safe_spec_drops_nondivisible(self):
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import safe_spec

        class _StubMesh:  # safe_spec only reads mesh.shape sizes
            shape = {"data": 4, "model": 2}
        mesh = _StubMesh()
        assert safe_spec((8, 6), P("data", "model"), mesh) == \
            P("data", "model")
        assert safe_spec((7, 6), P("data", "model"), mesh) == \
            P(None, "model")


class TestMiniDryRun:
    def test_small_mesh_train_compiles(self):
        """End-to-end mini dry-run: reduced arch on a 2x4 mesh, lower +
        compile + memory/cost analysis, exactly like production."""
        code = """
import os, sys
import jax
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import lower_cell
from repro.configs import get_config
mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_config("qwen3-0.6b").reduced().with_(vocab_size=1024)
_, comp, cell = lower_cell("qwen3-0.6b", "train_4k", mesh, verbose=False,
                           cfg_override=cfg.with_(n_layers=4), hints=True)
assert cell.hlo_flops > 0 and cell.t_memory > 0
assert comp.memory_analysis().temp_size_in_bytes >= 0
print("OK", cell.bottleneck)
"""
        # override shapes: train_4k batch 256 divisible by 2 ✓
        _run(code, devices=8)

    def test_multipod_mini(self):
        code = """
import jax
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import lower_cell
from repro.configs import get_config
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_config("llama3.2-1b").reduced().with_(vocab_size=1024, n_layers=4)
_, comp, cell = lower_cell("llama3.2-1b", "train_4k", mesh, verbose=False,
                           cfg_override=cfg, hints=True)
assert cell.n_devices == 8
print("OK")
"""
        _run(code, devices=8)


class TestMoeEP:
    def test_ep_matches_local_with_headroom(self):
        """shard_map EP path == local dropless path when capacity is
        ample (no drops)."""
        code = """
import numpy as np, jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.distributed.hints import enable_hints, disable_hints
from repro.configs import get_config
from repro.models import moe as moe_mod
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_config("deepseek_moe_16b").reduced().with_(
    n_experts=8, moe_top_k=2, capacity_factor=64.0)  # no drops
p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)), jnp.float32)

disable_hints()
ref, aux_ref = moe_mod.moe_forward_local(p, cfg, x)

enable_hints(mesh)
with mesh:
    out, aux = jax.jit(lambda p, x: moe_mod.moe_forward(p, cfg, x)
                       if False else moe_mod.moe_forward_ep(p, cfg, x))(p, x)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                           atol=2e-5)
np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-4)
print("OK")
"""
        _run(code, devices=8)


class TestElasticCheckpoint:
    def test_restore_onto_different_mesh(self, tmp_path):
        code = f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.checkpoint import store

mesh1 = make_mesh((4, 2), ("data", "model"))
w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
sharded = jax.device_put(w, NamedSharding(mesh1, P("data", "model")))
store.save(r"{tmp_path}", 1, {{"w": sharded}})

mesh2 = make_mesh((2, 4), ("data", "model"))
back, _ = store.restore(r"{tmp_path}", {{"w": w}},
    shardings={{"w": NamedSharding(mesh2, P("model", None))}})
np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(w))
assert back["w"].sharding.spec == P("model", None)
print("OK")
"""
        _run(code, devices=8)
