"""Fault-tolerant supervision: run_with_restarts resume + preemption,
and hlocost windowed-operand byte capping."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.restart import (Preemption, TrainState,
                                      run_with_restarts)


def _make_fns(log):
    def init_fn():
        return TrainState(step=0, params={"w": jnp.zeros((4,))},
                          opt_state={"m": jnp.zeros((4,))},
                          pipeline_state={"seed": 0, "step": 0})

    def step_fn(state):
        log.append(state.step)
        w = state.params["w"] + 1.0
        return TrainState(step=state.step + 1, params={"w": w},
                          opt_state=state.opt_state,
                          pipeline_state={"seed": 0, "step": state.step + 1})

    return init_fn, step_fn


class TestRunWithRestarts:
    def test_runs_to_completion(self, tmp_path):
        log = []
        init_fn, step_fn = _make_fns(log)
        final = run_with_restarts(ckpt_dir=str(tmp_path), init_fn=init_fn,
                                  step_fn=step_fn, total_steps=7,
                                  ckpt_every=3)
        assert final.step == 7
        assert float(final.params["w"][0]) == 7.0

    def test_injected_failure_then_resume(self, tmp_path):
        log = []
        init_fn, step_fn = _make_fns(log)
        with pytest.raises(Preemption):
            run_with_restarts(ckpt_dir=str(tmp_path), init_fn=init_fn,
                              step_fn=step_fn, total_steps=10,
                              ckpt_every=2, fail_at=5)
        # restart: resumes from the last checkpoint (step 4), same result
        final = run_with_restarts(ckpt_dir=str(tmp_path), init_fn=init_fn,
                                  step_fn=step_fn, total_steps=10,
                                  ckpt_every=2)
        assert final.step == 10
        assert float(final.params["w"][0]) == 10.0  # bit-exact trajectory
        # resumed at 4, not 0 (the checkpoint was used)
        assert 4 in log and log.count(0) == 1


class TestHlocostWindowedCap:
    def test_scan_accumulator_not_charged_per_step(self):
        """A scan writing per-step ys must NOT charge the whole stacked
        output array every iteration (in-place dynamic-update-slice)."""
        from repro.launch.hlocost import analyze

        def f(x):
            def body(c, _):
                c = jnp.tanh(c)
                return c, c            # ys: (64, 256, 256) stacked
            _, ys = jax.lax.scan(body, x, None, length=64)
            return ys

        x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        t = analyze(jax.jit(f).lower(x).compile().as_text())
        full_ys = 64 * 256 * 256 * 4
        # naive accounting would charge >= 64 × full_ys ≈ 1.07e9;
        # windowed accounting stays within a few × the real traffic
        assert t.bytes < 8 * full_ys, t.bytes
