"""Heterogeneous tensors, schema detection, transformencode (paper §3.3/§4.2)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.hetero import (DataTensor, block_shape, detect_value_type,
                               reblock, transformapply, transformencode)


class TestSchemaDetection:
    def test_types(self):
        assert detect_value_type(np.array(["1", "2", "3"], object)) == "i32"
        assert detect_value_type(np.array(["1.5", "2"], object)) == "f64"
        assert detect_value_type(np.array(["true", "false"], object)) == "bool"
        assert detect_value_type(np.array(["a", "b"], object)) == "str"
        assert detect_value_type(
            np.array([str(2**40)], object)) == "i64"

    def test_from_frame(self):
        frame = np.array([["1", "2.5", "x", "true"],
                          ["2", "3.5", "y", "false"]], dtype=object)
        dt = DataTensor.from_frame(frame)
        assert dt.types == ["i32", "f64", "str", "bool"]
        assert dt.shape == (2, 4)


class TestDataTensor:
    def _dt(self):
        return DataTensor.from_dict({
            "age": [25, 30, 45, 22],
            "income": [50.0, 60.5, 80.0, 45.0],
            "city": np.array(["a", "b", "a", "c"], dtype=object),
        }, types={"city": "str"})

    def test_schema(self):
        dt = self._dt()
        assert dt.schema == [("age", "i64"), ("income", "f64"),
                             ("city", "str")]

    def test_select_rows(self):
        dt = self._dt().select_rows(np.array([0, 2]))
        assert dt.nrows == 2
        assert dt.column("age").tolist() == [25, 45]

    def test_numeric_matrix(self):
        m = self._dt().numeric_matrix()
        assert m.shape == (4, 2)


class TestTransformEncode:
    def test_recode_dummycode_scale(self):
        dt = DataTensor.from_dict({
            "cat": np.array(["a", "b", "a", "c"], dtype=object),
            "num": [1.0, 2.0, 3.0, 4.0],
        }, types={"cat": "str"})
        x, meta = transformencode(dt, {"cat": "dummycode", "num": "scale"})
        assert x.shape == (4, 4)  # 3 dummy cols + 1 scaled
        np.testing.assert_allclose(x[:, :3].sum(axis=1), 1.0)
        np.testing.assert_allclose(x[:, 3].mean(), 0.0, atol=1e-12)

    def test_apply_matches_encode(self):
        dt = DataTensor.from_dict({
            "cat": np.array(["a", "b", "a"], dtype=object),
            "num": [1.0, 2.0, 3.0]}, types={"cat": "str"})
        x, meta = transformencode(dt, {"cat": "recode", "num": "scale"})
        x2 = transformapply(dt, meta)
        np.testing.assert_array_equal(x, x2)

    def test_binning(self):
        dt = DataTensor.from_dict({"v": np.arange(100.0)})
        x, meta = transformencode(dt, {"v": "bin:4"})
        assert set(np.unique(x)) <= {0.0, 1.0, 2.0, 3.0}

    def test_unseen_category_apply(self):
        dt = DataTensor.from_dict({"c": np.array(["a", "b"], object)},
                                  types={"c": "str"})
        _, meta = transformencode(dt, {"c": "dummycode"})
        dt2 = DataTensor.from_dict({"c": np.array(["z"], object)},
                                   types={"c": "str"})
        x2 = transformapply(dt2, meta)
        assert x2.sum() == 0.0  # unseen -> all-zero row


class TestBlocking:
    def test_block_shapes_scheme(self):
        # the paper's exponentially decreasing edge lengths
        assert block_shape(2) == (1024, 1024)
        assert block_shape(3) == (128, 128, 128)
        assert block_shape(4) == (32,) * 4
        assert block_shape(7) == (8,) * 7

    def test_reblock_conversion_example(self):
        """1024^2 matrix block -> 64 sub-blocks of 128^2 (paper §3.3)."""
        arr = np.arange(1024 * 1024, dtype=np.float32).reshape(1024, 1024)
        blocks = reblock(arr, target_rank=3)
        assert len(blocks) == 64
        assert blocks[(0, 0)].shape == (128, 128)
        # reassembly is lossless
        out = np.zeros_like(arr)
        for (bi, bj), blk in blocks.items():
            out[bi * 128:(bi + 1) * 128, bj * 128:(bj + 1) * 128] = blk
        np.testing.assert_array_equal(out, arr)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 50), st.integers(0, 10 ** 6))
def test_roundtrip_property(nrows, seed):
    rng = np.random.default_rng(seed)
    dt = DataTensor.from_dict({
        "a": rng.integers(0, 5, nrows),
        "b": rng.normal(size=nrows),
        "c": np.array([f"s{v}" for v in rng.integers(0, 3, nrows)], object),
    }, types={"c": "str"})
    x, meta = transformencode(dt, {"a": "passthrough", "b": "scale",
                                   "c": "recode"})
    x2 = transformapply(dt, meta)
    np.testing.assert_array_equal(x, x2)
    assert x.shape[0] == nrows
