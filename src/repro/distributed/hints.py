"""Sharding hints: optional with_sharding_constraint annotations.

GSPMD auto-sharding occasionally replicates compute it should split
(measured: attention score tiles replicated across the `model` axis in
the baseline dry-run — EXPERIMENTS.md §Perf iteration 1). `shard_hint`
lets model code pin intermediate shardings *when enabled by the
launcher*; disabled (the default) it is a no-op, so unit tests and the
paper-faithful baseline run the pure auto-sharded graph.

Spec tokens: mesh axis names, plus "dp" which expands to the configured
data axes ("data" or ("pod", "data")).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_STATE = {"enabled": False, "data_axes": ("data",), "axes": set(),
          "sizes": {}, "mesh": None}


def enable_hints(mesh) -> None:
    _STATE["enabled"] = True
    _STATE["axes"] = set(mesh.shape.keys())
    _STATE["sizes"] = dict(mesh.shape)
    _STATE["mesh"] = mesh
    _STATE["data_axes"] = tuple(a for a in ("pod", "data")
                                if a in mesh.shape)


def current_mesh():
    return _STATE["mesh"]


def disable_hints() -> None:
    _STATE["enabled"] = False


def hints_enabled() -> bool:
    return _STATE["enabled"]


def axis_size(name: str) -> int:
    return int(_STATE["sizes"].get(name, 1))


def _expand(token):
    if token == "dp":
        dp = _STATE["data_axes"]
        return dp if len(dp) > 1 else (dp[0] if dp else None)
    if token is None or token in _STATE["axes"]:
        return token
    if isinstance(token, tuple):
        kept = tuple(t for t in token if t in _STATE["axes"])
        return kept if kept else None
    return None


def shard_hint(x, *spec):
    """Annotate x with PartitionSpec(*spec) if hints are enabled."""
    if not _STATE["enabled"]:
        return x
    expanded = [_expand(s) for s in spec]
    # drop axes that don't divide the dim (graceful degradation)
    for i, (s, d) in enumerate(zip(expanded, x.shape)):
        if s is None:
            continue
        names = s if isinstance(s, tuple) else (s,)
        size = 1
        for n in names:
            size *= _STATE["sizes"].get(n, 1)
        if d % max(size, 1) != 0:
            expanded[i] = None
    try:
        return jax.lax.with_sharding_constraint(x, P(*expanded))
    except Exception:
        return x
