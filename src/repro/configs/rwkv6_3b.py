"""rwkv6-3b — Finch, data-dependent decay [arXiv:2404.05892; hf].

32L d_model=2560 (attn-free) d_ff=8960 vocab=65536. Heads = d_model/64.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # 2560 / 64 rwkv head dim
    d_ff=8960,
    vocab_size=65536,
    attn_type="none",
    ssm_type="rwkv6",
    rwkv_head_dim=64,
)
