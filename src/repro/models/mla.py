"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV states are compressed into a rank-`kv_lora_rank` latent c_kv plus a
single shared RoPE key. Train/prefill expands per-head K/V from the
latent; decode runs in *absorbed* form — scores and context are computed
directly against the compressed cache, so the per-token cache is just
(kv_lora_rank + rope_head_dim) floats instead of 2·H·hd.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.hints import shard_hint

from .attention import NEG_INF, attention_core
from .layers import (Params, apply_rope, cdtype, dense_init, rmsnorm,
                     rmsnorm_init)


def mla_init(key, cfg) -> Params:
    d = cfg.d_model
    nh, dn, dr, dv = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim, cfg.vdim
    L, qL = cfg.kv_lora_rank, cfg.q_lora_rank
    ks = jax.random.split(key, 8)
    p: Params = {}
    if qL:
        p["wq_a"] = dense_init(ks[0], d, qL)
        p["q_norm"] = rmsnorm_init(qL)
        p["wq_b"] = dense_init(ks[1], qL, nh * (dn + dr))
    else:
        p["wq"] = dense_init(ks[1], d, nh * (dn + dr))
    p["wkv_a"] = dense_init(ks[2], d, L + dr)
    p["kv_norm"] = rmsnorm_init(L)
    p["wkv_b_k"] = (jax.random.normal(ks[3], (L, nh, dn), jnp.float32)
                    / np.sqrt(L))
    p["wkv_b_v"] = (jax.random.normal(ks[4], (L, nh, dv), jnp.float32)
                    / np.sqrt(L))
    p["wo"] = dense_init(ks[5], nh * dv, d)
    return p


def _queries(p: Params, cfg, x, positions):
    B, S, _ = x.shape
    nh, dn, dr = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    dt = x.dtype
    if cfg.q_lora_rank:
        cq = rmsnorm(p["q_norm"], x @ p["wq_a"].astype(dt))
        q = (cq @ p["wq_b"].astype(dt)).reshape(B, S, nh, dn + dr)
    else:
        q = (x @ p["wq"].astype(dt)).reshape(B, S, nh, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q_nope = shard_hint(q_nope, "dp", None, "model", None)
    q_rope = shard_hint(q_rope, "dp", None, "model", None)
    return q_nope, q_rope


def _latents(p: Params, cfg, x, positions):
    dt = x.dtype
    L, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    ckv_full = x @ p["wkv_a"].astype(dt)               # (B, S, L + dr)
    c_kv = rmsnorm(p["kv_norm"], ckv_full[..., :L])
    k_rope = ckv_full[..., L:][:, :, None, :]          # (B, S, 1, dr)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope[:, :, 0, :]


def mla_forward(p: Params, cfg, x, positions,
                impl: Optional[str] = None):
    """Training / prefill path: expand per-head K/V from the latent."""
    B, S, _ = x.shape
    nh, dn, dr, dv = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim, cfg.vdim
    dt = x.dtype
    q_nope, q_rope = _queries(p, cfg, x, positions)
    c_kv, k_rope = _latents(p, cfg, x, positions)
    k_nope = shard_hint(
        jnp.einsum("bsl,lhd->bshd", c_kv, p["wkv_b_k"].astype(dt)),
        "dp", None, "model", None)
    v = shard_hint(
        jnp.einsum("bsl,lhd->bshd", c_kv, p["wkv_b_v"].astype(dt)),
        "dp", None, "model", None)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, nh, dr))],
        axis=-1)
    # pad v to q/k head size so GQA core can run, then slice back
    out = attention_core(q, k, jnp.pad(v, ((0, 0),) * 3 + ((0, dn + dr - dv),)),
                         causal=True, cfg=cfg, impl=impl)[..., :dv]
    out = out.reshape(B, S, nh * dv)
    cache = (c_kv, k_rope)
    return out @ p["wo"].astype(dt), cache


def mla_decode(p: Params, cfg, x, cache, cur_len):
    """Absorbed decode: attention directly over the compressed cache."""
    B = x.shape[0]
    nh, dn, dr, dv = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim, cfg.vdim
    dt = x.dtype
    positions = jnp.full((B, 1), cur_len, dtype=jnp.int32)
    q_nope, q_rope = _queries(p, cfg, x, positions)     # (B,1,nh,·)
    c_new, kr_new = _latents(p, cfg, x, positions)      # (B,1,L), (B,1,dr)
    ckv_cache, kr_cache = cache
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, c_new.astype(ckv_cache.dtype), cur_len, axis=1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(
        kr_cache, kr_new.astype(kr_cache.dtype), cur_len, axis=1)

    # absorb wkv_b_k into the query: (B,1,nh,dn)·(L,nh,dn) -> (B,1,nh,L)
    q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope, p["wkv_b_k"].astype(dt))
    ck = ckv_cache.astype(dt)
    scores = (jnp.einsum("bqhl,bsl->bhqs", q_abs, ck)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, kr_cache.astype(dt)))
    scores = scores.astype(jnp.float32) / float(np.sqrt(dn + dr))
    mask = jnp.arange(ck.shape[1])[None, :] < (cur_len + 1)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    ctx = jnp.einsum("bhqs,bsl->bqhl", probs, ck)       # (B,1,nh,L)
    out = jnp.einsum("bqhl,lhd->bqhd", ctx, p["wkv_b_v"].astype(dt))
    out = out.reshape(B, 1, nh * dv) @ p["wo"].astype(dt)
    return out, (ckv_cache, kr_cache)


def mla_cache_spec(cfg, batch: int, max_len: int):
    return (jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank),
                                 cdtype(cfg)),
            jax.ShapeDtypeStruct((batch, max_len, cfg.rope_head_dim),
                                 cdtype(cfg)))
