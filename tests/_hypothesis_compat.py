"""Compatibility shim for `hypothesis`.

When hypothesis is installed, re-export the real `given`/`settings`/`st`.
When it is absent (slim CI containers), provide a tiny deterministic
fallback that runs each property test over a fixed number of
pseudo-randomly drawn examples from a seeded PRNG, supporting exactly
the strategy subset this suite uses (`integers`, `sampled_from`,
`lists`, `floats`, `composite`). Failures are reproducible because the
draw sequence depends only on the example index.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import random

    _DEFAULT_EXAMPLES = 15

    class _Strategy:
        __slots__ = ("_draw",)

        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rnd: random.Random):
            return self._draw(rnd)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float = 0.0, max_value: float = 1.0,
                   **_kw) -> _Strategy:
            return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            elements = list(elements)
            return _Strategy(lambda rnd: rnd.choice(elements))

        @staticmethod
        def lists(elem: _Strategy, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            def draw(rnd):
                n = rnd.randint(min_size, max_size)
                return [elem.draw(rnd) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def composite(fn):
            def make(*args, **kwargs):
                def draw(rnd):
                    return fn(lambda s: s.draw(rnd), *args, **kwargs)
                return _Strategy(draw)
            return make

    st = _Strategies()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            # Zero-arg wrapper: without the hypothesis pytest plugin the
            # drawn parameters must not look like fixtures to pytest.
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                for i in range(n):
                    rnd = random.Random(0xA11CE + 7919 * i)
                    drawn = [s.draw(rnd) for s in strategies]
                    try:
                        fn(*drawn)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example #{i}: {drawn!r}") from e
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
