"""Compile-time per-node cost model (SystemDS §3.2 cost-based compilation).

Estimates the execution cost of a single HOP from its size/sparsity
metadata alone, *before* anything runs. Two consumers:

  * probe-point selection (`repro.core.compiler`) — an intermediate is a
    lineage-reuse probe point only when its estimated cost clears the
    cache's worth-keeping threshold (`reuse.MIN_CACHE_COST_S`), so
    segments stay maximal between probes instead of degenerating to one
    instruction per segment;
  * format assignment — sparsity-scaled flop estimates keep the cost
    model consistent with the executor's dense/bcoo decision (both sides
    read `dag.SPARSE_THRESHOLD`).

The model is deliberately coarse — a per-op launch overhead plus a
roofline term max'd over compute and memory. Heavy operators (BLAS-class
calls, factorizations) carry a real dispatch/launch constant: that
mirrors what the per-instruction interpreter actually measures for them
(an eager dispatch with a device sync never costs less than ~20 µs), so
estimate-gated probing selects the same intermediates the measured-cost
gate used to keep.  Deeper per-instruction analysis lives in
`repro.launch.hlocost`, which needs compiled HLO and is therefore not
available at plan-compile time.
"""
from __future__ import annotations

import os

import numpy as np

from .dag import SPARSE_THRESHOLD, Node
from .reuse import MIN_CACHE_COST_S

# Calibration: effective single-stream rates for the local backend.
# These are intentionally conservative (well below hardware peak) so
# borderline intermediates err toward "worth caching".
PEAK_FLOPS = 4e9     # flop/s
PEAK_BW = 2e10       # bytes/s

# Per-op launch overhead (seconds): BLAS-class / factorization kernels
# pay a real dispatch+sync constant; cheap elementwise ops are fusable
# and nearly free to re-issue.
HEAVY_OP_BASE_S = 25e-6
LIGHT_OP_BASE_S = 1e-6

# Ops with BLAS/LAPACK-class launch cost regardless of operand size.
HEAVY_OPS = frozenset({
    "matmul", "gram", "xtv", "solve", "cholesky", "inv",
})

# Federated placement calibration: effective master<->site link
# bandwidth and per-site round-trip latency. Deliberately below local
# memory bandwidth — moving bytes across the federation boundary is the
# dominant cost the placement pass must weigh (§3.3 "exchange
# constraints"), so collect decisions are cost-based, not syntactic.
NET_BW = 1e9          # bytes/s across the exchange boundary
FED_TRIP_S = 50e-6    # per-site round-trip launch latency

# An intermediate becomes a lineage-reuse probe point when its estimated
# cost clears the cache's own worth-keeping threshold: anything cheaper
# is, by the cache's definition, not worth a pool entry — or a segment
# boundary.
PROBE_MIN_COST_S = MIN_CACHE_COST_S


def _numel(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def _exec_sparsity(n: Node) -> float:
    """Density the executor can actually exploit for operand `n`.

    Mirrors the format pass exactly: BCOO only flows from a qualifying
    input leaf through the structure-preserving ops (transpose,
    zero-preserving unaries, scalar scaling) — dense never re-sparsifies
    mid-plan. So walk that chain to its source; anything else executes
    dense and gets no flop discount, no matter how sparse its values.
    Mode-independent by design: the same estimate (and therefore the
    same probe set and cache-entry costs) is used whether or not the
    executing runtime enables sparse_inputs, which is what keeps reuse
    behaviour identical across runtimes sharing one cache.
    """
    from . import backend
    if not backend.HAS_SPARSE:
        return 1.0
    cur = n
    while cur.op != "input":
        i = backend.bcoo_passthrough_arg(cur)
        if i is None:
            return 1.0  # produced by a dense-output op
        cur = cur.inputs[i]
    if backend.leaf_format(cur) == backend.BCOO:
        return max(cur.sparsity, 1e-6)
    return 1.0


def node_flops(n: Node) -> float:
    """Estimated floating-point work of one HOP (sparsity-aware)."""
    op = n.op
    out = _numel(n.shape)
    if op == "matmul":
        a, b = n.inputs
        k = a.shape[-1]
        return 2.0 * out * k * min(_exec_sparsity(a), _exec_sparsity(b))
    if op == "gram":
        (a,) = n.inputs
        m = a.shape[0]
        return 2.0 * out * m * _exec_sparsity(a)
    if op == "xtv":
        a, v = n.inputs
        m = a.shape[0]
        return 2.0 * out * m * _exec_sparsity(a)
    if op in ("solve", "inv"):
        k = n.inputs[0].shape[0]
        return (2.0 / 3.0) * k ** 3 + 2.0 * k * k * out
    if op == "cholesky":
        k = n.shape[0]
        return k ** 3 / 3.0
    if op in ("sum", "mean", "max", "min", "trace", "nnz", "colSums",
              "rowSums", "colMeans", "rowMeans", "colMaxs", "colMins",
              "colVars", "cumsum"):
        return float(max((_numel(i.shape) for i in n.inputs), default=out))
    # elementwise / structural / generators: ~1 flop per output element
    return float(out)


def node_bytes(n: Node) -> float:
    """Estimated memory traffic: inputs read + output written, at the
    format-aware sizes from `Node.est_bytes` (sparse operands charge
    their compressed footprint)."""
    return float(n.est_bytes() + sum(i.est_bytes() for i in n.inputs))


def est_cost_s(n: Node) -> float:
    """Estimated wall-clock seconds to execute one HOP standalone."""
    if n.op == "collect" or n.op.startswith("fed_"):
        return fed_cost_s(n)
    if n.op.startswith("shard_") or n.op == "reshard" \
            or n.placement == "sharded":
        return shard_cost_s(n)
    if n.op.startswith("chunk_") or n.op == "combine":
        return chunk_cost_s(n)
    base = HEAVY_OP_BASE_S if n.op in HEAVY_OPS else LIGHT_OP_BASE_S
    return base + max(node_flops(n) / PEAK_FLOPS, node_bytes(n) / PEAK_BW)


# ---------------------------------------------------------------------------
# Federated placement costs (§3.3): exchange bytes as a first-class term
# ---------------------------------------------------------------------------

def _dense_bytes(n: Node) -> float:
    return float(_numel(n.shape)) * np.dtype(n.dtype).itemsize


def fed_exchange_bytes(n: Node) -> tuple[float, float]:
    """(bytes master->sites, bytes sites->master) for one `fed_*` /
    `collect` instruction — the compile-time estimate of what the
    runtime's `ExchangeLog` will meter."""
    sites = int(n.attr("n_sites", 1) or 1)
    op = n.op
    out_b = _dense_bytes(n)
    if op == "collect":
        return 0.0, _dense_bytes(n.inputs[0])
    if op == "fed_gram":
        return 0.0, sites * out_b
    if op in ("fed_xtv", "fed_vm"):
        fed_args = set(n.attr("fed_args", (0,)))
        sent = sum(_dense_bytes(i) for pos, i in enumerate(n.inputs)
                   if pos not in fed_args)  # row-aligned operands, sliced
        return sent, sites * out_b
    if op == "fed_mv":
        w = n.inputs[1]
        return sites * _dense_bytes(w), out_b  # broadcast w, rbind result
    if op == "fed_colsums":
        return 0.0, sites * out_b
    if op == "fed_map":
        # fed_args/gen_args index the *inner argument list*; n.inputs is
        # that list with generated operands removed — walk the inner
        # positions and advance through inputs exactly like the runtime
        # executor does, so this estimate matches what ExchangeLog meters
        fed_args = set(n.attr("fed_args", ()))
        gens = {g[0] for g in n.attr("gen_args", ())}
        sent = 0.0
        inputs = iter(n.inputs)
        for pos in range(int(n.attr("n_args", len(n.inputs)))):
            if pos in gens:
                continue  # generated on site — never sent
            i = next(inputs)
            if pos in fed_args or i.shape == ():
                continue  # on-site already / scalar constant
            b = _dense_bytes(i)
            sent += sites * b if i.shape[0] == 1 else b  # broadcast : slice
        return sent, 0.0  # output stays federated — nothing comes back
    return 0.0, 0.0


def _fed_flops(n: Node) -> float:
    """Total across sites of the per-site local work."""
    op = n.op
    out = _numel(n.shape)
    if op == "fed_gram":
        return 2.0 * out * n.inputs[0].shape[0]
    if op in ("fed_xtv", "fed_vm"):
        m = max(i.shape[0] for i in n.inputs)
        return 2.0 * out * m
    if op == "fed_mv":
        return 2.0 * out * n.inputs[0].shape[1]
    if op in ("fed_colsums", "fed_map"):
        return float(max((_numel(i.shape) for i in n.inputs), default=out))
    return 0.0  # collect: pure data movement


def fed_cost_s(n: Node) -> float:
    """Estimated seconds for a federated instruction: per-site launch
    round trips + exchange bytes over the federation link + the per-site
    local compute (sites work in parallel)."""
    sites = int(n.attr("n_sites", 1) or 1)
    to_b, from_b = fed_exchange_bytes(n)
    compute = _fed_flops(n) / sites / PEAK_FLOPS
    return sites * FED_TRIP_S + (to_b + from_b) / NET_BW + compute


def collect_cost_s(fed_value: Node, n_sites: int) -> float:
    """Cost of materializing a federated value at the master — the
    explicit boundary the placement pass inserts for non-lowerable
    consumers, and the baseline every `fed_*` lowering must beat."""
    return n_sites * FED_TRIP_S + _dense_bytes(fed_value) / NET_BW


# ---------------------------------------------------------------------------
# Sharded placement costs: collectives over the device mesh as
# first-class terms, weighed against the roofline `est_cost_s`
# ---------------------------------------------------------------------------

# Per-hop device-interconnect bandwidth (ICI on TPU, shared-memory copy
# between forced host devices on CPU). Well above the federation link
# (NET_BW) and below local memory bandwidth — collectives are cheap but
# not free, which is what makes small outputs shard and huge ones pay.
ICI_BW = 1e10          # bytes/s per link

# shard_map segment dispatch overhead: device-collective setup costs
# more than a plain jit launch, so tiny plans must not shard.
SHARD_LAUNCH_S = 50e-6

# Leaves below this dense footprint are never worth row-sharding: the
# dispatch overhead alone beats any per-shard compute win.
SHARD_MIN_LEAF_BYTES = 1 << 20


def allreduce_bytes(n: Node, d: int) -> int:
    """Total bytes crossing device links for a ring all-reduce of this
    node's output over a `d`-device axis: 2·B·(d-1). The compile-time
    estimate behind the runtime's `ShardLog.collective_bytes` meter."""
    return int(2.0 * _dense_bytes(n) * max(d - 1, 0))


def allgather_bytes(n: Node, d: int) -> int:
    """Total link bytes to all-gather a value to global size B on every
    device: B·(d-1) — the `reshard` boundary's meter estimate."""
    return int(_dense_bytes(n) * max(d - 1, 0))


def collective_bytes(n: Node) -> int:
    """Estimated link bytes one sharded instruction moves (0 for
    row-preserving sharded ops — they need no collective at all)."""
    d = int(n.attr("n_dev", 1) or 1)
    if n.op == "reshard":
        return allgather_bytes(n, d)
    if n.op.startswith("shard_"):
        return allreduce_bytes(n, d)
    return 0


def _shard_flops(n: Node) -> float:
    """Total flops of the underlying computation of a shard op (the
    per-device share is this / n_dev — shards work in parallel)."""
    op = n.op
    out = _numel(n.shape)
    if op == "shard_gram":
        return 2.0 * out * n.inputs[0].shape[0]
    if op == "shard_xtv":
        return 2.0 * out * n.inputs[0].shape[0]
    if op in ("shard_colsums", "shard_sum"):
        return float(max((_numel(i.shape) for i in n.inputs), default=out))
    return node_flops(n)  # row-preserving sharded ops keep their base op


def shard_cost_s(n: Node) -> float:
    """Estimated seconds for one sharded instruction: shard_map launch
    + the per-device roofline share + the collective (ring time over
    `d` parallel links)."""
    d = int(n.attr("n_dev", 1) or 1)
    if n.op == "reshard":
        return SHARD_LAUNCH_S + allgather_bytes(n, d) / (d * ICI_BW)
    compute = max(_shard_flops(n) / d / PEAK_FLOPS,
                  node_bytes(n) / d / PEAK_BW)
    coll = collective_bytes(n) / (d * ICI_BW)
    base = SHARD_LAUNCH_S if n.op.startswith("shard_") else LIGHT_OP_BASE_S
    return base + compute + coll


def reshard_cost_s(x: Node, d: int) -> float:
    """Cost of materializing a row-sharded value as a replicated local
    one (`all_gather`) — the boundary `lower_distributed` inserts for
    non-lowerable consumers, and the baseline every sharded lowering
    must beat (the shard-level analogue of `collect_cost_s`)."""
    return SHARD_LAUNCH_S + _dense_bytes(x) * max(d - 1, 0) / (d * ICI_BW)


# ---------------------------------------------------------------------------
# Task-parallel batched execution (§5 parfor): vmap-vs-sequential
# arbitration for the config axis
# ---------------------------------------------------------------------------

# Control-program overhead per configuration on the sequential path: one
# plan compile + leaf binding + per-segment python dispatch with a device
# sync. Measured on the PR-3 grid-search loop this is a few hundred µs
# per λ even with every heavy intermediate served from the reuse cache.
PARFOR_DISPATCH_S = 300e-6

# Memory ceiling for the vmapped config-variant suffix: every variant
# intermediate is materialized `bucket` times at once, so giants must
# fall back to the sequential loop instead of thrashing.
VMAP_MEM_BUDGET = 1 << 30


def _work_s(n: Node) -> float:
    """Roofline term of one HOP (est_cost_s minus the launch constant)."""
    return max(node_flops(n) / PEAK_FLOPS, node_bytes(n) / PEAK_BW)


def batched_cost_s(invariant: list[Node], variant: list[Node],
                   bucket: int) -> float:
    """Estimated seconds for one batched (vmapped) execution.

    The config-invariant prefix runs once at per-config size; every
    config-variant instruction pays its launch constant ONCE but does
    `bucket`× the per-config work (the batch axis is padded up to a
    power-of-two bucket, so the padding waste is part of the estimate —
    that is what lets a memory-bound giant lose to the sequential loop
    when the bucket overshoots k).
    """
    total = PARFOR_DISPATCH_S  # one plan dispatch for the whole grid
    for n in invariant:
        total += est_cost_s(n)
    for n in variant:
        base = HEAVY_OP_BASE_S if n.op in HEAVY_OPS else LIGHT_OP_BASE_S
        total += base + bucket * _work_s(n)
    return total


def config_shard_cost_s(invariant: list[Node], variant: list[Node],
                        bucket: int, c: int) -> float:
    """Estimated seconds for the batched grid with the bucket axis
    sharded over the mesh's `config` axis (`c` devices): the invariant
    prefix still runs once replicated, each variant instruction pays a
    shard_map launch but only `bucket / c` of the per-config work —
    k × padded cost vs single-device vmap is exactly the arbitration
    the ISSUE names."""
    total = PARFOR_DISPATCH_S
    for n in invariant:
        total += est_cost_s(n)
    per_dev = max(bucket // max(c, 1), 1)
    for n in variant:
        base = HEAVY_OP_BASE_S if n.op in HEAVY_OPS else LIGHT_OP_BASE_S
        total += base + SHARD_LAUNCH_S + per_dev * _work_s(n)
    return total


# ---------------------------------------------------------------------------
# Serving (repro.serving): coalesced-dispatch cost and the adaptive
# batching-delay policy — padding waste traded against queue delay
# ---------------------------------------------------------------------------

def _serve_bucket(k: int) -> int:
    """Power-of-two vmap bucket for k coalesced requests. Mirrors
    `batching.bucket_size` (duplicated here because `batching` imports
    this module) — serving replays warm under exactly those buckets."""
    return 2 if k <= 2 else 1 << (k - 1).bit_length()


def serve_batch_cost_s(invariant: list[Node], variant: list[Node],
                       bucket: int) -> float:
    """Estimated seconds for ONE coalesced serving dispatch.

    Same cost structure as `batched_cost_s` — the `PARFOR_DISPATCH_S`
    control-program constant is paid once for the whole coalesced
    batch, the request-invariant prefix runs once, and every
    request-variant instruction does `bucket`× the per-request work.
    The padding waste is priced in: a batch of k requests padded to a
    `bucket` > k executes `bucket - k` wasted lanes, which is what the
    coalescer's delay policy weighs against queue time.
    """
    return batched_cost_s(invariant, variant, bucket)


def coalesce_gain_s(invariant: list[Node], variant: list[Node],
                    k: int, max_batch: int) -> float:
    """Seconds saved by absorbing ONE more request into a pending batch
    of k instead of letting it pay its own dispatch later.

    Three regimes:
      * k below the current bucket — the next request rides a padding
        lane that is already paid for: the full cost of a solo dispatch
        is saved;
      * k exactly on a bucket boundary — absorbing one more request
        doubles the vmap bucket, so the marginal batched work eats into
        the solo-dispatch saving;
      * k at `max_batch` — nothing to gain, dispatch now.
    """
    if k >= max_batch:
        return 0.0
    solo = serve_batch_cost_s(invariant, variant, _serve_bucket(1))
    b = _serve_bucket(k)
    if k < b:
        return solo
    marginal = (serve_batch_cost_s(invariant, variant, 2 * b)
                - serve_batch_cost_s(invariant, variant, b))
    return max(solo - marginal, 0.0)


def coalesce_wait_s(invariant: list[Node], variant: list[Node],
                    k: int, max_batch: int, max_wait_s: float) -> float:
    """Adaptive batching delay: how much LONGER a coalescer holding k
    queued requests should wait for the next arrival.

    Waiting dt seconds delays all k held requests (total queue-delay
    cost k·dt); absorbing the next arrival saves `coalesce_gain_s`.
    Break-even at dt = gain / k — the budget shrinks as the batch
    fills, so a nearly-full batch dispatches almost immediately while a
    lone request is willing to wait for company. Clamped to the
    operator-set `max_wait_s` policy ceiling (the p99 guard).
    """
    if k >= max_batch:
        return 0.0
    gain = coalesce_gain_s(invariant, variant, k, max_batch)
    return min(max_wait_s, gain / max(k, 1))


# ---------------------------------------------------------------------------
# Chunked (out-of-core) placement: streaming row-partitioned execution
# under an explicit device-memory budget (ROADMAP item 4)
# ---------------------------------------------------------------------------

# Device-memory budget for the streaming executor's live working set.
# Row-partitionable plans whose leaves exceed this are lowered to
# per-chunk segments with streaming combine; env-overridable so CI can
# force chunking on toy data (`REPRO_CHUNK_MEM_BUDGET=65536`).
CHUNK_MEM_BUDGET = int(os.environ.get("REPRO_CHUNK_MEM_BUDGET",
                                      str(256 << 20)))

# One in-flight chunk's live set is roughly the raw slice, its
# row-preserving transforms inside the fused segment, and the partial
# accumulators — budget a fixed multiple of the raw slice bytes.
CHUNK_LIVE_FACTOR = 4

# Floor on the chunk row bucket: below this the per-chunk dispatch
# overhead swamps any memory win.
CHUNK_MIN_ROWS = 16

# Per-chunk control-program overhead on the streaming path: host slice +
# fingerprint + one warm-executable dispatch with a device sync.
CHUNK_DISPATCH_S = 30e-6


def leaf_row_bytes(n: Node) -> float:
    """Per-row payload bytes of a row-partitioned leaf, format-aware.

    A BCOO leaf charges its *stored* payload — data plus 2 index coords
    per stored element, the same accounting `reuse.nbytes` applies to
    materialized BCOO values — instead of the dense row footprint, so
    sparse chunking doesn't undershoot the budgeted row count by 1/sp.
    """
    from . import backend
    itemsize = np.dtype(n.dtype).itemsize
    cols = n.shape[1] if len(n.shape) > 1 else 1
    if backend.HAS_SPARSE and len(n.shape) == 2 \
            and backend.leaf_format(n) == backend.BCOO:
        nse_per_row = float(cols) * max(n.sparsity, 1e-6)
        return max(nse_per_row * (itemsize + 8), 1.0)  # data + 2×int32
    return float(cols) * itemsize


def chunk_rows(row_bytes: float) -> int:
    """Chunk row-count for a streaming pass: the largest power of two
    whose live working set (CHUNK_LIVE_FACTOR × slice bytes) fits in
    CHUNK_MEM_BUDGET. Power-of-two bucketing means every full chunk of
    a run shares ONE jit-cache signature (one warm executable), and the
    bucket depends only on the budget and the row payload — never on the
    total row count — so appending rows leaves existing chunk
    boundaries (and their cached partials) intact.
    """
    target = CHUNK_MEM_BUDGET / (CHUNK_LIVE_FACTOR * max(row_bytes, 1.0))
    c = 1 << max(int(target).bit_length() - 1, 0)
    return max(c, CHUNK_MIN_ROWS)


# ---------------------------------------------------------------------------
# Asynchronous pipelined dispatch (ROADMAP items 1/2/4 "Remaining"):
# how deep the runtime may run ahead of the device
# ---------------------------------------------------------------------------

# Spawning/joining a prefetch worker and keeping a second bucket live
# costs roughly one chunk dispatch of control-program overhead; below
# that, pipelining is pure tax.
PIPELINE_MIN_GAIN_S = CHUNK_DISPATCH_S


def pipeline_depth() -> int:
    """Resolved async-dispatch depth for the runtime's segment executor.

    ``REPRO_PIPELINE_DEPTH`` is the deployment surface: ``1`` forces the
    fully synchronous PR-8 behaviour (bitwise- and meter-identical to
    the pre-pipeline runtime), ``>=2`` forces async dispatch with that
    much chunk-prefetch lookahead. Unset/0 means auto, which defaults to
    2: deferred device sync is free in the worst case (XLA dispatches
    asynchronously regardless), so only an explicit operator override
    should pin the runtime to the blocking path.

    Read per plan run, not at import, so one process can compare both
    modes (the pipeline benchmark does exactly that).
    """
    env = int(os.environ.get("REPRO_PIPELINE_DEPTH", "0") or 0)
    if env >= 1:
        return env
    return 2


def pipeline_gain_s(row_bytes: float) -> float:
    """Estimated host-prep seconds per streaming bucket that depth>=2
    prefetch can overlap with device compute: slicing + block-checksum
    traffic of one bucket at memory bandwidth (the prep is bandwidth-
    bound — two passes over the slice payload)."""
    return 2.0 * chunk_rows(row_bytes) * max(row_bytes, 1.0) / PEAK_BW


def prefetch_depth(row_bytes: float, n_chunks: int) -> int:
    """Chunk-prefetch lookahead for one streaming scope.

    An explicit ``REPRO_PIPELINE_DEPTH`` wins (capped at the chunk
    count — looking further ahead than the stream is meaningless). In
    auto mode the gate is economic: prefetch only when there is more
    than one bucket AND the overlappable host prep per bucket clears
    the control-program cost of running the worker at all
    (`PIPELINE_MIN_GAIN_S`). Depth 1 is always the fallback and means
    the exact synchronous loop.
    """
    env = int(os.environ.get("REPRO_PIPELINE_DEPTH", "0") or 0)
    if env >= 1:
        return max(1, min(env, n_chunks))
    if n_chunks < 2 or pipeline_gain_s(row_bytes) <= PIPELINE_MIN_GAIN_S:
        return 1
    return min(2, n_chunks)


# ---------------------------------------------------------------------------
# Fault policy (ISSUE 10): per-site timeouts + bounded exponential
# backoff, consumed by the runtime's federated/IO recovery ladders
# ---------------------------------------------------------------------------

# Per-site RPC timeout. Generous by default — a first call includes the
# per-site jit compile, and a clean run must never trip the ladder; the
# chaos tests force it down via env to exercise the timeout path
# against injected stragglers. In-process sites cannot be preempted, so
# the timeout binds at the attempt boundary: a late result is
# discarded, counted, and the call retried.
FED_TIMEOUT_S = 30.0

# Exponential-backoff base: sleep RETRY_BASE_S * 2^(attempt-1) before
# re-attempt k. Small — the local transport has no congestion to yield
# to; real deployments raise it via env.
RETRY_BASE_S = 0.01

# Bounded retries per site call / IO read (re-attempts after the first
# try). Exhaustion hands over to the degradation ladder.
MAX_RETRIES = 2


def fed_timeout_s() -> float:
    """Per-site RPC timeout (env ``REPRO_FED_TIMEOUT_S``), read per
    call like the pipeline knobs so one process can compare policies."""
    return float(os.environ.get("REPRO_FED_TIMEOUT_S", FED_TIMEOUT_S))


def retry_base_s() -> float:
    return float(os.environ.get("REPRO_RETRY_BASE_S", RETRY_BASE_S))


def max_retries() -> int:
    return int(os.environ.get("REPRO_MAX_RETRIES", MAX_RETRIES))


def retry_backoff_s(attempt: int) -> float:
    """Backoff before re-attempt `attempt` (1-based): exponential in
    the attempt number, bounded by the caller's `max_retries` loop."""
    return retry_base_s() * (2.0 ** max(attempt - 1, 0))


def should_chunk(n: Node) -> bool:
    """True when a leaf is worth streaming: a 2-D row-partitioned local
    leaf whose (format-aware) payload exceeds the memory budget."""
    if n.op != "input" or n.placement != "local" or len(n.shape) != 2:
        return False
    if n.attr("batch") is not None:
        return False
    rows = n.shape[0]
    payload = rows * leaf_row_bytes(n)
    return payload > CHUNK_MEM_BUDGET and rows > chunk_rows(
        leaf_row_bytes(n))


def _chunk_flops(n: Node) -> float:
    """Total flops of the underlying full-data computation of a chunk
    partial-aggregate op (work is identical to the base op — chunking
    changes residency, not arithmetic)."""
    op = n.op
    out = _numel(n.shape)
    if op in ("chunk_gram", "chunk_xtv"):
        return 2.0 * out * n.inputs[0].shape[0]
    if op in ("chunk_colsums", "chunk_sum"):
        return float(max((_numel(i.shape) for i in n.inputs), default=out))
    return node_flops(n)


def chunk_cost_s(n: Node) -> float:
    """Estimated seconds for one chunked instruction: the base-op
    roofline over the full data plus the per-chunk dispatch overhead of
    the streaming loop. `combine` is the materialization boundary — a
    light accumulator handoff."""
    if n.op == "combine":
        return LIGHT_OP_BASE_S + _dense_bytes(n) / PEAK_BW
    rows = n.inputs[0].shape[0] if n.inputs and n.inputs[0].shape else 1
    c = chunk_rows(leaf_row_bytes(n.inputs[0])) if n.inputs else 1
    n_chunks = max(-(-rows // c), 1)
    base = HEAVY_OP_BASE_S if n.op in ("chunk_gram", "chunk_xtv") \
        else LIGHT_OP_BASE_S
    return base + n_chunks * CHUNK_DISPATCH_S + max(
        _chunk_flops(n) / PEAK_FLOPS, node_bytes(n) / PEAK_BW)


def sequential_cost_s(roots_list: list[list[Node]],
                      reuse_active: bool) -> float:
    """Estimated seconds for the PR-3 sequential path over k configs.

    Walks every per-config DAG (post-rewrite, so reuse decompositions
    like the CV fold grams are visible) and sums per-node estimates,
    deduplicating across configs exactly where the sequential runtime
    would: with an active reuse cache, a repeated intermediate whose
    cost clears the probe threshold is served from the cache after its
    first computation. Value identity is the lineage hash with
    uid-based leaf identity — the same notion the cache keys on.
    """
    from .dag import _lhash_rec  # uid-keyed memo is shareable: uids are global
    seen: set[str] = set()
    memo: dict[int, str] = {}
    total = len(roots_list) * PARFOR_DISPATCH_S
    for roots in roots_list:
        order: list[Node] = []
        seen_uid: set[int] = set()

        def rec(n: Node) -> None:
            if n.uid in seen_uid:
                return
            seen_uid.add(n.uid)
            for i in n.inputs:
                rec(i)
            order.append(n)

        for r in roots:
            rec(r)
        for n in order:
            if n.op in ("input", "literal"):
                continue
            h = _lhash_rec(n, {}, memo)
            c = est_cost_s(n)
            if h in seen:
                if reuse_active and c >= PROBE_MIN_COST_S:
                    continue  # cache hit on the sequential path
            else:
                seen.add(h)
            total += c
    return total
