"""Atomic, elastic checkpointing.

Layout: <dir>/step_<n>/ containing
  manifest.json  — tree structure, leaf shapes/dtypes, step, lineage note
  shard_<i>.npz  — leaf arrays, chunked ~512 MB per file

Atomicity: written to step_<n>.tmp, fsync'd, then renamed — a crashed
writer never corrupts the latest checkpoint (restart.py relies on this).

Elasticity: leaves are stored as *full logical arrays* (gathered from
devices on save); `restore(..., shardings=...)` re-places them under any
mesh — the saved file is mesh-independent, so a 256-chip checkpoint
restores onto 512 chips (or 1 CPU) unchanged.

Lineage: the manifest carries a `lineage` blob (run id, data-pipeline
state, rng) — SystemDS §4.1 model versioning applied to training runs.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

SHARD_BYTES = 512 << 20


def _flatten(tree) -> tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any,
         lineage: Optional[dict] = None, keep_last: int = 3) -> str:
    leaves, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [],
        "lineage": lineage or {},
    }
    shard, shard_bytes, shard_id = {}, 0, 0

    def flush():
        nonlocal shard, shard_bytes, shard_id
        if shard:
            np.savez(os.path.join(tmp, f"shard_{shard_id}.npz"), **shard)
            shard, shard_bytes = {}, 0
            shard_id += 1

    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)     # gathers from devices if sharded
        manifest["leaves"].append({
            "index": i, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "shard": shard_id, "key": f"leaf_{i}"})
        shard[f"leaf_{i}"] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= SHARD_BYTES:
            flush()
    flush()
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _cleanup(ckpt_dir, keep_last)
    return final


def _cleanup(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def _list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> tuple[Any, dict]:
    """Restore into the structure of `like` (pytree of arrays or
    ShapeDtypeStructs). `shardings`: optional matching pytree of
    jax.sharding.Sharding for elastic re-placement onto a mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    _, treedef = _flatten(like)
    shard_cache: dict[int, Any] = {}
    leaves = []
    for meta in manifest["leaves"]:
        sid = meta["shard"]
        if sid not in shard_cache:
            shard_cache[sid] = np.load(
                os.path.join(path, f"shard_{sid}.npz"))
        leaves.append(shard_cache[sid][meta["key"]])
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda arr, s: jax.device_put(arr, s), tree, shardings)
    return tree, manifest
