"""End-to-end training driver.

Single-host CPU trains the reduced/small configs for real (the
examples); on a TPU mesh the same driver jits the train step with the
production shardings. Fault tolerance: atomic checkpoints + resume, and
the data pipeline's batch-at-step purity makes restarts bit-exact.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch lm-100m --steps 200
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.distributed.fault import StepMonitor
from repro.launch.steps import init_train_state, make_train_step
from repro.models import build_model
from repro.optim.schedules import warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config of the arch")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced or not hasattr(cfg, "reduced"):
        cfg = cfg.reduced() if hasattr(cfg, "reduced") else cfg
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.n_params()/1e6:.2f}M "
          f"layers={cfg.n_layers} d={cfg.d_model}")

    pipe = TokenPipeline(vocab=cfg.vocab_size, batch=args.batch,
                         seq_len=args.seq_len, seed=args.seed,
                         n_codebooks=cfg.n_codebooks)

    lr_fn = lambda step: warmup_cosine(  # noqa: E731
        step, peak_lr=args.lr, warmup_steps=args.warmup,
        total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, lr=lr_fn))

    start_step = 0
    params, opt_state = init_train_state(model, jax.random.PRNGKey(args.seed))
    if args.ckpt_dir and store.latest_step(args.ckpt_dir) is not None:
        tree, manifest = store.restore(
            args.ckpt_dir, {"params": params, "opt_state": opt_state})
        params, opt_state = tree["params"], tree["opt_state"]
        start_step = manifest["step"]
        pipe.step = start_step
        print(f"resumed from step {start_step}")

    monitor = StepMonitor()
    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.time() - t0
        monitor.record(step, dt)
        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq_len / dt
            print(f"step {step:5d} loss={metrics['loss']:.4f} "
                  f"ce={metrics['ce']:.4f} gnorm={metrics['grad_norm']:.3f} "
                  f"{dt*1e3:6.1f} ms/step {tok_s:8.0f} tok/s")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            store.save(args.ckpt_dir, step + 1,
                       {"params": params, "opt_state": opt_state},
                       lineage={"pipeline": pipe.state()})
    p50, p99 = monitor.p50_p99()
    print(f"done in {time.time()-t_start:.1f}s  p50={p50*1e3:.1f}ms "
          f"p99={p99*1e3:.1f}ms stragglers={len(monitor.incidents)}")


if __name__ == "__main__":
    main()
