"""Dispatching wrappers for the gram/tsmm kernel family.

`gram(x)` / `xtv(x, v)` pick the execution path:
  * TPU            — Pallas kernel (upper-triangle + mirror for gram)
  * CPU/GPU        — jnp fallback (XLA dot), f64-capable
  * interpret=True — Pallas kernel body interpreted on CPU (tests)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import kernel, ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _pad_to(x: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _mirror_upper(g: jnp.ndarray, bn: int) -> jnp.ndarray:
    """Combine upper-triangle block results into the full symmetric gram.

    Blocks strictly above the diagonal are computed once; their transpose
    fills the lower triangle. Diagonal blocks are complete already.
    """
    n = g.shape[0]
    bi = jnp.arange(n) // bn
    upper_strict = bi[:, None] < bi[None, :]
    # strict-lower blocks of g are zero; fill them with the upper transpose
    return g + jnp.where(upper_strict, g, 0).T


def gram(x, *, use_pallas: Optional[bool] = None, interpret: bool = False,
         bm: int = kernel.DEFAULT_BM, bn: int = kernel.DEFAULT_BN):
    """G = X^T X (f32 accumulation on the kernel path)."""
    x = jnp.asarray(x)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not (use_pallas or interpret):
        return ref.gram(x)
    n = x.shape[1]
    xp = _pad_to(x, bm, bn)
    g = kernel.gram_pallas(xp, bm=bm, bn=bn, interpret=interpret)
    g = _mirror_upper(g, bn)
    return g[:n, :n]


def xtv(x, v, *, use_pallas: Optional[bool] = None, interpret: bool = False,
        bm: int = kernel.DEFAULT_BM, bn: int = kernel.DEFAULT_BN):
    """X^T v fused (no transpose materialization on the kernel path)."""
    x = jnp.asarray(x)
    v = jnp.asarray(v)
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not (use_pallas or interpret):
        out = ref.xtv(x, v)
        return out[:, 0] if squeeze else out
    n, c = x.shape[1], v.shape[1]
    lane = 128
    xp = _pad_to(x, bm, bn)
    vp = _pad_to(v, bm, lane)
    out = kernel.xtv_pallas(xp, vp, bm=bm, bn=bn, interpret=interpret)
    out = out[:n, :c]
    return out[:, 0] if squeeze else out


def gram_aug(x, y, **kw):
    """One-pass sufficient statistics for lmDS: gram([X|y]) =
    [[X^T X, X^T y], [y^T X, y^T y]] — beyond-paper fusion (DESIGN.md §5)."""
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    if y.ndim == 1:
        y = y[:, None]
    return gram(jnp.concatenate([x, y], axis=1), **kw)
