"""LM text-generation driver: batched prefill + decode with KV caches.

This is the *token-loop* server for the transformer model zoo — an
autoregressive generate() over prefill/decode step functions. It is
NOT the lifecycle scoring subsystem: deploying a compiled
`PreparedScript` (lmDS scoring, pipelines) behind a request queue with
adaptive coalescing lives in `repro.serving.ModelServer`
(examples/serve_plan.py). The two serve different artifacts — this
module serves *models by architecture*, `repro.serving` serves
*compiled plans*.

CPU-runnable on reduced configs (examples/serve_lm.py); the step
functions are the exact ones the decode_32k / long_500k dry-run lowers
at production shapes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import build_model


def generate(model, params, prompts: np.ndarray, *, max_new: int,
             max_len: int, temperature: float = 0.0, seed: int = 0,
             image_embeds=None):
    """prompts: (B, S) int32 (or (B, S, K)). Greedy/temperature sampling."""
    cfg = model.cfg
    prefill = jax.jit(make_prefill_step(model, max_len=max_len))
    decode = jax.jit(make_decode_step(model))
    if image_embeds is not None:
        logits, caches = prefill(params, jnp.asarray(prompts),
                                 jnp.asarray(image_embeds))
    else:
        logits, caches = prefill(params, jnp.asarray(prompts))
    cur = prompts.shape[1]
    key = jax.random.PRNGKey(seed)
    out_tokens = []
    tok = None
    for i in range(max_new):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        if cfg.n_codebooks:
            tok = tok.reshape(tok.shape[0], 1, cfg.n_codebooks)
        else:
            tok = tok[:, None]
        # issue the next decode step BEFORE materializing this token on
        # the host: XLA dispatch is async, so the step-i+1 compute
        # overlaps the step-i device->host copy instead of serializing
        # behind it (the token-loop analogue of the runtime's pipelined
        # dispatch; `tok` stays a device array through the decode call)
        logits, caches = decode(params, tok, caches, jnp.int32(cur + i))
        out_tokens.append(np.asarray(tok))
    return np.concatenate(out_tokens, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    shape = (args.batch, args.prompt_len, cfg.n_codebooks) \
        if cfg.n_codebooks else (args.batch, args.prompt_len)
    prompts = rng.integers(0, cfg.vocab_size, shape).astype(np.int32)
    img = None
    if cfg.family == "vlm":
        from repro.data.frontends import vision_embeddings
        cfg2 = cfg.with_(n_image_tokens=16)
        model = build_model(cfg2)
        params = model.init(jax.random.PRNGKey(0))
        img = vision_embeddings(args.batch, 16, cfg.d_model)

    max_len = args.prompt_len + args.max_new
    t0 = time.time()
    toks = generate(model, params, prompts, max_new=args.max_new,
                    max_len=max_len, temperature=args.temperature,
                    image_embeds=img)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {toks.shape} in {dt:.2f}s "
          f"({args.batch*args.max_new/dt:.1f} tok/s)")
    print("sample:", toks[0].reshape(args.max_new, -1)[:8].tolist())


if __name__ == "__main__":
    main()
