"""Dispatching wrapper for the mamba selective-scan kernel."""
from __future__ import annotations

from typing import Optional

import jax

from . import kernel, ref


def ssm_scan(x, dt, A, B, C, D_skip, h0, *,
             use_pallas: Optional[bool] = None, interpret: bool = False,
             bd: int = kernel.DEFAULT_BD, tc: int = kernel.DEFAULT_TC):
    """See ref.ssm_scan for the contract."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not (use_pallas or interpret):
        return ref.ssm_scan(x, dt, A, B, C, D_skip, h0)
    return kernel.ssm_scan_pallas(x, dt, A, B, C, D_skip, h0,
                                  bd=bd, tc=tc, interpret=interpret)
