"""§5.2's kernel-level comparison, adapted: the gram (tsmm) hot op via
(a) XLA dense dot, (b) fused upper-triangle accounting, (c) BCOO sparse —
the SysDS / SysDS-B / sparse-kernel trio of the paper, on this host.
Also sanity-times the chunked attention / wkv / ssm model paths at smoke
scale (the TPU kernels are validated in interpret mode by tests)."""
from __future__ import annotations

import numpy as np

from .common import emit, timed


def main() -> None:
    import jax
    import jax.numpy as jnp
    from repro.kernels.gram import ref as gref
    rng = np.random.default_rng(0)

    m, n = 20000, 256
    x64 = rng.normal(size=(m, n))
    x = jnp.asarray(x64, jnp.float32)
    gram_jit = jax.jit(gref.gram)
    gram_jit(x).block_until_ready()
    t = timed(lambda: gram_jit(x).block_until_ready())
    gf = 2 * m * n * n / 1e9
    emit("gram_xla_dense_f32", t, f"gflops={gf/t:.2f}")

    tnp = timed(lambda: x64.T @ x64)
    emit("gram_numpy_blas_f64", tnp, f"gflops={2*m*n*n/1e9/tnp:.2f}")

    # sparse path (paper Fig 5b territory)
    from jax.experimental import sparse as jsparse
    xs = np.where(rng.random((m, n)) < 0.1, x64, 0.0)
    xb = jsparse.BCOO.fromdense(jnp.asarray(xs, jnp.float32))
    spmm = jax.jit(lambda a: (a.T @ jnp.asarray(xs, jnp.float32)))
    # BCOO gram: (X^T X) via sparse-dense
    def sparse_gram():
        return (xb.T @ jnp.asarray(xs, jnp.float32)).block_until_ready()
    sparse_gram()
    t = timed(sparse_gram)
    emit("gram_bcoo_sparse_0.1", t, f"dense_equiv_gflops={gf/t:.2f}")


if __name__ == "__main__":
    main()
