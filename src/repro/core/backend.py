"""Runtime operation library (the TensorBlock operation layer, §3.2/§3.3).

Every HOP is implemented as a *kernel builder*: `attrs -> fn(*inputs)`,
registered in `_KERNEL_BUILDERS`. The returned kernels are pure and
jax-traceable, so the same registry serves two execution modes:

  * standalone   — `execute_op` builds and calls one kernel eagerly
                   (the per-instruction interpreter / `fuse=False` path)
  * fused        — `repro.core.segments.build_segment_fn` chains kernels
                   into one closure per segment and hands it to
                   `jax.jit` (the segment engine)

Two physical representations are supported, mirroring SystemDS's
dense/sparse blocks:

  * dense — jnp arrays (fp64 default on the lifecycle path, like SystemDS)
  * bcoo  — jax.experimental.sparse.BCOO for 2D matrices below the shared
            density threshold (`dag.SPARSE_THRESHOLD`).

Formats are assigned at *compile time* by `repro.core.compiler
.assign_formats` (size/sparsity propagation on the HOP DAG), and kernels
are selected per (op, input formats) at build time — there are no
runtime `is_sparse` branches on the hot path, so BCOO values trace
straight through fused jit segments. Ops without a registered sparse
variant get an automatic densify boundary (`BCOO.todense` is itself a
traceable primitive). `gram`/`xtv` route through `repro.kernels.gram`
(dense Pallas on TPU) and `repro.kernels.spmm` (block-masked sparse
Pallas on TPU; BCOO math elsewhere).
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .dag import SPARSE_THRESHOLD, Node  # single source of truth

try:  # BCOO sparse support (available on CPU)
    from jax.experimental import sparse as jsparse
    HAS_SPARSE = True
except Exception:  # pragma: no cover
    jsparse = None
    HAS_SPARSE = False

# physical format names used across compiler/segments/runtime
DENSE = "dense"
BCOO = "bcoo"

# Minimum element count before a leaf is worth converting to BCOO
# (below this, conversion overhead beats any kernel savings).
SPARSE_MIN_NUMEL = 1 << 12


def is_sparse(x) -> bool:
    return HAS_SPARSE and isinstance(x, jsparse.BCOO)


def densify(x):
    return x.todense() if is_sparse(x) else x


def block_ready(x):
    """block_until_ready that also understands BCOO values."""
    buf = x.data if is_sparse(x) else x
    if hasattr(buf, "block_until_ready"):
        buf.block_until_ready()


def _bucket_nse(nse: int) -> int:
    """Round a buffer size up to its power-of-two bucket (min 256)."""
    return 256 if nse <= 256 else 1 << (nse - 1).bit_length()


def sparsify(arr):
    """Eager dense -> BCOO conversion (leaf binding on the bcoo format).

    Built host-side with numpy: `BCOO.fromdense` dispatches a chain of
    eager XLA ops (count/argwhere/gather) costing ~10 ms per bind at
    benchmark sizes — ~100x the numpy scan — and leaf conversion is on
    every call path of a prepared script.

    The nse is padded up to a power-of-two bucket with zero-valued
    duplicates of the last index. nse is part of the BCOO aval — and
    therefore of every fused executable's signature — so without
    bucketing each fresh batch (distinct nnz) would re-trace and
    recompile its segments; with it, batches of similar density share
    warm executables at the cost of ≤ 2x sparse buffer slack. Zero
    padding is exact: BCOO ops treat duplicate indices additively.
    """
    a = np.asarray(arr)
    if a.ndim != 2:  # BCOO leaves are matrices; anything else stays dense
        return jnp.asarray(a)
    rows, cols = np.nonzero(a)
    # np.nonzero is row-major: indices are sorted (and pre-padding,
    # unique) by construction, which lets sparse rules skip a sort
    indices = np.ascontiguousarray(
        np.stack([rows, cols], axis=1).astype(np.int32))
    data = a[rows, cols]
    nse = len(data)
    pad = min(_bucket_nse(nse), a.size) - nse
    if pad > 0:
        tail = indices[-1:] if nse else np.zeros((1, 2), dtype=np.int32)
        indices = np.concatenate([indices, np.repeat(tail, pad, axis=0)])
        data = np.concatenate([data, np.zeros(pad, dtype=data.dtype)])
    # unique_indices is always False so every bind in a bucket carries
    # identical pytree flags — a pad==0 bind must not fork (or collide
    # with) the executables its padded neighbours compiled
    return jsparse.BCOO((jnp.asarray(data), jnp.asarray(indices)),
                        shape=a.shape, indices_sorted=True,
                        unique_indices=False)


def maybe_sparsify(arr, sparsity_est: float):
    """Convert a 2D array to BCOO when the estimate says it pays off.

    Legacy eager heuristic — plan execution now uses the compile-time
    format assignment (`compiler.assign_formats`); this remains for
    standalone/array-level callers.
    """
    if (HAS_SPARSE and sparsity_est < SPARSE_THRESHOLD
            and getattr(arr, "ndim", 0) == 2
            and arr.size >= SPARSE_MIN_NUMEL):
        return jsparse.BCOO.fromdense(arr)
    return arr


# ---------------------------------------------------------------------------
# Compile-time format propagation (consumed by compiler.assign_formats)
# ---------------------------------------------------------------------------

# Unary ops with f(0) == 0: applying them to BCOO .data preserves the
# sparsity structure exactly. Single source for both the format rule
# (infer_format) and the sparse kernel registrations below — an op in
# one but not the other would let the compiler assign a BCOO output
# with no kernel to produce it.
_ZERO_PRESERVING_FNS = {
    "neg": jnp.negative, "abs": jnp.abs, "sqrt": jnp.sqrt,
    "sign": jnp.sign, "round": jnp.round, "floor": jnp.floor,
    "ceil": jnp.ceil,
}
ZERO_PRESERVING_UNARY = frozenset(_ZERO_PRESERVING_FNS)


def leaf_format(node: Node) -> str:
    """Physical format for an input leaf, from propagated estimates.

    Federated leaves are bound to `FederatedTensor` metadata objects,
    not arrays — they never take a local physical format. Batched
    leaves (`dag.batch_input`) bind stacked ``(k,)+shape`` arrays that
    flow through `jax.vmap` as dense values — BCOO batch axes are not
    supported on this path."""
    if node.placement != "local":
        return DENSE
    if node.attr("batch") is not None:
        return DENSE
    if (HAS_SPARSE and len(node.shape) == 2
            and node.sparsity < SPARSE_THRESHOLD
            and node.numel >= SPARSE_MIN_NUMEL):
        return BCOO
    return DENSE


def bcoo_passthrough_arg(node: Node) -> Optional[int]:
    """Index of the input whose BCOO structure passes through `node`
    unchanged, or None for dense-producing ops.

    The single definition of "structure-preserving" shared by the
    format rule (`infer_format`) and the cost model
    (`costmodel._exec_sparsity`) — one list to extend when a new sparse
    kernel is registered.
    """
    if node.op == "t" or node.op in ZERO_PRESERVING_UNARY:
        return 0
    if node.op == "mul" and len(node.inputs) == 2:
        a, b = node.inputs
        if b.shape == ():  # matrix * scalar keeps the sparse structure
            return 0
        if a.shape == ():
            return 1
    return None


def infer_format(node: Node, in_fmts: tuple[str, ...]) -> str:
    """Output format of one HOP given its input formats.

    Sparse outputs are produced only by ops that preserve the BCOO
    structure for free (see `bcoo_passthrough_arg`); everything else —
    including sparse matmul/gram/xtv, whose products are dense-ish —
    produces dense. Dense never re-sparsifies mid-plan:
    `BCOO.fromdense` inside a trace needs a static nse bound, and a
    wrong estimate would silently drop values.
    """
    if not HAS_SPARSE or BCOO not in in_fmts:
        return DENSE
    i = bcoo_passthrough_arg(node)
    if i is not None and in_fmts[i] == BCOO:
        return BCOO
    return DENSE


# ---------------------------------------------------------------------------
# op implementations
# ---------------------------------------------------------------------------

def _gram(x):
    from repro.kernels.gram import ops as gram_ops
    return gram_ops.gram(densify(x))


def _xtv(x, v):
    from repro.kernels.gram import ops as gram_ops
    return gram_ops.xtv(densify(x), densify(v))


def _matmul(a, b):
    return densify(a) @ densify(b)


def _solve(a, b):
    a = densify(a).astype(jnp.float64)
    b = densify(b).astype(jnp.float64)
    # SPD fast path (normal equations): cholesky solve, else generic
    return jax.scipy.linalg.solve(a, b, assume_a="pos") \
        if a.shape[0] == a.shape[1] else jnp.linalg.lstsq(a, b)[0]


def _slice(x, index):
    x = densify(x)
    idx = []
    for (start, stop, kind) in index:
        idx.append(start if kind == 1 else slice(start, stop))
    return x[tuple(idx)]


def _colvars(x):
    x = densify(x)
    return jnp.var(x, axis=0, keepdims=True, ddof=1)


_BINARY = {
    "add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply,
    "div": jnp.divide, "pow": jnp.power,
    "min2": jnp.minimum, "max2": jnp.maximum,
    "gt": lambda a, b: (a > b).astype(jnp.float32),
    "lt": lambda a, b: (a < b).astype(jnp.float32),
    "ge": lambda a, b: (a >= b).astype(jnp.float32),
    "le": lambda a, b: (a <= b).astype(jnp.float32),
    "eq": lambda a, b: (a == b).astype(jnp.float32),
    "ne": lambda a, b: (a != b).astype(jnp.float32),
    "and": lambda a, b: jnp.logical_and(a != 0, b != 0).astype(jnp.float32),
    "or": lambda a, b: jnp.logical_or(a != 0, b != 0).astype(jnp.float32),
}

_UNARY = {
    "neg": jnp.negative, "exp": jnp.exp, "log": jnp.log, "sqrt": jnp.sqrt,
    "abs": jnp.abs, "sign": jnp.sign, "round": jnp.round,
    "floor": jnp.floor, "ceil": jnp.ceil, "sigmoid": jax.nn.sigmoid,
    "not": lambda x: (x == 0).astype(jnp.float32),
}

_AGG = {
    "sum": jnp.sum, "mean": jnp.mean, "max": jnp.max, "min": jnp.min,
    "trace": jnp.trace,
    "nnz": lambda x: jnp.count_nonzero(x).astype(jnp.float64),
    "colSums": partial(jnp.sum, axis=0, keepdims=True),
    "rowSums": partial(jnp.sum, axis=1, keepdims=True),
    "colMeans": partial(jnp.mean, axis=0, keepdims=True),
    "rowMeans": partial(jnp.mean, axis=1, keepdims=True),
    "colMaxs": partial(jnp.max, axis=0, keepdims=True),
    "colMins": partial(jnp.min, axis=0, keepdims=True),
    "colVars": _colvars,
}


# ---------------------------------------------------------------------------
# Kernel registry: op name -> (attrs -> pure fn(*inputs))
# ---------------------------------------------------------------------------

KernelFn = Any  # Callable[..., array]

_KERNEL_BUILDERS: dict[str, Any] = {}

# Sparse kernel variants, keyed by (op, input format tuple) and mapping
# to (builder, output format). Selected at build time from the
# compile-time format assignment — every entry is a pure jit-traceable
# fn over BCOO/array operands (no eager densify). A variant is only
# picked when its output format matches the one the compiler assigned
# (e.g. `mul(bcoo, scalar)` keeps BCOO, `mul(bcoo, matrix)` falls back
# to the dense kernel through a densify boundary).
_SPARSE_KERNEL_BUILDERS: dict[tuple[str, tuple[str, ...]],
                              tuple[Any, str]] = {}

# Federated instructions (SystemDS §3.3): generated by the compiler's
# placement pass (`repro.core.compiler.lower_federated`), executed by the
# runtime's federated executor — per-site local work runs as compiled
# sub-segments through `LocalSite.execute`, only aggregates cross the
# exchange boundary. They have no entry in the kernel registry: the
# master-side orchestration (site loop + exchange metering) is host
# python, so they are non-traceable by construction.
FED_OPS: frozenset[str] = frozenset({
    "fed_gram", "fed_xtv", "fed_mv", "fed_vm", "fed_colsums", "fed_map",
})
# `collect` is the explicit, cost-modeled federation boundary: it
# materializes a federated value at the master (full partition bytes
# exchanged) so non-lowerable consumers can run locally.
COLLECT_OP = "collect"

# Sharded instructions (the paper's distributed backend as a compiler
# placement): generated by `repro.core.compiler.lower_distributed` when
# a device mesh is attached. Partial-reduction ops compute per-shard on
# the row-sharded operand and `psum` over the mesh's `data` axis;
# `reshard` is the explicit, cost-gated boundary materializing a
# row-sharded value as a replicated one (`all_gather`). They only ever
# trace inside a `jax.shard_map`-wrapped segment
# (`segments.build_sharded_segment_fn`); on hosts without enough
# devices — and on the per-instruction interpreter, which holds global
# arrays — `kernel_for_node(..., unshard=True)` swaps each for its
# local equivalent (`SHARD_BASE_OPS`), which is the 3-way parity oracle.
SHARD_REDUCE_OPS: frozenset[str] = frozenset({
    "shard_gram", "shard_xtv", "shard_colsums", "shard_sum",
})
RESHARD_OP = "reshard"
SHARD_EXEC_OPS: frozenset[str] = SHARD_REDUCE_OPS | {RESHARD_OP}
# local-equivalent op per shard op (None: identity)
SHARD_BASE_OPS: dict[str, Optional[str]] = {
    "shard_gram": "gram", "shard_xtv": "xtv",
    "shard_colsums": "colSums", "shard_sum": "sum", RESHARD_OP: None,
}

# Chunked instructions (out-of-core streaming as a compiler placement):
# generated by `repro.core.compiler.lower_chunked` when a
# row-partitionable reduction's leaves exceed `costmodel
# .CHUNK_MEM_BUDGET`. A `chunk_*` op is a *partial* aggregate — its
# kernel is exactly the base op over whatever rows it is handed, so the
# streaming runtime can run it per-chunk and sum the partials, while
# the per-instruction interpreter (which holds full arrays) gets the
# identical full aggregate from the very same kernel: parity by
# construction, no unshard-style mode flag needed. `combine` is the
# explicit materialization boundary closing the streaming scope — the
# accumulator handoff, an identity on the local path.
CHUNK_PARTIAL_OPS: frozenset[str] = frozenset({
    "chunk_gram", "chunk_xtv", "chunk_colsums", "chunk_sum",
})
COMBINE_OP = "combine"
# local/base-equivalent op per chunk op (None: identity)
CHUNK_BASE_OPS: dict[str, Optional[str]] = {
    "chunk_gram": "gram", "chunk_xtv": "xtv",
    "chunk_colsums": "colSums", "chunk_sum": "sum", COMBINE_OP: None,
}

# Ops that must never be traced into a fused jit segment (data-dependent
# python control flow, host side effects, dynamic output shapes). The
# segmenter isolates them into single-instruction segments which the
# runtime executes eagerly (host path), outside any jit trace:
#   * fed_* / collect — host-side site orchestration + exchange metering
#   * quantile — sort-based order statistics on the host (numpy
#     nanquantile), the control-program analogue of SystemDS's
#     sort-based quantiles; as a DAG node it stays inside the lineage
#     scope, so downstream reuse sees it (unlike an evaluate() round
#     trip that severs lineage mid-pipeline)
NON_TRACEABLE_OPS: frozenset[str] = FED_OPS | {COLLECT_OP, "quantile"}


def register_kernel(op: str):
    """Register `builder(attrs) -> fn(*inputs)` for an op."""
    def deco(builder):
        _KERNEL_BUILDERS[op] = builder
        return builder
    return deco


def register_sparse_kernel(op: str, in_fmts: tuple[str, ...],
                           out_fmt: str = DENSE):
    """Register a sparse variant for (op, input formats) -> out_fmt."""
    def deco(builder):
        _SPARSE_KERNEL_BUILDERS[(op, tuple(in_fmts))] = (builder, out_fmt)
        return builder
    return deco


def has_kernel(op: str) -> bool:
    return op in _KERNEL_BUILDERS


def get_kernel(op: str, attrs: dict[str, Any],
               in_fmts: Optional[tuple[str, ...]] = None,
               out_fmt: str = DENSE) -> KernelFn:
    """Build the pure kernel for one instruction.

    `attrs` is the node's attribute dict plus `_shape` (output shape) for
    generator ops; `in_fmts`/`out_fmt` are the compile-time formats from
    `compiler.assign_formats` (None ≡ all dense). When a BCOO input has a
    registered sparse variant producing the assigned output format it is
    selected here, at build time; any op without one gets the dense
    kernel, whose `densify` calls become traced `BCOO.todense`
    boundaries. The returned fn is closed over static attrs only, so it
    is safe to call standalone or inside a `jax.jit` trace.
    """
    if in_fmts and BCOO in in_fmts:
        entry = _SPARSE_KERNEL_BUILDERS.get((op, tuple(in_fmts)))
        if entry is not None and entry[1] == out_fmt:
            return entry[0](attrs)
    builder = _KERNEL_BUILDERS.get(op)
    if builder is None:
        raise NotImplementedError(f"op {op!r}")
    return builder(attrs)


def _register_table(table: dict[str, Any], arity: int) -> None:
    def make_builder(fn):
        if arity == 1:
            def build(attrs):
                return lambda x: fn(densify(x))
        else:
            def build(attrs):
                return lambda a, b: fn(densify(a), densify(b))
        return build
    for op, fn in table.items():
        _KERNEL_BUILDERS[op] = make_builder(fn)


_register_table(_BINARY, 2)
_register_table(_UNARY, 1)
_register_table(_AGG, 1)


@register_kernel("matmul")
def _build_matmul(attrs):
    return _matmul


@register_kernel("gram")
def _build_gram(attrs):
    return _gram


@register_kernel("xtv")
def _build_xtv(attrs):
    return _xtv


@register_kernel("t")
def _build_t(attrs):
    return lambda x: jnp.transpose(densify(x))


@register_kernel("solve")
def _build_solve(attrs):
    return _solve


# -- sparse (bcoo) kernel variants -------------------------------------------
# All jit-traceable: BCOO matmul/transpose and `todense` are primitives.

if HAS_SPARSE:
    @register_sparse_kernel("gram", (BCOO,))
    def _sparse_gram(attrs):
        from repro.kernels.spmm import ops as spmm_ops
        return spmm_ops.gram_bcoo

    @register_sparse_kernel("xtv", (BCOO, DENSE))
    def _sparse_xtv(attrs):
        from repro.kernels.spmm import ops as spmm_ops
        return spmm_ops.xtv_bcoo

    @register_sparse_kernel("matmul", (BCOO, DENSE))
    def _sparse_matmul(attrs):
        from repro.kernels.spmm import ops as spmm_ops
        return spmm_ops.matmul_bcoo

    # (DENSE, BCOO) needs no entry: the dense fallback's densify
    # boundary computes the identical dense @ todense(b)
    register_sparse_kernel("matmul", (BCOO, BCOO))(
        lambda attrs: (lambda a, b: a @ b.todense()))
    register_sparse_kernel("t", (BCOO,), BCOO)(
        lambda attrs: (lambda x: x.T))

    def _bcoo_map(fn):
        """Apply a zero-preserving elementwise fn to BCOO values only."""
        def run(x):
            return jsparse.BCOO((fn(x.data), x.indices), shape=x.shape,
                                indices_sorted=x.indices_sorted,
                                unique_indices=x.unique_indices)
        return run

    for _op, _fn in _ZERO_PRESERVING_FNS.items():
        register_sparse_kernel(_op, (BCOO,), BCOO)(
            (lambda fn: lambda attrs: _bcoo_map(fn))(_fn))

    # only selected when the compiler assigned a BCOO output, i.e. the
    # dense operand is a scalar (see infer_format)
    register_sparse_kernel("mul", (BCOO, DENSE), BCOO)(
        lambda attrs: (lambda x, s: _bcoo_map(lambda d: d * s)(x)))
    register_sparse_kernel("mul", (DENSE, BCOO), BCOO)(
        lambda attrs: (lambda s, x: _bcoo_map(lambda d: s * d)(x)))


# -- sharded (shard_map) kernel variants -------------------------------------
# Pure jax collectives over the mesh axis carried in the node attrs;
# valid only inside a shard_map trace (the sharded segment builder).

@register_kernel("shard_gram")
def _build_shard_gram(attrs):
    axis = attrs.get("axis", "data")
    return lambda x: jax.lax.psum(_gram(x), axis)


@register_kernel("shard_xtv")
def _build_shard_xtv(attrs):
    axis = attrs.get("axis", "data")
    return lambda x, v: jax.lax.psum(_xtv(x, v), axis)


@register_kernel("shard_colsums")
def _build_shard_colsums(attrs):
    axis = attrs.get("axis", "data")
    return lambda x: jax.lax.psum(
        jnp.sum(densify(x), axis=0, keepdims=True), axis)


@register_kernel("shard_sum")
def _build_shard_sum(attrs):
    axis = attrs.get("axis", "data")
    return lambda x: jax.lax.psum(jnp.sum(densify(x)), axis)


@register_kernel(RESHARD_OP)
def _build_reshard(attrs):
    axis = attrs.get("axis", "data")
    return lambda x: jax.lax.all_gather(densify(x), axis, axis=0,
                                        tiled=True)


@register_kernel("cholesky")
def _build_cholesky(attrs):
    return lambda x: jnp.linalg.cholesky(densify(x).astype(jnp.float64))


@register_kernel("inv")
def _build_inv(attrs):
    return lambda x: jnp.linalg.inv(densify(x).astype(jnp.float64))


@register_kernel("diag")
def _build_diag(attrs):
    return lambda x: jnp.diagonal(densify(x))[:, None]


@register_kernel("diagm")
def _build_diagm(attrs):
    return lambda x: jnp.diag(densify(x)[:, 0])


@register_kernel("slice")
def _build_slice(attrs):
    index = attrs["index"]
    return lambda x: _slice(x, index)


@register_kernel("reshape")
def _build_reshape(attrs):
    newshape = attrs["newshape"]
    return lambda x: jnp.reshape(densify(x), newshape)


def _build_concat(attrs):
    axis = attrs["axis"]
    return lambda *xs: jnp.concatenate([densify(x) for x in xs], axis=axis)


_KERNEL_BUILDERS["rbind"] = _build_concat
_KERNEL_BUILDERS["cbind"] = _build_concat


@register_kernel("where")
def _build_where(attrs):
    return lambda c, a, b: jnp.where(densify(c) != 0, densify(a), densify(b))


@register_kernel("replace_nan")
def _build_replace_nan(attrs):
    value = attrs["value"]
    return lambda x: jnp.nan_to_num(densify(x), nan=value)


@register_kernel("cumsum")
def _build_cumsum(attrs):
    return lambda x: jnp.cumsum(densify(x), axis=0)


@register_kernel("quantile")
def _build_quantile(attrs):
    """Host op (in NON_TRACEABLE_OPS): per-column nan-aware quantile via
    numpy's sort-based implementation — must only run on concrete
    values, which the segmenter guarantees by isolating it."""
    q = attrs["q"]

    def run(x):
        arr = np.asarray(densify(x), dtype=np.float64)
        return jnp.asarray(
            np.nanquantile(arr, q, axis=0, keepdims=True))
    return run


@register_kernel("literal")
def _build_literal(attrs):
    value = attrs["value"]
    return lambda: jnp.asarray(value)


@register_kernel("full")
def _build_full(attrs):
    shape, value = attrs.get("_shape", ()), attrs["value"]
    return lambda: jnp.full(shape, value)


@register_kernel("eye")
def _build_eye(attrs):
    n = attrs["_shape"][0]
    return lambda: jnp.eye(n)


@register_kernel("seq")
def _build_seq(attrs):
    n = attrs["_shape"][0]
    start, step = attrs["start"], attrs["step"]
    return lambda: (start + step * jnp.arange(n, dtype=jnp.float64))[:, None]


@register_kernel("rand")
def _build_rand(attrs):
    shape, seed = attrs["_shape"], attrs["seed"]
    dist = attrs.get("dist")
    sp = attrs.get("sparsity_gen", 1.0)

    def run():
        key = jax.random.PRNGKey(seed)
        if dist == "normal":
            out = jax.random.normal(key, shape, dtype=jnp.float64)
        else:
            out = jax.random.uniform(key, shape, dtype=jnp.float64)
        if sp < 1.0:
            key2 = jax.random.PRNGKey(seed + 0x9E3779B9)
            mask = jax.random.uniform(key2, shape) < sp
            out = jnp.where(mask, out, 0.0)
        return out
    return run


@lru_cache(maxsize=4096)
def _kernel_cached(op: str, attrs: tuple, shape: tuple,
                   in_fmts: Optional[tuple], out_fmt: str,
                   unshard: bool = False) -> KernelFn:
    if unshard and op in SHARD_BASE_OPS:
        base = SHARD_BASE_OPS[op]
        if base is None:  # reshard of a global array is the identity
            return lambda x: densify(x)
        op = base
    if op in CHUNK_BASE_OPS:
        # chunk partials ARE the base op over the rows they are handed
        # (full rows on the interpreter, one chunk on the streaming
        # path) — route through the base builder so sparse variants and
        # Pallas kernels apply unchanged
        base = CHUNK_BASE_OPS[op]
        if base is None:  # combine: the accumulator handoff
            return lambda x: densify(x)
        op = base
    d = dict(attrs)
    d["_shape"] = shape
    return get_kernel(op, d, in_fmts=in_fmts, out_fmt=out_fmt)


def kernel_for_node(node, in_fmts: Optional[tuple[str, ...]] = None,
                    out_fmt: str = DENSE, unshard: bool = False) -> KernelFn:
    """Memoized kernel lookup for a HOP node — kernels depend only on
    (op, attrs, shape, formats), so repeated plan executions (the
    interpreter loop, segment lowering) reuse one closure instead of
    rebuilding. `unshard=True` swaps `shard_*`/`reshard` collectives
    for their local equivalents (`SHARD_BASE_OPS`) — the interpreter
    and the no-mesh fallback hold *global* arrays, for which the
    per-shard compute + collective is exactly the base op."""
    return _kernel_cached(node.op, node.attrs, node.shape, in_fmts,
                          out_fmt, unshard)


def execute_op(op: str, attrs: dict[str, Any], inputs: list) -> Any:
    """Execute one instruction eagerly; inputs are jnp arrays (or BCOO)."""
    return get_kernel(op, attrs)(*inputs)


def to_numpy(x) -> np.ndarray:
    return np.asarray(densify(x))
