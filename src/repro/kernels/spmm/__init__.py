"""Block-sparse SpMM/gram Pallas kernels (the `bcoo` format's backend)."""
from . import kernel, ops, ref  # noqa: F401
