"""Pure-jnp oracle for the WKV6 recurrence: naive per-step scan.

  y_t = r_t^T (S_{t-1} + u ⊙ k_t v_t^T)
  S_t = diag(w_t) S_{t-1} + k_t v_t^T          (w_t = exp(logw_t))
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6(r, k, v, logw, u, state):
    """r,k,v,logw: (B, S, H, dh); u: (H, dh); state: (B, H, dh, dh).

    Returns (y (B,S,H,dh), final state). All math in f32.
    """
    f32 = jnp.float32
    B, S, H, dh = r.shape

    def step(S_c, xs):
        r_t, k_t, v_t, w_t = xs                       # (B, H, dh)
        kv = k_t[..., :, None] * v_t[..., None, :]    # (B, H, dh, dh)
        att = S_c + u[None, :, :, None].astype(f32) * kv
        y = jnp.einsum("bhd,bhde->bhe", r_t, att)
        S_c = jnp.exp(w_t)[..., None] * S_c + kv
        return S_c, y

    xs = tuple(t.astype(f32).swapaxes(0, 1) for t in (r, k, v, logw))
    state, ys = jax.lax.scan(step, state.astype(f32), xs)
    return ys.swapaxes(0, 1).astype(r.dtype), state
