"""Step-function builders: train_step / prefill_step / decode_step.

These are the units the dry-run lowers and the trainers jit. Signatures
are pure (params/opt/batch in, params/opt/metrics out) so they compose
with pjit shardings directly.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import AdamWState, adamw_init, adamw_update


def make_train_step(model: Model, *, lr: float | Callable = 3e-4,
                    weight_decay: float = 0.1,
                    max_grad_norm: float = 1.0) -> Callable:
    def train_step(params, opt_state: AdamWState, batch: dict):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        step_lr = lr(opt_state.step) if callable(lr) else lr
        new_params, new_opt, om = adamw_update(
            grads, opt_state, params, lr=step_lr,
            weight_decay=weight_decay, max_grad_norm=max_grad_norm)
        out = {"loss": loss, **metrics, **om}
        return new_params, new_opt, out

    return train_step


def make_prefill_step(model: Model, *, max_len: int) -> Callable:
    def prefill_step(params, tokens, image_embeds=None):
        return model.prefill(params, tokens, max_len=max_len,
                             image_embeds=image_embeds)

    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, token, caches, cur_len):
        return model.decode_step(params, token, caches, cur_len)

    return decode_step


def init_train_state(model: Model, rng) -> tuple[Any, AdamWState]:
    params = model.init(rng)
    return params, adamw_init(params)


def train_state_shapes(model: Model) -> tuple[Any, AdamWState]:
    """ShapeDtypeStructs for (params, opt_state) — dry-run inputs."""
    return jax.eval_shape(
        lambda k: init_train_state(model, k), jax.random.PRNGKey(0))
