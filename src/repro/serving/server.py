"""ModelServer: adaptive request coalescing onto bucketed vmapped
executables.

Architecture (one `ModelServer` per deployed `PreparedScript`):

  deploy()   traces the script's serving plan once
             (`PreparedScript.prepare_batched` → `batching
             .compile_serving`), then replays zero-stacks through every
             power-of-two bucket up to `max_batch` so each vmapped
             segment executable is compiled, cached, and **pinned**
             (`jit_cache.pinning`) before the first request arrives.

  score()    validates the request against the declared arg shapes
             (`PreparedScript.validate_args`), enqueues it on a BOUNDED
             queue (backpressure: `QueueFullError` past `queue_limit`
             rather than unbounded latency), and blocks on its
             completion event.

  coalescer  a single dispatcher thread. While requests queue up it
             holds dispatch for an *adaptive* window: the cost model
             prices what one more coalesced request is worth
             (`costmodel.coalesce_wait_s` — the whole solo dispatch if
             the next padding lane is free, only the marginal vmap cost
             at a bucket boundary, nothing at `max_batch`), divides by
             the queue depth already waiting, and clamps by
             `max_wait_us` (the p99 guard). The deadline is anchored to
             the oldest queued request so arrivals can only shrink it.

  dispatch   stacks the coalesced bindings, pads to the nearest warm
             bucket, and replays through the PR-5 batched-segment
             machinery (`LineageRuntime.replay_batch`). Any jit-cache
             miss taken here after warmup is counted in
             `RuntimeStats.serving.retraces` — the deploy contract is
             that this stays 0.

Continuous rebatching (pipeline depth >= 2, see `core.costmodel
.pipeline_depth`): the coalescer splits into an ISSUE stage (the
coalescer thread itself — pops a batch, stacks its bindings) and a
COMPLETION worker (a second thread that replays the batch and delivers
futures), joined by a 1-deep handoff queue. While the worker blocks on
the device for batch N, the coalescer is already admitting arrivals
into batch N+1 and stacking it — so a sustained open-loop stream never
serializes queue-drain behind device compute. Batches coalesced while
another was in flight are counted in `RuntimeStats.pipeline.rebatches`.
All runtime execution stays on the single completion worker; the
coalescer touches only its own queue and pure-numpy stacking. At depth
1 the dispatcher replays inline — the pre-pipeline behaviour,
unchanged.

Mesh-aware degradation: a script compiled under a device mesh keeps
its sharded segment lowering; at replay the runtime swaps in the
local-equivalent (unsharded) executable whenever the mesh cannot be
realized on the serving host — same graceful fallback as PR 6, no
serving-specific handling.
"""
from __future__ import annotations

import queue as _queue
import threading
import time
from collections import deque
from typing import Any, Optional, Sequence

import numpy as np

from repro.core import costmodel, faults
from repro.core.batching import bucket_size, stack_requests
from repro.core.faults import DeadlineExceededError, ServerClosedError
from repro.core.jit_cache import get_jit_cache
from repro.core.runtime import LineageRuntime, PreparedScript


class QueueFullError(RuntimeError):
    """Backpressure: the server's bounded request queue is at
    `queue_limit`. Callers should shed load or retry with backoff —
    queueing further would trade an explicit rejection for unbounded
    tail latency."""


class ScoreFuture:
    """Handle for one in-flight request (`ModelServer.submit`). Client
    event loops keep several of these outstanding so the coalescer sees
    real concurrency without one OS thread per request."""

    __slots__ = ("arrays", "done", "_result", "error", "t_enqueue",
                 "deadline", "_server")

    def __init__(self, arrays: list[np.ndarray],
                 deadline_us: Optional[float] = None,
                 server: Optional["ModelServer"] = None):
        self.arrays = arrays
        self.done = threading.Event()
        self._result: Optional[list[np.ndarray]] = None
        self.error: Optional[BaseException] = None
        self.t_enqueue = time.monotonic()
        # absolute monotonic deadline; expired requests are shed at
        # dispatch entry (DeadlineExceededError), never mid-replay
        self.deadline = (None if deadline_us is None
                         else self.t_enqueue + float(deadline_us) * 1e-6)
        self._server = server

    def result(self, timeout: Optional[float] = None) -> list[np.ndarray]:
        """Block until the request's coalesced batch has been dispatched
        and return the per-request output list.

        Waits in short slices so a dead dispatcher surfaces as
        `ServerClosedError` instead of an infinite hang — the wait ends
        the moment the result lands either way."""
        limit = None if timeout is None else time.monotonic() + timeout
        while not self.done.is_set():
            slice_s = 0.05
            if limit is not None:
                slice_s = min(slice_s, limit - time.monotonic())
                if slice_s <= 0:
                    raise TimeoutError(
                        f"score timed out after {timeout}s")
            if self.done.wait(max(slice_s, 1e-4)):
                break
            srv = self._server
            if srv is not None and not srv._dispatcher_alive():
                raise ServerClosedError(
                    "serving dispatcher is gone (shutdown or "
                    "unrecoverable crash) — request will never be "
                    "dispatched")
        if self.error is not None:
            raise self.error
        return self._result  # type: ignore[return-value]


class ModelServer:
    """Low-latency scoring server for one `PreparedScript`.

    Thread-safe: any number of caller threads may `score()`
    concurrently; a single dispatcher thread coalesces them. Use as a
    context manager (`with ModelServer(script) as srv:`) or call
    `deploy()` / `shutdown()` explicitly.

    Parameters
    ----------
    script:       the compiled `PreparedScript` to serve.
    max_batch:    largest coalesced batch (also the largest bucket
                  warmed at deploy); rounded up to a power of two.
    max_wait_us:  hard cap on how long a queued request may be held for
                  coalescing — the p99 latency guard.
    queue_limit:  bounded-queue depth; enqueueing past it raises
                  `QueueFullError`.
    adaptive:     price the coalescing window with the cost model
                  (True) or always hold for `max_wait_us` (False).
    runtime:      override the runtime (defaults to the script's).
    """

    def __init__(self, script: PreparedScript, *, max_batch: int = 16,
                 max_wait_us: float = 2000.0, queue_limit: int = 256,
                 adaptive: bool = True,
                 runtime: Optional[LineageRuntime] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.script = script
        self.runtime = runtime or script.runtime
        self.max_batch = bucket_size(max_batch) \
            if max_batch > 1 else max_batch
        self.max_wait_s = float(max_wait_us) * 1e-6
        self.queue_limit = int(queue_limit)
        self.adaptive = bool(adaptive)
        # supervisor restart budget: crashes beyond this kill the
        # dispatcher thread (persistent poison) instead of spinning
        self.max_restarts = 64

        self._bplan = None
        self._inv_nodes: list = []
        self._var_nodes: list = []
        self._budget_s: list[float] = []   # wait budget per k (deploy)
        self._pinned_keys: set = set()
        self._queue: deque[ScoreFuture] = deque()
        self._cv = threading.Condition()
        self._busy = False          # dispatcher currently replaying
        self._force = False         # flush(): dispatch without waiting
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self._deployed = False
        self._warm_misses = 0       # jit-cache miss watermark at deploy
        # continuous rebatching (pipeline depth >= 2): issue/completion
        # split — the coalescer hands stacked batches to a single
        # completion worker through a 1-deep queue and keeps admitting
        self._pipelined = False
        self._inflight = 0          # batches issued, not yet delivered
        self._pending: Optional[_queue.Queue] = None
        self._worker: Optional[threading.Thread] = None
        # fault policy: the batch popped but not yet handed off/
        # dispatched — the supervisor fails exactly these futures when
        # the coalescer crashes in that window, then restarts in-thread
        self._popped: Optional[list[ScoreFuture]] = None

    # -- lifecycle -----------------------------------------------------
    def deploy(self) -> "ModelServer":
        """Compile the serving plan and warm every power-of-two bucket
        up to `max_batch`, pinning the executables against LRU
        eviction. All compile cost is paid here, off the request path;
        after `deploy` returns, the hot path is lookup-only."""
        if self._deployed:
            return self
        self._bplan = self.script.prepare_batched()
        plan = self._bplan.plan
        variant = self._bplan.variant_uids
        self._var_nodes = [i.node for i in plan.instructions
                           if i.out_id in variant]
        self._inv_nodes = [i.node for i in plan.instructions
                           if i.out_id not in variant]
        # price the coalescing window once per queue depth — the cost
        # model walks the instruction lists, far too slow per wakeup
        self._budget_s = [0.0] + [
            self._wait_budget_s(k) for k in range(1, self.max_batch + 1)]
        jcache = get_jit_cache()
        buckets = sorted({bucket_size(k)
                          for k in range(1, self.max_batch + 1)})
        with jcache.pinning() as touched:
            for b in buckets:
                zeros = [np.zeros((b,) + shape, dtype=dtype)
                         for shape, dtype in zip(self.script._arg_shapes,
                                                 self.script._arg_dtypes)]
                self.runtime.replay_batch(self._bplan, zeros, b)
        self._pinned_keys = set(touched)
        self._warm_misses = jcache.stats.misses
        self._stop = False
        self._pipelined = costmodel.pipeline_depth() >= 2
        if self._pipelined:
            self._pending = _queue.Queue(maxsize=1)
            self._worker = threading.Thread(
                target=self._complete_loop,
                name="repro-serving-completer", daemon=True)
            self._worker.start()
        self._thread = threading.Thread(
            target=self._run_dispatcher, name="repro-serving-coalescer",
            daemon=True)
        self._thread.start()
        self._deployed = True
        return self

    def shutdown(self) -> None:
        """Drain queued requests, stop the dispatcher, unpin the warm
        executables (they fall back under normal LRU pressure), and
        release the serving plan's placeholder leaves."""
        if not self._deployed:
            return
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._worker is not None:
            # the coalescer has drained and exited; a sentinel past any
            # still-queued batch stops the completion worker after it
            # delivers everything in flight — no dropped batches
            self._pending.put(None)
            self._worker.join()
            self._worker = None
            self._pending = None
        # a clean coalescer exit drains the queue first, so leftovers
        # exist only when the dispatcher died unrecoverably (policy
        # off) — deliver a terminal error rather than leaving waiters
        # to hang / poll out
        leftover: list[ScoreFuture] = []
        with self._cv:
            while self._queue:
                leftover.append(self._queue.popleft())
        if leftover:
            err = ServerClosedError(
                "server shut down before this request was dispatched")
            for req in leftover:
                if not req.done.is_set():
                    req.error = err
                    req.done.set()
        get_jit_cache().unpin_all(self._pinned_keys)
        self._pinned_keys = set()
        if self._bplan is not None:
            self._bplan.release_leaves()
        self._deployed = False

    def __enter__(self) -> "ModelServer":
        return self.deploy()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- request path --------------------------------------------------
    def submit(self, *arrays,
               deadline_us: Optional[float] = None) -> ScoreFuture:
        """Enqueue one request without blocking on its result.

        Validates against the declared arg shapes/dtypes, applies
        backpressure (`QueueFullError` at `queue_limit`), and returns a
        `ScoreFuture` — pipelining clients keep several outstanding so
        coalescing happens without one blocked thread per request.

        `deadline_us` sets a per-request deadline: a request still
        queued when it expires is shed at dispatch entry with
        `DeadlineExceededError` (counted in `RuntimeStats.faults.shed`)
        instead of wasting a padded lane on an answer nobody is waiting
        for. A request whose batch has reached the device always
        delivers its (possibly late) result — shed before dispatch,
        never after."""
        if not self._deployed:
            raise RuntimeError("ModelServer.submit before deploy()")
        validated = self.script.validate_args(arrays, exact_shapes=True)
        req = ScoreFuture(validated, deadline_us=deadline_us, server=self)
        log = self.runtime.stats.serving
        with self._cv:
            if len(self._queue) >= self.queue_limit:
                log.rejected += 1
                raise QueueFullError(
                    f"serving queue at limit ({self.queue_limit}); "
                    "shed load or retry with backoff")
            self._queue.append(req)
            depth = len(self._queue)
            log.queue_peak = max(log.queue_peak, depth)
            # Wake the dispatcher only where the coalescing price
            # changes: the first request (opens the window), a
            # power-of-two bucket boundary (marginal cost jumps), or a
            # full batch (dispatch now). Intermediate arrivals land in
            # free padding lanes — the pending deadline already covers
            # them, and waking a single-core dispatcher per request
            # costs more in context switches than it saves in hold time.
            if (depth == 1 or depth >= self.max_batch
                    or depth == bucket_size(depth)):
                self._cv.notify_all()
        return req

    def score(self, *arrays, timeout: Optional[float] = None,
              deadline_us: Optional[float] = None) -> list[np.ndarray]:
        """Score one request. Blocks until its coalesced batch has been
        dispatched and returns the per-request output list, bitwise
        what a solo `script(*arrays)` run computes.

        Raises `QueueFullError` when the bounded queue is at
        `queue_limit` (backpressure), `TimeoutError` when `timeout`
        seconds elapse first, `DeadlineExceededError` when
        `deadline_us` expires while still queued, and
        `ServerClosedError` when the dispatcher is gone."""
        return self.submit(*arrays,
                           deadline_us=deadline_us).result(timeout)

    def flush(self) -> None:
        """Dispatch everything queued right now — skipping any pending
        coalescing window — and block until it has completed."""
        with self._cv:
            self._force = True
            self._cv.notify_all()
            self._cv.wait_for(
                lambda: (not self._queue and not self._busy
                         and not self._inflight)
                or (self._stop and self._thread is None))

    # -- coalescer -----------------------------------------------------
    def _dispatcher_alive(self) -> bool:
        """True while the dispatch machinery can still deliver queued
        requests (`ScoreFuture.result` polls this instead of hanging
        on a dead dispatcher): the coalescer thread, plus the
        completion worker when pipelined."""
        t = self._thread
        if t is None or not t.is_alive():
            return False
        w = self._worker
        return w is None or w.is_alive()

    def _run_dispatcher(self) -> None:
        """Dispatcher thread target: `_coalesce_loop` under a
        supervisor. A coalescer crash (injected `serving_dispatch`
        faults, or a real bug in the pop→dispatch window) fails ONLY
        the batch it had popped — queued and in-flight requests are
        untouched — then restarts the loop in-thread, so repeated
        crash/recover cycles leak zero threads. With the policy off
        the error is delivered and the thread dies raw (pre-policy
        behaviour); waiters then surface `ServerClosedError` via the
        liveness poll. Restarts are capped (`max_restarts`) so a
        *persistent* poison — one that crashes every restart — kills
        the thread instead of spinning hot forever."""
        crashes = 0
        while True:
            try:
                self._coalesce_loop()
                return  # clean shutdown
            except BaseException as e:
                with self._cv:
                    batch, self._popped = self._popped, None
                    if batch:
                        # undo the pop-time state so flush()/shutdown
                        # cannot wedge on a batch that will never run
                        if self._pipelined:
                            self._inflight -= 1
                        else:
                            self._busy = False
                    self._cv.notify_all()
                for req in batch or []:
                    if not req.done.is_set():
                        req.error = e
                        req.done.set()
                crashes += 1
                if not faults.policy_enabled() or crashes > self.max_restarts:
                    raise
                flog = self.runtime.stats.faults
                if isinstance(e, faults.InjectedFault):
                    flog.injected += 1
                flog.restarts += 1

    def _wait_budget_s(self, k: int) -> float:
        """How long holding k queued requests for one more is worth."""
        if not self.adaptive:
            return self.max_wait_s
        return costmodel.coalesce_wait_s(
            self._inv_nodes, self._var_nodes, k, self.max_batch,
            self.max_wait_s)

    def _coalesce_loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait()
                if self._stop and not self._queue:
                    return
                # Adaptive hold: wait while the cost model says one
                # more coalesced request is worth it. The window is
                # anchored at whichever is later — the oldest queued
                # request or the moment this dispatcher went idle
                # (requests that queued up during the PREVIOUS dispatch
                # have aged, but dispatching on them instantly would
                # chronically under-coalesce a pipelining client) —
                # and hard-clamped to `max_wait_us` past the oldest
                # enqueue, so no request is ever *held* longer than the
                # p99 guard. Arrivals re-price the budget (gain/k
                # shrinks as k grows) but can never extend the anchor.
                idle_from = time.monotonic()
                while not self._stop and not self._force:
                    k = len(self._queue)
                    if k >= self.max_batch:
                        break
                    oldest = self._queue[0].t_enqueue
                    budget = self._budget_s[min(k, self.max_batch)]
                    deadline = min(max(oldest, idle_from) + budget,
                                   oldest + self.max_wait_s)
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cv.wait(timeout=remaining)
                batch = [self._queue.popleft()
                         for _ in range(min(len(self._queue),
                                            self.max_batch))]
                if not self._queue:
                    self._force = False
                if self._pipelined:
                    if self._inflight:
                        # coalesced while the completion worker still
                        # had a batch on the device: the continuous-
                        # rebatching overlap actually happened
                        self.runtime.stats.pipeline.rebatches += 1
                    self._inflight += 1
                else:
                    self._busy = True
                # the supervisor's responsibility window opens here:
                # these futures are off the queue but not yet owned by
                # a dispatch (which delivers errors itself)
                self._popped = batch if batch else None
            if batch:
                faults.dispatch_entry()  # injected coalescer crash
            if self._pipelined:
                # issue stage: stack batch N+1's bindings while the
                # worker replays batch N (the put blocks only when a
                # stacked batch is already waiting — at most one batch
                # is ever staged ahead of the device)
                stacked = stack_requests(
                    [r.arrays for r in batch],
                    len(self.script._arg_shapes))
                self._pending.put((batch, stacked))
                with self._cv:
                    self._popped = None  # the worker owns delivery now
            else:
                try:
                    self._dispatch(batch)
                finally:
                    with self._cv:
                        self._popped = None
                        self._busy = False
                        self._cv.notify_all()

    def _complete_loop(self) -> None:
        """Completion worker (pipeline depth >= 2): replay staged
        batches and deliver their futures. The ONLY thread that touches
        the runtime — execution stays single-threaded under
        rebatching."""
        while True:
            item = self._pending.get()
            if item is None:
                return
            batch, stacked = item
            try:
                self._dispatch(batch, stacked)
            finally:
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()

    def _dispatch(self, batch: list[ScoreFuture],
                  stacked: Optional[list[np.ndarray]] = None) -> None:
        if batch and faults.policy_enabled():
            # deadline shedding — at dispatch ENTRY only: an expired
            # request is answered DeadlineExceededError instead of
            # burning a padded lane on a result nobody waits for; once
            # the (possibly pre-stacked) batch proceeds to the device
            # every survivor delivers, late or not
            flog = self.runtime.stats.faults
            now = time.monotonic()
            live = [r for r in batch
                    if r.deadline is None or now <= r.deadline]
            if len(live) != len(batch):
                err = DeadlineExceededError(
                    "request deadline expired while queued; shed "
                    "before dispatch")
                for r in batch:
                    if r not in live and not r.done.is_set():
                        flog.shed += 1
                        r.error = err
                        r.done.set()
                batch = live
                stacked = None  # pre-stacked bindings no longer match
        k = len(batch)
        if k == 0:
            return
        jcache = get_jit_cache()
        log = self.runtime.stats.serving
        t0 = time.monotonic()
        try:
            if stacked is None:
                stacked = [np.stack([r.arrays[i] for r in batch])
                           for i in range(len(self.script._arg_shapes))]
            miss0 = jcache.stats.misses
            results = self.runtime.replay_batch(self._bplan, stacked, k)
            # the hot-path hygiene counter: any compile after deploy
            # warmup is a retrace the bucket warming should have covered
            log.retraces += jcache.stats.misses - miss0
            log.requests += k
            log.batches += 1
            log.max_coalesce = max(log.max_coalesce, k)
            log.padded += bucket_size(k) - k
            log.queue_wait_s += sum(t0 - r.t_enqueue for r in batch)
            for req, res in zip(batch, results):
                req._result = res
                req.done.set()
        except BaseException as e:  # deliver, don't kill the dispatcher
            for req in batch:
                if not req.done.is_set():
                    req.error = e
                    req.done.set()
        finally:
            dt = time.monotonic() - t0
            log.busy_s += dt
            # per-dispatch latency through the rescued straggler
            # monitor (repro.distributed.fault.StepMonitor): p50/p99
            # and median+k·MAD flags surface in stats['faults']
            self.runtime.stats.faults.record_dispatch(log.batches, dt)

    # -- introspection -------------------------------------------------
    def explain(self) -> str:
        """EXPLAIN dump of the deployed serving plan (see
        `BatchedPlan.explain`), prefixed with the warm-bucket set."""
        if self._bplan is None:
            return "ModelServer: not deployed"
        buckets = sorted({bucket_size(k)
                          for k in range(1, self.max_batch + 1)})
        head = (f"serving: max_batch={self.max_batch} "
                f"warm_buckets={buckets} "
                f"pinned={len(self._pinned_keys)} "
                f"adaptive={self.adaptive} "
                f"max_wait_us={self.max_wait_s * 1e6:.0f}")
        return head + "\n" + self._bplan.explain(
            reuse_active=self.runtime.cache is not None)
