"""repro.serving (ISSUE 7): PreparedScript bind-time validation,
jit-cache pinning under eviction pressure, the adaptive coalescer
(bitwise parity vs sequential scoring, zero hot-path retraces,
bounded-queue backpressure), and mesh-aware graceful degradation.

Parity note: the coalesced path replays through vmapped executables.
XLA-CPU's batched gemm is bitwise-identical to the unbatched kernel for
single-row contractions ((1, d) @ (d, 1) — the serving-representative
one-example-per-request shape) but may differ by one ulp for multi-row
request blocks, so the bitwise tests score feature *rows* and the
multi-row block test asserts allclose at 1e-12.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import LineageRuntime, ReuseCache, input_tensor, ops
from repro.core.batching import bucket_size
from repro.core.jit_cache import JitProgramCache, get_jit_cache
from repro.core.runtime import PreparedScript
from repro.serving import ModelServer, QueueFullError

D = 16


@pytest.fixture
def weights(rng):
    return input_tensor("srvW", rng.normal(size=(D, 1)))


def _scoring(W):
    def scoring(x):
        yhat = ops.matmul(x, W)
        return yhat, ops.sigmoid(yhat)
    return scoring


# ---------------------------------------------------------------------------
# Satellite: PreparedScript bind-time validation
# ---------------------------------------------------------------------------

class TestPreparedScriptValidation:
    def test_arg_count(self, rng, weights):
        s = PreparedScript(_scoring(weights), [(1, D)])
        with pytest.raises(ValueError, match="1 argument"):
            s(np.zeros((1, D)), np.zeros((1, D)))

    def test_rank_mismatch_rejected(self, weights):
        s = PreparedScript(_scoring(weights), [(1, D)])
        with pytest.raises(ValueError, match="bound shape"):
            s(np.zeros((D,)))

    def test_unsafe_dtype_rejected(self, weights):
        s = PreparedScript(_scoring(weights), [(1, D)])
        with pytest.raises(ValueError, match="safe-cast"):
            s(np.zeros((1, D), dtype=np.complex128))

    def test_safe_dtype_cast(self, weights):
        s = PreparedScript(_scoring(weights), [(1, D)])
        xi = np.arange(D, dtype=np.int32).reshape(1, D)
        got = s(xi)
        ref = s(xi.astype(np.float64))
        for a, b in zip(got, ref):
            assert (a == b).all()

    def test_free_axis_accepted(self, rng):
        # colSums never constrains the row axis: a (7, D) binding against
        # a declared (4, D) re-traces to the identical instruction stream
        s = PreparedScript(lambda x: ops.colSums(x), [(4, D)])
        xn = rng.normal(size=(7, D))
        got, = s(xn)
        np.testing.assert_allclose(got, xn.sum(axis=0, keepdims=True))
        # memoized verdict: second deviating call takes the fast path
        assert s._shape_verdicts[((7, D),)] is None
        got2, = s(xn)
        assert (got2 == got).all()

    def test_constrained_axis_rejected(self, rng):
        # gram(x) + eye(n) bakes n into the eye generator: the column
        # axis is constrained, so a different ncol must raise at bind
        s = PreparedScript(
            lambda x: ops.gram(x) + ops.eye(D), [(8, D)])
        with pytest.raises(ValueError, match="declared"):
            s(rng.normal(size=(8, D - 2)))
        # ...while the row axis stays free
        got, = s(rng.normal(size=(5, D)))
        assert got.shape == (D, D)

    def test_generator_row_axis_rejected(self, rng):
        # cbind(x, ones((m, 1))) bakes the row count into the ones
        # generator — the intercept column of lmDS-style scripts
        s = PreparedScript(
            lambda x: ops.cbind(x, ops.ones((6, 1))), [(6, D)])
        with pytest.raises(ValueError, match="declared"):
            s(rng.normal(size=(9, D)))

    def test_exact_shapes_mode(self, rng):
        # the serving path refuses ANY deviation (requests must stack)
        s = PreparedScript(lambda x: ops.colSums(x), [(4, D)])
        with pytest.raises(ValueError, match="bound shape"):
            s.validate_args([rng.normal(size=(7, D))], exact_shapes=True)


# ---------------------------------------------------------------------------
# Satellite: jit-cache pinning
# ---------------------------------------------------------------------------

class TestJitCachePinning:
    def _fill(self, cache, n, start=0):
        for i in range(start, start + n):
            key, exe = cache.lookup(f"k{i}", (np.float64(i),))
            if exe is None:
                cache.compile(key, lambda x: (x + 1.0,), (np.float64(i),))

    def test_pinned_survive_entry_pressure(self):
        cache = JitProgramCache(capacity=2, byte_capacity=1 << 40)
        with cache.pinning() as keys:
            self._fill(cache, 2)
        assert len(keys) == 2 and cache.stats.pinned == 2
        self._fill(cache, 4, start=2)   # 4 unpinned entries churn through
        for i in (0, 1):                # the pinned pair is untouched
            _, exe = cache.lookup(f"k{i}", (np.float64(i),))
            assert exe is not None
        assert cache.stats.evictions > 0

    def test_pinned_survive_byte_pressure(self):
        cache = JitProgramCache(capacity=64, byte_capacity=1)
        with cache.pinning():
            self._fill(cache, 2)
        self._fill(cache, 3, start=2)
        # every unpinned executable exceeds the 1-byte cap: only the
        # newest unpinned entry plus the two pinned ones survive
        assert len(cache) == 3
        for i in (0, 1):
            _, exe = cache.lookup(f"k{i}", (np.float64(i),))
            assert exe is not None

    def test_unpinned_behavior_unchanged(self):
        # no pinning => byte-for-byte the pre-pinning LRU semantics
        cache = JitProgramCache(capacity=2, byte_capacity=1 << 40)
        self._fill(cache, 3)
        assert len(cache) == 2
        assert cache.stats.evictions == 1
        _, exe = cache.lookup("k0", (np.float64(0.0),))
        assert exe is None
        assert cache.stats.pinned == 0

    def test_unpin_reapplies_caps(self):
        cache = JitProgramCache(capacity=1, byte_capacity=1 << 40)
        with cache.pinning() as keys:
            self._fill(cache, 3)
        assert len(cache) == 3          # pinned: beyond capacity, kept
        cache.unpin_all(keys)
        assert cache.stats.pinned == 0
        assert len(cache) == 1          # caps re-applied on unpin

    def test_clear_drops_pins(self):
        cache = JitProgramCache()
        with cache.pinning():
            self._fill(cache, 1)
        cache.clear()
        assert cache.stats.pinned == 0 and len(cache) == 0

    def test_pinned_surfaces_in_stats(self):
        cache = JitProgramCache()
        assert cache.stats.as_dict()["pinned"] == 0


# ---------------------------------------------------------------------------
# Tentpole: the coalescer
# ---------------------------------------------------------------------------

def _serve(script, rt, **kw):
    kw.setdefault("max_batch", 8)
    kw.setdefault("max_wait_us", 500.0)
    return ModelServer(script, runtime=rt, **kw)


class TestCoalescer:
    @pytest.mark.parametrize("k", [1, 2, 3, 5, 8])
    def test_concurrent_bitwise_parity(self, rng, weights, k):
        rt = LineageRuntime()
        script = PreparedScript(_scoring(weights), [(1, D)], runtime=rt)
        xs = [rng.normal(size=(1, D)) for _ in range(k)]
        with _serve(script, rt) as srv:
            outs = [None] * k
            ts = [threading.Thread(
                target=lambda i=i: outs.__setitem__(i, srv.score(xs[i])))
                for i in range(k)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            log = rt.stats.serving
            assert log.requests == k and log.retraces == 0
        for i in range(k):
            ref = script(xs[i])
            assert len(outs[i]) == len(ref)
            for a, b in zip(outs[i], ref):
                assert a.shape == b.shape and (a == b).all()

    def test_multirow_requests_allclose(self, rng, weights):
        # multi-row request blocks: vmapped gemm may differ by an ulp
        # from the unbatched kernel, so assert tight allclose
        rt = LineageRuntime()
        script = PreparedScript(_scoring(weights), [(4, D)], runtime=rt)
        xs = [rng.normal(size=(4, D)) for _ in range(3)]
        with _serve(script, rt) as srv:
            outs = [None] * 3
            ts = [threading.Thread(
                target=lambda i=i: outs.__setitem__(i, srv.score(xs[i])))
                for i in range(3)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        for i in range(3):
            for a, b in zip(outs[i], script(xs[i])):
                np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-14)

    def test_padding_sliced_and_counted(self, rng, weights):
        rt = LineageRuntime()
        script = PreparedScript(_scoring(weights), [(1, D)], runtime=rt)
        with _serve(script, rt, adaptive=False, max_wait_us=5e4) as srv:
            outs = [None] * 3
            ts = [threading.Thread(
                target=lambda i=i: outs.__setitem__(
                    i, srv.score(rng.normal(size=(1, D)))))
                for i in range(3)]
            for t in ts:
                t.start()
            deadline = time.monotonic() + 5.0
            while (rt.stats.serving.queue_peak < 3
                   and time.monotonic() < deadline):
                time.sleep(0.001)       # let all three enqueue
            srv.flush()
            for t in ts:
                t.join()
        log = rt.stats.serving
        assert log.batches == 1 and log.requests == 3
        assert log.padded == bucket_size(3) - 3 == 1
        for o in outs:                   # bucket lane never leaks out
            assert o[0].shape == (1, 1)

    def test_zero_retraces_after_warmup(self, rng, weights):
        rt = LineageRuntime()
        script = PreparedScript(_scoring(weights), [(1, D)], runtime=rt)
        with _serve(script, rt) as srv:
            for k in (1, 2, 3, 5, 8, 4, 7):
                xs = [rng.normal(size=(1, D)) for _ in range(k)]
                ts = [threading.Thread(target=srv.score, args=(x,))
                      for x in xs]
                for t in ts:
                    t.start()
                for t in ts:
                    t.join()
            assert rt.stats.serving.retraces == 0
            assert rt.stats.serving.requests == 30

    def test_bounded_queue_rejects(self, rng, weights):
        rt = LineageRuntime()
        script = PreparedScript(_scoring(weights), [(1, D)], runtime=rt)
        srv = ModelServer(script, runtime=rt, max_batch=4,
                          max_wait_us=10e6, queue_limit=2, adaptive=False)
        srv.deploy()
        ok, rej = [], []

        def call():
            try:
                ok.append(srv.score(rng.normal(size=(1, D))))
            except QueueFullError:
                rej.append(1)

        ts = [threading.Thread(target=call) for _ in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=0.2)
        srv.flush()
        for t in ts:
            t.join()
        srv.shutdown()
        log = rt.stats.serving
        assert log.rejected == len(rej) >= 1
        assert log.requests == len(ok) == 8 - len(rej)
        assert log.queue_peak <= 2

    def test_score_before_deploy_raises(self, weights):
        rt = LineageRuntime()
        script = PreparedScript(_scoring(weights), [(1, D)], runtime=rt)
        srv = ModelServer(script, runtime=rt)
        with pytest.raises(RuntimeError, match="deploy"):
            srv.score(np.zeros((1, D)))

    def test_invalid_request_rejected_not_fatal(self, rng, weights):
        rt = LineageRuntime()
        script = PreparedScript(_scoring(weights), [(1, D)], runtime=rt)
        with _serve(script, rt) as srv:
            with pytest.raises(ValueError, match="bound shape"):
                srv.score(np.zeros((2, D)))
            y, = srv.score(np.zeros((1, D)))[:1]  # server still healthy
            assert y.shape == (1, 1)

    def test_reuse_cache_runtime_stays_sound(self, rng, weights):
        # a reuse-enabled runtime must key probes on request content —
        # two different requests through the same server never alias
        rt = LineageRuntime(cache=ReuseCache())
        script = PreparedScript(_scoring(weights), [(1, D)], runtime=rt)
        x1, x2 = rng.normal(size=(1, D)), rng.normal(size=(1, D))
        with _serve(script, rt) as srv:
            y1 = srv.score(x1)
            y2 = srv.score(x2)
        assert not (y1[0] == y2[0]).all()
        for a, b in zip(y1, script(x1)):
            assert (a == b).all()

    def test_deploy_warms_and_pins_all_buckets(self, rng, weights):
        rt = LineageRuntime()
        script = PreparedScript(_scoring(weights), [(1, D)], runtime=rt)
        jc = get_jit_cache()
        pinned0 = jc.stats.pinned
        srv = _serve(script, rt, max_batch=16)
        srv.deploy()
        # one vmapped variant executable per power-of-two bucket
        assert jc.stats.pinned - pinned0 == len({2, 4, 8, 16})
        srv.shutdown()
        assert jc.stats.pinned == pinned0


# ---------------------------------------------------------------------------
# Mesh-aware graceful degradation
# ---------------------------------------------------------------------------

class TestMeshDegradation:
    def test_unrealizable_mesh_falls_back(self, rng):
        # compiled under a production mesh spec, served on a 1-device
        # host: the runtime swaps in local-equivalent executables (the
        # PR-6 unshard fallback) — results must match the no-mesh server
        from repro.distributed import MeshSpec, use_mesh
        assert MeshSpec(data=8).jax_mesh() is None  # CPU: 1 device
        wn = rng.normal(size=(D, 1))
        results = []
        for mesh in (None, dict(data=8)):
            W = input_tensor("mW", wn)
            rt = LineageRuntime()
            ctx = use_mesh(**mesh) if mesh else None
            if ctx:
                with ctx:
                    script = PreparedScript(_scoring(W), [(1, D)],
                                            runtime=rt)
                    srv = _serve(script, rt)
                    srv.deploy()
            else:
                script = PreparedScript(_scoring(W), [(1, D)],
                                        runtime=rt)
                srv = _serve(script, rt)
                srv.deploy()
            x = np.linspace(0.0, 1.0, D).reshape(1, D)
            results.append(srv.score(x))
            assert rt.stats.serving.retraces == 0
            srv.shutdown()
        for a, b in zip(results[0], results[1]):
            assert (a == b).all()
