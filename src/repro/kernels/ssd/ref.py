"""Pure-jnp oracle for the mamba selective scan.

  h_t = exp(dt_t ⊙ A) h_{t-1} + (dt_t x_t) B_t^T    (per channel, outer)
  y_t = h_t C_t + D x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan(x, dt, A, B, C, D_skip, h0):
    """x, dt: (Bt, S, di); A: (di, ds); B, C: (Bt, S, ds);
    h0: (Bt, di, ds). Returns (y (Bt,S,di) f32, h_final)."""
    f32 = jnp.float32
    xs = (x.astype(f32) * dt.astype(f32)).swapaxes(0, 1)
    dts = dt.astype(f32).swapaxes(0, 1)
    Bs = B.astype(f32).swapaxes(0, 1)
    Cs = C.astype(f32).swapaxes(0, 1)

    def body(h, step):
        x_t, dt_t, B_t, C_t = step
        dA = jnp.exp(dt_t[..., None] * A[None].astype(f32))
        h = dA * h + x_t[..., None] * B_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    h, ys = jax.lax.scan(body, h0.astype(f32), (xs, dts, Bs, Cs))
    y = ys.swapaxes(0, 1) + x.astype(f32) * D_skip[None, None].astype(f32)
    return y, h
