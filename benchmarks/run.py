"""Benchmark driver. One module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  fig5_hpo_baseline_*   — Fig. 5(a,b): k lmDS models, dense/sparse, no reuse
  fig5c/fig5d_*         — Fig. 5(c,d) + Fig. 6: lineage reuse speedups
  fig7_cv_*             — Fig. 7: cross-validation partial reuse
  ex2_fed_*             — §4.3 Example 2: federated MV/VM/gram + lmDS
  fed_compiled_vs_eager — ISSUE 4: federated plans through the compiler
                          (placement pass + per-site fused segments +
                          lineage reuse) vs the eager-numpy federated
                          island (BENCH_federated.json)
  gram_*                — §5.2 kernel trio (dense XLA / BLAS / sparse)
  roofline_*            — §Roofline cells from the dry-run sweep
  fused_vs_interpreted  — ISSUE 1: segment JIT engine vs per-op interpreter
                          (appends a BENCH_fusion.json trajectory entry)
  sparse_*              — ISSUE 3: sparsity-aware fused execution +
                          cost-gated reuse probes (BENCH_sparse.json)
  parfor_batched_grid   — ISSUE 5: the whole HPO grid as one vmapped
                          fused-segment stack vs the sequential-reuse
                          loop, plus federated exchange-round invariants
                          (BENCH_parfor.json)
  distributed_*         — ISSUE 6: shard_map-lowered segments on a
                          forced 8-device host mesh (data-parallel lmDS
                          + config-sharded grid) vs the local fused
                          baseline, parity asserted
                          (BENCH_distributed.json)
  serving_*             — ISSUE 7: `repro.serving.ModelServer` —
                          coalesced scoring on deploy-warmed vmap
                          buckets vs solo PreparedScript calls, plus
                          open-loop p50/p99/QPS at seeded-Poisson load
                          (BENCH_serving.json)
  streaming_*           — ISSUE 8: out-of-core chunked execution at a
                          10x-undersized memory budget (bounded
                          peak_live_bytes, one warm executable) and
                          lineage-driven incremental retrain after a
                          10% row append (BENCH_streaming.json)
  pipeline_*            — ISSUE 9: async pipelined dispatch at depth 2
                          (chunk prefetch + buffer donation + serving
                          rebatching) vs the depth-1 synchronous
                          executor, parity asserted at both lanes
                          (BENCH_pipeline.json)
  faults_*              — ISSUE 10: fault-free overhead of the fault
                          policy (asserted <= 2% vs
                          REPRO_FAULT_POLICY=off) plus seeded chaos
                          recovery — dead federated site, killed
                          prefetch worker, serving shed + supervisor
                          restart, parity asserted at 1e-12
                          (BENCH_faults.json)

Every run ends with a summary table aggregating the latest entry of all
``BENCH_*.json`` trajectories.

``--smoke`` runs the fusion + sparse + federated benchmarks at reduced
sizes (CI).
"""
import glob
import json
import os
import sys

sys.path.insert(0, "src")

BENCH_DIR = os.path.dirname(os.path.abspath(__file__))


def aggregate() -> None:
    """Print one summary row per BENCH_*.json (latest trajectory entry).

    Tolerant of missing / schema-drifted trajectories: a file that
    vanished mid-run, is not a JSON list, is empty, or whose latest
    entry is not an object gets a warning line and is skipped — a
    single stale trajectory must never crash the whole summary table.
    """
    paths = sorted(glob.glob(os.path.join(BENCH_DIR, "BENCH_*.json")))
    if not paths:
        return
    rows = []
    for path in paths:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                trajectory = json.load(f)
        except FileNotFoundError:
            print(f"!! {name}: disappeared during aggregation — skipped")
            continue
        except Exception as e:
            print(f"!! {name}: unreadable trajectory "
                  f"({type(e).__name__}: {e}) — skipped")
            continue
        if not isinstance(trajectory, list) or not trajectory:
            print(f"!! {name}: expected a non-empty JSON list of entries, "
                  f"got {type(trajectory).__name__} — skipped")
            continue
        entry = trajectory[-1]
        if not isinstance(entry, dict):
            print(f"!! {name}: latest entry is "
                  f"{type(entry).__name__}, not an object — skipped")
            continue
        try:
            metrics = "; ".join(
                f"{k.replace('_us_per_call', '')}={v}us" if
                k.endswith("_us_per_call") else f"{k}={v}"
                for k, v in entry.items()
                if k.endswith("_us_per_call") or k.endswith("speedup")
                or k == "devices"
                # serving latency/throughput columns (BENCH_serving)
                or k.endswith("_p50_us") or k.endswith("_p99_us")
                or k.endswith("_qps")
                # streaming residency columns (BENCH_streaming)
                or k.endswith("chunks") or k == "peak_live_bytes"
                # async-pipeline columns (BENCH_pipeline)
                or k == "overlap_ratio" or k == "rebatches"
                or k == "donated_buffers"
                # fault-tolerance columns (BENCH_faults)
                or k == "incidents" or k.endswith("_overhead_pct"))
            rows.append((name,
                         str(entry.get("benchmark", "?")),
                         str(entry.get("workload", ""))[:46],
                         metrics))
        except Exception as e:  # drifted field types inside the entry
            print(f"!! {name}: schema drift in latest entry "
                  f"({type(e).__name__}: {e}) — skipped")
    if not rows:
        return
    headers = ("trajectory", "benchmark", "workload", "metrics")
    widths = [max(len(headers[i]), *(len(r[i]) for r in rows))
              for i in range(3)]
    print("\n== benchmark summary (latest entry per trajectory) ==")
    print("  ".join(h.ljust(w) for h, w in zip(headers[:3], widths))
          + "  " + headers[3])
    for r in rows:
        print("  ".join(c.ljust(w) for c, w in zip(r[:3], widths))
              + "  " + r[3])


def main() -> None:
    if "--smoke" in sys.argv:
        from benchmarks import (distributed_bench, faults_bench,
                                federated_bench, fusion_bench,
                                parfor_bench, pipeline_bench,
                                serving_bench, sparse_bench,
                                streaming_bench)
        print("name,us_per_call,derived")
        fusion_bench.main(rows=500, cols=32, calls=20, repeats=2)
        sparse_bench.main(rows=512, cols=64, calls=10, repeats=2)
        # large enough that per-site gram dominates the eager baseline
        # (at toy sizes fixed plan/probe overhead hides the reuse win)
        federated_bench.main(rows=4096, cols=96, n_sites=3, repeats=3,
                             eager_layer=False)
        parfor_bench.main(rows=2048, cols=64, k=16, repeats=2,
                          fed_rows=1024, fed_cols=32)
        distributed_bench.main(rows=8192, cols=64, k=8, repeats=2)
        serving_bench.main(d=64, n=256, concurrency=8, max_batch=8,
                           rates=(500.0, 1000.0), openloop_n=120)
        streaming_bench.main(rows=16384, repeats=2, min_speedup=2.5)
        pipeline_bench.main(rows=16384, repeats=2, min_speedup=1.05,
                            d=64, rate=2600.0, openloop_n=300,
                            qps_floor=1200.0)
        faults_bench.main(n_scores=100, rows=8192, repeats=5)
        aggregate()
        return
    from benchmarks import (cv_reuse, distributed_bench, faults_bench,
                            federated_bench, fusion_bench, hpo_baseline,
                            hpo_reuse, kernel_bench, parfor_bench,
                            pipeline_bench, roofline_bench,
                            serving_bench, sparse_bench,
                            streaming_bench)
    quick = "--quick" in sys.argv
    ks = (1, 5, 10) if quick else (1, 5, 10, 20)
    print("name,us_per_call,derived")
    hpo_baseline.main(ks=ks)
    hpo_reuse.main(ks=ks)
    cv_reuse.main(folds=(4,) if quick else (4, 8))
    federated_bench.main()
    kernel_bench.main()
    roofline_bench.main()
    fusion_bench.main(calls=20 if quick else 50)
    sparse_bench.main(calls=10 if quick else 20)
    parfor_bench.main(k=8 if quick else 16, repeats=2 if quick else 3)
    distributed_bench.main(k=8 if quick else 16,
                           repeats=2 if quick else 3)
    serving_bench.main(n=256 if quick else 512,
                       openloop_n=120 if quick else 200)
    streaming_bench.main(rows=65536 if quick else 131072,
                         repeats=2 if quick else 3,
                         min_speedup=3.0 if quick else 5.0)
    pipeline_bench.main(rows=65536 if quick else 131072,
                        repeats=2 if quick else 3,
                        min_speedup=1.1 if quick else 1.15,
                        qps_floor=1800.0 if quick else 2105.0)
    faults_bench.main(rows=16384 if quick else 32768,
                      repeats=5 if quick else 8)
    aggregate()


if __name__ == "__main__":
    main()
