"""Shared transformer layers: norms, RoPE, SwiGLU, embeddings, chunked CE.

Conventions:
  * params are plain nested dicts of jnp arrays (pytrees), stored in f32;
    compute casts to cfg.dtype (bf16 on TPU).
  * activations: (B, S, D); attention heads (B, S, H, hd).
  * the output-projection / loss path is chunked over the sequence so the
    (B, S, V) logits tensor never materializes (V up to 152k).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.hints import shard_hint

Params = dict


def cdtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# -- init helpers -------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None
               ) -> jnp.ndarray:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale)


def embed_init(key, vocab: int, d: int) -> jnp.ndarray:
    return jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02


# -- RMSNorm -------------------------------------------------------------------

def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), dtype=jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(dt)


# -- RoPE ---------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    dim = x.shape[-1]
    freqs = rope_freqs(dim, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# -- SwiGLU MLP ----------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, d, d_ff),
            "w_up": dense_init(k2, d, d_ff),
            "w_down": dense_init(k3, d_ff, d)}


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    g = x @ p["w_gate"].astype(dt)
    u = x @ p["w_up"].astype(dt)
    return (jax.nn.silu(g) * u) @ p["w_down"].astype(dt)


# -- Embedding + chunked loss ---------------------------------------------------

def embedding_init(key, cfg) -> Params:
    n_books = cfg.n_codebooks or 1
    keys = jax.random.split(key, n_books + 1)
    p: Params = {}
    if n_books == 1:
        p["tok"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model)
    else:  # musicgen: one table per codebook; embeddings are summed
        p["books"] = jnp.stack([
            embed_init(keys[i], cfg.vocab_size, cfg.d_model)
            for i in range(n_books)])
    return p


def embed_tokens(p: Params, cfg, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: (B, S) or (B, S, n_books) for multi-codebook audio."""
    dt = cdtype(cfg)
    if cfg.n_codebooks:
        # (B, S, K) -> sum_k books[k][tokens[..., k]]
        outs = 0
        for k in range(cfg.n_codebooks):
            outs = outs + jnp.take(p["books"][k], tokens[..., k], axis=0)
        return outs.astype(dt)
    return jnp.take(p["tok"], tokens, axis=0).astype(dt)


def head_init(key, cfg) -> Params:
    n_books = cfg.n_codebooks or 1
    if n_books == 1:
        return {"w": dense_init(key, cfg.d_model, cfg.vocab_size, scale=0.02)}
    keys = jax.random.split(key, n_books)
    return {"w": jnp.stack([
        dense_init(keys[k], cfg.d_model, cfg.vocab_size, scale=0.02)
        for k in range(n_books)])}


def logits_last(p: Params, cfg, h_last: jnp.ndarray) -> jnp.ndarray:
    """h_last: (B, D) -> logits (B, V) (or (B, K, V) multi-codebook)."""
    dt = h_last.dtype
    if cfg.n_codebooks:
        return jnp.einsum("bd,kdv->bkv", h_last, p["w"].astype(dt))
    return h_last @ p["w"].astype(dt)


def chunked_cross_entropy(p: Params, cfg, h: jnp.ndarray,
                          labels: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token CE without materializing (B, S, V).

    h: (B, S, D) final hidden states; labels: (B, S) int32 (or
    (B, S, K) multi-codebook). Scans over sequence chunks; each chunk
    computes its logits, logsumexp, and label log-prob.
    """
    B, S, D = h.shape
    c = min(cfg.loss_chunk, S)
    assert S % c == 0, (S, c)
    w = p["w"].astype(h.dtype)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_loss(h_c, y_c):
        # checkpointed: backward recomputes the (B, c, V) logits chunk
        # instead of saving it (V up to 152k — this is what keeps the
        # loss within HBM at train_4k)
        if cfg.n_codebooks:
            logits = jnp.einsum("bsd,kdv->bskv", h_c, w)
        else:
            logits = h_c @ w                       # (B, c, V)
        logits = shard_hint(logits.astype(jnp.float32), "dp", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - ll)

    hs = h.reshape(B, S // c, c, D).swapaxes(0, 1)     # (n, B, c, D)
    if cfg.n_codebooks:
        ys = labels.reshape(B, S // c, c, cfg.n_codebooks).swapaxes(0, 1)
    else:
        ys = labels.reshape(B, S // c, c).swapaxes(0, 1)

    def body(acc, xs):
        h_c, y_c = xs
        return acc + chunk_loss(h_c, y_c), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ys))
    denom = labels.size
    return total / denom
