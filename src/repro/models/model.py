"""Unified Model: init / forward / loss / prefill / decode for every family.

Layer stacking: `first_dense_layers` run unscanned; the remaining layers
are grouped into identical *periods* (the repeating heterogeneous
super-block — 1 layer for homogeneous archs, 8 for jamba, 5 for the
vision model) and scanned with stacked parameters. `cfg.remat`
checkpoints the period body (activation rematerialization).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.hints import shard_hint

from . import blocks
from .config import ModelConfig
from .layers import (Params, cdtype, chunked_cross_entropy, embed_tokens,
                     embedding_init, head_init, logits_last, rmsnorm,
                     rmsnorm_init)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.kinds = cfg.layer_kinds()

    # ------------------------------------------------------------------
    # parameters
    # ------------------------------------------------------------------
    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        k_embed, k_head, k_prefix, k_periods = jax.random.split(rng, 4)
        params: Params = {
            "embed": embedding_init(k_embed, cfg),
            "head": head_init(k_head, cfg),
            "final_norm": rmsnorm_init(cfg.d_model),
        }
        if cfg.first_dense_layers:
            pk = jax.random.split(k_prefix, cfg.first_dense_layers)
            params["prefix"] = [
                blocks.block_init(pk[i], cfg, "attn+mlp_first")
                for i in range(cfg.first_dense_layers)]

        def init_period(key):
            ks = jax.random.split(key, len(self.kinds))
            return {f"{i}:{kind}": blocks.block_init(ks[i], cfg, kind)
                    for i, kind in enumerate(self.kinds)}

        period_keys = jax.random.split(k_periods, cfg.n_periods())
        if cfg.scan_layers:
            params["periods"] = jax.vmap(init_period)(period_keys)
        else:
            params["periods"] = [init_period(k) for k in period_keys]
        return params

    def param_shapes(self) -> Params:
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def n_params(self) -> int:
        import numpy as np
        return sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(self.param_shapes()))

    # ------------------------------------------------------------------
    # forward (train / prefill share this body)
    # ------------------------------------------------------------------
    def forward(self, params: Params, tokens: jnp.ndarray, *,
                image_embeds: Optional[jnp.ndarray] = None,
                collect_cache: bool = False):
        """Returns (h_final (B,S,D), aux_loss, caches-or-None)."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], cfg, tokens)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        aux_total = jnp.zeros((), jnp.float32)
        prefix_caches = []
        for p in params.get("prefix", []):
            x, aux, c = blocks.block_forward(
                p, cfg, "attn+mlp_first", x, positions, image_embeds,
                collect_cache=collect_cache)
            aux_total = aux_total + aux
            prefix_caches.append(c)

        def period_body(x, period_params):
            # sequence-parallel boundary: the residual stream (and thus
            # the per-layer remat checkpoint) is stored sharded on the
            # model axis — 16× less checkpointed activation memory
            x = shard_hint(x, "dp", "model", None)
            aux_p = jnp.zeros((), jnp.float32)
            caches = {}
            for i, kind in enumerate(self.kinds):
                x, aux, c = blocks.block_forward(
                    period_params[f"{i}:{kind}"], cfg, kind, x, positions,
                    image_embeds, collect_cache=collect_cache)
                aux_p = aux_p + aux
                if collect_cache:
                    caches[f"{i}:{kind}"] = c
            return x, (aux_p, caches if collect_cache else None)

        if cfg.scan_layers:
            body = period_body
            if cfg.remat:
                body = jax.checkpoint(period_body,
                                      prevent_cse=False)
            x, (auxes, caches) = jax.lax.scan(body, x, params["periods"])
            aux_total = aux_total + auxes.sum()
        else:
            caches_list = []
            for pp in params["periods"]:
                x, (aux_p, c) = period_body(x, pp)
                aux_total = aux_total + aux_p
                caches_list.append(c)
            caches = caches_list if collect_cache else None

        x = rmsnorm(params["final_norm"], x)
        all_caches = {"prefix": prefix_caches, "periods": caches} \
            if collect_cache else None
        return x, aux_total, all_caches

    # ------------------------------------------------------------------
    # losses / serving
    # ------------------------------------------------------------------
    def loss(self, params: Params, batch: dict) -> tuple[jnp.ndarray, dict]:
        """batch: {'tokens', 'labels', optional 'image_embeds'}."""
        h, aux, _ = self.forward(params, batch["tokens"],
                                 image_embeds=batch.get("image_embeds"))
        ce = chunked_cross_entropy(params["head"], self.cfg, h,
                                   batch["labels"])
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    def prefill(self, params: Params, tokens, *, max_len: int,
                image_embeds=None):
        """Process a prompt; returns (next-token logits (B,V), caches).

        Attention caches are allocated at `max_len` and filled with the
        prompt's K/V (prompt length = tokens.shape[1])."""
        cfg = self.cfg
        h, _, caches = self.forward(params, tokens,
                                    image_embeds=image_embeds,
                                    collect_cache=True)
        S = tokens.shape[1]
        caches = _pad_seq_caches(self, caches, tokens.shape[0], S, max_len)
        logits = logits_last(params["head"], cfg, h[:, -1])
        return logits, caches

    def decode_step(self, params: Params, token, caches, cur_len):
        """token: (B, 1) (or (B,1,K) audio); cur_len: () int32 current
        sequence length (number of tokens already in the cache)."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], cfg, token)
        new_prefix = []
        for p, c in zip(params.get("prefix", []), caches["prefix"]):
            x, c2 = blocks.block_decode(p, cfg, "attn+mlp_first", x, c,
                                        cur_len)
            new_prefix.append(c2)

        def period_body(x, xs):
            period_params, cache = xs
            new_cache = {}
            for i, kind in enumerate(self.kinds):
                key = f"{i}:{kind}"
                x, new_cache[key] = blocks.block_decode(
                    period_params[key], cfg, kind, x, cache[key], cur_len)
            return x, new_cache

        if cfg.scan_layers:
            x, new_period_caches = jax.lax.scan(
                period_body, x, (params["periods"], caches["periods"]))
        else:
            new_period_caches = []
            for pp, c in zip(params["periods"], caches["periods"]):
                x, c2 = period_body(x, (pp, c))
                new_period_caches.append(c2)

        x = rmsnorm(params["final_norm"], x)
        logits = logits_last(params["head"], cfg, x[:, -1])
        return logits, {"prefix": new_prefix, "periods": new_period_caches}

    # ------------------------------------------------------------------
    # cache specs (ShapeDtypeStructs — for dry-run and allocation)
    # ------------------------------------------------------------------
    def cache_shapes(self, batch: int, max_len: int):
        cfg = self.cfg
        prefix = [blocks.cache_spec(cfg, "attn+mlp_first", batch, max_len)
                  for _ in range(cfg.first_dense_layers)]
        period = {f"{i}:{kind}": blocks.cache_spec(cfg, kind, batch, max_len)
                  for i, kind in enumerate(self.kinds)}
        n = cfg.n_periods()

        def stack(s):
            return jax.ShapeDtypeStruct((n,) + s.shape, s.dtype)

        periods = jax.tree_util.tree_map(stack, period) if cfg.scan_layers \
            else [period] * n
        return {"prefix": prefix, "periods": periods}

    def init_cache(self, batch: int, max_len: int):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_shapes(batch, max_len))


def _pad_seq_caches(model: "Model", caches, batch: int, S: int,
                    max_len: int):
    """Pad seq-carrying cache leaves from S to max_len.

    The seq axis is located *exactly* by diffing the cache-shape trees at
    the two lengths (no positional heuristics — scan-stacked leaves carry
    the sequence on axis 2, unstacked on axis 1, states not at all)."""
    if max_len == S:
        return caches
    small = model.cache_shapes(batch, S)
    big = model.cache_shapes(batch, max_len)

    def pad(leaf, s_spec, b_spec):
        if not hasattr(leaf, "ndim"):
            return leaf
        pads = [(0, b - a) for a, b in zip(s_spec.shape, b_spec.shape)]
        if all(p == (0, 0) for p in pads):
            return leaf
        return jnp.pad(leaf, pads)

    return jax.tree_util.tree_map(pad, caches, small, big)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
