"""Task-parallel batched execution (SystemDS §5 `parfor`).

Takes k structurally identical HOP DAGs that differ only in scalar
literals and/or leaf bindings — a λ grid, CV fold selections, seeds —
and compiles them into ONE plan over a *template* DAG:

  * varying scalars / leaves are hoisted into batched leaves
    (`dag.batch_input`: the node keeps the per-config element shape,
    the binding is the stacked ``(k,) + shape`` array);
  * the template compiles through the ordinary stack (rewrites →
    placement → format assignment → segmentation), once, instead of k
    times;
  * instructions are split into a **config-invariant prefix** (no
    batched leaf in their transitive inputs — gram/xtv computed once
    and broadcast into the batch, subsuming the sequential path's
    reuse-probe wins by construction) and a **config-variant suffix**,
    which the runtime executes through `jax.vmap` over the batch axis
    (`LineageRuntime.evaluate_batch`);
  * the batch axis is padded up to a power-of-two *bucket* (pad rows
    repeat the last config) so a growing grid re-uses warm compiled
    executables instead of re-tracing per k.

`choose_mode` is the cost-model arbitration: vmapping k small `solve`s
amortizes k launch constants into one, but a memory-bound giant padded
to a 2× bucket (or spilling past `costmodel.VMAP_MEM_BUDGET`) loses to
the PR-3 sequential-reuse loop — the declarative contract is that the
*system* picks the parallelization, per plan.

The user-facing entry point is `repro.lifecycle.validation.parfor`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from . import costmodel
from .compiler import Plan, compile_plan
from .dag import LEAVES, LTensor, Node, batch_input, is_batched_leaf


class BatchingError(ValueError):
    """The k configuration plans cannot be merged into one template
    (structural mismatch, unstackable leaves, ...). Callers fall back
    to the sequential per-config path."""


def bucket_size(k: int) -> int:
    """Batch sizes are bucketed to powers of two (min 2) so growing
    grids hit warm executables: k=9..16 all compile for 16."""
    return 2 if k <= 2 else 1 << (k - 1).bit_length()


# ---------------------------------------------------------------------------
# Template extraction: k DAGs -> one DAG with batched leaves
# ---------------------------------------------------------------------------

def merge_roots(roots_list: Sequence[Sequence[Node]]
                ) -> tuple[list[Node], frozenset[int], int]:
    """Canonicalize k per-config root lists into one template.

    Walks the k DAGs in lockstep. Positions where all configs share a
    node (same uid) — or rebuild the same structure over shared leaves
    — stay as-is (config-invariant). Positions that differ are hoisted:

      * literals with differing values  -> batched scalar leaf
      * input leaves with differing uids -> batched leaf stacking the
        k bound arrays (shapes/dtypes must agree)

    Any other divergence (different op/attrs/shape, unstackable
    bindings such as `FederatedTensor` leaves) raises `BatchingError`.

    Returns (template_roots, batched_leaf_uids, k).
    """
    k = len(roots_list)
    if k < 2:
        raise BatchingError("batching needs >= 2 configurations")
    n_out = {len(r) for r in roots_list}
    if len(n_out) != 1:
        raise BatchingError(f"configs produce differing output counts {n_out}")

    memo: dict[tuple[int, ...], Node] = {}
    batched: set[int] = set()

    def hoist_literals(nodes: tuple[Node, ...]) -> Node:
        vals = [float(n.attr("value")) for n in nodes]
        dtype = np.result_type(*(n.dtype for n in nodes))
        leaf = batch_input(None, np.asarray(vals, dtype=dtype))
        batched.add(leaf.node.uid)
        return leaf.node

    def hoist_rand(nodes: tuple[Node, ...]) -> Node:
        """Seed grids: `rand` generators differing only in their seed
        are materialized per config (the same deterministic kernel the
        sequential path runs in-plan) and stacked into a batched leaf."""
        from . import backend
        arrays = [np.asarray(backend.kernel_for_node(n)()) for n in nodes]
        leaf = batch_input("seeds", np.stack(arrays, axis=0),
                           sparsity=max(n.sparsity for n in nodes))
        batched.add(leaf.node.uid)
        return leaf.node

    def hoist_leaves(nodes: tuple[Node, ...]) -> Node:
        arrays = []
        for n in nodes:
            v = LEAVES.values.get(n.uid)
            if v is None or not isinstance(v, np.ndarray):
                raise BatchingError(
                    f"leaf {n.attr('name')!r} has no stackable binding "
                    f"({type(v).__name__})")
            arrays.append(v)
        shapes = {a.shape for a in arrays}
        if len(shapes) != 1:
            raise BatchingError(
                f"varying leaves have differing shapes {sorted(shapes)}")
        stacked = np.stack(arrays, axis=0)
        sp = max(n.sparsity for n in nodes)
        leaf = batch_input(nodes[0].attr("name"), stacked, sparsity=sp)
        batched.add(leaf.node.uid)
        return leaf.node

    def merge(nodes: tuple[Node, ...]) -> Node:
        first = nodes[0]
        if all(n.uid == first.uid for n in nodes):
            return first  # literally shared across configs
        key = tuple(n.uid for n in nodes)
        got = memo.get(key)
        if got is not None:
            return got
        if any(n.op != first.op for n in nodes):
            raise BatchingError(
                f"structural mismatch: {sorted({n.op for n in nodes})}")
        if any(n.shape != first.shape or n.dtype != first.dtype
               for n in nodes):
            raise BatchingError(
                f"op {first.op!r} differs in shape/dtype across configs")
        if first.op == "literal":
            vals = {n.attr("value") for n in nodes}
            out = first if len(vals) == 1 else hoist_literals(nodes)
        elif first.op == "input":
            out = hoist_leaves(nodes)
        elif (first.op == "rand"
              and len({n.attr("seed") for n in nodes}) > 1
              and len({tuple(kv for kv in n.attrs if kv[0] != "seed")
                       for n in nodes}) == 1):
            # identical-seed rand nodes fall through to the generic
            # branch below and stay config-invariant
            out = hoist_rand(nodes)
        else:
            if any(n.attrs != first.attrs for n in nodes):
                raise BatchingError(
                    f"op {first.op!r} differs in attrs across configs")
            children = tuple(
                merge(tuple(n.inputs[i] for n in nodes))
                for i in range(len(first.inputs)))
            if all(c is i for c, i in zip(children, first.inputs)):
                out = first
            else:
                out = Node(op=first.op, inputs=children, attrs=first.attrs,
                           shape=first.shape, dtype=first.dtype,
                           sparsity=max(n.sparsity for n in nodes),
                           placement=first.placement)
        memo[key] = out
        return out

    template = [merge(tuple(roots[i] for roots in roots_list))
                for i in range(n_out.pop())]
    return template, frozenset(batched), k


# ---------------------------------------------------------------------------
# BatchedPlan: a Plan plus the config axis
# ---------------------------------------------------------------------------

@dataclass
class BatchedPlan:
    """A compiled template plan with its batch metadata.

    `variant_uids` marks every instruction whose transitive inputs
    reach a batched leaf — the config-variant suffix the runtime vmaps;
    everything else is the config-invariant prefix, executed exactly
    like an ordinary plan (same jit-cache keys, same reuse probes, so
    repeated grids share warm executables and cached gram/xtv with
    single-config runs).
    """

    plan: Plan
    batch: int                       # k — the true number of configs
    bucket: int                      # padded batch size (power of two)
    batched_leaf_uids: frozenset[int]
    variant_uids: frozenset[int]
    # 'vmap' | 'sequential' | 'shard' (cost-chosen); 'shard' splits the
    # bucket axis over the device mesh's `config` axis — each device
    # vmaps over bucket/c configurations (see
    # `segments.build_config_sharded_segment_fn`), degrading to plain
    # vmap at runtime when the mesh cannot be realized
    mode: str = "vmap"
    # serving plans (`compile_serving`): batched leaf uids in argument
    # order, so `LineageRuntime.replay_batch` can bind positional
    # request stacks without consulting the global leaf registry
    leaf_order: tuple = ()
    _segments: dict = field(default_factory=dict, repr=False)

    @property
    def batched_value_uids(self) -> frozenset[int]:
        """All uids carrying a leading batch axis at runtime."""
        return self.batched_leaf_uids | self.variant_uids

    def release_leaves(self) -> None:
        """Unbind the hoisted stacked arrays from the global leaf
        registry. `parfor` calls this once the plan has executed (or
        the arbitration fell back to sequential): the (k, ...) stacks
        are parfor-internal temporaries, and leaving one per call in
        `LEAVES` would grow resident memory without bound across a
        long session. After release the plan cannot be re-executed."""
        from .dag import LEAVES
        for uid in self.batched_leaf_uids | set(self.leaf_order):
            LEAVES.values.pop(uid, None)
            LEAVES.lineage.pop(uid, None)

    def segments_for(self, reuse_active: bool):
        """Variance-aware segmentation (memoized): segment boundaries
        additionally break where config-invariant flips to
        config-variant, so the prefix compiles to ordinary executables
        and the suffix to vmapped ones."""
        reuse_active = bool(reuse_active)
        got = self._segments.get(reuse_active)
        if got is None:
            from .segments import segment_plan
            got = segment_plan(self.plan, reuse_active=reuse_active,
                               variant_uids=self.variant_uids)
            self._segments[reuse_active] = got
        return got

    def explain(self, reuse_active: Optional[bool] = None,
                sparse: bool = False) -> str:
        """EXPLAIN dump mirroring `Plan.explain`, annotated with the
        batch structure: hoisted batched leaves, `[config-invariant]`
        prefix segments, and `[batch=k]` vmapped segments."""
        plan = self.plan
        if reuse_active is None:
            reuse_active = plan.reuse_enabled
        fmts = plan.formats_for(sparse)
        lines = [f"batched plan: k={self.batch} bucket={self.bucket} "
                 f"mode={self.mode}"]
        listed: set[int] = set()
        for ins in plan.instructions:
            for inp in ins.node.inputs:
                if inp.uid in self.batched_leaf_uids \
                        and inp.uid not in listed:
                    listed.add(inp.uid)
                    tag = " [hoisted scalar]" if inp.shape == () else ""
                    lines.append(
                        f"%{inp.uid} = batched-leaf '{inp.attr('name')}' "
                        f"k={inp.attr('batch')} elem={inp.shape}{tag}")
        for seg in self.segments_for(reuse_active):
            outs = ",".join(f"%{u}" for u in seg.output_uids)
            kind = "fused" if len(seg.instructions) > 1 else "single"
            tag = (f"[batch={self.batch}]" if seg.variant
                   else "[config-invariant]")
            lines.append(
                f"-- segment {seg.index} [{seg.target}] {kind} "
                f"{len(seg.instructions)} op(s) {tag} "
                f"key={seg.key[:10]} -> {outs}")
            lines.extend(f"  {plan._ins_line(ins, reuse_active, fmts)}"
                         for ins in seg.instructions)
        lines.append("outputs: "
                     + ", ".join(f"%{i}" for i in plan.output_ids))
        return "\n".join(lines)


def _variant_uids(plan: Plan) -> frozenset[int]:
    """Forward pass: an instruction is config-variant iff any transitive
    input is a batched leaf."""
    variant: set[int] = set()
    for ins in plan.instructions:
        for uid, inp in zip(ins.input_ids, ins.node.inputs):
            if uid in variant or is_batched_leaf(inp):
                variant.add(ins.out_id)
                break
    return frozenset(variant)


def compile_batched(config_outputs: Sequence[Sequence[LTensor]], *,
                    reuse_enabled: bool = False,
                    opt_level: int = 2) -> BatchedPlan:
    """Compile k per-config output lists into one `BatchedPlan`.

    Raises `BatchingError` when the configs cannot be merged; callers
    (see `lifecycle.validation.parfor`) fall back to the sequential
    per-config loop.
    """
    roots_list = [[o.node for o in outs] for outs in config_outputs]
    template, batched_uids, k = merge_roots(roots_list)
    plan = compile_plan([LTensor(r) for r in template],
                        reuse_enabled=reuse_enabled, opt_level=opt_level)
    return _finalize_bplan(plan, k)


def _finalize_bplan(plan: Plan, k: int) -> BatchedPlan:
    """Wrap a compiled template plan into a `BatchedPlan`.

    Rewrites rebuild nodes but never fold batched leaves (they are
    inputs, not literals) — recompute the reachable batched set and
    variance on the final instruction stream; a batched leaf that is
    itself a plan root (identity configs) has no consuming instruction
    but still carries the batch axis."""
    live_batched = set()
    for ins in plan.instructions:
        for inp in ins.node.inputs:
            if is_batched_leaf(inp):
                live_batched.add(inp.uid)
    for r in plan.roots:
        if is_batched_leaf(r):
            live_batched.add(r.uid)
    return BatchedPlan(plan=plan, batch=k, bucket=bucket_size(k),
                       batched_leaf_uids=frozenset(live_batched),
                       variant_uids=_variant_uids(plan))


def compile_serving(fn, arg_shapes: Sequence[tuple], arg_dtypes=None,
                    arg_sparsities=None, *, reuse_enabled: bool = False,
                    opt_level: int = 2) -> BatchedPlan:
    """Compile a scoring function once into a *serving* `BatchedPlan`.

    The serving counterpart of `compile_batched`, without the
    `merge_roots` pass: every request executes the SAME plan with
    different leaf bindings, so there is nothing to merge — `fn` is
    traced directly over batched placeholder leaves (`dag.batch_input`
    with a ``(1,) + shape`` zero stack, element shape = the declared
    per-request shape). Every transitive consumer of a request leaf is
    request-variant and lowers to vmapped segments; anything else
    (model weights, folded constants) is the request-invariant prefix.

    The returned plan carries `leaf_order` (batched-leaf uid per
    argument position) and is replayed at ANY batch size through
    `LineageRuntime.replay_batch`: batch k and its power-of-two bucket
    are call-time properties — the segment set is k-independent and
    executables re-specialize per bucket via the jit cache's concrete
    argument signature, which is exactly what lets a server warm every
    bucket at deploy time and replay live traffic with zero retraces.

    Placeholder stacks are zeros, so `arg_sparsities` defaults to dense
    (1.0) like `PreparedScript` — formats are declared, not guessed.
    """
    dtypes = list(arg_dtypes) if arg_dtypes is not None \
        else [np.float64] * len(arg_shapes)
    sps = list(arg_sparsities) if arg_sparsities is not None \
        else [1.0] * len(arg_shapes)
    leaves = [
        batch_input(f"req{i}", np.zeros((1,) + tuple(s), dtype=d),
                    sparsity=sp)
        for i, (s, d, sp) in enumerate(zip(arg_shapes, dtypes, sps,
                                           strict=True))]
    outs = fn(*leaves)
    if isinstance(outs, LTensor):
        outs = [outs]
    plan = compile_plan(list(outs), reuse_enabled=reuse_enabled,
                        opt_level=opt_level)
    bplan = _finalize_bplan(plan, 1)
    bplan.bucket = bucket_size(1)
    bplan.leaf_order = tuple(leaf.node.uid for leaf in leaves)
    return bplan


# ---------------------------------------------------------------------------
# Cost-model arbitration: vmapped batch vs sequential-reuse loop
# ---------------------------------------------------------------------------

# fed_* instructions (and the collect boundary) with a batched
# execution path in the runtime: batched local operands travel as one
# stacked exchange per site instead of k round trips, and batched
# fed_map outputs carry the stacked (k, rows_i, c) site layout that the
# other instructions' vmapped site work consumes.
BATCHABLE_FED_OPS = frozenset({"fed_mv", "fed_xtv", "fed_vm", "fed_map",
                               "fed_gram", "fed_colsums", "collect"})


def choose_mode(bplan: BatchedPlan,
                roots_list: Sequence[Sequence[Node]],
                reuse_active: bool,
                sparse_inputs: bool = False) -> str:
    """Pick 'vmap', 'shard', or 'sequential' for a batched plan.

    Feasibility gates first (no vmap path exists):
      * a config-variant federated/host instruction outside the
        batchable set;
      * a BCOO format assigned to a config-variant value (sparse batch
        axes are unsupported — the invariant prefix may stay sparse).

    Then the cost gate: estimated vmapped cost (launch constants paid
    once, roofline work × bucket, padding waste included) vs the
    sequential-reuse loop (per-config dispatch overhead, cross-config
    cache hits deduplicated). A memory guard rejects suffixes whose
    bucket-replicated intermediates overflow `VMAP_MEM_BUDGET`.

    When the plan was compiled against a mesh whose `config` axis has
    c > 1 devices and the bucket divides evenly, a third option enters
    the arbitration: shard the bucket axis over `config` — each device
    pays the per-config roofline for bucket/c configs plus a dispatch
    constant (`costmodel.config_shard_cost_s`). It wins exactly when
    k × the padded per-config cost exceeds the single-device vmap cost
    by more than the extra launch overhead.
    """
    plan = bplan.plan
    variant = bplan.variant_uids
    if not variant:
        return "sequential"  # nothing varies — plain loop, full reuse
    var_ins = [i for i in plan.instructions if i.out_id in variant]
    inv_ins = [i for i in plan.instructions if i.out_id not in variant]
    for ins in var_ins:
        op = ins.node.op
        if (op.startswith("fed_") or op == "collect") \
                and op not in BATCHABLE_FED_OPS:
            return "sequential"
    fmts = plan.formats_for(sparse_inputs)
    if any(u in fmts for u in bplan.batched_value_uids):
        return "sequential"
    var_bytes = sum(ins.node.est_bytes() for ins in var_ins)
    if bplan.bucket * var_bytes > costmodel.VMAP_MEM_BUDGET:
        return "sequential"
    inv_nodes = [i.node for i in inv_ins]
    var_nodes = [i.node for i in var_ins]
    bat = costmodel.batched_cost_s(inv_nodes, var_nodes, bplan.bucket)
    seq = costmodel.sequential_cost_s(list(roots_list), reuse_active)
    ms = getattr(plan, "mesh_spec", None)
    c = int(getattr(ms, "config", 1) or 1) if ms is not None else 1
    sh = (costmodel.config_shard_cost_s(inv_nodes, var_nodes,
                                        bplan.bucket, c)
          if c > 1 and bplan.bucket % c == 0 else float("inf"))
    if seq < min(bat, sh):
        return "sequential"
    return "shard" if sh < bat else "vmap"


def pad_batch(arr: np.ndarray, bucket: int) -> np.ndarray:
    """Pad a stacked (k, ...) array to (bucket, ...) by repeating the
    last configuration — numerically safe for every kernel (duplicate
    λ solves, duplicate folds) and sliced off before results surface."""
    k = arr.shape[0]
    if k >= bucket:
        return arr
    pad = np.repeat(arr[-1:], bucket - k, axis=0)
    return np.concatenate([arr, pad], axis=0)


def stack_requests(request_arg_lists: Sequence[Sequence[np.ndarray]],
                   n_args: int) -> list[np.ndarray]:
    """Stack k per-request argument lists into the one-array-per-arg
    layout `LineageRuntime.replay_batch` consumes.

    Split out of `ModelServer._dispatch` so the serving pipeline's
    issue stage can prep batch N+1's host-side stacking while batch N
    is still in flight on the completion worker (continuous
    rebatching)."""
    return [np.stack([reqs[i] for reqs in request_arg_lists])
            for i in range(n_args)]
