from .adamw import AdamWState, adamw_init, adamw_update  # noqa: F401
from .schedules import warmup_cosine  # noqa: F401
