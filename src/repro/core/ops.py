"""Functional surface of the declarative DSL (DML-flavoured builtins).

These functions build HOP DAG nodes; nothing executes until
`repro.core.runtime.evaluate` is called.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .dag import LTensor, as_ltensor, batch_input, make_node

__all__ = [
    "t", "matmul", "gram", "xtv", "rbind", "cbind", "solve", "cholesky",
    "inv", "diag", "diag_matrix", "sum_", "mean_", "min_", "max_", "trace",
    "colSums", "rowSums", "colMeans", "rowMeans", "colVars", "colMaxs",
    "colMins", "nnz", "exp", "log", "sqrt", "abs_", "sign", "sigmoid",
    "round_", "minimum", "maximum", "where", "ones", "zeros", "full", "eye",
    "rand", "seq", "replace_nan", "cumsum", "quantile", "batch_input",
]

# `batch_input` (re-exported from `dag`) is the §5 task-parallel config
# axis: a leaf whose node has the per-config element shape while the
# binding is the stacked (k, ...) array — the batching pass
# (`repro.core.batching`) hoists varying literals/leaves into these,
# and `LineageRuntime.evaluate_batch` vmaps their consumers.


# -- structural -------------------------------------------------------------

def t(x: LTensor) -> LTensor:
    return as_ltensor(x).T


def matmul(a: LTensor, b: LTensor) -> LTensor:
    return as_ltensor(a) @ as_ltensor(b)


def gram(x: LTensor) -> LTensor:
    """tsmm: X^T X — SystemDS's dedicated fused operator (maps to the Pallas
    `gram` kernel on TPU)."""
    x = as_ltensor(x)
    n = x.shape[1]
    s = min(max(x.node.sparsity, 0.0), 1.0)
    base = min(max(1.0 - s * s, 0.0), 1.0)
    sp = min(1.0, max(1e-6, 1.0 - base ** min(x.shape[0], 1024)))
    return LTensor(make_node("gram", (x.node,), (n, n), x.dtype, sp))


def xtv(x: LTensor, v: LTensor) -> LTensor:
    """Fused X^T v (MV over the transpose without materializing t(X))."""
    x, v = as_ltensor(x), as_ltensor(v)
    assert x.shape[0] == v.shape[0], (x.shape, v.shape)
    shape = (x.shape[1],) + v.shape[1:]
    return LTensor(make_node("xtv", (x.node, v.node), shape,
                             np.result_type(x.dtype, v.dtype), 1.0))


def _concat(xs: Sequence[LTensor], axis: int, op: str) -> LTensor:
    xs = [as_ltensor(x) for x in xs]
    if len(xs) == 1:
        return xs[0]
    base = list(xs[0].shape)
    tot = 0
    for x in xs:
        for ax in range(len(base)):
            if ax != axis and x.shape[ax] != base[ax]:
                raise ValueError(f"{op}: shape mismatch {x.shape} vs {base}")
        tot += x.shape[axis]
    base[axis] = tot
    sp = float(np.average([x.node.sparsity for x in xs],
                          weights=[x.node.numel or 1 for x in xs]))
    dtype = np.result_type(*[x.dtype for x in xs])
    return LTensor(make_node(op, tuple(x.node for x in xs), tuple(base),
                             dtype, sp, axis=axis))


def rbind(*xs) -> LTensor:
    if len(xs) == 1 and isinstance(xs[0], (list, tuple)):
        xs = tuple(xs[0])
    return _concat(xs, 0, "rbind")


def cbind(*xs) -> LTensor:
    if len(xs) == 1 and isinstance(xs[0], (list, tuple)):
        xs = tuple(xs[0])
    return _concat(xs, 1, "cbind")


# -- linear solvers ----------------------------------------------------------

def solve(a: LTensor, b: LTensor) -> LTensor:
    a, b = as_ltensor(a), as_ltensor(b)
    assert a.shape[0] == a.shape[1] == b.shape[0]
    return LTensor(make_node("solve", (a.node, b.node), b.shape,
                             np.result_type(a.dtype, b.dtype, np.float64), 1.0))


def cholesky(a: LTensor) -> LTensor:
    a = as_ltensor(a)
    return LTensor(make_node("cholesky", (a.node,), a.shape, a.dtype, 0.5))


def inv(a: LTensor) -> LTensor:
    a = as_ltensor(a)
    return LTensor(make_node("inv", (a.node,), a.shape, a.dtype, 1.0))


def diag(x: LTensor) -> LTensor:
    """Extract diagonal of a matrix as a column vector."""
    x = as_ltensor(x)
    n = min(x.shape)
    return LTensor(make_node("diag", (x.node,), (n, 1), x.dtype, 1.0))


def diag_matrix(v: LTensor) -> LTensor:
    """Column vector -> diagonal matrix."""
    v = as_ltensor(v)
    n = v.shape[0]
    return LTensor(make_node("diagm", (v.node,), (n, n), v.dtype,
                             max(1.0 / n, 1e-6)))


# -- aggregates ---------------------------------------------------------------

def _agg(x, op, shape, keep_sparsity=False):
    x = as_ltensor(x)
    sp = x.node.sparsity if keep_sparsity else 1.0
    dtype = x.dtype if np.issubdtype(x.dtype, np.floating) else np.float64
    return LTensor(make_node(op, (x.node,), shape, dtype, sp))


def sum_(x): return _agg(x, "sum", ())
def mean_(x): return _agg(x, "mean", ())
def min_(x): return _agg(x, "min", ())
def max_(x): return _agg(x, "max", ())
def trace(x): return _agg(x, "trace", ())
def nnz(x): return _agg(x, "nnz", ())


def colSums(x):
    x = as_ltensor(x)
    return _agg(x, "colSums", (1, x.shape[1]))


def rowSums(x):
    x = as_ltensor(x)
    return _agg(x, "rowSums", (x.shape[0], 1))


def colMeans(x):
    x = as_ltensor(x)
    return _agg(x, "colMeans", (1, x.shape[1]))


def rowMeans(x):
    x = as_ltensor(x)
    return _agg(x, "rowMeans", (x.shape[0], 1))


def colVars(x):
    x = as_ltensor(x)
    return _agg(x, "colVars", (1, x.shape[1]))


def colMaxs(x):
    x = as_ltensor(x)
    return _agg(x, "colMaxs", (1, x.shape[1]))


def colMins(x):
    x = as_ltensor(x)
    return _agg(x, "colMins", (1, x.shape[1]))


def cumsum(x):
    x = as_ltensor(x)
    return LTensor(make_node("cumsum", (x.node,), x.shape, x.dtype, 1.0))


def quantile(x, q: float):
    """Per-column nan-aware quantile as a *host-op node* (SystemDS runs
    sort-based order statistics in the control program).

    Unlike an `evaluate()` round trip, this keeps quantile-based
    cleaning (impute_by_median, outlier_by_iqr, winsorize) inside one
    plan: lineage is preserved through the quantile, so downstream
    reuse sees the whole pipeline. The op is in
    `backend.NON_TRACEABLE_OPS` — the segmenter isolates it and the
    runtime executes it eagerly on the host, outside any jit trace.
    """
    x = as_ltensor(x)
    if x.ndim != 2:
        raise ValueError(f"quantile requires a matrix, got shape {x.shape}")
    if not 0.0 <= float(q) <= 1.0:
        raise ValueError(f"quantile q must be in [0, 1], got {q}")
    return LTensor(make_node("quantile", (x.node,), (1, x.shape[1]),
                             np.float64, 1.0, q=float(q)))


# -- elementwise ---------------------------------------------------------------

def _unary(x, op, sparsity_preserving=False):
    x = as_ltensor(x)
    sp = x.node.sparsity if sparsity_preserving else 1.0
    return LTensor(make_node(op, (x.node,), x.shape, x.dtype, sp))


def exp(x): return _unary(x, "exp")
def log(x): return _unary(x, "log")
def sqrt(x): return _unary(x, "sqrt", True)
def abs_(x): return _unary(x, "abs", True)
def sign(x): return _unary(x, "sign", True)
def sigmoid(x): return _unary(x, "sigmoid")
def round_(x): return _unary(x, "round", True)


def minimum(a, b):
    return as_ltensor(a)._bin(b, "min2")


def maximum(a, b):
    return as_ltensor(a)._bin(b, "max2")


def where(cond: LTensor, a, b) -> LTensor:
    cond = as_ltensor(cond)
    a, b = as_ltensor(a, like=cond), as_ltensor(b, like=cond)
    shape = np.broadcast_shapes(cond.shape, a.shape, b.shape)
    dtype = np.result_type(a.dtype, b.dtype)
    return LTensor(make_node("where", (cond.node, a.node, b.node),
                             tuple(shape), dtype, 1.0))


def replace_nan(x: LTensor, value: float) -> LTensor:
    x = as_ltensor(x)
    return LTensor(make_node("replace_nan", (x.node,), x.shape, x.dtype, 1.0,
                             value=float(value)))


# -- generators ------------------------------------------------------------------

def full(shape, value, dtype=np.float64) -> LTensor:
    shape = tuple(int(s) for s in shape)
    return LTensor(make_node("full", (), shape, dtype,
                             0.0 if value == 0 else 1.0, value=float(value)))


def ones(shape, dtype=np.float64):
    return full(shape, 1.0, dtype)


def zeros(shape, dtype=np.float64):
    return full(shape, 0.0, dtype)


def eye(n, dtype=np.float64) -> LTensor:
    return LTensor(make_node("eye", (), (n, n), dtype, max(1.0 / n, 1e-6)))


def seq(start, stop, step=1, dtype=np.float64) -> LTensor:
    n = int(max(0, np.floor((stop - start) / step) + 1))
    return LTensor(make_node("seq", (), (n, 1), dtype, 1.0,
                             start=float(start), stop=float(stop),
                             step=float(step)))


def rand(shape, seed: int, dist: str = "uniform", sparsity: float = 1.0,
         dtype=np.float64) -> LTensor:
    """Random generator. The seed is part of the lineage (SystemDS traces
    "non-determinism like system-generated seeds")."""
    shape = tuple(int(s) for s in shape)
    return LTensor(make_node("rand", (), shape, dtype, sparsity,
                             seed=int(seed), dist=dist,
                             sparsity_gen=float(sparsity)))
