"""Dispatching wrappers for the block-sparse SpMM/gram kernel family.

These are the kernels registered behind the `bcoo` physical format in
`repro.core.backend` — every function takes/returns jax values and is
fully jit-traceable, so fused segments trace straight through them:

  * TPU            — densify to the block layout, compute the int32
                     block-nonzero map, run the Pallas kernel with the
                     map scalar-prefetched (block-level sparsity:
                     zero-block MXU work is skipped)
  * CPU/GPU        — BCOO math (sparse-dense dot_general), value-level
  * interpret=True — Pallas kernel body interpreted on CPU (tests)
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

# shared with the dense gram kernel family: backend detection, block
# padding, and the upper-triangle mirror must not drift between the
# dense and block-sparse paths
from repro.kernels.gram.ops import _mirror_upper, _on_tpu, _pad_to

from . import kernel


def block_mask(x: jnp.ndarray, bm: int, bn: int) -> jnp.ndarray:
    """Traceable int32 per-block nonzero counts of a padded dense x."""
    m, n = x.shape
    blocks = x.reshape(m // bm, bm, n // bn, bn)
    return jnp.count_nonzero(blocks, axis=(1, 3)).astype(jnp.int32)


def gram_dense_masked(xd: jnp.ndarray, *, bm: int = kernel.DEFAULT_BM,
                      bn: int = kernel.DEFAULT_BN,
                      interpret: bool = False) -> jnp.ndarray:
    """Block-masked gram over a dense-layout matrix (the TPU path)."""
    n = xd.shape[1]
    xp = _pad_to(xd, bm, bn)
    mask = block_mask(xp, bm, bn)
    g = kernel.gram_block_sparse(xp, mask, bm=bm, bn=bn,
                                 interpret=interpret)
    return _mirror_upper(g, bn)[:n, :n]


def spmm_dense_masked(xd: jnp.ndarray, w: jnp.ndarray, *,
                      bm: int = kernel.DEFAULT_BM,
                      bk: int = kernel.DEFAULT_BN,
                      interpret: bool = False) -> jnp.ndarray:
    """Block-masked X @ W over a dense-layout X (the TPU path)."""
    m, c = xd.shape[0], w.shape[1]
    lane = 128
    xp = _pad_to(xd, bm, bk)
    wp = _pad_to(w, bk, lane)
    mask = block_mask(xp, bm, bk)
    out = kernel.spmm_block_sparse(xp, wp, mask, bm=bm, bk=bk,
                                   interpret=interpret)
    return out[:m, :c]


def xtv_dense_masked(xd: jnp.ndarray, v: jnp.ndarray, *,
                     bm: int = kernel.DEFAULT_BM,
                     bn: int = kernel.DEFAULT_BN,
                     interpret: bool = False) -> jnp.ndarray:
    """Block-masked X^T v over a dense-layout X (the TPU path)."""
    n, c = xd.shape[1], v.shape[1]
    lane = 128
    xp = _pad_to(xd, bm, bn)
    vp = _pad_to(v, bm, lane)
    mask = block_mask(xp, bm, bn)
    out = kernel.xtv_block_sparse(xp, vp, mask, bm=bm, bn=bn,
                                  interpret=interpret)
    return out[:n, :c]


# -- BCOO entry points (the backend's bcoo-format kernels) -------------------

def gram_bcoo(x, *, use_pallas: Optional[bool] = None,
              interpret: bool = False):
    """G = X^T X for BCOO X."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return gram_dense_masked(x.todense(), interpret=interpret)
    # sparse-dense: flops ∝ nnz·n (sparse-sparse lowering is slow)
    return x.T @ x.todense()


def xtv_bcoo(x, v, *, use_pallas: Optional[bool] = None,
             interpret: bool = False):
    """X^T v for BCOO X, dense v."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if (use_pallas or interpret) and getattr(v, "ndim", 2) == 2:
        return xtv_dense_masked(x.todense(), v, interpret=interpret)
    return x.T @ v


def matmul_bcoo(a, b, *, use_pallas: Optional[bool] = None,
                interpret: bool = False):
    """A @ B for BCOO A, dense B."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if (use_pallas or interpret) and getattr(b, "ndim", 2) == 2:
        return spmm_dense_masked(a.todense(), b, interpret=interpret)
    return a @ b
