"""Lifecycle builtins: regression, validation, cleaning, algorithms."""
import numpy as np
import pytest

from repro.core import LineageRuntime, ReuseCache, input_tensor, ops
from repro.lifecycle import (cross_validate_lm, grid_search_lm,
                             impute_by_mean, impute_by_median, kmeans,
                             l2svm, lm, lmCG, lmDS, mice_lite, mlogreg,
                             outlier_by_iqr, outlier_by_sd, pca,
                             scale_matrix, steplm, winsorize)
from repro.lifecycle.validation import make_folds


@pytest.fixture
def reg_data(rng):
    n, d = 400, 10
    x = rng.normal(size=(n, d))
    beta = rng.normal(size=(d, 1))
    y = x @ beta + 0.01 * rng.normal(size=(n, 1))
    return x, y, beta


class TestRegression:
    def test_lmds_matches_numpy(self, reg_data):
        x, y, _ = reg_data
        b = lmDS(input_tensor("X", x), input_tensor("y", y), reg=1e-6)
        ref = np.linalg.solve(x.T @ x + 1e-6 * np.eye(10), x.T @ y)
        np.testing.assert_allclose(b, ref, rtol=1e-6, atol=1e-8)

    def test_lmcg_matches_lmds(self, reg_data):
        x, y, _ = reg_data
        X, Y = input_tensor("X", x), input_tensor("y", y)
        np.testing.assert_allclose(lmCG(X, Y, reg=1e-3),
                                   lmDS(X, Y, reg=1e-3),
                                   rtol=1e-4, atol=1e-6)

    def test_lm_dispatch(self, reg_data):
        x, y, _ = reg_data
        b = lm(input_tensor("X", x), input_tensor("y", y))
        assert b.shape == (10, 1)

    def test_intercept(self, reg_data):
        x, y, _ = reg_data
        b = lmDS(input_tensor("X", x), input_tensor("y", y),
                 intercept=True)
        assert b.shape == (11, 1)

    def test_steplm_selects_informative(self, rng):
        n = 300
        x = rng.normal(size=(n, 8))
        y = (3.0 * x[:, 2:3] - 2.0 * x[:, 5:6]
             + 0.01 * rng.normal(size=(n, 1)))
        beta, sel = steplm(input_tensor("X", x), input_tensor("y", y))
        assert set(sel[:2]) == {2, 5}

    def test_steplm_reuse_saves_work(self, rng):
        # enough selected features that gram(cbind(S, c)) decomposes
        # (base >= 4 columns incl. intercept): gram(S) is computed once
        # per outer iteration and hit by every other candidate, and the
        # per-column gram(c) entries are hit across iterations. Probe
        # points are cost-gated now, so only these genuinely expensive
        # intermediates are probed — trivial slice/assembly values no
        # longer inflate the hit count.
        x = rng.normal(size=(200, 8))
        y = x @ rng.normal(size=(8, 1)) + 0.01 * rng.normal(size=(200, 1))
        rt = LineageRuntime(cache=ReuseCache())
        steplm(input_tensor("X", x), input_tensor("y", y),
               max_features=5, runtime=rt)
        assert rt.cache.stats.hits > 8


class TestValidation:
    def test_grid_search_all_lambdas_correct(self, reg_data):
        x, y, _ = reg_data
        rt = LineageRuntime(cache=ReuseCache())
        lambdas = [0.01, 0.1, 1.0, 10.0]
        betas, losses = grid_search_lm(input_tensor("X", x),
                                       input_tensor("y", y), lambdas,
                                       runtime=rt)
        for j, lam in enumerate(lambdas):
            ref = np.linalg.solve(x.T @ x + lam * np.eye(10), x.T @ y)
            np.testing.assert_allclose(betas[:, j:j + 1], ref, rtol=1e-5,
                                       atol=1e-7)
        assert losses == sorted(losses)  # more reg -> more train loss
        # auto mode batches the λ axis: gram/xtv live in the
        # config-invariant prefix (computed once by construction) and
        # the solve suffix runs as vmapped segments
        assert rt.stats.batched_segments > 0

    def test_grid_search_sequential_reuses_gram(self, reg_data):
        x, y, _ = reg_data
        rt = LineageRuntime(cache=ReuseCache())
        betas, losses = grid_search_lm(input_tensor("X", x),
                                       input_tensor("y", y),
                                       [0.01, 0.1, 1.0, 10.0],
                                       runtime=rt, mode="sequential")
        # the PR-3 path: X^T X and X^T y computed once, reused 3x each
        assert rt.cache.stats.hits >= 6
        assert rt.stats.batched_segments == 0

    def test_cv_reuse_equals_no_reuse(self, reg_data):
        x, y, _ = reg_data
        fx, fy = make_folds(x, y, 5, seed=1)
        b1, e1 = cross_validate_lm(fx, fy,
                                   runtime=LineageRuntime(
                                       cache=ReuseCache()))
        b2, e2 = cross_validate_lm(fx, fy, runtime=LineageRuntime())
        np.testing.assert_allclose(b1, b2, rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(e1, e2, rtol=1e-5)

    def test_cv_reuse_counts(self, reg_data):
        x, y, _ = reg_data
        fx, fy = make_folds(x, y, 6, seed=2)
        rt = LineageRuntime(cache=ReuseCache())
        cross_validate_lm(fx, fy, runtime=rt)
        # 6 folds: each per-fold gram/xtv computed once, hit 4 more times
        assert rt.stats.reused >= 2 * 6 * 4


class TestCleaning:
    def test_impute_by_mean(self, rng):
        x = rng.normal(size=(50, 4))
        x[5, 1] = np.nan
        x[7, 2] = np.nan
        out = impute_by_mean(input_tensor("X", x))
        assert not np.isnan(out).any()
        np.testing.assert_allclose(out[5, 1], np.nanmean(x[:, 1]),
                                   rtol=1e-9)

    def test_impute_by_median(self, rng):
        x = rng.normal(size=(50, 3))
        x[0, 0] = np.nan
        out = impute_by_median(input_tensor("X", x))
        np.testing.assert_allclose(out[0, 0], np.nanmedian(x[:, 0]))

    def test_mice_beats_mean_on_correlated(self, rng):
        n = 400
        z = rng.normal(size=(n, 1))
        x = np.hstack([z + 0.1 * rng.normal(size=(n, 1)) for _ in range(4)])
        x_miss = x.copy()
        mask = rng.random(x.shape) < 0.15
        x_miss[mask] = np.nan
        m_mean = impute_by_mean(input_tensor("Xm", x_miss))
        m_mice = mice_lite(input_tensor("Xc", x_miss), n_iter=3)
        err_mean = np.abs(m_mean - x)[mask].mean()
        err_mice = np.abs(m_mice - x)[mask].mean()
        assert err_mice < 0.7 * err_mean

    def test_outliers(self, rng):
        x = rng.normal(size=(200, 2))
        x[0, 0] = 100.0
        flagged = outlier_by_sd(input_tensor("X", x), k=4, repair="nan")
        assert np.isnan(flagged[0, 0])
        assert np.isnan(flagged).sum() <= 3
        clipped = outlier_by_iqr(input_tensor("X2", x), repair="clip")
        assert clipped[0, 0] < 100.0

    def test_winsorize_and_scale(self, rng):
        x = rng.normal(size=(300, 3))
        w = winsorize(input_tensor("X", x), 0.05, 0.95)
        assert w.max() <= np.quantile(x, 0.95, axis=0).max() + 1e-9
        s = scale_matrix(input_tensor("Xs", x))
        np.testing.assert_allclose(s.mean(axis=0), 0, atol=1e-12)
        np.testing.assert_allclose(s.std(axis=0, ddof=1), 1, rtol=1e-6)

    def test_quantile_host_op_matches_numpy(self, rng):
        x = rng.normal(size=(150, 4))
        x[3, 1] = np.nan
        q = LineageRuntime().evaluate(
            [ops.quantile(input_tensor("X", x), 0.25)])[0]
        np.testing.assert_allclose(
            q, np.nanquantile(x, 0.25, axis=0, keepdims=True))
        with pytest.raises(ValueError, match="q must be"):
            ops.quantile(input_tensor("Xq", x), 1.5)

    def test_outlier_iqr_matches_numpy_reference(self, rng):
        x = rng.normal(size=(200, 3))
        x[0, 0] = 50.0
        x[5, 2] = np.nan
        q1 = np.nanquantile(x, 0.25, axis=0, keepdims=True)
        q3 = np.nanquantile(x, 0.75, axis=0, keepdims=True)
        lo, hi = q1 - 1.5 * (q3 - q1), q3 + 1.5 * (q3 - q1)
        bad = (x < lo) | (x > hi)
        flagged = outlier_by_iqr(input_tensor("Xa", x), repair="nan")
        np.testing.assert_array_equal(np.isnan(flagged),
                                      np.isnan(x) | bad)
        clipped = outlier_by_iqr(input_tensor("Xb", x), repair="clip")
        np.testing.assert_allclose(
            clipped[~np.isnan(x)], np.clip(x, lo, hi)[~np.isnan(x)])
        flags = outlier_by_iqr(input_tensor("Xc", x), repair="flag")
        np.testing.assert_array_equal(flags != 0, bad)

    def test_cleaning_stays_in_one_plan_with_lineage(self, rng):
        """Quantiles are host-op *nodes* now: the cleaning pipelines run
        as one plan, and downstream reuse sees the quantile values
        (previously an evaluate() round trip severed lineage)."""
        x = rng.normal(size=(4000, 8))
        x[rng.random(x.shape) < 0.05] = np.nan
        X = input_tensor("XL", x)
        rt = LineageRuntime(cache=ReuseCache())
        first = winsorize(X, runtime=rt)
        probes_after_first = rt.cache.stats.probes
        assert rt.cache.stats.hits == 0
        second = winsorize(X, runtime=rt)   # identical lineage -> hits
        assert rt.cache.stats.hits > 0
        assert rt.cache.stats.probes > probes_after_first
        np.testing.assert_allclose(first, second, equal_nan=True)
        # median imputation likewise single-plan; matches the reference
        out = impute_by_median(X, runtime=rt)
        med = np.nanmedian(x, axis=0, keepdims=True)
        np.testing.assert_allclose(out, np.where(np.isnan(x), med, x))


class TestAlgorithms:
    def test_pca_matches_numpy(self, rng):
        x = rng.normal(size=(100, 6)) @ np.diag([5, 3, 1, .5, .2, .1])
        comps, proj = pca(input_tensor("X", x), k=2)
        xc = x - x.mean(0)
        _, _, vt = np.linalg.svd(xc, full_matrices=False)
        # same subspace up to sign
        overlap = np.abs(comps.T @ vt[:2].T)
        np.testing.assert_allclose(np.diag(overlap), 1.0, atol=1e-6)

    def test_kmeans_separates_clusters(self, rng):
        a = rng.normal(size=(100, 2)) + [10, 10]
        b = rng.normal(size=(100, 2)) - [10, 10]
        x = np.vstack([a, b])
        centers, assign = kmeans(input_tensor("X", x), k=2, seed=1)
        assert len(set(assign[:100])) == 1
        assert assign[0] != assign[150]

    def test_l2svm_separable(self, rng):
        x = rng.normal(size=(200, 5))
        w_true = rng.normal(size=(5, 1))
        y = np.sign(x @ w_true)
        w = l2svm(input_tensor("X", x), input_tensor("y", y), max_iter=50)
        assert (np.sign(x @ w) == y).mean() > 0.97

    def test_mlogreg_learns(self, rng):
        x = rng.normal(size=(300, 4))
        labels = (x[:, 0] > 0).astype(int)
        yoh = np.zeros((300, 2))
        yoh[np.arange(300), labels] = 1
        W = mlogreg(input_tensor("X", x), input_tensor("y", yoh),
                    max_iter=150)
        assert ((x @ W).argmax(1) == labels).mean() > 0.95
