"""Federated parameter server over pods (SystemDS §4.3 + DiLoCo-style
relaxed sync).

`FedAvgTrainer` simulates K federated sites (pods): each site runs H
local optimizer steps on its own data shard, then sites exchange
parameter deltas (optionally int8-compressed with error feedback) and
apply the average. Cross-site traffic per sync = one (compressed) param
delta instead of H gradient all-reduces — the knob that makes the pod
axis tolerant of slow inter-pod links (DCN vs ICI).

This is the host-level simulation used by tests/benchmarks; on a real
multi-pod mesh the same schedule maps to a shard_map over the `pod`
axis (params carry a leading pod dim between syncs).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamWState, adamw_init, adamw_update

from . import compress


@dataclass
class SiteState:
    params: Any
    opt_state: AdamWState
    err: Any = None            # error-feedback residual (compression)


@dataclass
class FedAvgTrainer:
    loss_fn: Callable[[Any, dict], tuple]  # (params, batch) -> (loss, aux)
    n_sites: int
    sync_every: int = 8
    lr: float = 1e-3
    compress_int8: bool = False
    sites: list[SiteState] = field(default_factory=list)
    anchor: Any = None         # last synced global params
    bytes_exchanged: int = 0
    step: int = 0

    def init(self, params: Any) -> None:
        self.anchor = params
        self.sites = [
            SiteState(params=jax.tree_util.tree_map(jnp.copy, params),
                      opt_state=adamw_init(params),
                      err=compress.init_error_state(params))
            for _ in range(self.n_sites)]
        self._grad = jax.jit(jax.value_and_grad(self.loss_fn, has_aux=True))

    def local_step(self, site: int, batch: dict) -> float:
        s = self.sites[site]
        (loss, _), grads = self._grad(s.params, batch)
        s.params, s.opt_state, _ = adamw_update(
            grads, s.opt_state, s.params, lr=self.lr, weight_decay=0.0)
        return float(loss)

    def maybe_sync(self) -> bool:
        self.step += 1
        if self.step % self.sync_every:
            return False
        # exchange deltas from the anchor (what actually crosses pods)
        deltas = []
        for s in self.sites:
            delta = jax.tree_util.tree_map(
                lambda p, a: p.astype(jnp.float32) - a.astype(jnp.float32),
                s.params, self.anchor)
            if self.compress_int8:
                q, scale, s.err = compress.compress_tree(delta, s.err)
                self.bytes_exchanged += compress.compressed_bytes(delta)[0]
                delta = jax.tree_util.tree_map(compress.dequantize, q, scale)
            else:
                self.bytes_exchanged += compress.compressed_bytes(delta)[1]
            deltas.append(delta)
        mean_delta = jax.tree_util.tree_map(
            lambda *ds: sum(ds) / len(ds), *deltas)
        self.anchor = jax.tree_util.tree_map(
            lambda a, d: (a.astype(jnp.float32) + d).astype(a.dtype),
            self.anchor, mean_delta)
        for s in self.sites:
            s.params = jax.tree_util.tree_map(jnp.copy, self.anchor)
        return True
