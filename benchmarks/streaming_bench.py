"""Out-of-core streaming execution + lineage-driven incremental recompute.

ISSUE 8: lmDS and PCA compiled through `lower_chunked` and executed by
the streaming runtime lane under a device-memory budget 10x smaller
than the input:

  * **bounded residency** — the streamed run's `peak_live_bytes` stays
    under the budget while the materialized baseline (budget lifted)
    holds the whole input; results agree to 1e-10 (lmDS vs numpy) and
    1e-8 (PCA components, sign-aligned).
  * **one warm executable** — jit-cache misses during the streamed run
    stay bounded by the segment count, never the chunk count (the
    power-of-two row bucket gives every full chunk one signature).
  * **incremental retrain** — after a warm base run, appending 10% more
    rows re-dispatches only the tail buckets (cached partials cover the
    rest); measured against a cold streamed retrain of the full
    appended matrix the delta path must be >= 5x faster.

Appends a trajectory entry to ``benchmarks/BENCH_streaming.json``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(__file__), "BENCH_streaming.json")


def _lm_ref(Xh, yh, reg=1e-3):
    return np.linalg.solve(Xh.T @ Xh + reg * np.eye(Xh.shape[1]),
                           Xh.T @ yh)


def _lm_run(rt, Xh, yh, reg=1e-3):
    from repro.core.dag import input_tensor
    from repro.lifecycle.regression import lmDS
    X = input_tensor("X", Xh)
    y = input_tensor("y", yh)
    return np.asarray(lmDS(X, y, reg=reg, runtime=rt)).ravel()


def _align_signs(a, b):
    s = np.sign(np.sum(a * b, axis=0))
    s[s == 0] = 1.0
    return b * s


def main(rows: int = 131072, cols: int = 256, budget_ratio: int = 10,
         repeats: int = 3, min_speedup: float = 5.0) -> dict:
    from repro.core import costmodel
    from repro.core.jit_cache import get_jit_cache
    from repro.core.reuse import ReuseCache
    from repro.core.runtime import LineageRuntime
    from repro.lifecycle.algorithms import pca

    rng = np.random.default_rng(8)
    Xh = rng.normal(size=(rows, cols))
    yh = rng.normal(size=(rows,))
    budget = int(Xh.nbytes // budget_ratio)
    saved = costmodel.CHUNK_MEM_BUDGET
    jstats = get_jit_cache().stats
    try:
        costmodel.CHUNK_MEM_BUDGET = budget

        # ---- streamed lmDS under the tight budget ----
        # warmup run compiles the per-bucket executables; the timed run
        # then measures steady-state streaming (matching the medianed
        # materialized baseline below, whose first repeat compiles)
        miss0 = jstats.misses
        _lm_run(LineageRuntime(cache=None, fuse=True), Xh, yh)
        rt = LineageRuntime(cache=None, fuse=True)
        t0 = time.perf_counter()
        got = _lm_run(rt, Xh, yh)
        t_stream = time.perf_counter() - t0
        s = rt.stats.streaming
        err = float(np.abs(got - _lm_ref(Xh, yh).ravel()).max())
        assert err < 1e-10, f"streamed lmDS err {err:.2e}"
        assert s.chunks > 1 and 0 < s.peak_live_bytes <= budget, \
            f"live set {s.peak_live_bytes} exceeds budget {budget}"
        retraces = (jstats.misses - miss0) - rt.stats.segments
        assert retraces <= 0, f"{retraces} chunk-level retraces"
        chunks = s.chunks

        # streamed PCA parity on the same matrix
        prt = LineageRuntime(cache=ReuseCache(), fuse=True)
        comps_s, _ = pca(_as_leaf(Xh), 3, runtime=prt)
        assert prt.stats.streaming.chunks > 1

        # ---- materialized baseline (budget lifted) ----
        costmodel.CHUNK_MEM_BUDGET = 1 << 62
        ts = []
        for _ in range(repeats):
            mrt = LineageRuntime(cache=None, fuse=True)
            t0 = time.perf_counter()
            got_m = _lm_run(mrt, Xh, yh)
            ts.append(time.perf_counter() - t0)
            assert mrt.stats.streaming.total == 0
        t_mat = float(np.median(ts))
        assert np.abs(got - got_m).max() < 1e-10
        mrt = LineageRuntime(cache=ReuseCache(), fuse=True)
        comps_m, _ = pca(_as_leaf(Xh), 3, runtime=mrt)
        pca_err = float(np.abs(np.asarray(comps_s)
                               - _align_signs(np.asarray(comps_s),
                                              np.asarray(comps_m))).max())
        assert pca_err < 1e-8, f"streamed PCA err {pca_err:.2e}"

        # ---- append-10% incremental retrain vs cold streamed retrain ----
        costmodel.CHUNK_MEM_BUDGET = budget
        extra = rows // 10
        # warm the appended-shape executables (the ragged tail bucket
        # compiles once per shape) so neither timed path pays compile
        wrng = np.random.default_rng(99)
        _lm_run(LineageRuntime(cache=None, fuse=True),
                np.vstack([Xh, wrng.normal(size=(extra, cols))]),
                np.concatenate([yh, wrng.normal(size=(extra,))]))
        t_cold, t_inc, new_chunks, reused_chunks = [], [], 0, 0
        for r in range(repeats):
            arng = np.random.default_rng(100 + r)
            Xa = np.vstack([Xh, arng.normal(size=(extra, cols))])
            ya = np.concatenate([yh, arng.normal(size=(extra,))])
            ref = _lm_ref(Xa, ya).ravel()

            cold = LineageRuntime(cache=ReuseCache(), fuse=True)
            t0 = time.perf_counter()
            g = _lm_run(cold, Xa, ya)
            t_cold.append(time.perf_counter() - t0)
            assert np.abs(g - ref).max() < 1e-10

            warm = LineageRuntime(cache=ReuseCache(), fuse=True)
            _lm_run(warm, Xh, yh)          # base training populates
            w = warm.stats.streaming       # the chunk-partial cache
            b_chunks, b_re = w.chunks, w.chunks_reused
            t0 = time.perf_counter()
            g = _lm_run(warm, Xa, ya)
            t_inc.append(time.perf_counter() - t0)
            assert np.abs(g - ref).max() < 1e-10
            new_chunks = w.chunks - b_chunks
            reused_chunks = w.chunks_reused - b_re
            assert reused_chunks == b_chunks, \
                "append shifted existing chunk boundaries"
        cold_s, inc_s = float(np.median(t_cold)), float(np.median(t_inc))
        speedup = cold_s / inc_s
        assert speedup >= min_speedup, \
            f"append-10% retrain only {speedup:.2f}x " \
            f"(>= {min_speedup}x required)"
    finally:
        costmodel.CHUNK_MEM_BUDGET = saved

    emit("streaming_lmds", t_stream,
         f"mat_us={t_mat*1e6:.0f};chunks={chunks};"
         f"peak_live={s.peak_live_bytes}")
    emit("streaming_append_retrain", inc_s,
         f"cold_us={cold_s*1e6:.0f};speedup={speedup:.1f}x;"
         f"new_chunks={new_chunks};reused={reused_chunks}")

    entry = dict(
        benchmark="streaming_chunked",
        workload=f"lmDS {rows}x{cols}, budget=nbytes/{budget_ratio}",
        budget_bytes=budget,
        chunks=int(chunks),
        peak_live_bytes=int(s.peak_live_bytes),
        stream_us_per_call=round(t_stream * 1e6, 1),
        materialized_us_per_call=round(t_mat * 1e6, 1),
        stream_overhead=round(t_stream / t_mat, 2),
        lmds_err=err,
        pca_err=pca_err,
        cold_retrain_us_per_call=round(cold_s * 1e6, 1),
        incremental_retrain_us_per_call=round(inc_s * 1e6, 1),
        append_speedup=round(speedup, 2),
        append_new_chunks=int(new_chunks),
        append_reused_chunks=int(reused_chunks),
        retraces=0,
        ts=time.strftime("%Y-%m-%dT%H:%M:%S"),
    )
    trajectory = []
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                trajectory = json.load(f)
        except Exception:
            trajectory = []
    trajectory.append(entry)
    with open(BENCH_JSON, "w") as f:
        json.dump(trajectory, f, indent=2)
    return entry


def _as_leaf(Xh):
    from repro.core.dag import input_tensor
    return input_tensor("X", Xh)


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    print("name,us_per_call,derived")
    if "--smoke" in sys.argv:
        # smaller matrix = noisier ratio on shared CI cores; the full
        # run holds the paper-target >= 5x bar
        out = main(rows=16384, repeats=2, min_speedup=2.5)
    else:
        out = main()
    print(json.dumps(out, indent=2))
