"""QUARANTINED: transformer-era sharding rule table (pre-DSL).

This module preserves the regex-driven PartitionSpec policy written for
a transformer parameter tree (embed/attn/moe/mamba paths). Nothing in
the linear-algebra DSL produces such a tree — the compiler's sharded
placement lives in `repro.core.compiler.lower_distributed` over the
mesh axes of `repro.distributed.mesh` — but the launch-layer dry-run
tooling (`repro.launch.dryrun`) still sizes transformer checkpoints
with these builders, so they are kept here, out of the DSL path,
instead of deleted.

Do not extend this table; new placement logic belongs in the compiler
passes. The graceful-degradation helper it relies on (`safe_spec`) has
moved to `repro.distributed.sharding`, which re-exports these builders
for backward compatibility.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from .sharding import safe_spec

# (path-regex, spec builder) — first match wins. `dp` = data axes tuple.
_RULES: list[tuple[str, Any]] = [
    # embeddings / head
    (r"embed/tok$",          lambda dp: P("model", dp)),
    (r"embed/books$",        lambda dp: P(None, "model", dp)),
    (r"head/w$",             lambda dp: P(dp, "model")),
    # gqa attention
    (r"attn/w[qkv]$",        lambda dp: P(dp, "model")),
    (r"attn/wo$",            lambda dp: P("model", dp)),
    (r"xattn/w[qkv]$",       lambda dp: P(dp, "model")),
    (r"xattn/wo$",           lambda dp: P("model", dp)),
    # mla
    (r"attn/wq_a$",          lambda dp: P(dp, None)),
    (r"attn/wq_b$",          lambda dp: P(None, "model")),
    (r"attn/wkv_a$",         lambda dp: P(dp, None)),
    (r"attn/wkv_b_[kv]$",    lambda dp: P(None, "model", None)),
    # dense mlp
    (r"mlp/w_(gate|up)$",    lambda dp: P(dp, "model")),
    (r"mlp/w_down$",         lambda dp: P("model", dp)),
    (r"(moe|rwkv)/shared/w_(gate|up)$", lambda dp: P(dp, "model")),
    (r"moe/shared/w_down$",  lambda dp: P("model", dp)),
    # moe experts (EP on model)
    (r"moe/router$",         lambda dp: P(dp, None)),
    (r"moe/w_(gate|up)$",    lambda dp: P("model", dp, None)),
    (r"moe/w_down$",         lambda dp: P("model", None, dp)),
    # rwkv6
    (r"rwkv/w[rkvg]$",       lambda dp: P(dp, "model")),
    (r"rwkv/wo$",            lambda dp: P("model", dp)),
    (r"rwkv/w[rk]_c$",       lambda dp: P(dp, "model")),
    (r"rwkv/wv_c$",          lambda dp: P("model", dp)),
    (r"rwkv/tm_w1$",         lambda dp: P(dp, None)),
    (r"rwkv/wA$",            lambda dp: P(dp, None)),
    (r"rwkv/u$",             lambda dp: P("model", None)),
    # mamba
    (r"mamba/in_proj$",      lambda dp: P(dp, "model")),
    (r"mamba/conv_w$",       lambda dp: P("model", None, None)),
    (r"mamba/x_proj$",       lambda dp: P("model", None)),
    (r"mamba/dt_proj$",      lambda dp: P(None, "model")),
    (r"mamba/A_log$",        lambda dp: P("model", None)),
    (r"mamba/out_proj$",     lambda dp: P("model", dp)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(param_shapes: Any, mesh: Mesh,
                data_axes=("data",), fsdp: bool = True) -> Any:
    """PartitionSpec pytree matching a param(-shapes) pytree."""
    dp = data_axes if len(data_axes) > 1 else data_axes[0]
    dp = dp if fsdp else None

    def assign(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        spec = P()
        for pat, builder in _RULES:
            if re.search(pat, ps):
                spec = builder(dp)
                break
        # stacked period params carry a leading period axis
        if "periods/" in ps and len(spec) < len(shape):
            spec = P(*((None,) + tuple(spec)))
        return safe_spec(shape, spec, mesh)

    return jax.tree_util.tree_map_with_path(assign, param_shapes)


def batch_specs(batch: Any, mesh: Mesh, data_axes=("pod", "data")) -> Any:
    """Shard the leading (batch) dim of every leaf on the data axes."""
    dp = tuple(a for a in data_axes if a in mesh.shape)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def assign(leaf):
        spec = P(*((dp,) + (None,) * (len(leaf.shape) - 1)))
        return safe_spec(leaf.shape, spec, mesh)

    return jax.tree_util.tree_map(assign, batch)


def cache_specs(cache_shapes: Any, mesh: Mesh, batch: int,
                data_axes=("pod", "data"), seq_axis_name="model") -> Any:
    """Decode-cache sharding: batch on data, sequence on `model`.

    For batch=1 (long-context) the batch axis is unshardable, so the
    sequence axis takes every available device instead."""
    dp = tuple(a for a in data_axes if a in mesh.shape)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    long_context = batch % max(dp_size, 1) != 0
    dpa = dp if len(dp) > 1 else (dp[0] if dp else None)

    def assign(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        ndim = len(shape)
        if ndim == 0:
            return P()
        has_period = "periods/" in ps
        off = 1 if has_period else 0     # leading stacked-period axis
        spec = [None] * ndim
        if ndim > off:
            # batch axis
            if not long_context:
                spec[off] = dpa
            # sequence axis for kv/latent caches (large 2nd dim)
            if ndim > off + 1 and shape[off + 1] >= 4096:
                spec[off + 1] = (dp + (seq_axis_name,)) if long_context \
                    else seq_axis_name
            elif ndim > off + 1 and long_context and \
                    shape[off + 1] % 2 == 0 and shape[off + 1] >= 1024:
                spec[off + 1] = seq_axis_name
        return safe_spec(shape, P(*spec), mesh)

    return jax.tree_util.tree_map_with_path(assign, cache_shapes)
