"""Modality frontend STUBS (per assignment: "the modality frontend is a
STUB — input_specs() provides precomputed frame/patch embeddings").

  * vision (llama-3.2-vision): precomputed patch embeddings
    (B, n_image_tokens, d_model) — stands in for the ViT encoder.
  * audio (musicgen): EnCodec token ids (B, S, n_codebooks) with the
    delay interleaving pattern applied.
"""
from __future__ import annotations

import numpy as np


def vision_embeddings(batch: int, n_tokens: int, d_model: int,
                      seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(scale=0.02,
                      size=(batch, n_tokens, d_model)).astype(np.float32)


def encodec_tokens(batch: int, seq_len: int, vocab: int, n_books: int = 4,
                   seed: int = 0) -> np.ndarray:
    """Synthetic EnCodec codebook ids with MusicGen's delay pattern:
    book k at time t holds the frame from t-k (first k steps = pad 0)."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, vocab, size=(batch, seq_len, n_books))
    out = np.zeros_like(base)
    for k in range(n_books):
        out[:, k:, k] = base[:, : seq_len - k, k]
    return out.astype(np.int32)
