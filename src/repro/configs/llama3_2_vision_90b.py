"""llama-3.2-vision-90b — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; one cross-attn
layer per period of 5 (20 image layers). The vision encoder is a STUB:
input_specs() provides precomputed patch embeddings (B, 1024, d_model).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_period=5,
    n_image_tokens=1024,
    rope_theta=500000.0,
)
