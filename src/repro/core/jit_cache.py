"""Process-wide cache of compiled segment executables.

Keyed by (segment canonical structural key, concrete input signature
(shapes + dtypes)), so structurally identical segments compiled from
*different* plans — HPO loops, CV folds, repeated `PreparedScript`
construction — share one XLA executable and replay without re-tracing.

On a miss the segment closure is lowered ahead-of-time
(`jax.jit(fn).lower(*args).compile()`) so trace+compile cost is measured
explicitly and replay calls skip dispatch-time signature checks; if AOT
lowering is unavailable for some input combination we fall back to the
plain `jax.jit` wrapper (which still caches by aval internally).

The cache is bounded: LRU eviction on BOTH an entry cap and a resident
code-byte cap (executable size from XLA's `memory_analysis` when
available, a flat estimate otherwise), configurable via
``REPRO_JIT_CACHE_ENTRIES`` / ``REPRO_JIT_CACHE_BYTES`` — long sessions
sweeping many plan shapes (benchmark suites, growing `parfor` grids)
stay at a bounded footprint, and eviction/hit/miss counters surface in
`RuntimeStats.as_dict()['jit_cache']`.
"""
from __future__ import annotations

import os
import time
import warnings
from collections import OrderedDict
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax

from . import faults

try:
    from jax.experimental.sparse import BCOO as _BCOO
except Exception:  # pragma: no cover
    _BCOO = ()

# Defaults for the process-wide cache; overridable per-process via env
# so benchmark drivers / services can pin their own budget.
DEFAULT_CAPACITY = int(os.environ.get("REPRO_JIT_CACHE_ENTRIES", 512))
DEFAULT_BYTE_CAPACITY = int(
    os.environ.get("REPRO_JIT_CACHE_BYTES", 256 << 20))
# Executables that expose no memory analysis are charged a flat size so
# the byte cap still exerts pressure instead of silently unbounding.
FALLBACK_EXE_BYTES = 64 << 10


@dataclass
class JitCacheStats:
    hits: int = 0
    misses: int = 0
    trace_time: float = 0.0   # cumulative lower+compile seconds
    aot_fallbacks: int = 0    # segments served by plain jit (AOT failed)
    evictions: int = 0        # entries dropped by the entry/byte caps
    bytes_cached: int = 0     # resident generated-code bytes (estimate)
    pinned: int = 0           # entries exempt from LRU (deploy-warmed)

    def as_dict(self) -> dict:
        return dict(hits=self.hits, misses=self.misses,
                    trace_time_s=round(self.trace_time, 6),
                    aot_fallbacks=self.aot_fallbacks,
                    evictions=self.evictions,
                    bytes_cached=self.bytes_cached,
                    pinned=self.pinned)


def arg_signature(args) -> tuple:
    """Shape/dtype(/weak-type) signature of concrete call arguments.

    weak_type matters: AOT-compiled executables reject aval mismatches,
    and a weak-typed jax scalar (e.g. a literal crossing a segment
    boundary) has a different aval than a strong-typed array of the same
    shape/dtype. BCOO arguments additionally carry their nse (buffer
    size) — two sparse matrices of equal shape but different nnz have
    different avals and need separate executables.
    """
    out = []
    for a in args:
        if _BCOO and isinstance(a, _BCOO):
            # pytree flags are part of the aval too: an executable
            # compiled for unique_indices=True rejects a False-flagged
            # BCOO of identical shape/dtype/nse
            out.append(("bcoo", tuple(a.shape), str(a.dtype), int(a.nse),
                        bool(a.unique_indices), bool(a.indices_sorted)))
        else:
            out.append(
                (tuple(getattr(a, "shape", ())),
                 str(getattr(a, "dtype", type(a).__name__)),
                 bool(getattr(a, "weak_type", False))))
    return tuple(out)


def mesh_key_tag(mesh_tag: str, in_tags, out_tags) -> str:
    """Segment-key suffix for shard_map-lowered executables.

    A sharded segment closes over a concrete device mesh and per-arg
    partition specs — none of which appear in the argument signature
    (global shapes are identical). Suffixing the mesh shape and the
    's'/'r' spec tags keeps sharded executables from ever colliding
    with the local executable of the same segment body, or with the
    same body sharded over a different mesh shape.
    """
    return (f"|mesh:{mesh_tag}|in:{''.join(in_tags)}"
            f"|out:{''.join(out_tags)}")


def _exe_nbytes(exe: Any) -> int:
    """Resident-size estimate of one compiled executable (generated
    code; argument buffers are owned by the caller, not the cache)."""
    try:
        ma = exe.memory_analysis()
        nb = int(getattr(ma, "generated_code_size_in_bytes", 0))
        if nb > 0:
            return nb
    except Exception:
        pass
    return FALLBACK_EXE_BYTES


class JitProgramCache:
    """LRU cache: (segment key, input signature) -> compiled executable.

    Bounded by `capacity` entries AND `byte_capacity` resident code
    bytes; the least-recently-used entries are evicted when either cap
    is exceeded (`stats.evictions` / `stats.bytes_cached`)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 byte_capacity: int = DEFAULT_BYTE_CAPACITY):
        self.capacity = int(capacity)
        self.byte_capacity = int(byte_capacity)
        # key -> (executable, code bytes)
        self._entries: "OrderedDict[tuple, tuple[Callable, int]]" = \
            OrderedDict()
        # keys exempt from LRU eviction (deploy-warmed serving
        # executables: evicting one would put trace+compile back on a
        # request's critical path — exactly what deploy-time warmup paid
        # to remove)
        self._pinned: set[tuple] = set()
        # active pinning() recorders (normally 0 or 1)
        self._recorders: list[set[tuple]] = []
        self.stats = JitCacheStats()

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, seg_key: str, args) -> tuple[tuple, Optional[Callable]]:
        """Return (full key, executable-or-None); counts hit/miss."""
        key = (seg_key, arg_signature(args))
        for rec in self._recorders:
            rec.add(key)
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return key, entry[0]
        self.stats.misses += 1
        return key, None

    def compile(self, key: tuple, fn: Callable, args,
                donate_argnums: tuple = ()) -> tuple[Callable, float]:
        """Compile `fn` for `args`, store under `key`; returns
        (executable, trace_seconds).

        `donate_argnums` marks dead-after-segment arguments whose
        buffers XLA may alias into the outputs (the async pipeline's
        `_free`-uid candidates). Donation is baked into the caller's
        `key` (a `|don:` seg-key suffix), so a donated executable can
        never be replayed with live arguments under the plain key."""
        # seeded fault injection (ISSUE 10): a `compile` rule fails this
        # call before any tracing happens — callers degrade to the
        # interpreter (segments) or retry (site sub-segments)
        faults.compile_entry(key[0] if isinstance(key, tuple) else key)
        t0 = time.perf_counter()
        jitted = jax.jit(fn, donate_argnums=donate_argnums) \
            if donate_argnums else jax.jit(fn)
        if hasattr(jitted, "lower"):
            # Genuine trace/compile errors propagate immediately — masking
            # them here would cache a broken wrapper that re-raises on
            # every subsequent run with a misleading 'fallback' stat.
            if donate_argnums:
                # XLA warns when a donated buffer finds no same-
                # shape/dtype output to alias — harmless (the buffer is
                # dead either way) and would spam every compile
                with warnings.catch_warnings():
                    warnings.filterwarnings(
                        "ignore", message=".*[Dd]onat.*")
                    exe: Any = jitted.lower(*args).compile()
            else:
                exe = jitted.lower(*args).compile()
        else:  # pragma: no cover - AOT API unavailable on this jax
            warnings.warn("jax.jit(...).lower unavailable; segment will "
                          "use dispatch-path jit", RuntimeWarning,
                          stacklevel=2)
            self.stats.aot_fallbacks += 1
            exe = jitted
        dt = time.perf_counter() - t0
        self.stats.trace_time += dt
        nb = _exe_nbytes(exe)
        old = self._entries.pop(key, None)
        if old is not None:  # racing recompile of the same key
            self.stats.bytes_cached -= old[1]
        self._entries[key] = (exe, nb)
        self.stats.bytes_cached += nb
        self._evict()
        return exe, dt

    def _evict(self) -> None:
        # Walk LRU-first, skipping pinned entries — pinned executables
        # still occupy entry/byte budget (their pressure falls on the
        # unpinned population) but can never be dropped. Keys recorded
        # by an open pinning() block are protected already: deploy-time
        # warmup compiles MORE executables than `capacity` allows in
        # sequence, and evicting bucket 2 while warming bucket 16 would
        # defeat the warmup. The newest unpinned entry is never evicted
        # either: a single over-budget executable is still the one we
        # must run.
        protected = self._pinned.union(*self._recorders) \
            if self._recorders else self._pinned
        while True:
            unpinned = [k for k in self._entries if k not in protected]
            if len(unpinned) <= 1:
                break
            over = (len(self._entries) > self.capacity
                    or self.stats.bytes_cached > self.byte_capacity)
            if not over:
                break
            key = unpinned[0]
            _, nb = self._entries.pop(key)
            self.stats.bytes_cached -= nb
            self.stats.evictions += 1

    # -- pinning (serving deploy-time warmup) --------------------------
    def pin(self, key: tuple) -> None:
        """Exempt `key` from LRU eviction (no-op if already pinned)."""
        if key not in self._pinned:
            self._pinned.add(key)
            self.stats.pinned = len(self._pinned)

    def unpin(self, key: tuple) -> None:
        self._pinned.discard(key)
        self.stats.pinned = len(self._pinned)
        self._evict()  # unpinned entries are back under the caps

    def unpin_all(self, keys=None) -> None:
        """Unpin `keys` (or everything) and re-apply the caps."""
        if keys is None:
            self._pinned.clear()
        else:
            self._pinned.difference_update(keys)
        self.stats.pinned = len(self._pinned)
        self._evict()

    @contextmanager
    def pinning(self):
        """Record every cache key touched inside the block and pin the
        ones resident at exit. `ModelServer.deploy` wraps its bucket
        warmup in this so the LRU can never evict a serving executable
        mid-flight; the yielded set is kept so `shutdown` can unpin."""
        rec: set[tuple] = set()
        self._recorders.append(rec)
        try:
            yield rec
        finally:
            self._recorders.remove(rec)
            for key in rec:
                if key in self._entries:
                    self.pin(key)

    def clear(self) -> None:
        self._entries.clear()
        self._pinned.clear()
        self.stats.bytes_cached = 0
        self.stats.pinned = 0


_global_cache: Optional[JitProgramCache] = None


def get_jit_cache() -> JitProgramCache:
    global _global_cache
    if _global_cache is None:
        _global_cache = JitProgramCache()
    return _global_cache


def clear_jit_cache() -> None:
    """Drop all compiled executables (tests / memory pressure)."""
    if _global_cache is not None:
        _global_cache.clear()
