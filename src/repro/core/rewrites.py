"""Compiler rewrites over the HOP DAG (SystemDS §3.2 "multiple rounds of
rewrites" + §4.1 "compiler-assisted reuse").

Passes (applied in `repro.core.compiler.compile_plan`):
  1. algebraic simplifications  — t(t(X))→X, sum(t(X))→sum(X), x*1→x, ...
  2. fused-operator detection   — t(X)@X → gram(X)   [tsmm]
                                  t(X)@y → xtv(X, y)
  3. matmul-chain reordering    — optimal parenthesization (DP on dims)
  4. reuse-enabling distribution (only when a reuse cache is active):
       gram(rbind(A,B,..))   → gram(A)+gram(B)+...            [CV, Fig. 7]
       xtv(rbind(A..), rbind(y..)) → Σ xtv(Ai, yi)            [CV, Fig. 7]
       gram(cbind(X, c))     → block([[gram(X), xtv(X,c)],
                                      [t(xtv(X,c)), gram(c)]]) [steplm, Ex. 1]
  5. common-subexpression elimination (structural hashing)

Each pass is a bottom-up DAG rebuild; DCE falls out of rebuilding only
reachable nodes.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from .dag import LTensor, Node, make_node, structural_key

# ---------------------------------------------------------------------------
# Generic bottom-up transformer
# ---------------------------------------------------------------------------


def transform(roots: list[Node], fn: Callable[[Node], Node]) -> list[Node]:
    """Rebuild the DAG bottom-up, applying `fn` to each node whose inputs
    were (possibly) rewritten. `fn` receives a node with *new* inputs and
    returns a replacement node (or the node itself)."""
    memo: dict[int, Node] = {}

    def rec(n: Node) -> Node:
        got = memo.get(n.uid)
        if got is not None:
            return got
        if n.inputs:
            new_inputs = tuple(rec(i) for i in n.inputs)
            if any(a is not b for a, b in zip(new_inputs, n.inputs)):
                n2 = Node(op=n.op, inputs=new_inputs, attrs=n.attrs,
                          shape=n.shape, dtype=n.dtype, sparsity=n.sparsity,
                          placement=n.placement)
            else:
                n2 = n
        else:
            n2 = n
        out = fn(n2)
        memo[n.uid] = out
        return out

    return [rec(r) for r in roots]


def use_counts(roots: list[Node]) -> dict[int, int]:
    counts: dict[int, int] = {}
    seen: set[int] = set()

    def rec(n: Node):
        for i in n.inputs:
            counts[i.uid] = counts.get(i.uid, 0) + 1
            if i.uid not in seen:
                seen.add(i.uid)
                rec(i)

    for r in roots:
        counts[r.uid] = counts.get(r.uid, 0) + 1
        if r.uid not in seen:
            seen.add(r.uid)
            rec(r)
    return counts


# ---------------------------------------------------------------------------
# Pass 1: algebraic simplification
# ---------------------------------------------------------------------------

def _is_literal(n: Node, value=None) -> bool:
    return n.op == "literal" and (value is None or n.attr("value") == value)


def simplify(n: Node) -> Node:
    op = n.op
    # t(t(X)) -> X
    if op == "t" and n.inputs[0].op == "t":
        return n.inputs[0].inputs[0]
    # sum(t(X)) -> sum(X); trace(t(X)) -> trace(X)
    if op in ("sum", "trace", "mean", "nnz") and n.inputs[0].op == "t":
        return make_node(op, (n.inputs[0].inputs[0],), n.shape, n.dtype,
                         n.sparsity)
    # x * 1 -> x ; x + 0 -> x ; x / 1 -> x ; x - 0 -> x (shape-safe cases)
    if op in ("mul", "div") and len(n.inputs) == 2:
        a, b = n.inputs
        if _is_literal(b, 1.0) and a.shape == n.shape:
            return a
        if op == "mul" and _is_literal(a, 1.0) and b.shape == n.shape:
            return b
    if op in ("add", "sub") and len(n.inputs) == 2:
        a, b = n.inputs
        if _is_literal(b, 0.0) and a.shape == n.shape:
            return a
        if op == "add" and _is_literal(a, 0.0) and b.shape == n.shape:
            return b
    # literal-literal folding for scalars
    if op in ("add", "sub", "mul", "div", "pow") and len(n.inputs) == 2 and \
            all(_is_literal(i) for i in n.inputs) and n.shape == ():
        a, b = (i.attr("value") for i in n.inputs)
        try:
            v = {"add": a + b, "sub": a - b, "mul": a * b,
                 "div": a / b if b != 0 else np.nan, "pow": a ** b}[op]
            return make_node("literal", (), (), n.dtype,
                             0.0 if v == 0 else 1.0, value=float(v))
        except Exception:
            pass
    return n


# ---------------------------------------------------------------------------
# Pass 2: fused operators (tsmm / xtv)
# ---------------------------------------------------------------------------

def fuse_tsmm(n: Node) -> Node:
    if n.op != "matmul":
        return n
    a, b = n.inputs
    if a.op == "t":
        x = a.inputs[0]
        if x.uid == b.uid and len(x.shape) == 2:
            # t(X) @ X -> gram(X)
            return make_node("gram", (x,), n.shape, n.dtype, n.sparsity)
        if len(x.shape) == 2 and len(b.shape) == 2:
            # t(X) @ Y -> xtv(X, Y) (fused, avoids materializing transpose)
            return make_node("xtv", (x, b), n.shape, n.dtype, n.sparsity)
    return n


# ---------------------------------------------------------------------------
# Pass 3: matmul chain reordering (dynamic programming)
# ---------------------------------------------------------------------------

def reorder_matmul_chains(roots: list[Node]) -> list[Node]:
    counts = use_counts(roots)

    def collect(n: Node, factors: list[Node]):
        """Flatten a matmul tree into its chain factors; only descend through
        intermediate products with a single consumer (splitting shared
        products would defeat CSE/reuse)."""
        if n.op == "matmul" and counts.get(n.uid, 1) <= 1:
            collect(n.inputs[0], factors)
            collect(n.inputs[1], factors)
        else:
            factors.append(n)

    def optimal(factors: list[Node]) -> Node:
        k = len(factors)
        dims = [f.shape[0] for f in factors] + [factors[-1].shape[-1]]
        cost = [[0.0] * k for _ in range(k)]
        split = [[0] * k for _ in range(k)]
        for span in range(1, k):
            for i in range(k - span):
                j = i + span
                cost[i][j] = float("inf")
                for s in range(i, j):
                    c = (cost[i][s] + cost[s + 1][j]
                         + dims[i] * dims[s + 1] * dims[j + 1])
                    if c < cost[i][j]:
                        cost[i][j] = c
                        split[i][j] = s

        def build(i: int, j: int) -> Node:
            if i == j:
                return factors[i]
            s = split[i][j]
            lhs, rhs = build(i, s), build(s + 1, j)
            shape = lhs.shape[:-1] + rhs.shape[1:]
            return make_node("matmul", (lhs, rhs), shape,
                             np.result_type(lhs.dtype, rhs.dtype), 1.0)

        return build(0, k - 1)

    def fn(n: Node) -> Node:
        if n.op != "matmul":
            return n
        factors: list[Node] = []
        collect(n, factors)
        if len(factors) <= 2:
            return n
        if any(len(f.shape) != 2 for f in factors):
            return n
        return optimal(factors)

    return transform(roots, fn)


# ---------------------------------------------------------------------------
# Pass 4: reuse-enabling distribution (compensation-plan rewrites)
# ---------------------------------------------------------------------------

def distribute_for_reuse(n: Node) -> Node:
    # gram(rbind(A, B, ...)) -> gram(A) + gram(B) + ...
    if n.op == "gram" and n.inputs[0].op == "rbind" \
            and n.inputs[0].attr("axis") == 0:
        parts = n.inputs[0].inputs
        if len(parts) >= 2:
            acc = None
            for p in parts:
                g = make_node("gram", (p,), n.shape, n.dtype, n.sparsity)
                acc = g if acc is None else make_node(
                    "add", (acc, g), n.shape, n.dtype, n.sparsity)
            return acc
    # xtv(rbind(A..), rbind(y..)) with aligned splits -> Σ xtv(Ai, yi)
    if n.op == "xtv" and n.inputs[0].op == "rbind" and \
            n.inputs[1].op == "rbind":
        xs, ys = n.inputs[0].inputs, n.inputs[1].inputs
        if len(xs) == len(ys) >= 2 and \
                all(a.shape[0] == b.shape[0] for a, b in zip(xs, ys)):
            acc = None
            for a, b in zip(xs, ys):
                p = make_node("xtv", (a, b), n.shape, n.dtype, 1.0)
                acc = p if acc is None else make_node(
                    "add", (acc, p), n.shape, n.dtype, 1.0)
            return acc
    # gram(cbind(X, c)) -> block composition reusing gram(X)  [steplm]
    if n.op == "gram" and n.inputs[0].op == "cbind" \
            and n.inputs[0].attr("axis") == 1:
        parts = n.inputs[0].inputs
        if len(parts) == 2 and parts[1].shape[1] <= 4 <= parts[0].shape[1]:
            x, c = parts
            gx = make_node("gram", (x,), (x.shape[1], x.shape[1]),
                           n.dtype, n.sparsity)
            xc = make_node("xtv", (x, c), (x.shape[1], c.shape[1]),
                           n.dtype, 1.0)
            cx = make_node("t", (xc,), (c.shape[1], x.shape[1]), n.dtype, 1.0)
            gc = make_node("gram", (c,), (c.shape[1], c.shape[1]),
                           n.dtype, 1.0)
            top = make_node("cbind", (gx, xc),
                            (x.shape[1], n.shape[1]), n.dtype, 1.0, axis=1)
            bot = make_node("cbind", (cx, gc),
                            (c.shape[1], n.shape[1]), n.dtype, 1.0, axis=1)
            return make_node("rbind", (top, bot), n.shape, n.dtype, 1.0,
                             axis=0)
    return n


# ---------------------------------------------------------------------------
# Pass 5: CSE
# ---------------------------------------------------------------------------

def cse(roots: list[Node]) -> list[Node]:
    canon: dict[str, Node] = {}
    memo: dict[int, str] = {}

    def fn(n: Node) -> Node:
        key = structural_key(n, memo)
        got = canon.get(key)
        if got is not None and got.shape == n.shape:
            return got
        canon[key] = n
        return n

    return transform(roots, fn)


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------

def run_rewrites(roots: list[Node], reuse_enabled: bool,
                 opt_level: int = 2) -> list[Node]:
    if opt_level >= 1:
        roots = transform(roots, simplify)
        roots = transform(roots, fuse_tsmm)
    if opt_level >= 2:
        roots = reorder_matmul_chains(roots)
        # re-run fusion: reordering can expose new t(X)@X patterns
        roots = transform(roots, fuse_tsmm)
    if reuse_enabled and opt_level >= 1:
        roots = transform(roots, distribute_for_reuse)
        roots = transform(roots, simplify)
    roots = cse(roots)
    return roots
