"""Model zoo: the assigned architectures as composable JAX modules."""
from .config import ModelConfig  # noqa: F401
from .model import Model, build_model  # noqa: F401
