"""RWKV-6 "Finch" block (arXiv:2404.05892) — attention-free, data-dependent
per-channel decay.

Time-mix uses the chunked WKV form (GLA-style): intra-chunk is an
attention-like triangular matmul with relative decays, inter-chunk is a
rank-dh state passed through a scan — O(S·C·dh) instead of O(S²), and
decode is O(1) per token from the recurrent state. The Pallas kernel
(repro.kernels.rwkv6) implements the same chunked algorithm per
(batch, head) grid cell; this module is the pure-JAX path (and oracle
feedstock).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Params, dense_init, rmsnorm, rmsnorm_init

LORA_SHIFT = 32     # token-shift ddlerp lora rank
LORA_DECAY = 64     # decay lora rank
SUB = 16            # intra-chunk sub-block for the stable factorization
MAX_DECAY = 5.0     # per-step |log w| clamp: decays stronger than e^-5
                    # per step are numerically indistinguishable after a
                    # few tokens; clamping keeps every factored exponent
                    # within |SUB · MAX_DECAY| = 80 < f32's exp range.


def rwkv6_init(key, cfg) -> Params:
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = d // dh
    ks = jax.random.split(key, 16)
    p: Params = {
        # token-shift ddlerp
        "mu_x": jnp.zeros((d,), jnp.float32) + 0.5,
        "mu": jnp.full((5, d), 0.5, jnp.float32),      # r,k,v,w,g
        "tm_w1": dense_init(ks[0], d, 5 * LORA_SHIFT, scale=0.01),
        "tm_w2": (jax.random.normal(ks[1], (5, LORA_SHIFT, d), jnp.float32)
                  * 0.01),
        # projections
        "wr": dense_init(ks[2], d, d),
        "wk": dense_init(ks[3], d, d),
        "wv": dense_init(ks[4], d, d),
        "wg": dense_init(ks[5], d, d),
        "wo": dense_init(ks[6], d, d),
        # data-dependent decay
        "w0": jnp.full((d,), -1.0, jnp.float32),
        "wA": dense_init(ks[7], d, LORA_DECAY, scale=0.01),
        "wB": dense_init(ks[8], LORA_DECAY, d, scale=0.01),
        # bonus + output norm (per-head group norm)
        "u": jax.random.normal(ks[9], (H, dh), jnp.float32) * 0.1,
        "ln_x": {"scale": jnp.ones((d,), jnp.float32),
                 "bias": jnp.zeros((d,), jnp.float32)},
        # channel mix
        "mu_rc": jnp.full((d,), 0.5, jnp.float32),
        "mu_kc": jnp.full((d,), 0.5, jnp.float32),
        "wr_c": dense_init(ks[10], d, d),
        "wk_c": dense_init(ks[11], d, cfg.d_ff),
        "wv_c": dense_init(ks[12], cfg.d_ff, d),
    }
    return p


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Shift sequence right by one; `prev` is the carry for decode."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(p: Params, x, xprev):
    """Data-dependent lerp producing the 5 mixed inputs (r,k,v,w,g)."""
    xx = xprev - x
    base = x + xx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(base @ p["tm_w1"].astype(x.dtype))
    B, S, _ = x.shape
    lora = lora.reshape(B, S, 5, LORA_SHIFT)
    delta = jnp.einsum("bsfl,fld->fbsd", lora, p["tm_w2"].astype(x.dtype))
    mixed = x[None] + xx[None] * (p["mu"].astype(x.dtype)[:, None, None]
                                  + delta)
    return mixed  # (5, B, S, D)


def _group_norm(p: Params, y: jnp.ndarray, H: int) -> jnp.ndarray:
    """Per-head group norm over the head channel (ln_x in RWKV)."""
    B, S, D = y.shape
    dh = D // H
    yh = y.reshape(B, S, H, dh).astype(jnp.float32)
    mu = yh.mean(axis=-1, keepdims=True)
    var = yh.var(axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 1e-5)
    out = yh.reshape(B, S, D) * p["ln_x"]["scale"] + p["ln_x"]["bias"]
    return out.astype(y.dtype)


def wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """Chunked WKV6: r,k,v,logw (B,S,H,dh); u (H,dh); state (B,H,dh,dh).

    Returns (y (B,S,H,dh), state').  logw = log of per-step decay < 0
    (clamped to [-MAX_DECAY, 0) by the caller).

    Intra-chunk coefficients exp(lw_ex[t] − lw[s]) are factored per
    sub-block pair (b, a) around a boundary Ba inside/next to sub-block
    a, so every materialized exponent is bounded by SUB·MAX_DECAY —
    stable even under maximal decays (GLA-style secondary chunking).
    """
    B, S, H, dh = r.shape
    C = min(chunk, max(S, SUB))
    C = max((C // SUB) * SUB, SUB)
    pad = (-S) % C
    if pad:
        # zero r/k with zero log-decay is an exact no-op for both the
        # outputs we keep and the carried state
        zeros = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, zeros)
        k = jnp.pad(k, zeros)
        v = jnp.pad(v, zeros)
        logw = jnp.pad(logw, zeros)
    S_run = S + pad
    nc = S_run // C
    nu = C // SUB
    f32 = jnp.float32

    rs = r.reshape(B, nc, C, H, dh).swapaxes(0, 1)
    ks_ = k.reshape(B, nc, C, H, dh).swapaxes(0, 1)
    vs = v.reshape(B, nc, C, H, dh).swapaxes(0, 1)
    ws = logw.reshape(B, nc, C, H, dh).swapaxes(0, 1).astype(f32)
    strict = (jnp.arange(SUB)[:, None] > jnp.arange(SUB)[None, :])

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(S_carry, blk):
        # checkpointed: backward recomputes intra-chunk A tiles instead
        # of saving them (matches the kernel's recompute strategy)
        rc, kc, vc, wc = blk                          # (B, C, H, dh)
        rcf, kcf, vcf = (t.astype(f32) for t in (rc, kc, vc))
        lw = jnp.cumsum(wc, axis=1)                   # inclusive
        lw_ex = lw - wc                               # exclusive

        # inter-chunk: bounded (lw_ex <= 0)
        y = jnp.einsum("bthd,bhde->bthe", rcf * jnp.exp(lw_ex), S_carry)

        # intra-chunk: sub-block pairs with per-pair boundary
        diag = jnp.einsum("bthd,bthd->bth", rcf * u.astype(f32), kcf)
        y = y + diag[..., None] * vcf
        for b in range(nu):
            t0 = b * SUB
            rb = rcf[:, t0:t0 + SUB]
            lweb = lw_ex[:, t0:t0 + SUB]
            for a in range(b + 1):
                s0 = a * SUB
                ka = kcf[:, s0:s0 + SUB]
                va = vcf[:, s0:s0 + SUB]
                lwa = lw[:, s0:s0 + SUB]
                if a == b:
                    base = lw_ex[:, t0][:, None]      # start-exclusive
                else:
                    base = lw[:, s0 + SUB - 1][:, None]  # end of block a
                left = rb * jnp.exp(lweb - base)      # exponent <= 0
                right = ka * jnp.exp(base - lwa)      # 0 <= exp <= U·clamp
                A = jnp.einsum("bthd,bshd->bhts", left, right)
                if a == b:
                    A = jnp.where(strict[None, None], A, 0.0)
                y = y.at[:, t0:t0 + SUB].add(
                    jnp.einsum("bhts,bshd->bthd", A, va))

        # state update: bounded (lw_last - lw <= 0, lw_last <= 0)
        lw_last = lw[:, -1]                           # (B, H, dh)
        decay_rest = jnp.exp(lw_last[:, None] - lw)   # (B, C, H, dh)
        S_new = (jnp.exp(lw_last)[..., None] * S_carry
                 + jnp.einsum("bshd,bshe->bhde", kcf * decay_rest, vcf))
        return S_new, y

    state, ys = jax.lax.scan(body, state.astype(f32), (rs, ks_, vs, ws))
    y = ys.swapaxes(0, 1).reshape(B, S_run, H, dh)[:, :S]
    return y.astype(r.dtype), state


def wkv_step(r, k, v, logw, u, state):
    """One decode step: r,k,v,logw (B,H,dh); state (B,H,dh,dh)."""
    f32 = jnp.float32
    rf, kf, vf = r.astype(f32), k.astype(f32), v.astype(f32)
    att = state + u.astype(f32)[None, :, :, None] * (kf[..., None]
                                                     * vf[..., None, :])
    y = jnp.einsum("bhd,bhde->bhe", rf, att)
    state = (jnp.exp(logw.astype(f32))[..., None] * state
             + kf[..., None] * vf[..., None, :])
    return y.astype(r.dtype), state


def time_mix(p: Params, cfg, x, shift_prev, state, decode: bool = False):
    """x: (B, S, D). Returns (out, new_shift, new_state)."""
    B, S, D = x.shape
    dh = cfg.rwkv_head_dim
    H = D // dh
    dt = x.dtype
    xprev = _token_shift(x, shift_prev)
    xr, xk, xv, xw, xg = _ddlerp(p, x, xprev)
    r = (xr @ p["wr"].astype(dt)).reshape(B, S, H, dh)
    k = (xk @ p["wk"].astype(dt)).reshape(B, S, H, dh)
    v = (xv @ p["wv"].astype(dt)).reshape(B, S, H, dh)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    logw = -jnp.exp(p["w0"].astype(jnp.float32)
                    + (jnp.tanh(xw @ p["wA"].astype(dt))
                       @ p["wB"].astype(dt)).astype(jnp.float32))
    logw = jnp.clip(logw, -MAX_DECAY, -1e-4)  # see MAX_DECAY note
    logw = logw.reshape(B, S, H, dh)
    if decode:
        y, state = wkv_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0],
                            p["u"], state)
        y = y[:, None]
    else:
        if cfg.use_pallas:
            from repro.kernels.rwkv6 import ops as rops
            y, state = rops.wkv6(r, k, v, logw, p["u"], state,
                                 chunk=cfg.rwkv_chunk)
        else:
            y, state = wkv_chunked(r, k, v, logw, p["u"], state,
                                   chunk=cfg.rwkv_chunk)
    y = _group_norm(p, y.reshape(B, S, D), H) * g
    return y @ p["wo"].astype(dt), x[:, -1:], state


def channel_mix(p: Params, x, shift_prev):
    dt = x.dtype
    xprev = _token_shift(x, shift_prev)
    xx = xprev - x
    xr = x + xx * p["mu_rc"].astype(dt)
    xk = x + xx * p["mu_kc"].astype(dt)
    rr = jax.nn.sigmoid(xr @ p["wr_c"].astype(dt))
    kk = jnp.square(jax.nn.relu(xk @ p["wk_c"].astype(dt)))
    return rr * (kk @ p["wv_c"].astype(dt)), x[:, -1:]


def rwkv6_state_spec(cfg, batch: int):
    """Decode state: wkv state + 2 token-shift carries per layer."""
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = d // dh
    return {
        "wkv": jax.ShapeDtypeStruct((batch, H, dh, dh), jnp.float32),
        "shift_tm": jax.ShapeDtypeStruct((batch, 1, d), jnp.dtype(cfg.dtype)),
        "shift_cm": jax.ShapeDtypeStruct((batch, 1, d), jnp.dtype(cfg.dtype)),
    }
