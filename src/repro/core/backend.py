"""Runtime operation library (the TensorBlock operation layer, §3.2/§3.3).

Every HOP is implemented as a *kernel builder*: `attrs -> fn(*inputs)`,
registered in `_KERNEL_BUILDERS`. The returned kernels are pure and
jax-traceable, so the same registry serves two execution modes:

  * standalone   — `execute_op` builds and calls one kernel eagerly
                   (the per-instruction interpreter / `fuse=False` path)
  * fused        — `repro.core.segments.build_segment_fn` chains kernels
                   into one closure per segment and hands it to
                   `jax.jit` (the segment engine)

Two physical representations are supported, mirroring SystemDS's
dense/sparse blocks:

  * dense  — jnp arrays (fp64 default on the lifecycle path, like SystemDS)
  * sparse — jax.experimental.sparse.BCOO for 2D matrices below a density
             threshold; matmul/gram/xtv stay sparse, everything else
             densifies (TPU adaptation note in DESIGN.md §2a: sparsity
             exploitation is block-level on TPU, value-level on CPU).

The `gram` op routes through `repro.kernels.gram.ops` which picks the
Pallas TPU kernel on TPU and the jnp path elsewhere.
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

try:  # BCOO sparse support (available on CPU)
    from jax.experimental import sparse as jsparse
    HAS_SPARSE = True
except Exception:  # pragma: no cover
    jsparse = None
    HAS_SPARSE = False

SPARSE_THRESHOLD = 0.3


def is_sparse(x) -> bool:
    return HAS_SPARSE and isinstance(x, jsparse.BCOO)


def densify(x):
    return x.todense() if is_sparse(x) else x


def maybe_sparsify(arr, sparsity_est: float):
    """Convert a 2D array to BCOO when the estimate says it pays off."""
    if (HAS_SPARSE and sparsity_est < SPARSE_THRESHOLD
            and getattr(arr, "ndim", 0) == 2 and arr.size > 1 << 16):
        return jsparse.BCOO.fromdense(arr)
    return arr


# ---------------------------------------------------------------------------
# op implementations
# ---------------------------------------------------------------------------

def _gram(x):
    if is_sparse(x):
        # sparse-dense: flops ∝ nnz·n (sparse-sparse lowering is slow)
        return densify(x.T @ x.todense())
    from repro.kernels.gram import ops as gram_ops
    return gram_ops.gram(x)


def _xtv(x, v):
    if is_sparse(x):
        out = x.T @ densify(v)
        return densify(out)
    from repro.kernels.gram import ops as gram_ops
    return gram_ops.xtv(x, v)


def _matmul(a, b):
    if is_sparse(a) or is_sparse(b):
        out = a @ b
        return densify(out)
    return a @ b


def _solve(a, b):
    a = densify(a).astype(jnp.float64)
    b = densify(b).astype(jnp.float64)
    # SPD fast path (normal equations): cholesky solve, else generic
    return jax.scipy.linalg.solve(a, b, assume_a="pos") \
        if a.shape[0] == a.shape[1] else jnp.linalg.lstsq(a, b)[0]


def _slice(x, index):
    x = densify(x)
    idx = []
    for (start, stop, kind) in index:
        idx.append(start if kind == 1 else slice(start, stop))
    return x[tuple(idx)]


def _colvars(x):
    x = densify(x)
    return jnp.var(x, axis=0, keepdims=True, ddof=1)


_BINARY = {
    "add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply,
    "div": jnp.divide, "pow": jnp.power,
    "min2": jnp.minimum, "max2": jnp.maximum,
    "gt": lambda a, b: (a > b).astype(jnp.float32),
    "lt": lambda a, b: (a < b).astype(jnp.float32),
    "ge": lambda a, b: (a >= b).astype(jnp.float32),
    "le": lambda a, b: (a <= b).astype(jnp.float32),
    "eq": lambda a, b: (a == b).astype(jnp.float32),
    "ne": lambda a, b: (a != b).astype(jnp.float32),
    "and": lambda a, b: jnp.logical_and(a != 0, b != 0).astype(jnp.float32),
    "or": lambda a, b: jnp.logical_or(a != 0, b != 0).astype(jnp.float32),
}

_UNARY = {
    "neg": jnp.negative, "exp": jnp.exp, "log": jnp.log, "sqrt": jnp.sqrt,
    "abs": jnp.abs, "sign": jnp.sign, "round": jnp.round,
    "floor": jnp.floor, "ceil": jnp.ceil, "sigmoid": jax.nn.sigmoid,
    "not": lambda x: (x == 0).astype(jnp.float32),
}

_AGG = {
    "sum": jnp.sum, "mean": jnp.mean, "max": jnp.max, "min": jnp.min,
    "trace": jnp.trace,
    "nnz": lambda x: jnp.count_nonzero(x).astype(jnp.float64),
    "colSums": partial(jnp.sum, axis=0, keepdims=True),
    "rowSums": partial(jnp.sum, axis=1, keepdims=True),
    "colMeans": partial(jnp.mean, axis=0, keepdims=True),
    "rowMeans": partial(jnp.mean, axis=1, keepdims=True),
    "colMaxs": partial(jnp.max, axis=0, keepdims=True),
    "colMins": partial(jnp.min, axis=0, keepdims=True),
    "colVars": _colvars,
}


# ---------------------------------------------------------------------------
# Kernel registry: op name -> (attrs -> pure fn(*inputs))
# ---------------------------------------------------------------------------

KernelFn = Any  # Callable[..., array]

_KERNEL_BUILDERS: dict[str, Any] = {}

# Ops that must never be traced into a fused jit segment (data-dependent
# python control flow, host side effects, dynamic output shapes). All
# current kernels are traceable; the segmenter breaks segments here so
# future ops can opt out of fusion by name.
NON_TRACEABLE_OPS: frozenset[str] = frozenset()


def register_kernel(op: str):
    """Register `builder(attrs) -> fn(*inputs)` for an op."""
    def deco(builder):
        _KERNEL_BUILDERS[op] = builder
        return builder
    return deco


def has_kernel(op: str) -> bool:
    return op in _KERNEL_BUILDERS


def get_kernel(op: str, attrs: dict[str, Any]) -> KernelFn:
    """Build the pure kernel for one instruction.

    `attrs` is the node's attribute dict plus `_shape` (output shape) for
    generator ops. The returned fn is closed over static attrs only, so
    it is safe to call standalone or inside a `jax.jit` trace.
    """
    builder = _KERNEL_BUILDERS.get(op)
    if builder is None:
        raise NotImplementedError(f"op {op!r}")
    return builder(attrs)


def _register_table(table: dict[str, Any], arity: int) -> None:
    def make_builder(fn):
        if arity == 1:
            def build(attrs):
                return lambda x: fn(densify(x))
        else:
            def build(attrs):
                return lambda a, b: fn(densify(a), densify(b))
        return build
    for op, fn in table.items():
        _KERNEL_BUILDERS[op] = make_builder(fn)


_register_table(_BINARY, 2)
_register_table(_UNARY, 1)
_register_table(_AGG, 1)


@register_kernel("matmul")
def _build_matmul(attrs):
    return _matmul


@register_kernel("gram")
def _build_gram(attrs):
    return _gram


@register_kernel("xtv")
def _build_xtv(attrs):
    return _xtv


@register_kernel("t")
def _build_t(attrs):
    return lambda x: x.T if is_sparse(x) else jnp.transpose(densify(x))


@register_kernel("solve")
def _build_solve(attrs):
    return _solve


@register_kernel("cholesky")
def _build_cholesky(attrs):
    return lambda x: jnp.linalg.cholesky(densify(x).astype(jnp.float64))


@register_kernel("inv")
def _build_inv(attrs):
    return lambda x: jnp.linalg.inv(densify(x).astype(jnp.float64))


@register_kernel("diag")
def _build_diag(attrs):
    return lambda x: jnp.diagonal(densify(x))[:, None]


@register_kernel("diagm")
def _build_diagm(attrs):
    return lambda x: jnp.diag(densify(x)[:, 0])


@register_kernel("slice")
def _build_slice(attrs):
    index = attrs["index"]
    return lambda x: _slice(x, index)


@register_kernel("reshape")
def _build_reshape(attrs):
    newshape = attrs["newshape"]
    return lambda x: jnp.reshape(densify(x), newshape)


def _build_concat(attrs):
    axis = attrs["axis"]
    return lambda *xs: jnp.concatenate([densify(x) for x in xs], axis=axis)


_KERNEL_BUILDERS["rbind"] = _build_concat
_KERNEL_BUILDERS["cbind"] = _build_concat


@register_kernel("where")
def _build_where(attrs):
    return lambda c, a, b: jnp.where(densify(c) != 0, densify(a), densify(b))


@register_kernel("replace_nan")
def _build_replace_nan(attrs):
    value = attrs["value"]
    return lambda x: jnp.nan_to_num(densify(x), nan=value)


@register_kernel("cumsum")
def _build_cumsum(attrs):
    return lambda x: jnp.cumsum(densify(x), axis=0)


@register_kernel("literal")
def _build_literal(attrs):
    value = attrs["value"]
    return lambda: jnp.asarray(value)


@register_kernel("full")
def _build_full(attrs):
    shape, value = attrs.get("_shape", ()), attrs["value"]
    return lambda: jnp.full(shape, value)


@register_kernel("eye")
def _build_eye(attrs):
    n = attrs["_shape"][0]
    return lambda: jnp.eye(n)


@register_kernel("seq")
def _build_seq(attrs):
    n = attrs["_shape"][0]
    start, step = attrs["start"], attrs["step"]
    return lambda: (start + step * jnp.arange(n, dtype=jnp.float64))[:, None]


@register_kernel("rand")
def _build_rand(attrs):
    shape, seed = attrs["_shape"], attrs["seed"]
    dist = attrs.get("dist")
    sp = attrs.get("sparsity_gen", 1.0)

    def run():
        key = jax.random.PRNGKey(seed)
        if dist == "normal":
            out = jax.random.normal(key, shape, dtype=jnp.float64)
        else:
            out = jax.random.uniform(key, shape, dtype=jnp.float64)
        if sp < 1.0:
            key2 = jax.random.PRNGKey(seed + 0x9E3779B9)
            mask = jax.random.uniform(key2, shape) < sp
            out = jnp.where(mask, out, 0.0)
        return out
    return run


@lru_cache(maxsize=4096)
def _kernel_cached(op: str, attrs: tuple, shape: tuple) -> KernelFn:
    d = dict(attrs)
    d["_shape"] = shape
    return get_kernel(op, d)


def kernel_for_node(node) -> KernelFn:
    """Memoized kernel lookup for a HOP node — kernels depend only on
    (op, attrs, shape), so repeated plan executions (the interpreter
    loop, segment lowering) reuse one closure instead of rebuilding."""
    return _kernel_cached(node.op, node.attrs, node.shape)


def execute_op(op: str, attrs: dict[str, Any], inputs: list) -> Any:
    """Execute one instruction eagerly; inputs are jnp arrays (or BCOO)."""
    return get_kernel(op, attrs)(*inputs)


def to_numpy(x) -> np.ndarray:
    return np.asarray(densify(x))
