"""Dispatching wrapper for the WKV6 kernel (model layout <-> kernel layout)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import kernel, ref


def wkv6(r, k, v, logw, u, state, *, chunk: int = 128,
         use_pallas: Optional[bool] = None, interpret: bool = False):
    """Model layout: r,k,v,logw (B, S, H, dh); u (H, dh);
    state (B, H, dh, dh). Returns (y (B,S,H,dh), state')."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not (use_pallas or interpret):
        # pure-JAX chunked path lives in repro.models.rwkv6
        from repro.models.rwkv6 import wkv_chunked
        return wkv_chunked(r, k, v, logw, u, state, chunk)
    B, S, H, dh = r.shape
    fl = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    u_f = jnp.broadcast_to(u[None], (B, H, dh)).reshape(B * H, dh)
    s_f = state.reshape(B * H, dh, dh)
    y, s_out = kernel.wkv6_pallas(fl(r), fl(k), fl(v), fl(logw), u_f, s_f,
                                  chunk=chunk, interpret=interpret)
    y = y.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
    return y, s_out.reshape(B, H, dh, dh)
