"""LineageRuntime: the control program (SystemDS §3.2 Fig. 3-3).

Interprets compiled plans instruction-by-instruction, maintains the
intermediate environment (buffer pool with liveness-based frees), traces
lineage for every executed operation, and probes/populates the lineage
reuse cache (§4.1).

`PreparedScript` is the JMLC analogue: trace a python function once into
a DAG with placeholder leaves, then re-execute with new in-memory inputs
at low latency (plan is compiled once; lineage is recomputed per input so
reuse stays sound).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import numpy as np

from . import backend
from .compiler import Plan, compile_plan
from .dag import LEAVES, LTensor, Node, _lhash_rec, input_tensor
from .jit_cache import get_jit_cache
from .reuse import ReuseCache


@dataclass
class RuntimeStats:
    instructions: int = 0
    executed: int = 0      # instructions actually computed (not reused)
    reused: int = 0
    exec_time: float = 0.0
    segments: int = 0        # segments dispatched on the fused path
    jit_cache_hits: int = 0  # warm compiled-executable lookups
    trace_time: float = 0.0  # seconds spent tracing+compiling segments

    def as_dict(self):
        return dict(instructions=self.instructions, executed=self.executed,
                    reused=self.reused, exec_time_s=round(self.exec_time, 6),
                    segments=self.segments,
                    jit_cache_hits=self.jit_cache_hits,
                    trace_time_s=round(self.trace_time, 6))


class LineageRuntime:
    """Executes plans with lineage tracing and optional reuse."""

    def __init__(self, cache: Optional[ReuseCache] = None,
                 opt_level: int = 2, sparse_inputs: bool = False,
                 fuse: bool = True):
        # sparse_inputs: BCOO physical representation for low-density
        # leaves. Default OFF: measured on this backend (XLA-CPU),
        # BCOO gram at density 0.1 is ~4x SLOWER than dense — SystemDS's
        # hand-tuned CSR kernels have no XLA analogue (DESIGN.md §2a,
        # EXPERIMENTS.md §Baseline). The path stays for API fidelity.
        #
        # fuse: execute plans as jit-compiled segments (see
        # repro.core.segments). BCOO values are not traced through the
        # fused path, so sparse_inputs forces the per-instruction
        # interpreter.
        self.cache = cache
        self.opt_level = opt_level
        self.sparse_inputs = sparse_inputs
        self.fuse = fuse
        self.stats = RuntimeStats()

    # ------------------------------------------------------------------
    def evaluate(self, outputs: Sequence[LTensor]) -> list[np.ndarray]:
        plan = compile_plan(list(outputs),
                            reuse_enabled=self.cache is not None,
                            opt_level=self.opt_level)
        return self.run_plan(plan)

    # ------------------------------------------------------------------
    def run_plan(self, plan: Plan,
                 leaf_values: Optional[dict[int, Any]] = None,
                 leaf_lineage: Optional[dict[int, str]] = None) -> list[np.ndarray]:
        values, lin = self._bind_leaves(plan, leaf_values, leaf_lineage)
        if self.fuse and not self.sparse_inputs and self.cache is None:
            self._run_segments(plan, values)
        else:
            # Reuse-active execution IS the boundary interpreter: with a
            # cache, segmentation degenerates to one instruction per
            # segment (see segments.py), and the per-instruction loop
            # probes/populates the cache at exactly those boundaries with
            # cost measurements identical across fuse modes.
            self._run_instructions(plan, values, lin)
        return [backend.to_numpy(values[i]) for i in plan.output_ids]

    # ------------------------------------------------------------------
    def _bind_leaves(self, plan: Plan,
                     leaf_values: Optional[dict[int, Any]],
                     leaf_lineage: Optional[dict[int, str]]
                     ) -> tuple[dict[int, Any], dict[int, str]]:
        values: dict[int, Any] = {}
        lin: dict[int, str] = {}
        if self.cache is not None:  # lineage only drives reuse probing
            lin = dict(LEAVES.lineage)
            if leaf_lineage:
                lin.update(leaf_lineage)
        for ins in plan.instructions:
            for inp in ins.node.inputs:
                if inp.op == "input" and inp.uid not in values:
                    src = None
                    if leaf_values and inp.uid in leaf_values:
                        src = leaf_values[inp.uid]
                    elif inp.uid in LEAVES.values:
                        src = LEAVES.values[inp.uid]
                    else:
                        raise KeyError(
                            f"unbound input leaf {inp.attr('name')}")
                    arr = np.asarray(src)
                    val = arr
                    if self.sparse_inputs:
                        val = backend.maybe_sparsify(arr, inp.sparsity)
                    values[inp.uid] = val
        for r in plan.roots:  # outputs that are themselves leaves
            if r.op == "input" and r.uid not in values:
                values[r.uid] = (leaf_values or LEAVES.values)[r.uid]
        return values, lin

    # ------------------------------------------------------------------
    def _run_instructions(self, plan: Plan, values: dict[int, Any],
                          lin: dict[int, str]) -> None:
        """Per-instruction interpreter (the `fuse=False` fallback and the
        BCOO path); probes/populates the reuse cache at every op."""
        lmemo: dict[int, str] = {}  # lineage-hash memo shared across the run
        for ins in plan.instructions:
            self.stats.instructions += 1
            node = ins.node
            lhash = None
            if self.cache is not None:
                lhash = _lhash_rec(node, lin, lmemo)
                hit = self.cache.probe(lhash)
                if hit is not None:
                    values[ins.out_id] = hit
                    self.stats.reused += 1
                    self._free(values, ins.last_use_of, plan)
                    continue
            ins_inputs = [values[i] for i in ins.input_ids]
            t0 = time.perf_counter()
            out = backend.kernel_for_node(node)(*ins_inputs)
            if hasattr(out, "block_until_ready"):
                out.block_until_ready()
            dt = time.perf_counter() - t0
            self.stats.executed += 1
            self.stats.exec_time += dt
            values[ins.out_id] = out
            if self.cache is not None:
                self.cache.put(lhash, out, dt)
            self._free(values, ins.last_use_of, plan)

    # ------------------------------------------------------------------
    def _run_segments(self, plan: Plan, values: dict[int, Any]) -> None:
        """Segment executor (the fused, cache-less path): maximal fusable
        runs replayed through cached jit executables."""
        segments = plan.segments_for(False)
        jcache = get_jit_cache()
        for seg in segments:
            self.stats.segments += 1
            self.stats.instructions += len(seg.instructions)
            args = [values[u] for u in seg.input_uids]
            key, exe = jcache.lookup(seg.key, args)
            if exe is None:
                from .segments import build_segment_fn
                exe, dt_trace = jcache.compile(
                    key, build_segment_fn(seg), args)
                self.stats.trace_time += dt_trace
            else:
                self.stats.jit_cache_hits += 1
            t0 = time.perf_counter()
            outs = exe(*args)
            for o in outs:
                if hasattr(o, "block_until_ready"):
                    o.block_until_ready()
            dt = time.perf_counter() - t0
            self.stats.executed += len(seg.instructions)
            self.stats.exec_time += dt
            for uid, val in zip(seg.output_uids, outs, strict=True):
                values[uid] = val
            self._free(values, seg.frees, plan)

    @staticmethod
    def _free(values: dict[int, Any], uids: tuple[int, ...], plan: Plan):
        for uid in uids:
            values.pop(uid, None)


# ---------------------------------------------------------------------------
# Module-level convenience (a default runtime without reuse)
# ---------------------------------------------------------------------------

_default_runtime: Optional[LineageRuntime] = None


def get_runtime() -> LineageRuntime:
    global _default_runtime
    if _default_runtime is None:
        _default_runtime = LineageRuntime()
    return _default_runtime


def set_runtime(rt: LineageRuntime) -> None:
    global _default_runtime
    _default_runtime = rt


def evaluate(*outputs: LTensor, runtime: Optional[LineageRuntime] = None
             ) -> list[np.ndarray]:
    rt = runtime or get_runtime()
    return rt.evaluate(list(outputs))


def value(x: LTensor, runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    return evaluate(x, runtime=runtime)[0]


# ---------------------------------------------------------------------------
# PreparedScript (JMLC-style precompiled script, §3.1)
# ---------------------------------------------------------------------------

class PreparedScript:
    """Compile a DSL function once; execute repeatedly with new inputs."""

    def __init__(self, fn: Callable[..., Any],
                 arg_shapes: Sequence[tuple[int, ...]],
                 arg_dtypes: Optional[Sequence[Any]] = None,
                 runtime: Optional[LineageRuntime] = None):
        self.runtime = runtime or get_runtime()
        dtypes = arg_dtypes or [np.float64] * len(arg_shapes)
        self._leaves = [
            input_tensor(f"arg{i}", np.zeros(s, dtype=d))
            for i, (s, d) in enumerate(zip(arg_shapes, dtypes))]
        outs = fn(*self._leaves)
        if isinstance(outs, LTensor):
            outs = [outs]
        self._outputs = list(outs)
        self.plan = compile_plan(
            self._outputs, reuse_enabled=self.runtime.cache is not None,
            opt_level=self.runtime.opt_level)

    def __call__(self, *arrays) -> list[np.ndarray]:
        assert len(arrays) == len(self._leaves)
        leaf_values: dict[int, Any] = {}
        leaf_lineage: dict[int, str] = {}
        # content fingerprints keep reuse sound across re-binds, but they
        # cost a hash pass per input — only lineage consumers (a reuse
        # cache) need them
        need_lineage = self.runtime.cache is not None
        from .dag import _fingerprint
        for leaf, arr in zip(self._leaves, arrays):
            arr = np.asarray(arr)
            leaf_values[leaf.node.uid] = arr
            if need_lineage:
                leaf_lineage[leaf.node.uid] = \
                    f"{leaf.node.attr('name')}:{_fingerprint(arr)}"
        return self.runtime.run_plan(self.plan, leaf_values, leaf_lineage)


# ---------------------------------------------------------------------------
# Lineage trace export (§4.1 — debugging / versioning over lineage)
# ---------------------------------------------------------------------------

def lineage_trace(x: LTensor) -> str:
    """Serialize the lineage DAG in a SystemDS-log-like text format."""
    lines: list[str] = []
    seen: dict[int, int] = {}

    def rec(n: Node) -> int:
        if n.uid in seen:
            return seen[n.uid]
        args = [rec(i) for i in n.inputs]
        idx = len(lines)
        seen[n.uid] = idx
        if n.op == "input":
            lid = LEAVES.lineage.get(n.uid, f"input:{n.attr('name')}")
            lines.append(f"({idx}) L·input {lid}")
        elif n.op == "literal":
            lines.append(f"({idx}) L·lit {n.attr('value')}")
        else:
            attrs = {k: v for k, v in n.attrs if k != "index"}
            ref = " ".join(f"({a})" for a in args)
            lines.append(f"({idx}) L·{n.op} {ref} {attrs or ''}".rstrip())
        return idx

    rec(x.node)
    return "\n".join(lines)
