"""Production mesh builders.

Functions (not module-level constants) so importing this module never
touches jax device state — required for the forced-512-device dry-run
to control initialization order.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
