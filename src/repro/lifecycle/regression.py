"""Regression builtins: lm / lmDS / lmCG / steplm (paper Fig. 2).

Faithful ports of the DML builtins. `steplm` is Example 1: stepwise
forward feature selection by AIC, whose what-if `lm` calls expose the
fine-grained redundancy that lineage-based partial reuse eliminates
(gram(cbind(X_sel, c)) decomposes into a cached gram(X_sel) + fringe).

All builtins here are *placement-neutral* (§3.3): pass a
`federated_input` leaf as X and the same DSL programs compile to
federated plans — the optimizer lowers `gram`/`xtv` to `fed_gram`/
`fed_xtv`, per-site work runs as compiled sub-segments, and only
aggregates cross the exchange boundary. `lmDS_federated` /
`steplm_federated` are thin wrappers that bind a `FederatedTensor` and
call the ordinary builtins — there is no second federated code path.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import ops
from repro.core.dag import LTensor, input_tensor
from repro.core.federated import FederatedTensor, federated_input
from repro.core.runtime import LineageRuntime, get_runtime


def _rt(runtime: Optional[LineageRuntime]) -> LineageRuntime:
    return runtime or get_runtime()


def lmDS(X: LTensor, y: LTensor, reg: float = 1e-7, intercept: bool = False,
         runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """Closed-form ("direct solve") linear regression.

    beta = solve(t(X) %*% X + reg*I, t(X) %*% y) — the X^T X / X^T y pair
    is the paper's reusable intermediate (100.2 GFLOP per model at
    100K×1K, independent of reg)."""
    if intercept:
        X = ops.cbind(X, ops.ones((X.shape[0], 1)))
    n = X.shape[1]
    A = X.T @ X + reg * ops.eye(n)
    b = X.T @ y
    beta = ops.solve(A, b)
    return _rt(runtime).evaluate([beta])[0]


def lmCG(X: LTensor, y: LTensor, reg: float = 1e-7, tol: float = 1e-9,
         max_iter: int = 100,
         runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """Conjugate gradient on the normal equations (never forms t(X)%*%X).

    Mirrors DML lmCG: the hot ops are MV/VM against X; control flow runs
    in the control program (host), per SystemDS's hybrid plans."""
    rt = _rt(runtime)
    m, n = X.shape
    beta = np.zeros((n, 1))
    r_t = X.T @ y                       # initial residual = X^T y - A*0
    r = rt.evaluate([r_t])[0]
    p = r.copy()
    rs_old = float((r * r).sum())
    for _ in range(max_iter):
        pt = input_tensor("p", p)
        q_t = X.T @ (X @ pt) + reg * pt
        q = rt.evaluate([q_t])[0]
        alpha = rs_old / float((p * q).sum())
        beta = beta + alpha * p
        r = r - alpha * q
        rs_new = float((r * r).sum())
        if rs_new < tol * max(rs_old, 1e-30):
            break
        p = r + (rs_new / rs_old) * p
        rs_old = rs_new
    return beta


def lm(X: LTensor, y: LTensor, reg: float = 1e-7, intercept: bool = False,
       runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """DML `lm` dispatch: direct solve for narrow X, CG otherwise."""
    if X.shape[1] <= 1024:
        return lmDS(X, y, reg=reg, intercept=intercept, runtime=runtime)
    return lmCG(X, y, reg=reg, runtime=runtime)


def lmDS_federated(fx: FederatedTensor, y, reg: float = 1e-7,
                   intercept: bool = False,
                   runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """Federated lmDS *through the compiler* (§4.3 Example 2).

    Expressed in the DSL — the identical `lmDS` program over a
    federated leaf. The placement pass emits `fed_gram`/`fed_xtv`
    (intercept: the ones column is generated per site by the lowered
    `fed_map` cbind, exactly like the eager oracle), so exchange bytes
    match `repro.core.federated.federated_lmds` while per-site work
    runs fused and federated intermediates participate in lineage
    reuse. Exchange is metered in `runtime.stats.exchange`.
    """
    X = federated_input("fedX", fx)
    yt = y if isinstance(y, LTensor) else input_tensor("fedy", np.asarray(y))
    return lmDS(X, yt, reg=reg, intercept=intercept, runtime=runtime)


def steplm_federated(fx: FederatedTensor, y, reg: float = 1e-7,
                     max_features: Optional[int] = None,
                     intercept: bool = True,
                     runtime: Optional[LineageRuntime] = None
                     ) -> tuple[np.ndarray, list[int]]:
    """Federated stepwise regression (Example 1 over Example 2's data).

    The ordinary `steplm` DSL program over a federated leaf: candidate
    columns stay on their sites (`fed_map` slice/cbind), every
    candidate gram/xtv lowers to `fed_gram`/`fed_xtv`, and with a reuse
    cache attached the compensation-plan rewrite caches `fed_gram` of
    the selected block across candidates — federated partial reuse.
    """
    X = federated_input("fedX", fx)
    yt = y if isinstance(y, LTensor) else input_tensor("fedy", np.asarray(y))
    return steplm(X, yt, reg=reg, max_features=max_features,
                  intercept=intercept, runtime=runtime)


def _aic(n: int, rss: float, k: int) -> float:
    return n * float(np.log(max(rss, 1e-300) / n)) + 2.0 * k


def steplm(X: LTensor, y: LTensor, reg: float = 1e-7, max_features:
           Optional[int] = None, intercept: bool = True,
           runtime: Optional[LineageRuntime] = None
           ) -> tuple[np.ndarray, list[int]]:
    """Stepwise linear regression (Example 1, Fig. 2).

    Greedy forward selection on AIC. Each candidate model is lm() over
    cbind(X_selected, X[:, c]); with a reuse cache attached to the
    runtime, the compensation-plan rewrite turns gram(cbind(S, c)) into
    [[gram(S), xtv(S,c)], [t(xtv(S,c)), gram(c)]] so gram(S) — the bulk
    of the work — is computed once per outer iteration.
    """
    rt = _rt(runtime)
    m, ncol = X.shape
    y_np = rt.evaluate([y])[0] if not isinstance(y, np.ndarray) else y

    selected: list[int] = []
    # intercept-only baseline
    mean_y = float(y_np.mean())
    rss = float(((y_np - mean_y) ** 2).sum())
    best_aic = _aic(m, rss, 1)
    limit = max_features if max_features is not None else ncol
    best_beta = np.array([[mean_y]])

    cols = {c: X[:, c:c + 1] for c in range(ncol)}
    icpt = ops.ones((m, 1)) if intercept else None

    while len(selected) < limit:
        base_cols = ([icpt] if intercept else []) \
            + [cols[c] for c in selected]
        base = ops.cbind(*base_cols) if base_cols else None
        best_c, best_c_aic, best_c_beta = -1, best_aic, None
        for c in range(ncol):
            if c in selected:
                continue
            Xc = ops.cbind(base, cols[c]) if base is not None else cols[c]
            k = len(selected) + 1 + int(intercept)
            A = ops.gram(Xc) + reg * ops.eye(k)
            b = ops.xtv(Xc, y)
            beta_t = ops.solve(A, b)
            resid = y - Xc @ beta_t
            rss_t = ops.sum_(resid * resid)
            beta_v, rss_v = rt.evaluate([beta_t, rss_t])
            aic = _aic(m, float(rss_v), k + 1)
            if aic < best_c_aic:
                best_c, best_c_aic, best_c_beta = c, aic, beta_v
        if best_c < 0:
            break  # AIC no longer improves
        selected.append(best_c)
        best_aic = best_c_aic
        best_beta = best_c_beta
    return best_beta, selected
