"""Out-of-core chunked execution (ROADMAP item 4).

Covers the `lower_chunked` placement pass and the streaming runtime
lane:

  * compile-time — budget-gated lowering of row-partitionable
    reductions to `chunk_*` partial aggregates behind an explicit
    `combine` boundary, chunked-prefix propagation, `Plan.explain()`
    markers, inertness for in-budget plans and non-decomposable
    consumers (quantile fallback);
  * runtime — 3-way parity (streaming vs materialized-fused vs
    interpreter) for lmDS / PCA / cleaning on dense AND sparse inputs,
    one warm executable across all full chunks (zero retraces),
    `peak_live_bytes` bounded by the chunk memory budget;
  * incremental recompute — appending rows re-dispatches only the new
    tail buckets, correcting one value re-dispatches exactly its
    bucket, unchanged re-runs short-circuit the whole stream, and
    reuse hit counts stay identical across fuse modes;
  * I/O — `read_csv_chunks` yields the same rows as `read_csv`, one
    row bucket at a time.
"""
import numpy as np
import pytest

from repro.core import costmodel, ops
from repro.core.compiler import compile_plan
from repro.core.dag import input_tensor
from repro.core.jit_cache import get_jit_cache
from repro.core.reuse import ReuseCache
from repro.core.runtime import LineageRuntime
from repro.lifecycle.algorithms import pca
from repro.lifecycle.cleaning import impute_by_mean, outlier_by_iqr
from repro.lifecycle.regression import lmDS

BUDGET = 1 << 16  # 64 KiB: forces streaming on modest test matrices


@pytest.fixture(autouse=True)
def tiny_budget(monkeypatch):
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", BUDGET)


def _lm_ref(Xh, yh, reg=1e-3):
    return np.linalg.solve(Xh.T @ Xh + reg * np.eye(Xh.shape[1]),
                           Xh.T @ yh)


def _lm_run(rt, Xh, yh, reg=1e-3):
    X = input_tensor("X", Xh)
    y = input_tensor("y", yh)
    return np.asarray(lmDS(X, y, reg=reg, runtime=rt)).ravel()


def _dense(rng, m=4096, n=8):
    return rng.normal(size=(m, n)), rng.normal(size=(m,))


def _sparse(rng, m=8192, n=32, density=0.1):
    X = rng.normal(size=(m, n)) * (rng.random((m, n)) < density)
    return X, rng.normal(size=(m,))


# ---------------------------------------------------------------------------
# compile time
# ---------------------------------------------------------------------------

def test_lower_chunked_plan_structure(rng):
    Xh, yh = _dense(rng)
    X = input_tensor("X", Xh)
    y = input_tensor("y", yh)
    beta = ops.solve(X.T @ X + 1e-3 * ops.eye(8), X.T @ y)
    plan = compile_plan([beta], reuse_enabled=True)
    ops_seen = plan.count_ops()
    assert ops_seen.get("chunk_gram") == 1
    assert ops_seen.get("chunk_xtv") == 1
    assert ops_seen.get("combine") == 2
    assert X.node.uid in plan.chunk_sliced
    assert plan.chunk_sliced[X.node.uid] == 4096
    txt = plan.explain(reuse_active=True)
    assert "[chunked]" in txt
    assert ":chunk" in txt
    assert "[combine-boundary]" in txt
    # gram and xtv cluster into ONE streaming segment: a single pass
    # over the data serves both partial aggregates
    segs = plan.segments_for(True)
    chunked = [s for s in segs if s.chunked]
    assert len(chunked) == 1
    assert {i.node.op for i in chunked[0].instructions} >= {
        "chunk_gram", "chunk_xtv"}


def test_in_budget_plans_are_untouched(rng, monkeypatch):
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", 1 << 30)
    Xh, yh = _dense(rng)
    X = input_tensor("X", Xh)
    y = input_tensor("y", yh)
    beta = ops.solve(X.T @ X + 1e-3 * ops.eye(8), X.T @ y)
    plan = compile_plan([beta], reuse_enabled=True)
    assert not plan.chunk_sliced
    assert all(not op.startswith("chunk_") for op in plan.count_ops())


def test_row_shaped_consumer_falls_back(rng):
    # quantile (sort-based order statistics) is not row-decomposable:
    # its operand keeps the local (materialization) track, and the plan
    # still executes correctly under a tiny budget
    Xh = rng.normal(size=(4096, 8))
    out = outlier_by_iqr(
        input_tensor("X", Xh), repair="clip",
        runtime=LineageRuntime(cache=None, fuse=True))
    q1 = np.quantile(Xh, 0.25, axis=0, keepdims=True)
    q3 = np.quantile(Xh, 0.75, axis=0, keepdims=True)
    lo, hi = q1 - 1.5 * (q3 - q1), q3 + 1.5 * (q3 - q1)
    assert np.allclose(out, np.clip(Xh, lo, hi), atol=1e-12)


# ---------------------------------------------------------------------------
# 3-way parity: streaming vs materialized-fused vs interpreter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dense", "sparse"])
def test_lmds_three_way_parity(rng, monkeypatch, kind):
    Xh, yh = _dense(rng) if kind == "dense" else _sparse(rng)
    ref = _lm_ref(Xh, yh)
    stream_rt = LineageRuntime(cache=ReuseCache(), fuse=True,
                               sparse_inputs=(kind == "sparse"))
    got_stream = _lm_run(stream_rt, Xh, yh)
    assert stream_rt.stats.streaming.chunks > 1
    interp_rt = LineageRuntime(cache=ReuseCache(), fuse=False,
                               sparse_inputs=(kind == "sparse"))
    got_interp = _lm_run(interp_rt, Xh, yh)
    assert interp_rt.stats.streaming.total == 0
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", 1 << 30)
    mat_rt = LineageRuntime(cache=ReuseCache(), fuse=True,
                            sparse_inputs=(kind == "sparse"))
    got_mat = _lm_run(mat_rt, Xh, yh)
    assert mat_rt.stats.streaming.total == 0
    for got in (got_stream, got_interp, got_mat):
        assert np.abs(got - ref.ravel()).max() < 1e-10


def _align_signs(a, b):
    s = np.sign(np.sum(a * b, axis=0))
    s[s == 0] = 1.0
    return b * s


@pytest.mark.parametrize("kind", ["dense", "sparse"])
def test_pca_three_way_parity(rng, monkeypatch, kind):
    Xh, _ = _dense(rng) if kind == "dense" else _sparse(rng)
    k = 3
    runs = {}
    for mode in ("stream", "interp", "mat"):
        if mode == "mat":
            monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", 1 << 30)
        rt = LineageRuntime(cache=ReuseCache(), fuse=(mode != "interp"),
                            sparse_inputs=(kind == "sparse"))
        comps, _proj = pca(input_tensor("X", Xh), k, runtime=rt)
        runs[mode] = np.asarray(comps)
        if mode == "stream":
            assert rt.stats.streaming.chunks > 1
    for mode in ("interp", "mat"):
        aligned = _align_signs(runs["stream"], runs[mode])
        assert np.abs(runs["stream"] - aligned).max() < 1e-8


def test_cleaning_three_way_parity(rng, monkeypatch):
    Xh, _ = _dense(rng)
    Xh = Xh.copy()
    Xh[rng.random(Xh.shape) < 0.07] = np.nan
    mu = np.nanmean(Xh, axis=0, keepdims=True)
    ref = np.where(np.isnan(Xh), mu, Xh)
    got = {}
    for mode in ("stream", "interp", "mat"):
        if mode == "mat":
            monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", 1 << 30)
        rt = LineageRuntime(cache=ReuseCache(), fuse=(mode != "interp"))
        got[mode] = impute_by_mean(input_tensor("X", Xh), runtime=rt)
        if mode == "stream":
            # the colSums pair streams even though the imputed matrix
            # itself is row-shaped (materialization fallback for it)
            assert rt.stats.streaming.chunks > 1
    for mode in got:
        assert np.abs(got[mode] - ref).max() < 1e-10


# ---------------------------------------------------------------------------
# executable hygiene + memory bound
# ---------------------------------------------------------------------------

def test_one_executable_serves_all_chunks(rng):
    Xh, yh = _dense(rng)  # 4096 rows: the bucket divides evenly
    rt = LineageRuntime(cache=None, fuse=True)
    before = get_jit_cache().stats.misses
    _lm_run(rt, Xh, yh)
    s = rt.stats.streaming
    assert s.chunks > 8
    # every chunk replays ONE warm executable per streaming segment:
    # compiles stay bounded by the segment count, never the chunk count
    misses = get_jit_cache().stats.misses - before
    assert misses <= rt.stats.segments
    assert rt.stats.jit_cache_hits >= s.chunks - 1


def test_peak_live_bytes_under_budget(rng):
    Xh, yh = _dense(rng)
    rt = LineageRuntime(cache=None, fuse=True)
    _lm_run(rt, Xh, yh)
    s = rt.stats.streaming
    assert 0 < s.peak_live_bytes <= BUDGET
    assert s.bytes_streamed >= Xh.nbytes  # the whole input did stream


# ---------------------------------------------------------------------------
# incremental recompute (the delta engine)
# ---------------------------------------------------------------------------

def test_full_aggregate_short_circuit(rng):
    Xh, yh = _dense(rng)
    rt = LineageRuntime(cache=ReuseCache(), fuse=True)
    first = _lm_run(rt, Xh, yh)
    s1 = rt.stats.streaming
    chunks1 = s1.chunks
    second = _lm_run(rt, Xh, yh)  # fresh leaves, identical content
    assert np.array_equal(first, second)
    assert s1.full_hits == 1
    assert s1.chunks == chunks1  # not a single extra dispatch


def test_append_redispatches_only_new_chunks(rng):
    Xh, yh = _dense(rng)
    rt = LineageRuntime(cache=ReuseCache(), fuse=True)
    _lm_run(rt, Xh, yh)
    s = rt.stats.streaming
    base_chunks, base_reused = s.chunks, s.chunks_reused
    extra = 409  # +10%
    Xa = np.vstack([Xh, rng.normal(size=(extra, Xh.shape[1]))])
    ya = np.concatenate([yh, rng.normal(size=(extra,))])
    got = _lm_run(rt, Xa, ya)
    assert np.abs(got - _lm_ref(Xa, ya).ravel()).max() < 1e-10
    new = s.chunks - base_chunks
    reused = s.chunks_reused - base_reused
    # the bucket size depends only on the budget and row payload, so
    # appending never shifts earlier boundaries: every old full bucket
    # hits, only the appended tail (extra / bucket, +1 ragged) runs
    assert reused == base_chunks
    assert 1 <= new <= extra // 16 + 1
    assert new < base_chunks / 4


def test_correction_redispatches_one_chunk(rng):
    Xh, yh = _dense(rng)
    rt = LineageRuntime(cache=ReuseCache(), fuse=True)
    _lm_run(rt, Xh, yh)
    s = rt.stats.streaming
    base_chunks, base_reused = s.chunks, s.chunks_reused
    Xc = Xh.copy()
    Xc[777, 3] = 42.0  # one cell, one bucket
    got = _lm_run(rt, Xc, yh)
    assert np.abs(got - _lm_ref(Xc, yh).ravel()).max() < 1e-10
    assert s.chunks - base_chunks == 1
    assert s.chunks_reused - base_reused == base_chunks - 1


def test_reuse_hits_identical_across_fuse_modes(rng):
    Xh, yh = _dense(rng)
    counts = {}
    for fuse in (True, False):
        rt = LineageRuntime(cache=ReuseCache(), fuse=fuse)
        a = _lm_run(rt, Xh, yh)
        b = _lm_run(rt, Xh, yh)
        assert np.array_equal(a, b)
        counts[fuse] = (rt.stats.reused, rt.cache.stats.hits)
    # the streaming executor probes exactly the probe-flagged outputs
    # the interpreter probes, so warm-run hit counts cannot diverge
    assert counts[True] == counts[False]


# ---------------------------------------------------------------------------
# chunked CSV ingestion
# ---------------------------------------------------------------------------

def test_read_csv_chunks_matches_read_csv(rng, tmp_path):
    from repro.data.csv_io import read_csv, read_csv_chunks, write_csv
    x = rng.normal(size=(1000, 5))
    path = str(tmp_path / "x.csv")
    write_csv(path, x, fmt="%.17g")
    full = read_csv(path)
    parts = list(read_csv_chunks(path, 128, chunk_bytes=4096))
    assert [off for off, _ in parts] == list(range(0, 1000, 128))
    assert all(a.shape[0] == 128 for _, a in parts[:-1])
    assert parts[-1][1].shape[0] == 1000 - 128 * 7
    assert np.array_equal(np.vstack([a for _, a in parts]), full)
