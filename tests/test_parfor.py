"""Task-parallel batched execution (`parfor`, ISSUE 5): template
merging, invariant/variant segmentation, vmapped execution parity
against the sequential-reuse and interpreter paths, bucketed warm
executables, cost-model arbitration, federated exchange invariants, and
the bounded jit cache."""
import os
import sys

import numpy as np
import pytest

from repro.core import (LineageRuntime, ReuseCache, clear_jit_cache,
                        get_jit_cache, input_tensor, ops)
from repro.core.batching import (BatchingError, bucket_size, choose_mode,
                                 compile_batched)
from repro.core.dag import batch_input, is_batched_leaf
from repro.core.federated import FederatedTensor, federated_input
from repro.core.jit_cache import JitProgramCache
from repro.lifecycle.validation import (cross_validate_lm, grid_search_lm,
                                        make_folds, parfor)

LAMBDAS = [0.01, 0.1, 1.0, 10.0]


def _grid_runtimes(xn, yn, lambdas, sparse=False):
    """(batched, sequential-reuse, interpreter) results + runtimes."""
    runs = []
    for mode, rt in (
            ("vmap", LineageRuntime(sparse_inputs=sparse)),
            ("sequential", LineageRuntime(cache=ReuseCache(),
                                          sparse_inputs=sparse)),
            ("sequential", LineageRuntime(fuse=False,
                                          sparse_inputs=sparse))):
        X, y = input_tensor("gX", xn), input_tensor("gy", yn)
        betas, losses = grid_search_lm(X, y, lambdas, runtime=rt,
                                       mode=mode)
        runs.append((betas, losses, rt))
    return runs


class TestTemplateMerge:
    def test_bucket_sizes(self):
        assert [bucket_size(k) for k in (1, 2, 3, 5, 8, 9, 16, 17)] == \
            [2, 2, 4, 8, 8, 16, 16, 32]

    def test_batched_leaf(self):
        lam = batch_input("lams", np.array([0.1, 1.0, 10.0]))
        assert is_batched_leaf(lam.node)
        assert lam.shape == ()          # element shape, not stacked
        assert lam.node.attr("batch") == 3

    def test_batch_input_rejects_scalar(self):
        with pytest.raises(ValueError):
            batch_input("bad", np.float64(3.0))

    def test_merge_hoists_varying_literal(self, rng):
        x = input_tensor("mX", rng.normal(size=(32, 4)))
        outs = [[ops.gram(x) + lam * ops.eye(4)] for lam in LAMBDAS]
        bplan = compile_batched(outs)
        assert bplan.batch == 4 and bplan.bucket == 4
        assert len(bplan.batched_leaf_uids) == 1
        assert bplan.variant_uids        # the add is config-variant
        gram_ins = next(i for i in bplan.plan.instructions
                        if i.node.op == "gram")
        assert gram_ins.out_id not in bplan.variant_uids  # invariant

    def test_merge_hoists_varying_leaves(self, rng):
        arrs = [rng.normal(size=(16, 3)) for _ in range(3)]
        leaves = [input_tensor(f"vl{i}", a) for i, a in enumerate(arrs)]
        outs = [[ops.colSums(lv)] for lv in leaves]
        bplan = compile_batched(outs)
        assert len(bplan.batched_leaf_uids) == 1
        rt = LineageRuntime()
        per_config = rt.evaluate_batch(bplan)
        for a, (got,) in zip(arrs, per_config):
            np.testing.assert_allclose(got, a.sum(0, keepdims=True))

    def test_seed_grid_hoists_rand(self, rng):
        """`rand` generators differing only in seed batch as a stacked
        leaf — parity with the sequential path, which runs the same
        deterministic kernel in-plan."""
        seeds = [3, 5, 7]

        def model(seed):
            r = ops.rand((16, 4), seed=seed, dist="normal")
            return ops.colSums(r * r)

        rt = LineageRuntime()
        batched = parfor(seeds, model, runtime=rt, mode="vmap")
        assert rt.stats.batched_segments > 0
        sequential = parfor(seeds, model, mode="sequential")
        for (b,), (s,) in zip(batched, sequential):
            np.testing.assert_allclose(b, s, rtol=1e-12)

    def test_identical_seed_rand_stays_invariant(self, rng):
        """A fixed-seed rand rebuilt per config merges to one shared
        invariant node — never a batched leaf of k identical copies."""
        def model(lam):
            r = ops.rand((16, 4), seed=7, dist="normal")
            return ops.sum_(r * float(lam))
        bplan = compile_batched([[model(lam)] for lam in LAMBDAS])
        assert len(bplan.batched_leaf_uids) == 1     # just the λ grid
        rand_ins = next(i for i in bplan.plan.instructions
                        if i.node.op == "rand")
        assert rand_ins.out_id not in bplan.variant_uids

    def test_passthrough_leaf_output_and_no_aliasing(self, rng):
        """A shared input leaf returned untouched next to a variant
        output must bind on the batched path, and config-invariant
        outputs must be independent arrays per config."""
        zn = rng.normal(size=(4, 4))
        z = input_tensor("ptZ", zn)
        x = input_tensor("ptX", rng.normal(size=(32, 4)))
        outs = parfor(LAMBDAS,
                      lambda lam: (ops.colSums(x * float(lam)), z,
                                   ops.colSums(x)),
                      mode="vmap", runtime=LineageRuntime())
        for per_cfg in outs:
            np.testing.assert_allclose(per_cfg[1], zn)
        # invariant outputs are independent buffers per config (the
        # arrays themselves may be read-only jax views, like every
        # to_numpy result — so probe memory, not mutation)
        assert outs[0][2] is not outs[1][2]
        assert not np.shares_memory(outs[0][2], outs[1][2])

    def test_vmap_mode_single_config_raises(self, rng):
        x = input_tensor("k1X", rng.normal(size=(8, 4)))
        with pytest.raises(BatchingError):
            parfor([0.1], lambda lam: ops.sum_(x * float(lam)),
                   mode="vmap")

    def test_parfor_releases_hoisted_leaves(self, rng):
        """The (k, ...) stacks parfor hoists are unbound from the
        global leaf registry after the call — both on the vmap path
        and on the sequential fallback — so repeated grids don't grow
        resident memory without bound."""
        from repro.core.dag import LEAVES
        x = input_tensor("rlX", rng.normal(size=(32, 4)))
        for mode in ("vmap", "auto"):
            before = len(LEAVES.values)
            parfor(LAMBDAS, lambda lam: ops.colSums(x * float(lam)),
                   mode=mode, runtime=LineageRuntime())
            assert len(LEAVES.values) == before

    def test_identity_configs_return_per_config_leaves(self, rng):
        """Configs that return their (differing) input leaf untouched:
        the batched leaf IS the plan root and each config must get its
        own element back, not the whole stack."""
        arrs = [rng.normal(size=(4, 2)) for _ in range(2)]
        leaves = [input_tensor(f"id{i}", a) for i, a in enumerate(arrs)]
        outs = parfor([0, 1], lambda i: leaves[i], mode="vmap",
                      runtime=LineageRuntime())
        for (got,), want in zip(outs, arrs):
            assert got.shape == want.shape
            np.testing.assert_allclose(got, want)

    def test_batched_host_op_parity(self, rng):
        """A host op (quantile) in the config-variant suffix: looped
        per TRUE config on the host, padded back into the bucket —
        parity with the sequential path including non-pow2 k."""
        x = input_tensor("qX", np.abs(rng.normal(size=(64, 6))))

        def model(lam):
            return ops.sum_(ops.quantile(x * float(lam), 0.5))

        lams = [0.5, 1.0, 2.0]          # k=3, bucket 4
        batched = parfor(lams, model, mode="vmap",
                         runtime=LineageRuntime())
        sequential = parfor(lams, model, mode="sequential")
        for (b,), (s,) in zip(batched, sequential):
            np.testing.assert_allclose(b, s, rtol=1e-12)

    def test_structural_mismatch_raises(self, rng):
        x = input_tensor("sX", rng.normal(size=(16, 3)))
        outs = [[ops.colSums(x)], [ops.rowSums(x)]]
        with pytest.raises(BatchingError):
            compile_batched(outs)

    def test_shape_mismatch_raises(self, rng):
        a = input_tensor("sa", rng.normal(size=(16, 3)))
        b = input_tensor("sb", rng.normal(size=(8, 3)))
        with pytest.raises(BatchingError):
            compile_batched([[ops.colSums(a)], [ops.colSums(b)]])

    def test_parfor_falls_back_on_mismatch(self, rng):
        x = input_tensor("fbX", rng.normal(size=(16, 3)))
        rt = LineageRuntime()
        outs = parfor([0, 1], lambda i: ops.colSums(x) if i == 0
                      else ops.rowSums(x), runtime=rt)
        assert rt.stats.batched_segments == 0
        assert outs[0][0].shape == (1, 3) and outs[1][0].shape == (16, 1)

    def test_parfor_vmap_mode_propagates_error(self, rng):
        x = input_tensor("veX", rng.normal(size=(16, 3)))
        with pytest.raises(BatchingError):
            parfor([0, 1], lambda i: ops.colSums(x) if i == 0
                   else ops.rowSums(x), mode="vmap")

    def test_parfor_mode_validation(self):
        with pytest.raises(ValueError):
            parfor([1], lambda c: ops.ones((2, 2)), mode="nope")


class TestGridSearchParity:
    @pytest.mark.parametrize("sparse", [False, True])
    def test_three_way_parity(self, rng, sparse):
        if sparse:
            xn = rng.normal(size=(256, 32)) \
                * (rng.uniform(size=(256, 32)) < 0.05)
        else:
            xn = rng.normal(size=(120, 10))
        yn = rng.normal(size=(xn.shape[0], 1))
        (bb, lb, rt_b), (bs, ls, _), (bi, li, _) = \
            _grid_runtimes(xn, yn, LAMBDAS, sparse=sparse)
        assert rt_b.stats.batched_segments > 0
        np.testing.assert_allclose(bb, bs, rtol=1e-8, atol=1e-10)
        np.testing.assert_allclose(bb, bi, rtol=1e-8, atol=1e-10)
        np.testing.assert_allclose(lb, ls, rtol=1e-8)
        np.testing.assert_allclose(lb, li, rtol=1e-8)

    def test_grid_matches_numpy_reference(self, rng):
        xn = rng.normal(size=(120, 10))
        yn = rng.normal(size=(120, 1))
        X, y = input_tensor("rX", xn), input_tensor("ry", yn)
        betas, _ = grid_search_lm(X, y, LAMBDAS, mode="vmap",
                                  runtime=LineageRuntime())
        for j, lam in enumerate(LAMBDAS):
            ref = np.linalg.solve(xn.T @ xn + lam * np.eye(10),
                                  xn.T @ yn)
            np.testing.assert_allclose(betas[:, j:j + 1], ref,
                                       rtol=1e-6, atol=1e-9)

    def test_cv_three_way_parity(self, rng):
        xn = rng.normal(size=(160, 6))   # 4 equal folds of 40
        yn = rng.normal(size=(160, 1))
        results = []
        for mode, rt in (("vmap", LineageRuntime()),
                         ("sequential",
                          LineageRuntime(cache=ReuseCache())),
                         ("sequential", LineageRuntime(fuse=False))):
            fx, fy = make_folds(xn, yn, 4, seed=3)
            results.append(cross_validate_lm(fx, fy, runtime=rt,
                                             mode=mode))
        (bb, eb), (bs, es), (bi, ei) = results
        np.testing.assert_allclose(bb, bs, rtol=1e-8, atol=1e-10)
        np.testing.assert_allclose(bb, bi, rtol=1e-8, atol=1e-10)
        np.testing.assert_allclose(eb, es, rtol=1e-8)
        np.testing.assert_allclose(eb, ei, rtol=1e-8)

    def test_cv_unequal_folds_fall_back(self, rng):
        xn = rng.normal(size=(163, 5))   # array_split -> 41,41,41,40
        yn = rng.normal(size=(163, 1))
        fx, fy = make_folds(xn, yn, 4, seed=4)
        rt = LineageRuntime(cache=ReuseCache())
        betas, errs = cross_validate_lm(fx, fy, runtime=rt)
        assert rt.stats.batched_segments == 0   # sequential fallback
        assert betas.shape == (5, 4) and len(errs) == 4

    def test_invariant_output_shared_across_configs(self, rng):
        x = input_tensor("ioX", rng.normal(size=(32, 4)))
        rt = LineageRuntime()
        outs = parfor(LAMBDAS,
                      lambda lam: (ops.colSums(x),
                                   ops.sum_(x * float(lam))),
                      runtime=rt, mode="vmap")
        ref = np.asarray(outs[0][0])
        for per_cfg, lam in zip(outs, LAMBDAS):
            np.testing.assert_allclose(per_cfg[0], ref)
            np.testing.assert_allclose(
                per_cfg[1], float(lam) * rt.evaluate([ops.sum_(x)])[0])


class TestBatchedSegments:
    def _bplan(self, rng, lambdas=LAMBDAS, reuse=False):
        x = input_tensor("segX", rng.normal(size=(64, 8)))
        y = input_tensor("segy", rng.normal(size=(64, 1)))

        def model(lam):
            A = ops.gram(x) + float(lam) * ops.eye(8)
            return ops.solve(A, ops.xtv(x, y))
        return compile_batched([[model(lam)] for lam in lambdas],
                               reuse_enabled=reuse)

    def test_variance_splits_segments(self, rng):
        bplan = self._bplan(rng)
        segs = bplan.segments_for(False)
        assert any(s.variant for s in segs)
        assert any(not s.variant for s in segs)
        # gram/xtv (invariant) never share a segment with the solve
        for s in segs:
            ops_in_seg = {i.node.op for i in s.instructions}
            if s.variant:
                assert "gram" not in ops_in_seg
                assert "xtv" not in ops_in_seg
            else:
                assert "solve" not in ops_in_seg

    def test_explain_annotations(self, rng):
        bplan = self._bplan(rng)
        txt = bplan.explain()
        assert f"[batch={bplan.batch}]" in txt
        assert "[config-invariant]" in txt
        assert "batched-leaf" in txt
        assert "[hoisted scalar]" in txt

    def test_warm_executables_within_bucket(self, rng):
        """k=5 and k=7 share the bucket-of-8 padded shapes: the second
        grid replays the first grid's compiled executables."""
        clear_jit_cache()
        xn = rng.normal(size=(96, 8))
        yn = rng.normal(size=(96, 1))
        lams5 = [float(i + 1) for i in range(5)]
        lams7 = [float(i + 1) for i in range(7)]
        X, y = input_tensor("wX", xn), input_tensor("wy", yn)
        rt1 = LineageRuntime()
        grid_search_lm(X, y, lams5, runtime=rt1, mode="vmap")
        assert rt1.stats.trace_time > 0
        st = get_jit_cache().stats
        misses_before, hits_before = st.misses, st.hits
        rt2 = LineageRuntime()
        grid_search_lm(X, y, lams7, runtime=rt2, mode="vmap")
        assert st.misses == misses_before      # nothing re-traced
        assert st.hits > hits_before
        assert rt2.stats.trace_time == 0.0

    def test_reuse_probe_hits_on_repeated_grid(self, rng):
        """Variant probe points hash over the batched-leaf lineage: an
        identical grid re-run is a full cache hit."""
        bplan = self._bplan(rng, reuse=True)
        cache = ReuseCache()
        rt = LineageRuntime(cache=cache)
        first = rt.evaluate_batch(bplan)
        hits0 = cache.stats.hits
        again = rt.evaluate_batch(bplan)
        assert cache.stats.hits > hits0
        for a, b in zip(first, again):
            np.testing.assert_allclose(a[0], b[0])


class TestCostModel:
    def _configs(self, rng, k, rows=4000, cols=512):
        x = input_tensor("cmX", rng.normal(size=(rows, cols)))
        return [[ops.colSums(x * float(i + 1))] for i in range(k)]

    def test_memory_bound_giant_with_padding_waste_goes_sequential(
            self, rng):
        """k=5 pads to a bucket of 8: 8x the memory-bound work loses to
        5 sequential passes + dispatch overhead."""
        outs = self._configs(rng, 5)
        bplan = compile_batched(outs)
        roots = [[o.node for o in os_] for os_ in outs]
        assert choose_mode(bplan, roots, False) == "sequential"

    def test_exact_bucket_goes_vmap(self, rng):
        outs = self._configs(rng, 8)   # bucket == k: no padding waste
        bplan = compile_batched(outs)
        roots = [[o.node for o in os_] for os_ in outs]
        assert choose_mode(bplan, roots, False) == "vmap"

    def test_small_solve_grid_goes_vmap(self, rng):
        x = input_tensor("svX", rng.normal(size=(64, 8)))
        y = input_tensor("svy", rng.normal(size=(64, 1)))
        outs = [[ops.solve(ops.gram(x) + lam * ops.eye(8),
                           ops.xtv(x, y))] for lam in LAMBDAS]
        bplan = compile_batched(outs)
        roots = [[o.node for o in os_] for os_ in outs]
        assert choose_mode(bplan, roots, True) == "vmap"

    def test_vmap_mem_budget_guard(self, rng, monkeypatch):
        from repro.core import costmodel
        outs = self._configs(rng, 8)
        bplan = compile_batched(outs)
        roots = [[o.node for o in os_] for os_ in outs]
        assert choose_mode(bplan, roots, False) == "vmap"
        monkeypatch.setattr(costmodel, "VMAP_MEM_BUDGET", 1 << 20)
        assert choose_mode(bplan, roots, False) == "sequential"

    def test_parfor_auto_respects_cost_fallback(self, rng):
        rt = LineageRuntime()
        outs = parfor(range(5),
                      lambda i: ops.colSums(
                          input_tensor("pcX" if i == 0 else None,
                                       rng.normal(size=(8, 4)))
                          * float(i + 1)),
                      runtime=rt, mode="auto")
        assert len(outs) == 5  # executed *somehow*; strategy is free

    def test_no_variant_suffix_goes_sequential(self, rng):
        x = input_tensor("nvX", rng.normal(size=(16, 4)))
        outs = [[ops.colSums(x)], [ops.colSums(x)]]
        bplan = compile_batched(outs)
        assert not bplan.variant_uids
        roots = [[o.node for o in os_] for os_ in outs]
        assert choose_mode(bplan, roots, False) == "sequential"


class TestFederatedGrid:
    def _run(self, xn, yn, lams, mode, cache=None):
        fed = FederatedTensor.partition_rows(xn, 3)
        rt = LineageRuntime(cache=cache)
        X = federated_input("tfX", fed)
        y = input_tensor("tfy", yn)
        betas, losses = grid_search_lm(X, y, lams, runtime=rt, mode=mode)
        return betas, losses, rt.stats.exchange

    def test_one_round_per_site_independent_of_k(self, rng):
        xn = rng.normal(size=(300, 12))
        yn = rng.normal(size=(300, 1))
        lams = [0.1, 0.5, 1.0, 5.0]           # k=4 == bucket: exact
        b_bat, l_bat, ex_bat = self._run(xn, yn, lams, "vmap")
        _, _, ex_one = self._run(xn, yn, lams[:1], "sequential")
        b_seq, l_seq, ex_seq = self._run(xn, yn, lams, "sequential",
                                         cache=ReuseCache())
        np.testing.assert_allclose(b_bat, b_seq, rtol=1e-8, atol=1e-10)
        np.testing.assert_allclose(l_bat, l_seq, rtol=1e-8)
        # rounds: same as a single-config run, k-independent
        assert ex_bat.rounds_per_site == ex_one.rounds_per_site
        assert ex_seq.rounds > ex_bat.rounds
        # payload: one batched exchange == k sequential exchanges'
        # bytes (gram/xtv exchanged once on both paths)
        assert ex_bat.total == ex_seq.total

    def test_non_pow2_k_exchanges_true_k_payload(self, rng):
        """k=3 pads to a bucket of 4 for executable shapes, but only
        the TRUE 3 configs ever cross the federation boundary — the
        payload invariant holds for any k, not just powers of two."""
        xn = rng.normal(size=(200, 8))
        yn = rng.normal(size=(200, 1))
        lams = [0.1, 1.0, 10.0]
        b_bat, l_bat, ex_bat = self._run(xn, yn, lams, "vmap")
        b_seq, l_seq, ex_seq = self._run(xn, yn, lams, "sequential",
                                         cache=ReuseCache())
        np.testing.assert_allclose(b_bat, b_seq, rtol=1e-8, atol=1e-10)
        np.testing.assert_allclose(l_bat, l_seq, rtol=1e-8)
        assert ex_bat.total == ex_seq.total
        assert ex_seq.rounds > ex_bat.rounds

    def test_fed_exchange_bytes_scale_with_k_not_rounds(self, rng):
        xn = rng.normal(size=(200, 8))
        yn = rng.normal(size=(200, 1))
        _, _, ex4 = self._run(xn, yn, [0.1, 0.5, 1.0, 5.0], "vmap")
        _, _, ex8 = self._run(
            xn, yn, [0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0], "vmap")
        assert ex8.rounds == ex4.rounds
        assert ex8.total > ex4.total   # payload grows, trips do not


class TestBoundedJitCache:
    def _fill(self, cache, n):
        for i in range(n):
            key, exe = cache.lookup(f"k{i}", (np.float64(i),))
            assert exe is None
            cache.compile(key, lambda x: (x + 1.0,), (np.float64(i),))

    def test_entry_cap_evicts_lru(self):
        cache = JitProgramCache(capacity=2, byte_capacity=1 << 40)
        self._fill(cache, 3)
        assert len(cache) == 2
        assert cache.stats.evictions == 1
        _, exe = cache.lookup("k0", (np.float64(0.0),))
        assert exe is None             # k0 was the LRU victim
        _, exe = cache.lookup("k2", (np.float64(2.0),))
        assert exe is not None

    def test_byte_cap_evicts(self):
        cache = JitProgramCache(capacity=64, byte_capacity=1)
        self._fill(cache, 3)
        # every executable exceeds 1 byte: only the newest survives
        assert len(cache) == 1
        assert cache.stats.evictions == 2
        assert cache.stats.bytes_cached > 0

    def test_bytes_tracked_and_cleared(self):
        cache = JitProgramCache()
        self._fill(cache, 2)
        assert cache.stats.bytes_cached > 0
        cache.clear()
        assert cache.stats.bytes_cached == 0 and len(cache) == 0

    def test_runtime_stats_surface_jit_cache_counters(self, rng):
        rt = LineageRuntime()
        x = input_tensor("jcX", rng.normal(size=(8, 4)))
        rt.evaluate([ops.colSums(x)])
        d = rt.stats.as_dict()["jit_cache"]
        assert {"hits", "misses", "evictions", "bytes_cached"} <= set(d)


class TestRunAggregation:
    def test_schema_drift_warns_and_skips(self, tmp_path, capsys,
                                          monkeypatch):
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
        from benchmarks import run as bench_run
        good = [dict(benchmark="ok", workload="w", speedup=2.0)]
        (tmp_path / "BENCH_good.json").write_text(
            __import__("json").dumps(good))
        (tmp_path / "BENCH_notalist.json").write_text('{"a": 1}')
        (tmp_path / "BENCH_empty.json").write_text("[]")
        (tmp_path / "BENCH_badentry.json").write_text("[1, 2]")
        (tmp_path / "BENCH_garbage.json").write_text("{unparseable")
        monkeypatch.setattr(bench_run, "BENCH_DIR", str(tmp_path))
        bench_run.aggregate()   # must not raise
        out = capsys.readouterr().out
        assert "BENCH_good.json" in out and "speedup=2.0" in out
        for bad in ("notalist", "empty", "badentry", "garbage"):
            assert f"BENCH_{bad}.json" in out and "skipped" in out
