"""lm-100m — the end-to-end example training target (examples/train_lm.py).

A ~100M-param llama-style model trainable for a few hundred steps on CPU.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="lm-100m",
    family="dense",
    n_layers=8,
    d_model=640,
    n_heads=10,
    n_kv_heads=5,
    d_ff=1792,
    vocab_size=32768,
    dtype="float32",
    loss_chunk=128,
    attn_chunk=256,
)
