"""Federated execution benchmark (§4.3 Example 2 + ISSUE 4).

Two layers:

  * the original eager-instruction measurements (`ex2_fed_*`): bytes
    exchanged by fed MV/VM/gram vs centralizing the data;
  * the compiler-placement comparison (`fed_compiled_vs_eager`): a
    warm repeated federated lmDS solve — an HPO-style lambda grid run
    twice — executed (a) through the DAG -> placement pass ->
    fused-segment stack with a lineage `ReuseCache` (per-site work
    compiled once into warm jit executables; `fed_gram`/`fed_xtv`
    reused across the grid, so sites are touched once) vs (b) the
    eager-numpy `federated_lmds` island, which recomputes every
    per-site gram/xtv on every call. Exchange bytes are asserted to
    match the oracle exactly on the first solve and reported per site.

Appends a trajectory entry to ``benchmarks/BENCH_federated.json``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from .common import COLS, ROWS, emit, timed

BENCH_JSON = os.path.join(os.path.dirname(__file__), "BENCH_federated.json")

LAMBDAS = (0.01, 0.1, 1.0, 10.0, 100.0)


def _eager_exchange(rows=ROWS, cols=COLS, n_sites=4) -> None:
    """The original §4.3 Example 2 numbers (eager instructions)."""
    from repro.core.federated import FederatedTensor, federated_lmds
    from repro.data.synthetic import gen_regression
    x, y, _ = gen_regression(rows, cols, seed=13)
    data_bytes = x.nbytes

    f = FederatedTensor.partition_rows(x, n_sites)
    v = np.random.default_rng(0).normal(size=(cols, 1))
    t = timed(lambda: f.fed_mv(v))
    emit("ex2_fed_mv", t, f"exchanged={f.log.total}B")

    f = FederatedTensor.partition_rows(x, n_sites)
    vr = np.random.default_rng(0).normal(size=(rows, 1))
    t = timed(lambda: f.fed_vm(vr))
    emit("ex2_fed_vm", t, f"exchanged={f.log.total}B")

    f = FederatedTensor.partition_rows(x, n_sites)
    t = timed(lambda: f.fed_gram())
    emit("ex2_fed_gram", t,
         f"exchanged={f.log.total}B;centralize={data_bytes}B;"
         f"ratio={f.log.total/data_bytes:.4f}")

    f = FederatedTensor.partition_rows(x, n_sites)
    t = timed(lambda: federated_lmds(f, y))
    beta = federated_lmds(FederatedTensor.partition_rows(x, n_sites), y)
    ref = np.linalg.solve(x.T @ x + 1e-7 * np.eye(cols), x.T @ y)
    err = float(np.abs(beta - ref).max())
    emit("ex2_federated_lmds", t, f"max_err_vs_centralized={err:.2e}")


def _grid_compiled(x, y, n_sites, reuse: bool = True):
    """Compiled federated HPO grid: plans precompiled, runtime with a
    reuse cache — fed_gram/fed_xtv computed once, warm jit replay."""
    from repro.core import (FederatedTensor, LineageRuntime, ReuseCache,
                            federated_input, input_tensor, ops)
    from repro.core.compiler import compile_plan
    fed = FederatedTensor.partition_rows(x, n_sites)
    X, Y = federated_input("benchX", fed), input_tensor("benchy", y)
    n = x.shape[1]
    rt = LineageRuntime(cache=ReuseCache() if reuse else None)
    plans = [compile_plan(
        [ops.solve(ops.gram(X) + lam * ops.eye(n), ops.xtv(X, Y))],
        reuse_enabled=reuse) for lam in LAMBDAS]

    def solve_grid():
        return [rt.run_plan(p)[0] for p in plans]

    return rt, solve_grid


def _grid_eager(x, y, n_sites):
    from repro.core.federated import FederatedTensor, federated_lmds
    fed = FederatedTensor.partition_rows(x, n_sites)

    def solve_grid():
        return [federated_lmds(fed, y, reg=lam) for lam in LAMBDAS]

    return fed, solve_grid


def main(rows: int = 8192, cols: int = 128, n_sites: int = 4,
         repeats: int = 5, eager_layer: bool = True) -> dict:
    if eager_layer:
        _eager_exchange(n_sites=n_sites)

    rng = np.random.default_rng(7)
    x = rng.normal(size=(rows, cols))
    y = x @ rng.normal(size=(cols, 1)) + 0.01 * rng.normal(size=(rows, 1))

    rt, compiled = _grid_compiled(x, y, n_sites)
    fed_eager, eager = _grid_eager(x, y, n_sites)

    out_c = compiled()     # warm-up: trace/compile + populate reuse cache
    out_e = eager()
    parity = max(float(np.abs(a - b).max()) for a, b in zip(out_c, out_e))
    if parity >= 1e-8:  # a real gate, not an assert: CI may run with -O
        raise RuntimeError(
            f"compiled vs eager federated diverge (max abs err {parity})")

    # exchange-byte parity on the first (cold) grid pass: the compiled
    # plan moved exactly what the eager oracle moves for ONE solve —
    # fed_gram/fed_xtv were lineage-reused across the other lambdas
    one = fed_eager.log.total // len(LAMBDAS)
    ex = rt.stats.exchange
    if ex.total != one:
        raise RuntimeError(
            f"exchange bytes diverge from the eager oracle: compiled "
            f"moved {ex.total}, one eager solve moves {one}")

    t_compiled = timed(compiled, repeats=repeats)
    t_eager = timed(eager, repeats=repeats)
    speedup = t_eager / max(t_compiled, 1e-12)
    emit("fed_compiled_vs_eager", t_compiled,
         f"eager_us={t_eager*1e6:.1f};speedup={speedup:.2f}x;"
         f"exchange_per_site={dict(sorted(ex.per_site.items()))}")

    # transparency: the same compiled grid without a reuse cache —
    # measures pure warm-jit federated execution (per-site XLA kernels
    # vs numpy BLAS; on CPU the f64 gemm gap means reuse, not raw
    # kernel speed, is what wins the repeated-solve scenario)
    _, compiled_nr = _grid_compiled(x, y, n_sites, reuse=False)
    compiled_nr()  # warm the jit cache
    t_noreuse = timed(compiled_nr, repeats=repeats)

    entry = dict(
        benchmark="fed_compiled_vs_eager",
        workload=f"federated_lmDS_grid({rows}x{cols}, {n_sites} sites, "
                 f"{len(LAMBDAS)} lambdas, warm)",
        compiled_us_per_grid=round(t_compiled * 1e6, 1),
        compiled_noreuse_us_per_grid=round(t_noreuse * 1e6, 1),
        eager_numpy_us_per_grid=round(t_eager * 1e6, 1),
        speedup_compiled_vs_eager=round(speedup, 2),
        parity_max_abs_err=parity,
        exchange_bytes_total=ex.total,
        exchange_bytes_per_site={int(k): int(v)
                                 for k, v in sorted(ex.per_site.items())},
        exchange_matches_eager_single_solve=True,
        reuse=rt.cache.stats.as_dict(),
        runtime=rt.stats.as_dict(),
        ts=time.strftime("%Y-%m-%dT%H:%M:%S"),
    )
    trajectory = []
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                trajectory = json.load(f)
        except Exception:
            trajectory = []
    trajectory.append(entry)
    with open(BENCH_JSON, "w") as f:
        json.dump(trajectory, f, indent=2)
    return entry


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    print("name,us_per_call,derived")
    print(json.dumps(main(), indent=2))
