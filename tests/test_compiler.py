"""Compiler rewrites: semantics preservation (property-based) + specific
fusion/ordering rules (paper §3.2)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import LineageRuntime, ReuseCache, input_tensor, ops
from repro.core.compiler import compile_plan
from repro.core.dag import LTensor


def _ops_of(plan):
    return plan.count_ops()


class TestFusionRewrites:
    def test_tsmm_detected(self, rng):
        x = input_tensor("X", rng.normal(size=(30, 5)))
        plan = compile_plan([x.T @ x])
        assert _ops_of(plan).get("gram", 0) == 1
        assert _ops_of(plan).get("matmul", 0) == 0

    def test_xtv_detected(self, rng):
        x = input_tensor("X", rng.normal(size=(30, 5)))
        y = input_tensor("y", rng.normal(size=(30, 1)))
        plan = compile_plan([x.T @ y])
        assert _ops_of(plan).get("xtv", 0) == 1

    def test_double_transpose_eliminated(self, rng):
        x = input_tensor("X", rng.normal(size=(6, 4)))
        plan = compile_plan([x.T.T + 0.0])
        assert _ops_of(plan).get("t", 0) == 0

    def test_cse_merges(self, rng):
        x = input_tensor("X", rng.normal(size=(20, 4)))
        a = ops.gram(x)
        b = ops.gram(x)
        plan = compile_plan([a + b])
        assert _ops_of(plan).get("gram", 0) == 1


class TestMatmulChain:
    def test_chain_reordered_for_cost(self, rng):
        # (A@B)@v where A (50x50), B (50x50), v (50x1):
        # optimal order is A@(B@v) — two MVs instead of a MM
        a = input_tensor("A", rng.normal(size=(50, 50)))
        b = input_tensor("B", rng.normal(size=(50, 50)))
        v = input_tensor("v", rng.normal(size=(50, 1)))
        plan = compile_plan([(a @ b) @ v])
        shapes = [ins.node.shape for ins in plan.instructions
                  if ins.node.op == "matmul"]
        assert (50, 50) not in shapes  # no full MM materialized

    def test_chain_semantics(self, rng):
        an = rng.normal(size=(20, 30))
        bn = rng.normal(size=(30, 10))
        cn = rng.normal(size=(10, 40))
        a, b, c = (input_tensor(n, v) for n, v in
                   zip("abc", (an, bn, cn)))
        rt = LineageRuntime()
        out = rt.evaluate([(a @ b) @ c])[0]
        np.testing.assert_allclose(out, an @ bn @ cn, rtol=1e-6)

    def test_shared_intermediate_not_split(self, rng):
        a = input_tensor("A", rng.normal(size=(20, 20)))
        b = input_tensor("B", rng.normal(size=(20, 20)))
        ab = a @ b
        # ab used twice -> reordering must not duplicate it
        plan = compile_plan([ab @ ab])
        assert _ops_of(plan).get("matmul", 0) == 2


# property tests: random expressions evaluate identically with and
# without the optimizer

@st.composite
def expr_strategy(draw):
    """Build a random DSL expression over two fixed inputs."""
    depth = draw(st.integers(1, 4))
    seed = draw(st.integers(0, 2 ** 16))
    unaries = draw(st.lists(
        st.sampled_from(["exp", "abs", "sqrtabs", "neg", "t"]),
        min_size=0, max_size=depth))
    binaries = draw(st.lists(
        st.sampled_from(["add", "mul", "sub", "matmul_tx", "div"]),
        min_size=1, max_size=depth))
    return seed, unaries, binaries


def _build(x, unaries, binaries):
    cur = x
    for u in unaries:
        if u == "exp":
            cur = ops.exp(cur * 0.01)
        elif u == "abs":
            cur = ops.abs_(cur)
        elif u == "sqrtabs":
            cur = ops.sqrt(ops.abs_(cur) + 1.0)
        elif u == "neg":
            cur = -cur
        elif u == "t":
            cur = cur.T.T  # keep shape
    for b in binaries:
        if b == "add":
            cur = cur + cur
        elif b == "mul":
            cur = cur * cur
        elif b == "sub":
            cur = cur - 0.5 * cur
        elif b == "div":
            cur = cur / (ops.abs_(cur) + 1.0)
        elif b == "matmul_tx":
            cur = (cur.T @ cur) * 1e-2  # gram-able pattern
            cur = ops.sqrt(ops.abs_(cur) + 1.0)
    return ops.sum_(cur)


@settings(max_examples=25, deadline=None)
@given(expr_strategy())
def test_rewrites_preserve_semantics(params):
    seed, unaries, binaries = params
    rng = np.random.default_rng(seed)
    xn = rng.normal(size=(12, 12))
    x = input_tensor("X", xn)
    expr = _build(x, unaries, binaries)
    rt_opt = LineageRuntime(cache=ReuseCache(), opt_level=2)
    rt_raw = LineageRuntime(cache=None, opt_level=0)
    v_opt = rt_opt.evaluate([expr])[0]
    v_raw = rt_raw.evaluate([expr])[0]
    np.testing.assert_allclose(v_opt, v_raw, rtol=1e-5, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(0, 1000))
def test_fold_decomposition_matches_monolithic(k, seed):
    rng = np.random.default_rng(seed)
    folds = [input_tensor(f"pf{seed}_{i}", rng.normal(size=(16, 5)))
             for i in range(k)]
    stacked = np.concatenate(
        [rng2 for rng2 in
         [__import__("repro.core.dag", fromlist=["LEAVES"]).LEAVES.values[
             f.node.uid] for f in folds]])
    g = ops.gram(ops.rbind(*folds))
    with_reuse = LineageRuntime(cache=ReuseCache()).evaluate([g])[0]
    without = LineageRuntime(cache=None).evaluate([g])[0]
    np.testing.assert_allclose(with_reuse, without, rtol=1e-6)
    np.testing.assert_allclose(with_reuse, stacked.T @ stacked, rtol=1e-6)


def test_memory_estimate_targets(rng):
    # big op flagged distributed, small stays local
    x = input_tensor("X", rng.normal(size=(64, 64)))
    plan = compile_plan([ops.gram(x)], local_budget=1 << 10)
    targets = {ins.node.op: ins.target for ins in plan.instructions}
    assert targets["gram"] == "distributed"
    plan2 = compile_plan([ops.gram(x)])
    targets2 = {ins.node.op: ins.target for ins in plan2.instructions}
    assert targets2["gram"] == "local"


def test_explain_output(rng):
    x = input_tensor("X", rng.normal(size=(30, 5)))
    plan = compile_plan([x.T @ x])
    txt = plan.explain()
    assert "gram" in txt and "outputs:" in txt
