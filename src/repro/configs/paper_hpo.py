"""paper-hpo — scale knobs for the paper's own experiments (§5).

Not a transformer: describes the HPO/CV regression workloads
(benchmarks/hpo_*.py, cv_reuse.py). The paper uses 100K×1K dense
(800 MB) / sparsity-0.1 inputs; this container scales rows down so a
full Fig. 5 sweep finishes in minutes while keeping the 100:1 row:col
aspect and the GFLOP-per-model accounting.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class PaperWorkload:
    rows: int = 100_000
    cols: int = 1_000
    rows_cpu: int = 20_000      # scaled-down default for this container
    cols_cpu: int = 1_000
    sparsity: float = 0.1
    k_models: tuple = (1, 10, 20, 30, 40, 50, 60, 70)
    k_models_cpu: tuple = (1, 10, 20, 40, 70)
    n_folds: int = 8


CONFIG = PaperWorkload()
