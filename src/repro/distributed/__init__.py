from .mesh import (CONFIG_AXIS, DATA_AXIS, MeshSpec,  # noqa: F401
                   auto_mesh, get_mesh, set_mesh, use_mesh)
from .sharding import (batch_specs, cache_specs, param_specs,  # noqa: F401
                       rows_shardable, safe_spec)
