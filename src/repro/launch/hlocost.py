"""HLO-text cost analysis with while-loop trip-count accounting.

Why this exists (verified by probe, see DESIGN.md §5):
  * XLA's `compiled.cost_analysis()` visits each instruction ONCE — a
    jax.lax.scan of N iterations reports 1 body's FLOPs.
  * collective bytes are not reported at all.

This module re-derives per-device costs from `compiled.as_text()`
(post-SPMD-partitioning, post-optimization HLO):
  * splits the module into computations and builds a per-computation
    symbol table (var -> shape/dtype),
  * walks the call graph from ENTRY: `while` bodies/conditions are
    multiplied by the trip count recovered from the condition's
    `compare(counter, constant)` pattern; fusions/calls recurse with
    multiplier 1,
  * FLOPs: dot = 2·|out|·contraction; convolution = 2·|out|·window·Ci;
    elementwise/reduce ≈ 1 per element (transcendental ≈ 1),
  * bytes: Σ operand+output bytes per compute op (parameter/tuple/
    bitcast/gte are free),
  * collectives: wire bytes per chip under a ring model, bucketed by
    replica-group size so the roofline can attribute them to mesh axes.

Cross-validated against cost_analysis() on unrolled programs
(tests/test_hlocost.py).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "f16": 2, "bf16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "select", "compare", "and", "or", "not", "xor", "convert", "floor",
    "ceil", "sign", "cosine", "sine", "clamp", "expm1", "log1p", "atan2",
    "remainder", "round-nearest-afz", "round-nearest-even", "logistic",
    "cbrt", "erf",
}
_FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "opt-barrier", "partition-id", "replica-id", "domain",
    "get-dimension-size",
}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"([a-z][\w\-]*)\((.*)$")


@dataclass
class Shape:
    dtype: str
    dims: tuple[int, ...]

    @property
    def numel(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.numel * _DTYPE_BYTES.get(self.dtype, 4)


def _parse_shapes(type_str: str) -> list[Shape]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append(Shape(m.group(1), dims))
    return out


@dataclass
class Instr:
    name: str
    opcode: str
    shapes: list[Shape]          # output shapes (tuple flattened)
    operands: list[str]
    raw: str

    @property
    def out_bytes(self) -> int:
        return sum(s.bytes for s in self.shapes)

    @property
    def out_numel(self) -> int:
        return sum(s.numel for s in self.shapes)


@dataclass
class Computation:
    name: str
    instrs: dict[str, Instr] = field(default_factory=dict)
    order: list[str] = field(default_factory=list)


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_wire_bytes: float = 0.0     # ring-model per-chip bytes
    collective_raw_bytes: float = 0.0      # Σ payload bytes
    per_collective: dict = field(default_factory=lambda: defaultdict(float))
    by_group_size: dict = field(default_factory=lambda: defaultdict(float))
    unknown_trip_counts: int = 0

    def add(self, other: "CostTotals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        self.collective_wire_bytes += other.collective_wire_bytes * mult
        self.collective_raw_bytes += other.collective_raw_bytes * mult
        for k, v in other.per_collective.items():
            self.per_collective[k] += v * mult
        for k, v in other.by_group_size.items():
            self.by_group_size[k] += v * mult
        self.unknown_trip_counts += other.unknown_trip_counts


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def parse_module(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw_line in hlo_text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", raw_line)   # strip HLO comments
        stripped = line.strip()
        is_instr = re.match(r"^(ROOT\s+)?%?[\w.\-]+\s*=", stripped)
        # computation header: `%name (args) -> type {` or `ENTRY %name ...`
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$",
                     stripped)
        if m and not is_instr:
            cur = Computation(name=m.group(2))
            comps[m.group(2)] = cur
            if m.group(1):
                comps["__entry__"] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        _, name, type_str, opcode, rest = im.groups()
        # operands: up to the closing paren at depth 0
        depth, args_end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args_end = i
                    break
        arg_str = rest[:args_end]
        operands = _OPERAND_RE.findall(arg_str)
        instr = Instr(name=name, opcode=opcode,
                      shapes=_parse_shapes(type_str), operands=operands,
                      raw=stripped)
        cur.instrs[name] = instr
        cur.order.append(name)
    return comps


def _attr(raw: str, key: str) -> Optional[str]:
    """Parse `key=value` where value is a {...} block or a bare token
    (no commas — attribute separators)."""
    m = re.search(key + r"=((\{[^}]*\})|([%\w.\-]+))", raw)
    return m.group(1) if m else None


def _dims_list(raw: str, key: str) -> list[int]:
    v = _attr(raw, key)
    if not v:
        return []
    return [int(x) for x in re.findall(r"\d+", v)]


def _group_size(raw: str, n_devices: int) -> int:
    # new format: replica_groups=[8,64]<=[512]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", raw)
    if m:
        return int(m.group(2))
    # old format: replica_groups={{0,1,2},{3,4,5}}
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", raw)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def _trip_count(cond: Computation) -> Optional[int]:
    """Recover scan trip counts: condition compares counter < constant."""
    consts: dict[str, int] = {}
    for name in cond.order:
        ins = cond.instrs[name]
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.raw)
            if m:
                consts[name] = int(m.group(1))
    for name in cond.order:
        ins = cond.instrs[name]
        if ins.opcode == "compare" and "direction=LT" in ins.raw:
            for op in ins.operands:
                if op in consts:
                    return consts[op]
    # fallback: any positive constant in the condition
    pos = [v for v in consts.values() if v > 0]
    return max(pos) if pos else None


class HloCostAnalyzer:
    def __init__(self, hlo_text: str, n_devices: int = 1):
        self.comps = parse_module(hlo_text)
        self.n_devices = n_devices
        self._memo: dict[str, CostTotals] = {}

    # -- per-instruction costs ------------------------------------------------
    def _shape_of(self, comp: Computation, var: str) -> Optional[Shape]:
        ins = comp.instrs.get(var)
        if ins and ins.shapes:
            return ins.shapes[0]
        return None

    def _instr_cost(self, comp: Computation, ins: Instr) -> CostTotals:
        t = CostTotals()
        op = ins.opcode
        if op in _FREE:
            return t
        if op in ("while",):
            body_name = (_attr(ins.raw, "body") or "").strip("%")
            body = self.comps.get(body_name)
            cond_name = (_attr(ins.raw, "condition") or "").strip("%")
            cond = self.comps.get(cond_name)
            # primary: XLA annotates known trip counts in backend_config
            m = re.search(r'known_trip_count...?.?"n":"(\d+)"', ins.raw)
            trips = int(m.group(1)) if m else (
                _trip_count(cond) if cond else None)
            if trips is None:
                trips = 1
                t.unknown_trip_counts += 1
            if body:
                t.add(self.comp_cost(body.name), trips)
            if cond:
                t.add(self.comp_cost(cond.name), trips)
            return t
        if op == "dynamic-update-slice":
            # in-place update: traffic = the updated window (read+write),
            # not the full aliased buffer
            upd = self._shape_of(comp, ins.operands[1]) \
                if len(ins.operands) > 1 else None
            win = upd.bytes if upd else ins.out_bytes
            t.bytes += 2.0 * win
            return t
        if op in ("fusion", "call", "async-start", "async-done"):
            target = _attr(ins.raw, "calls") or _attr(ins.raw, "to_apply")
            root_win = None
            if target:
                tc = self.comps.get(target.strip("%"))
                if tc and tc.order:
                    root = tc.instrs[tc.order[-1]]
                    if root.opcode == "dynamic-update-slice" and \
                            len(root.operands) > 1:
                        ru = self._shape_of(tc, root.operands[1])
                        root_win = ru.bytes if ru else None
            if target:
                inner = self.comp_cost(target.strip("%"))
                # flops/collectives recurse; bytes do NOT — fusion
                # internals never touch HBM, only the fusion I/O does
                t.flops += inner.flops
                t.transcendentals += inner.transcendentals
                t.collective_wire_bytes += inner.collective_wire_bytes
                t.collective_raw_bytes += inner.collective_raw_bytes
                for k, v in inner.per_collective.items():
                    t.per_collective[k] += v
                for k, v in inner.by_group_size.items():
                    t.by_group_size[k] += v
                t.unknown_trip_counts += inner.unknown_trip_counts
            out_charge = root_win if root_win is not None else ins.out_bytes
            t.bytes += out_charge + self._operand_bytes(
                comp, ins, cap=out_charge)
            return t
        if op == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                  ins.raw)
            names = re.findall(r"%([\w.\-]+)", branches[0]) if branches \
                else []
            for nm in names[:1]:   # count one branch (they're exclusive)
                t.add(self.comp_cost(nm))
            return t
        if op in _COLLECTIVES:
            payload = ins.out_bytes
            n = max(_group_size(ins.raw, self.n_devices), 1)
            kind = op.replace("-start", "")
            if kind == "all-reduce":
                wire = 2.0 * (n - 1) / n * payload
            elif kind in ("all-gather", "reduce-scatter"):
                wire = (n - 1) / n * payload
            elif kind == "all-to-all":
                wire = (n - 1) / n * payload
            else:  # collective-permute
                wire = payload
            t.collective_raw_bytes += payload
            t.collective_wire_bytes += wire
            t.per_collective[kind] += wire
            t.by_group_size[n] += wire
            t.bytes += ins.out_bytes + self._operand_bytes(comp, ins)
            return t

        # compute ops
        if op == "dot":
            out = ins.shapes[0]
            lhs = self._shape_of(comp, ins.operands[0]) if ins.operands \
                else None
            cdims = _dims_list(ins.raw, "lhs_contracting_dims")
            csize = 1
            if lhs:
                for d in cdims:
                    if d < len(lhs.dims):
                        csize *= lhs.dims[d]
            t.flops += 2.0 * out.numel * csize
        elif op == "convolution":
            out = ins.shapes[0]
            window = _dims_list(ins.raw, "window")
            ksize = 1
            m = re.search(r"size=([0-9x]+)", ins.raw)
            if m:
                for d in m.group(1).split("x"):
                    ksize *= int(d)
            # feature_group_count handles depthwise
            fgc = int((_attr(ins.raw, "feature_group_count") or "1"))
            lhs = self._shape_of(comp, ins.operands[0])
            ci = lhs.dims[1] if lhs and len(lhs.dims) > 1 else 1
            t.flops += 2.0 * out.numel * ksize * max(ci // max(fgc, 1), 1)
        elif op in ("reduce", "reduce-window"):
            lhs = self._shape_of(comp, ins.operands[0])
            t.flops += float(lhs.numel if lhs else ins.out_numel)
        elif op in _ELEMENTWISE or op in (
                "broadcast", "iota", "reshape", "transpose", "slice",
                "concatenate", "pad", "reverse", "gather", "scatter",
                "dynamic-slice", "dynamic-update-slice", "sort", "rng",
                "copy", "select-and-scatter", "cumsum", "map", "exponential"):
            if op in _ELEMENTWISE:
                t.flops += float(ins.out_numel)
                if op in ("exponential", "log", "tanh", "rsqrt", "sqrt",
                          "logistic", "power", "cosine", "sine", "erf"):
                    t.transcendentals += float(ins.out_numel)
        else:
            # unknown op: count bytes only
            pass
        cap = ins.out_bytes if ins.opcode in self._WINDOWED else None
        t.bytes += ins.out_bytes + self._operand_bytes(comp, ins, cap=cap)
        return t

    def _operand_bytes(self, comp: Computation, ins: Instr,
                       cap: Optional[int] = None) -> float:
        total = 0.0
        for op in ins.operands:
            s = self._shape_of(comp, op)
            if s:
                b = s.bytes
                if cap is not None:
                    b = min(b, cap)
                total += b
        return total

    # ops that read/write only an output-sized window of big operands
    # (scan xs dynamic-slices, ys dynamic-update-slices are in-place):
    # charging the full carried array per trip overcounted memory terms
    # by up to ~300x (see EXPERIMENTS.md §Roofline methodology).
    _WINDOWED = {"fusion", "call", "dynamic-slice", "dynamic-update-slice",
                 "gather", "scatter", "select-and-scatter"}

    # -- computation / module totals -------------------------------------------
    def comp_cost(self, name: str) -> CostTotals:
        name = name.strip("%")
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        t = CostTotals()
        self._memo[name] = t       # break cycles defensively
        if comp is None:
            return t
        for iname in comp.order:
            ins = comp.instrs[iname]
            t.add(self._instr_cost(comp, ins))
        return t

    def total(self) -> CostTotals:
        return self.comp_cost("__entry__")


def analyze(hlo_text: str, n_devices: int = 1) -> CostTotals:
    return HloCostAnalyzer(hlo_text, n_devices=n_devices).total()
