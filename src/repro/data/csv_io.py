"""CSV / raw-format I/O with generated readers (SystemDS §4.2).

`make_reader(descriptor)` generates python source for a chunked parser
from a high-level format description and exec's it — the paper's
"automatically generate code for efficient readers and writers from
high-level descriptions of data formats" at this container's scale.
Generated readers parse in large chunks through numpy, not per-line
python loops.
"""
from __future__ import annotations

import io
import os
import time
from typing import Callable, Optional

import numpy as np


def _fault_read(f, chunk_bytes: int, fault_log=None) -> bytes:
    """One byte-window read behind the seeded chunk_io injection point
    (`repro.core.faults`), with bounded exponential-backoff retry.

    Transient IO errors — injected or real `OSError`s — are retried up
    to `costmodel.max_retries()` times; the file position is untouched
    by a failed attempt (injection fires *before* the read), so a
    retry resumes the stream exactly where it left off. `fault_log`
    (a `FaultLog`) meters injected/retries/backoff_s when given.
    ``REPRO_FAULT_POLICY=off`` bypasses everything."""
    from repro.core import costmodel, faults
    if not faults.policy_enabled():
        return f.read(chunk_bytes)
    tries = costmodel.max_retries() + 1
    for attempt in range(tries):
        try:
            faults.io_entry("read_csv_chunks")
            return f.read(chunk_bytes)
        except (OSError, faults.InjectedFault) as e:
            if fault_log is not None and isinstance(e, faults.InjectedFault):
                fault_log.injected += 1
            if attempt + 1 >= tries:
                raise
            pause = costmodel.retry_backoff_s(attempt + 1)
            if fault_log is not None:
                fault_log.retries += 1
                fault_log.backoff_s += pause
            if pause > 0:
                time.sleep(pause)
    raise AssertionError("unreachable")  # pragma: no cover


def write_csv(path: str, x: np.ndarray, fmt: str = "%.6g") -> int:
    """Returns bytes written."""
    with open(path, "w") as f:
        np.savetxt(f, x, delimiter=",", fmt=fmt)
    return os.path.getsize(path)


def read_csv(path: str, chunk_bytes: int = 64 << 20) -> np.ndarray:
    """Chunked numeric CSV reader (string->double is the hot loop, §5.2)."""
    chunks = []
    with open(path, "rb") as f:
        rem = b""
        while True:
            buf = f.read(chunk_bytes)
            if not buf:
                break
            buf = rem + buf
            last_nl = buf.rfind(b"\n")
            if last_nl < 0:
                rem = buf
                continue
            rem, block = buf[last_nl + 1:], buf[: last_nl + 1]
            chunks.append(np.genfromtxt(io.BytesIO(block), delimiter=","))
        if rem.strip():
            chunks.append(np.genfromtxt(io.BytesIO(rem), delimiter=","))
    out = np.vstack([np.atleast_2d(c) for c in chunks])
    return out


def read_csv_chunks(path: str, rows_per_chunk: int,
                    chunk_bytes: int = 64 << 20, fault_log=None):
    """Iterate a numeric CSV as `(row_offset, array)` chunks of exactly
    `rows_per_chunk` rows (the last one ragged) — the I/O twin of the
    out-of-core streaming executor: feed each yielded block to a
    `chunk_*` partial aggregate and only one block is ever resident.

    Reads the file in byte windows (same newline-split recipe as
    `read_csv`) and re-blocks the parsed rows to the requested row
    bucket, so the byte window size and the chunk row count are
    independent knobs.

    Each byte-window read goes through the fault policy (`_fault_read`):
    transient IO errors — injected via ``REPRO_FAULT_SPEC`` or real —
    retry with bounded exponential backoff, metered into `fault_log`
    when given, so a flaky source degrades to a slower stream instead
    of a dead ingestion loop."""
    if rows_per_chunk < 1:
        raise ValueError(f"rows_per_chunk must be >= 1, got "
                         f"{rows_per_chunk}")
    pending: list[np.ndarray] = []
    have = 0
    offset = 0

    def drain(final: bool):
        nonlocal pending, have, offset
        while have >= rows_per_chunk or (final and have):
            block = np.vstack(pending) if len(pending) > 1 else pending[0]
            out, tail = (block[:rows_per_chunk],
                         block[rows_per_chunk:])
            pending = [tail] if tail.shape[0] else []
            have = tail.shape[0]
            off, offset = offset, offset + out.shape[0]
            yield off, out

    with open(path, "rb") as f:
        rem = b""
        while True:
            buf = _fault_read(f, chunk_bytes, fault_log)
            if not buf:
                break
            buf = rem + buf
            last_nl = buf.rfind(b"\n")
            if last_nl < 0:
                rem = buf
                continue
            rem, block = buf[last_nl + 1:], buf[: last_nl + 1]
            arr = np.atleast_2d(np.genfromtxt(io.BytesIO(block),
                                              delimiter=","))
            pending.append(arr)
            have += arr.shape[0]
            yield from drain(final=False)
        if rem.strip():
            arr = np.atleast_2d(np.genfromtxt(io.BytesIO(rem),
                                              delimiter=","))
            pending.append(arr)
            have += arr.shape[0]
        yield from drain(final=True)


READER_TEMPLATE = '''
def _generated_reader(path, chunk_bytes={chunk_bytes}):
    """Generated by repro.data.csv_io.make_reader for format:
    delimiter={delim!r} columns={ncols} types={types!r}"""
    import io
    import numpy as np
    dtypes = {np_types!r}
    chunks = []
    with open(path, "rb") as f:
        rem = b""
        while True:
            buf = f.read(chunk_bytes)
            if not buf:
                break
            buf = rem + buf
            last = buf.rfind(b"\\n")
            if last < 0:
                rem = buf
                continue
            rem, block = buf[last + 1:], buf[: last + 1]
            arr = np.genfromtxt(io.BytesIO(block), delimiter={delim!r},
                                dtype=None, encoding="utf-8",
                                names={names!r})
            chunks.append(arr)
    import numpy.lib.recfunctions as rf
    rec = np.concatenate(chunks) if len(chunks) > 1 else chunks[0]
    return {{n: rec[n] for n in rec.dtype.names}}
'''


def make_reader(descriptor: dict) -> Callable[[str], dict]:
    """descriptor: {"delimiter": ",", "columns": [(name, type), ...]}
    type in {"f64","i64","str"}. Returns a reader(path) -> dict of cols."""
    delim = descriptor.get("delimiter", ",")
    cols = descriptor["columns"]
    names = [c[0] for c in cols]
    np_types = {"f64": "f8", "i64": "i8", "str": "U64"}
    src = READER_TEMPLATE.format(
        chunk_bytes=descriptor.get("chunk_bytes", 64 << 20),
        delim=delim, ncols=len(cols),
        types=[c[1] for c in cols],
        np_types=[np_types[c[1]] for c in cols],
        names=names)
    ns: dict = {}
    exec(src, ns)            # codegen'd reader (paper §4.2)
    fn = ns["_generated_reader"]
    fn.__source__ = src
    return fn
