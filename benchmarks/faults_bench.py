"""Fault-tolerance benchmark (ISSUE 10): fault-free overhead + recovery.

Two lanes, both appended to ``benchmarks/BENCH_faults.json``:

  * **overhead** — the acceptance gate: with NO faults injected, the
    fault policy (injection probes, per-site/per-dispatch latency
    monitors, deadline checks) must cost <= ``max_overhead`` (2%) over
    ``REPRO_FAULT_POLICY=off`` on the serving and streaming smoke
    workloads. Both modes interleave and compare min-of-N noise
    floors, retrying the measurement round on a noise spike.
  * **recovery** — seeded chaos: a dead federated site (collect-and-
    recompute ladder), a killed chunk-prefetch worker (synchronous-tail
    ladder), serving deadline shedding and a coalescer crash (supervisor
    restart). Every degraded result is asserted against the clean run
    to 1e-12 and the recovery counters are reported.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(__file__), "BENCH_faults.json")


def _serving_once(d: int, n_scores: int) -> float:
    from repro.core import LineageRuntime, ops
    from repro.core.dag import input_tensor
    from repro.core.runtime import PreparedScript
    from repro.serving import ModelServer

    rng = np.random.default_rng(0)
    rt = LineageRuntime()
    W = input_tensor("fbW", rng.normal(size=(d, 1)))
    script = PreparedScript(lambda x: (ops.matmul(x, W),), [(1, d)],
                            runtime=rt)
    xs = [rng.normal(size=(1, d)) for _ in range(n_scores)]
    with ModelServer(script, runtime=rt, max_batch=8,
                     max_wait_us=500.0) as srv:
        srv.score(xs[0])                   # warm
        t0 = time.perf_counter()
        for x in xs:
            srv.score(x)
        return time.perf_counter() - t0


def _stream_once(rows: int, cols: int) -> float:
    from repro.core import costmodel
    from repro.core.dag import input_tensor
    from repro.core.reuse import ReuseCache
    from repro.core.runtime import LineageRuntime
    from repro.lifecycle.regression import lmDS

    rng = np.random.default_rng(1)
    Xh = rng.normal(size=(rows, cols))
    yh = rng.normal(size=(rows, 1))
    saved = costmodel.CHUNK_MEM_BUDGET
    try:
        costmodel.CHUNK_MEM_BUDGET = Xh.nbytes // 10
        rt = LineageRuntime(cache=ReuseCache(), fuse=True)
        t0 = time.perf_counter()
        np.asarray(lmDS(input_tensor("X", Xh), input_tensor("y", yh),
                        reg=1e-3, runtime=rt))
        dt = time.perf_counter() - t0
        assert rt.stats.streaming.chunks > 1, "streaming never engaged"
        return dt
    finally:
        costmodel.CHUNK_MEM_BUDGET = saved


def _overhead_lane(d: int, n_scores: int, rows: int, cols: int,
                   repeats: int, max_overhead: float) -> dict:
    lanes = {"serving": lambda: _serving_once(d, n_scores),
             "stream": lambda: _stream_once(rows, cols)}
    out: dict = {}
    saved = os.environ.get("REPRO_FAULT_POLICY")
    try:
        for name, fn in lanes.items():
            for mode in ("off", "on"):     # warm both modes' jit keys
                os.environ["REPRO_FAULT_POLICY"] = mode
                fn()
            # min-of-N per mode estimates each mode's noise floor —
            # scheduler noise on a shared core swings single runs by
            # 2x, so an inherent <=2% cost is only resolvable at the
            # floor. Up to 3 measurement rounds: a true >2% policy
            # cost shows up in EVERY round; a noise spike does not.
            overhead, t_off, t_on = None, 0.0, 0.0
            for _ in range(3):
                ts: dict = {"off": [], "on": []}
                for _ in range(repeats):   # interleaved pairs
                    for mode in ("off", "on"):
                        os.environ["REPRO_FAULT_POLICY"] = mode
                        ts[mode].append(fn())
                o, n = min(ts["off"]), min(ts["on"])
                if overhead is None or n / o - 1.0 < overhead:
                    overhead, t_off, t_on = n / o - 1.0, o, n
                if overhead <= max_overhead:
                    break
            assert overhead <= max_overhead, \
                f"{name}: fault policy costs {overhead * 100:.2f}% " \
                f"fault-free (<= {max_overhead * 100:.0f}% required)"
            out[name] = dict(t_off=t_off, t_on=t_on, overhead=overhead)
    finally:
        if saved is None:
            os.environ.pop("REPRO_FAULT_POLICY", None)
        else:
            os.environ["REPRO_FAULT_POLICY"] = saved
    return out


def _recovery_lane(rows: int, cols: int) -> dict:
    from repro.core import costmodel, faults
    from repro.core.dag import input_tensor
    from repro.core.faults import DeadlineExceededError, InjectedFault
    from repro.core.federated import FederatedTensor
    from repro.core.reuse import ReuseCache
    from repro.core.runtime import LineageRuntime, PreparedScript
    from repro.core import ops
    from repro.lifecycle import lmDS_federated
    from repro.lifecycle.regression import lmDS
    from repro.serving import ModelServer

    rng = np.random.default_rng(3)
    out: dict = {}

    # dead federated site: exhaust retries, collect + recompute
    xh = rng.normal(size=(rows, 8))
    yh = rng.normal(size=(rows, 1))

    def fed(spec):
        rt = LineageRuntime()
        fx = FederatedTensor.partition_rows(xh, 4)
        with faults.inject(spec):
            w = lmDS_federated(fx, yh, intercept=True, runtime=rt)
        return np.asarray(w), rt.stats.faults

    w0, _ = fed(None)
    w1, f = fed("seed=11;site_dead:site=2;site_rpc@0,9")
    err = float(np.abs(w1 - w0).max())
    assert err < 1e-12, f"dead-site degradation parity {err}"
    out["fed"] = dict(parity=err, injected=f.injected,
                      retries=f.retries, degradations=f.degradations)

    # killed prefetch worker: synchronous-tail ladder
    saved_budget = costmodel.CHUNK_MEM_BUDGET
    saved_depth = os.environ.get("REPRO_PIPELINE_DEPTH")
    try:
        costmodel.CHUNK_MEM_BUDGET = xh.nbytes // 8
        os.environ["REPRO_PIPELINE_DEPTH"] = "2"

        def stream(spec):
            rt = LineageRuntime(cache=ReuseCache(), fuse=True)
            with faults.inject(spec):
                w = lmDS(input_tensor("X", xh), input_tensor("y", yh),
                         reg=1e-3, runtime=rt)
            return np.asarray(w), rt.stats.faults
        s0, _ = stream(None)
        s1, sf = stream("seed=2;chunk_io@1")
        serr = float(np.abs(s1 - s0).max())
        assert serr < 1e-12, f"prefetch-death parity {serr}"
        out["stream"] = dict(parity=serr, injected=sf.injected,
                             degradations=sf.degradations)
    finally:
        costmodel.CHUNK_MEM_BUDGET = saved_budget
        if saved_depth is None:
            os.environ.pop("REPRO_PIPELINE_DEPTH", None)
        else:
            os.environ["REPRO_PIPELINE_DEPTH"] = saved_depth

    # serving: deadline shed + supervisor restart
    d = 16
    rt = LineageRuntime()
    W = input_tensor("fbW2", rng.normal(size=(d, 1)))
    script = PreparedScript(lambda x: (ops.matmul(x, W),), [(1, d)],
                            runtime=rt)
    x = rng.normal(size=(1, d))
    with ModelServer(script, runtime=rt, max_batch=8, adaptive=False,
                     max_wait_us=5e4) as srv:
        with faults.inject("seed=1"):
            fut = srv.submit(x, deadline_us=1.0)
            try:
                fut.result(timeout=5.0)
                raise AssertionError("expired request was not shed")
            except DeadlineExceededError:
                pass
        with faults.inject("seed=1;serving_dispatch@0"):
            try:
                srv.score(x, timeout=5.0)
                raise AssertionError("injected dispatch crash lost")
            except InjectedFault:
                pass
        with faults.inject(None):
            got, = srv.score(x, timeout=5.0)
    ref, = script(x)
    assert (got == ref).all(), "post-restart scoring diverged"
    f = rt.stats.faults
    assert f.shed == 1 and f.restarts == 1
    out["serving"] = dict(shed=f.shed, restarts=f.restarts)
    return out


def main(d: int = 64, n_scores: int = 200, rows: int = 16384,
         cols: int = 32, repeats: int = 8,
         max_overhead: float = 0.02) -> dict:
    over = _overhead_lane(d, n_scores, rows, cols, repeats,
                          max_overhead)
    rec = _recovery_lane(min(rows, 4096), cols)

    emit("faults_serving_policy_off", over["serving"]["t_off"] / n_scores)
    emit("faults_serving_policy_on", over["serving"]["t_on"] / n_scores,
         f"overhead={over['serving']['overhead'] * 100:.2f}%")
    emit("faults_stream_policy_off", over["stream"]["t_off"])
    emit("faults_stream_policy_on", over["stream"]["t_on"],
         f"overhead={over['stream']['overhead'] * 100:.2f}%")
    emit("faults_recovery", 0.0,
         f"fed_deg={rec['fed']['degradations']} "
         f"stream_deg={rec['stream']['degradations']} "
         f"shed={rec['serving']['shed']} "
         f"restarts={rec['serving']['restarts']}")

    entry = dict(
        benchmark="faults",
        workload=f"serving d={d} n={n_scores}; "
                 f"stream {rows}x{cols} budget/10",
        serving_overhead_pct=round(
            over["serving"]["overhead"] * 100, 2),
        stream_overhead_pct=round(over["stream"]["overhead"] * 100, 2),
        fed_parity=rec["fed"]["parity"],
        stream_parity=rec["stream"]["parity"],
        incidents=int(rec["fed"]["injected"] + rec["fed"]["retries"]
                      + rec["fed"]["degradations"]
                      + rec["stream"]["injected"]
                      + rec["stream"]["degradations"]
                      + rec["serving"]["shed"]
                      + rec["serving"]["restarts"]),
        fed_degradations=rec["fed"]["degradations"],
        stream_degradations=rec["stream"]["degradations"],
        shed=rec["serving"]["shed"],
        restarts=rec["serving"]["restarts"],
        ts=time.strftime("%Y-%m-%dT%H:%M:%S"),
    )
    trajectory = []
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                trajectory = json.load(f)
        except Exception:
            trajectory = []
    trajectory.append(entry)
    with open(BENCH_JSON, "w") as f:
        json.dump(trajectory, f, indent=2)
    return entry


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    print("name,us_per_call,derived")
    if "--smoke" in sys.argv:
        out = main(n_scores=100, rows=8192, repeats=5)
    else:
        out = main()
    print(json.dumps(out, indent=2))
