"""LineageRuntime: the control program (SystemDS §3.2 Fig. 3-3).

Interprets compiled plans instruction-by-instruction, maintains the
intermediate environment (buffer pool with liveness-based frees), traces
lineage for every executed operation, and probes/populates the lineage
reuse cache (§4.1).

Federated plans (§3.3) execute here too: `fed_*` instructions emitted
by the compiler's placement pass loop over the bound `FederatedTensor`'s
sites, run each site's local work as compiled sub-segments
(`LocalSite.execute` -> kernel registry + jit cache), and meter every
byte crossing the federation boundary into `stats.exchange` — per site,
identically across fuse modes.

`PreparedScript` is the JMLC analogue: trace a python function once into
a DAG with placeholder leaves, then re-execute with new in-memory inputs
at low latency (plan is compiled once; lineage is recomputed per input so
reuse stays sound).
"""
from __future__ import annotations

import hashlib
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import numpy as np

from . import backend, costmodel, faults
from .compiler import Plan, compile_plan
from .dag import (LEAVES, LTensor, Node, _fingerprint, _lhash_rec,
                  _slice_fingerprint,
                  input_tensor)  # _fingerprint: PreparedScript lineage
from .faults import CompileFailedError, FaultLog, SiteFailedError
from .federated import ExchangeLog, FederatedTensor, LocalSite
from .jit_cache import get_jit_cache
from .reuse import ReuseCache
from .reuse import nbytes as _reuse_nbytes


@dataclass
class ShardLog:
    """Shard-level analogue of the federation's `ExchangeLog`: counts
    mesh-lowered segment dispatches and the collectives they carry.
    Bytes come from the compile-time cost-model formulas (ring
    all-reduce / all-gather total link bytes over the `data` axis), so
    the meter is deterministic and auditable against exactly the
    exchanges the compiler priced when it accepted each lowering."""

    sharded_segments: int = 0         # shard_map segment dispatches
    config_sharded_segments: int = 0  # bucket-axis (config) dispatches
    reshards: int = 0                 # reshard (all-gather) boundaries run
    collectives: int = 0              # psum-carrying shard_* reduces run
    collective_bytes: int = 0         # total link bytes (cost-model est.)

    @property
    def total(self) -> int:
        return (self.sharded_segments + self.config_sharded_segments
                + self.reshards + self.collectives)

    def as_dict(self) -> dict:
        return dict(sharded_segments=self.sharded_segments,
                    config_sharded_segments=self.config_sharded_segments,
                    reshards=self.reshards,
                    collectives=self.collectives,
                    collective_bytes=self.collective_bytes)


@dataclass
class StreamLog:
    """Out-of-core streaming meter (chunked segments, ROADMAP item 4):
    how many row buckets were dispatched vs served from the chunk-level
    lineage cache, the payload bytes moved through device memory, and
    the high-water mark of resident state (one live chunk's inputs plus
    the running partial aggregates) — the quantity the chunk-size
    selection bounds by `costmodel.CHUNK_MEM_BUDGET`."""

    chunked_segments: int = 0  # streaming scopes entered (per run)
    chunks: int = 0            # row-bucket executions dispatched
    chunks_reused: int = 0     # buckets served from chunk-level lineage
    combines: int = 0          # partial-aggregate accumulations
    bytes_streamed: int = 0    # input payload bytes moved per dispatch
    peak_live_bytes: int = 0   # max resident: live chunk + accumulators
    full_hits: int = 0         # whole-stream reuse short-circuits

    @property
    def total(self) -> int:
        return (self.chunked_segments + self.chunks + self.chunks_reused
                + self.full_hits)

    def as_dict(self) -> dict:
        return dict(chunked_segments=self.chunked_segments,
                    chunks=self.chunks,
                    chunks_reused=self.chunks_reused,
                    combines=self.combines,
                    bytes_streamed=self.bytes_streamed,
                    peak_live_bytes=self.peak_live_bytes,
                    full_hits=self.full_hits)


@dataclass
class ServingLog:
    """Observability of the `repro.serving` request path: queue/coalesce
    behaviour and the warm-path hygiene counter. Updated by the
    `ModelServer` owning this runtime — all mutation happens on its
    single dispatcher thread, so the counters need no locking."""

    requests: int = 0        # requests scored (excludes rejections)
    batches: int = 0         # coalesced dispatches
    max_coalesce: int = 0    # largest coalesced batch observed
    padded: int = 0          # padding lanes executed (bucket - k waste)
    queue_peak: int = 0      # deepest queue observed at enqueue time
    rejected: int = 0        # bounded-queue rejections (backpressure)
    # jit-cache misses taken by a dispatch AFTER deploy-time warmup.
    # The deploy contract is compile-off-the-request-path: this MUST
    # stay 0 in steady state, and the serving benchmark asserts it.
    retraces: int = 0
    queue_wait_s: float = 0.0  # total enqueue->dispatch delay
    # seconds the dispatch stage spent replaying batches — open-loop
    # benchmarks subtract this from wall span to report queue-idle time
    # (how much headroom the request path has at a given arrival rate)
    busy_s: float = 0.0

    @property
    def total(self) -> int:
        return self.requests + self.rejected

    def as_dict(self) -> dict:
        out = dict(requests=self.requests, batches=self.batches,
                   max_coalesce=self.max_coalesce, padded=self.padded,
                   queue_peak=self.queue_peak, rejected=self.rejected,
                   retraces=self.retraces,
                   queue_wait_s=round(self.queue_wait_s, 6),
                   busy_s=round(self.busy_s, 6))
        if self.batches:
            out["mean_coalesce"] = round(self.requests / self.batches, 2)
        return out


@dataclass
class PipelineLog:
    """Asynchronous-dispatch meter (the pipelined execution engine that
    closes ROADMAP items 1/2/4's carried "Remaining" bullets).

    At pipeline depth >= 2 the segment executor stops syncing the
    device at segment boundaries: `dispatch_s` is host time spent
    *issuing* executables (XLA computes in the background), `block_s`
    is host time actually blocked materializing results at plan roots /
    probe points / host-op boundaries, and `prefetch_s` is worker time
    spent prepping streaming buckets concurrently with device compute.
    All counters stay 0 at depth 1 (`REPRO_PIPELINE_DEPTH=1`), which is
    what keeps the depth-1 `as_dict()` bitwise-identical to the
    pre-pipeline runtime."""

    async_segments: int = 0   # dispatches returned without a device sync
    dispatch_s: float = 0.0   # host seconds issuing async dispatches
    block_s: float = 0.0      # host seconds blocked materializing results
    prefetch_s: float = 0.0   # worker seconds prepping buckets (overlapped)
    prefetch_issued: int = 0  # bucket preps handed to the worker
    prefetch_hits: int = 0    # prepped buckets consumed by a dispatch
    prefetch_cancelled: int = 0  # prepped buckets discarded (cache hit
                                 # raced the prep, or error shutdown)
    donated_buffers: int = 0  # dead intermediate buffers donated to XLA
    donated_bytes: int = 0    # their payload bytes
    rebatches: int = 0        # serving batches coalesced while another
                              # batch was still in flight

    @property
    def total(self) -> int:
        return (self.async_segments + self.prefetch_issued
                + self.donated_buffers + self.rebatches)

    def as_dict(self) -> dict:
        out = dict(async_segments=self.async_segments,
                   dispatch_s=round(self.dispatch_s, 6),
                   block_s=round(self.block_s, 6),
                   prefetch_s=round(self.prefetch_s, 6),
                   prefetch_issued=self.prefetch_issued,
                   prefetch_hits=self.prefetch_hits,
                   prefetch_cancelled=self.prefetch_cancelled,
                   donated_buffers=self.donated_buffers,
                   donated_bytes=self.donated_bytes,
                   rebatches=self.rebatches)
        # share of pipeline host time spent on useful (overlappable)
        # work — issuing dispatches and prepping buckets — vs blocked
        # waiting on the device; 1.0 means the host never waited
        busy = self.dispatch_s + self.prefetch_s
        wall = busy + self.block_s
        out["overlap_ratio"] = round(busy / wall, 4) if wall > 0 else 0.0
        return out


@dataclass
class RuntimeStats:
    instructions: int = 0
    executed: int = 0      # instructions actually computed (not reused)
    reused: int = 0
    exec_time: float = 0.0
    segments: int = 0        # segments dispatched on the fused path
    batched_segments: int = 0  # config-variant segments run under vmap
    jit_cache_hits: int = 0  # warm compiled-executable lookups
    trace_time: float = 0.0  # seconds spent tracing+compiling segments
    # bytes crossing the federation boundary (fed_* / collect
    # instructions), metered per site — the §3.3 "exchange constraints"
    # as an auditable budget. Identical across fuse modes by
    # construction: both executors run the same federated instructions
    # and probe the reuse cache at the same compile-time points.
    exchange: ExchangeLog = field(default_factory=ExchangeLog)
    # mesh-lowered execution meter (reshards / collective bytes) — the
    # shard-level analogue of `exchange`
    shard: ShardLog = field(default_factory=ShardLog)
    # request-path meter (queue depth / coalesce sizes / padding waste /
    # hot-path retraces), populated when this runtime backs a
    # `repro.serving.ModelServer`
    serving: ServingLog = field(default_factory=ServingLog)
    # out-of-core streaming meter (chunk dispatches / chunk-level reuse
    # hits / peak resident bytes), populated when the plan contains
    # `lower_chunked`-placed segments
    streaming: StreamLog = field(default_factory=StreamLog)
    # async-dispatch meter (deferred sync / donation / prefetch /
    # rebatching), populated only at pipeline depth >= 2
    pipeline: PipelineLog = field(default_factory=PipelineLog)
    # fault-policy meter (see repro.core.faults): injections observed,
    # retries/timeouts/backoff taken, degradation-ladder steps, serving
    # sheds — plus per-site / per-dispatch latency monitors and site
    # heartbeats (the rescued repro.distributed.fault control plane)
    faults: FaultLog = field(default_factory=FaultLog)

    def as_dict(self):
        out = dict(instructions=self.instructions, executed=self.executed,
                   reused=self.reused, exec_time_s=round(self.exec_time, 6),
                   segments=self.segments,
                   batched_segments=self.batched_segments,
                   jit_cache_hits=self.jit_cache_hits,
                   trace_time_s=round(self.trace_time, 6))
        if self.exchange.total:
            out["exchange"] = self.exchange.as_dict()
        if self.shard.total:
            out["shard"] = self.shard.as_dict()
        if self.serving.total:
            out["serving"] = self.serving.as_dict()
        if self.streaming.total:
            out["streaming"] = self.streaming.as_dict()
        if self.pipeline.total:
            out["pipeline"] = self.pipeline.as_dict()
        if self.faults.total:
            out["faults"] = self.faults.as_dict()
        # the process-wide compiled-executable cache: hit/miss/eviction
        # counters + resident bytes, surfaced here so long-running
        # sessions can watch cache pressure alongside runtime counters
        out["jit_cache"] = get_jit_cache().stats.as_dict()
        return out


@dataclass
class _RunCtx:
    """Per-run execution context of the async pipeline.

    `depth` is the resolved `costmodel.pipeline_depth()` for this run
    (1 = fully synchronous PR-8 behaviour — every gate in the executor
    keys off it). `owned` tracks uids whose CURRENT value is a device
    buffer produced by traced segment execution *this run* and not
    referenced anywhere the runtime cannot see — the run-time half of
    the `donate_argnums` decision: a uid is donatable only while it is
    here, and leaves it the moment the reuse cache takes a reference
    (`put`). Leaf values, cache hits, host-path and chunked outputs are
    never admitted. Kept per-run (not on the runtime) so concurrent
    `run_plan` calls on one runtime cannot alias each other's
    ownership."""

    depth: int = 1
    owned: set = field(default_factory=set)


@dataclass
class _BatchCtx:
    """Execution context of a batched (`parfor`) plan: which value uids
    carry the leading config axis, and how wide the padded axis is."""

    bplan: Any                 # repro.core.batching.BatchedPlan
    batch: int                 # true number of configurations (k)
    bucket: int                # padded batch width (power-of-two)
    bvals: frozenset           # uids with a leading batch axis
    cshard: int = 1            # bucket-axis shards (mesh `config` axis);
                               # 1 = plain vmap, >1 = shard_map over it
    cmesh: Any = None          # resolved jax Mesh when cshard > 1


def _pad_axis0(arr, bucket: int):
    """Re-pad a true-k host/federated result back to the bucket width
    (repeating the last config, like `batching.pad_batch`) so it slots
    into downstream vmapped executables compiled for the bucket."""
    import jax.numpy as jnp
    pad = bucket - arr.shape[0]
    if pad <= 0:
        return arr
    return jnp.concatenate([arr, jnp.repeat(arr[-1:], pad, axis=0)])


class LineageRuntime:
    """Executes plans with lineage tracing and optional reuse."""

    def __init__(self, cache: Optional[ReuseCache] = None,
                 opt_level: int = 2, sparse_inputs: bool = False,
                 fuse: bool = True):
        # sparse_inputs: allow the BCOO physical representation. The
        # compile-time format-assignment pass (compiler.assign_formats)
        # pins each value to dense/bcoo from its sparsity estimate and
        # kernels are selected per format at build time, so sparse plans
        # run through the fused segment engine like dense ones. Default
        # OFF: measured on XLA-CPU, value-level BCOO gram at density 0.1
        # is slower than dense (DESIGN.md §2a, EXPERIMENTS.md §Baseline);
        # on TPU the bcoo format routes to the block-masked Pallas SpMM
        # kernels (repro.kernels.spmm).
        #
        # fuse: execute plans as jit-compiled segments (see
        # repro.core.segments). With an active ReuseCache the segmenter
        # breaks only at cost-gated probe points, and this runtime
        # probes/populates the cache at those boundaries with hit
        # behaviour identical to the fuse=False interpreter.
        self.cache = cache
        self.opt_level = opt_level
        self.sparse_inputs = sparse_inputs
        self.fuse = fuse
        self.stats = RuntimeStats()

    # ------------------------------------------------------------------
    def evaluate(self, outputs: Sequence[LTensor]) -> list[np.ndarray]:
        plan = compile_plan(list(outputs),
                            reuse_enabled=self.cache is not None,
                            opt_level=self.opt_level)
        return self.run_plan(plan)

    # ------------------------------------------------------------------
    def run_plan(self, plan: Plan,
                 leaf_values: Optional[dict[int, Any]] = None,
                 leaf_lineage: Optional[dict[int, str]] = None) -> list[np.ndarray]:
        values, lin = self._bind_leaves(plan, leaf_values, leaf_lineage)
        if self.fuse:
            rctx = _RunCtx(depth=costmodel.pipeline_depth())
            self._run_segments(plan, values, lin, rctx=rctx)
            if rctx.depth >= 2:
                # plan roots are THE sync point of the async pipeline:
                # segment dispatches above returned without blocking,
                # so the whole device backlog drains here, metered
                t0 = time.perf_counter()
                outs = [backend.to_numpy(values[i])
                        for i in plan.output_ids]
                self.stats.pipeline.block_s += time.perf_counter() - t0
                return outs
        else:
            self._run_instructions(plan, values, lin)
        return [backend.to_numpy(values[i]) for i in plan.output_ids]

    # ------------------------------------------------------------------
    def evaluate_batch(self, bplan) -> list[list[np.ndarray]]:
        """Execute a `BatchedPlan` (see `repro.core.batching`): the
        config-invariant prefix runs once through the ordinary segment
        machinery (same executables, same reuse probes as single-config
        plans), config-variant segments run vmapped over the padded
        batch axis. Returns one output list per configuration, in grid
        order, padding sliced off.

        Batched execution is inherently fused — the vmapped suffix IS a
        jit segment — so this path is used regardless of `self.fuse`
        (the interpreter equivalent of a batched plan is the sequential
        per-config loop, which `parfor` falls back to).
        """
        from .batching import pad_batch
        plan = bplan.plan
        bctx = _BatchCtx(bplan=bplan, batch=bplan.batch,
                         bucket=bplan.bucket,
                         bvals=bplan.batched_value_uids)
        if getattr(bplan, "mode", "vmap") == "shard":
            # shard the bucket axis over the mesh's `config` axis;
            # gracefully degrade to plain vmap when the mesh cannot be
            # realized (too few devices) or the bucket does not divide
            ms = getattr(plan, "mesh_spec", None)
            c = int(getattr(ms, "config", 1) or 1) if ms is not None else 1
            jm = ms.jax_mesh() if ms is not None else None
            if c > 1 and jm is not None and bplan.bucket % c == 0:
                bctx.cshard, bctx.cmesh = c, jm
        leaf_values = {
            uid: pad_batch(np.asarray(LEAVES.values[uid]), bplan.bucket)
            for uid in bplan.batched_leaf_uids}
        values, lin = self._bind_leaves(plan, leaf_values, None)
        rctx = _RunCtx(depth=costmodel.pipeline_depth())
        self._run_segments(plan, values, lin, bctx=bctx, rctx=rctx)
        return self._unpack_batch(plan, values, bctx, rctx=rctx)

    # ------------------------------------------------------------------
    def _unpack_batch(self, plan: Plan, values: dict[int, Any],
                      bctx: _BatchCtx,
                      rctx: Optional[_RunCtx] = None
                      ) -> list[list[np.ndarray]]:
        """Split a batched run's outputs into one list per config, in
        order, with the bucket padding sliced off. At pipeline depth
        >= 2 this is the batched path's sync point: the invariant
        prefix and the vmapped variant suffix were all dispatched
        without blocking, and the device backlog drains here."""
        t0 = time.perf_counter() if rctx is not None \
            and rctx.depth >= 2 else None
        out = self._unpack_batch_sync(plan, values, bctx)
        if t0 is not None:
            self.stats.pipeline.block_s += time.perf_counter() - t0
        return out

    @staticmethod
    def _unpack_batch_sync(plan: Plan, values: dict[int, Any],
                           bctx: _BatchCtx) -> list[list[np.ndarray]]:
        k = bctx.batch
        per_config: list[list[np.ndarray]] = [[] for _ in range(k)]
        for uid in plan.output_ids:
            arr = backend.to_numpy(values[uid])
            if uid in bctx.bvals:
                for j in range(k):
                    per_config[j].append(arr[j])
            else:
                # config-invariant output: every config gets its own
                # copy, matching the sequential path's independent
                # arrays (callers may mutate results in place)
                for j in range(k):
                    per_config[j].append(arr if j == 0 else arr.copy())
        return per_config

    # ------------------------------------------------------------------
    def replay_batch(self, bplan, stacked: Sequence[Any],
                     k: int) -> list[list[np.ndarray]]:
        """Replay a serving `BatchedPlan` (see `batching.compile_serving`)
        on k stacked request bindings — the low-latency scoring entry.

        `stacked` holds one ``(k,) + arg_shape`` array per argument,
        aligned with `bplan.leaf_order`. The batch is padded up to the
        power-of-two bucket (repeating the last request, exactly like
        `parfor`) so every dispatch lands on a warm vmapped executable:
        batch size and bucket are call-time properties — the segment
        set is k-independent and the jit cache re-specializes per
        bucket via the concrete argument signature. Nothing is read
        from or written to the global leaf registry for the request
        leaves, so concurrent plans cannot alias request data; padding
        is sliced off before results are returned.
        """
        from .batching import bucket_size, pad_batch
        plan = bplan.plan
        bucket = bucket_size(k)
        bctx = _BatchCtx(bplan=bplan, batch=k, bucket=bucket,
                         bvals=bplan.batched_value_uids)
        leaf_values = {
            uid: pad_batch(np.asarray(a), bucket)
            for uid, a in zip(bplan.leaf_order, stacked, strict=True)}
        leaf_lineage = None
        if self.cache is not None:
            # reuse probes must key on the request content, not the
            # released placeholder lineage — mirror PreparedScript
            leaf_lineage = {
                uid: f"req:{_fingerprint(np.asarray(a))}"
                for uid, a in zip(bplan.leaf_order, stacked)}
        values, lin = self._bind_leaves(plan, leaf_values, leaf_lineage)
        rctx = _RunCtx(depth=costmodel.pipeline_depth())
        self._run_segments(plan, values, lin, bctx=bctx, rctx=rctx)
        return self._unpack_batch(plan, values, bctx, rctx=rctx)

    # ------------------------------------------------------------------
    def _bind_leaves(self, plan: Plan,
                     leaf_values: Optional[dict[int, Any]],
                     leaf_lineage: Optional[dict[int, str]]
                     ) -> tuple[dict[int, Any], dict[int, str]]:
        values: dict[int, Any] = {}
        lin: dict[int, str] = {}
        if self.cache is not None:  # lineage only drives reuse probing
            lin = dict(LEAVES.lineage)
            if leaf_lineage:
                lin.update(leaf_lineage)
        fmts = plan.formats_for(self.sparse_inputs)
        # chunk-sliced leaves consumed ONLY by the streaming lane stay
        # host-resident: the streaming executor sparsifies/uploads one
        # row bucket at a time, so converting the whole leaf up front
        # would materialize exactly what out-of-core execution avoids.
        # A non-chunked consumer (materialization fallback) forces the
        # ordinary device-format bind, and interpreter mode (fuse=False)
        # executes chunk ops eagerly on whole values so it needs it too.
        stream_host: set[int] = set()
        if getattr(plan, "chunk_sliced", None) and self.fuse:
            non_chunk = {u for ins in plan.instructions
                         if ins.target != "chunked"
                         for u in ins.input_ids}
            stream_host = {u for u in plan.chunk_sliced
                           if u not in non_chunk}
        for ins in plan.instructions:
            for inp in ins.node.inputs:
                if inp.op == "input" and inp.uid not in values:
                    src = None
                    if leaf_values and inp.uid in leaf_values:
                        src = leaf_values[inp.uid]
                    elif inp.uid in LEAVES.values:
                        src = LEAVES.values[inp.uid]
                    else:
                        raise KeyError(
                            f"unbound input leaf {inp.attr('name')}")
                    if isinstance(src, FederatedTensor):
                        # federated leaves bind the metadata object;
                        # partitions never move unless a `collect`
                        # instruction says so
                        values[inp.uid] = src
                        continue
                    # sparsify per bind, never memoized: a cached
                    # conversion cannot detect in-place mutation of the
                    # source array without a full-content scan that
                    # costs as much as the conversion itself
                    arr = np.asarray(src)
                    if (fmts.get(inp.uid) == backend.BCOO
                            and inp.uid not in stream_host):
                        arr = backend.sparsify(arr)
                    values[inp.uid] = arr
        for r in plan.roots:  # outputs that are themselves leaves
            if r.op == "input" and r.uid not in values:
                # overrides first, registry fallback: a partial
                # leaf_values dict (batched leaves only, see
                # evaluate_batch) must not shadow ordinary leaves
                if leaf_values and r.uid in leaf_values:
                    values[r.uid] = leaf_values[r.uid]
                else:
                    values[r.uid] = LEAVES.values[r.uid]
        return values, lin

    # ------------------------------------------------------------------
    def _run_instructions(self, plan: Plan, values: dict[int, Any],
                          lin: dict[int, str]) -> None:
        """Per-instruction interpreter (the `fuse=False` fallback);
        probes/populates the reuse cache at cost-gated probe points —
        the same compile-time set the segment executor uses, so hit
        behaviour is identical across both modes."""
        fmts = plan.formats_for(self.sparse_inputs)
        lmemo: dict[int, str] = {}  # lineage-hash memo shared across the run
        for ins in plan.instructions:
            self.stats.instructions += 1
            node = ins.node
            lhash = None
            if self.cache is not None and ins.probe:
                lhash = _lhash_rec(node, lin, lmemo)
                hit = self.cache.probe(lhash)
                if hit is not None:
                    values[ins.out_id] = _coerce_format(
                        hit, fmts.get(ins.out_id, backend.DENSE))
                    self.stats.reused += 1
                    self._free(values, ins.last_use_of)
                    continue
            t0, tt0 = time.perf_counter(), self.stats.trace_time
            out = self._exec_one(ins, values, fmts)
            # per-site sub-segment compiles (federated ops) book into
            # trace_time inside LocalSite.execute — keep them out of
            # exec_time, mirroring _execute_cached's split
            dt = (time.perf_counter() - t0
                  - (self.stats.trace_time - tt0))
            self.stats.executed += 1
            self.stats.exec_time += dt
            values[ins.out_id] = out
            if lhash is not None:
                # admission was decided by the compile-time gate; store
                # the *estimated* cost too — deterministic and identical
                # across fuse modes, so eviction ordering (and therefore
                # hit counts) cannot diverge under pool pressure the way
                # measured wall-times would
                self.cache.put(lhash, out, ins.est_cost_s, gated=False)
            self._free(values, ins.last_use_of)

    # ------------------------------------------------------------------
    def _run_segments(self, plan: Plan, values: dict[int, Any],
                      lin: dict[int, str],
                      bctx: Optional[_BatchCtx] = None,
                      rctx: Optional[_RunCtx] = None) -> None:
        """Segment executor: maximal fusable runs replayed through cached
        jit executables. With an active reuse cache, probe points are
        segment-final (see segments.py): the cache is probed before a
        probe-final segment runs — a hit skips the whole segment — and
        populated from its output afterwards.

        With a `_BatchCtx` (batched `parfor` plans), segmentation is
        variance-aware and config-variant segments execute as
        `jax.vmap`-wrapped executables over the padded batch axis —
        cached under a vmap-tagged key so they never collide with the
        unbatched executable of the same segment body.

        At pipeline depth >= 2 (`_RunCtx.depth`) dispatches return
        without a device sync (XLA computes asynchronously while the
        host walks on to the next segment), and dead-after-segment
        device buffers owned by this run are donated to XLA via
        `donate_argnums` — the donation mask is baked into the jit-
        cache key, so a donated executable can never serve a call whose
        arguments must stay live."""
        if rctx is None:
            rctx = _RunCtx()
        reuse = self.cache is not None
        segments = (bctx.bplan.segments_for(reuse) if bctx is not None
                    else plan.segments_for(reuse))
        fmts = plan.formats_for(self.sparse_inputs)
        jcache = get_jit_cache()
        lmemo: dict[int, str] = {}
        # resolve the plan's mesh once per run; None means not enough
        # devices — sharded segments then run their local-equivalent
        # (unshard) executables, bit-identical in results
        mesh_spec = getattr(plan, "mesh_spec", None)
        jmesh = (mesh_spec.jax_mesh() if mesh_spec is not None else None)
        for seg in segments:
            batched = bctx is not None and seg.variant
            self.stats.segments += 1
            if batched:
                self.stats.batched_segments += 1
            self.stats.instructions += len(seg.instructions)
            last = seg.instructions[-1]
            args = [values[u] for u in seg.input_uids]
            seg_key = seg.key
            # physical formats are part of the executable; all-dense
            # segments share one executable across sparse_inputs modes
            # (internal formats derive from the boundary ones)
            boundary = (*seg.input_uids, *seg.output_uids)
            if fmts and any(u in fmts for u in boundary):
                fsig = ",".join(fmts.get(u, backend.DENSE)
                                for u in boundary)
                seg_key = f"{seg_key}|f:{fsig}"
            if getattr(seg, "chunked", False):
                # streaming lane: dispatch the segment once per row
                # bucket and sum the partial aggregates — probes and
                # cache puts happen inside (per output AND per chunk)
                self._run_chunked_segment(plan, seg, seg_key, fmts,
                                          values, lin, lmemo, jcache,
                                          rctx=rctx)
                self._free(values, seg.frees)
                continue
            if batched:
                axes = "".join("0" if u in bctx.bvals else "-"
                               for u in seg.input_uids)
                seg_key = f"{seg_key}|vmap:{axes}"
                if bctx.cshard > 1:
                    # bucket axis split over the mesh's config axis:
                    # a different executable than plain vmap
                    seg_key = (f"{seg_key}|cshard:{bctx.cshard}x"
                               f"{mesh_spec.key_tag()}")
                    self.stats.shard.config_sharded_segments += 1
            seg_sharded = getattr(seg, "sharded", False)
            if seg_sharded:
                if jmesh is not None:
                    from .jit_cache import mesh_key_tag
                    from .segments import shard_specs
                    in_t, out_t = shard_specs(seg)
                    seg_key += mesh_key_tag(mesh_spec.key_tag(),
                                            in_t, out_t)
                    self._meter_shard_segment(seg)
                else:
                    seg_key += "|unshard"  # local-equivalent fallback
            lhash = None
            if reuse and last.probe:
                lhash = _lhash_rec(last.node, lin, lmemo)
                hit = self.cache.probe(lhash)
                if hit is not None:
                    values[last.out_id] = _coerce_format(
                        hit, fmts.get(last.out_id, backend.DENSE))
                    self.stats.reused += 1
                    rest = tuple(u for u in seg.output_uids
                                 if u != last.out_id)
                    if rest:
                        # multi-output segment: run the compensation
                        # executable — the segment minus the probe value
                        # and everything only it needed — mirroring what
                        # the interpreter computes after the same hit
                        self._run_compensation(
                            seg, seg_key, fmts, args, rest, last.out_id,
                            jcache, values,
                            bctx=bctx if batched else None,
                            jmesh=jmesh, rctx=rctx)
                    self._free(values, seg.frees)
                    continue
            if last.node.op in backend.NON_TRACEABLE_OPS:
                # host-path segment (always single-instruction): the
                # SAME `_exec_one` the interpreter uses, so fuse modes
                # cannot diverge — federated orchestration / collect
                # boundaries dispatch per-site compiled sub-segments
                # and meter the exchange; other host ops (quantile) run
                # their kernel eagerly, outside any jit trace
                t0, tt0 = time.perf_counter(), self.stats.trace_time
                out = self._exec_one(last, values, fmts,
                                     bctx=bctx if batched else None)
                # per-site compiles booked into trace_time by
                # LocalSite.execute; exec_time gets the rest
                self.stats.exec_time += (time.perf_counter() - t0
                                         - (self.stats.trace_time - tt0))
                outs = (out,)
                self.stats.executed += 1
            else:
                # note: the REAL bctx, not the variant-gated one — in a
                # batched (parfor/serving) plan even invariant-prefix
                # segments must keep deterministic plain keys
                don = self._donation_mask(seg, values, rctx, bctx)
                if don:
                    # donation changes executable semantics — bake the
                    # mask into the structural key so the donated and
                    # plain executables of one body never collide
                    seg_key = (f"{seg_key}|don:"
                               + ",".join(map(str, don)))
                    plog = self.stats.pipeline
                    plog.donated_buffers += len(don)
                    plog.donated_bytes += sum(
                        _reuse_nbytes(args[i]) for i in don)
                try:
                    outs = self._execute_cached(
                        seg_key, self._seg_builder(seg, fmts,
                                                   bctx if batched
                                                   else None,
                                                   jmesh=jmesh),
                        args, jcache, rctx=rctx, donate=don)
                except CompileFailedError as e:
                    # degradation ladder: a segment whose jit compile
                    # failed runs its instructions eagerly through the
                    # fuse=False kernels (parity by construction);
                    # vmapped/sharded segments have no eager equivalent
                    # of the same executable and re-raise
                    e.args = (f"{e.args[0]} [{seg.summary()}]",)
                    if batched or seg_sharded:
                        raise
                    outs = self._interpret_segment(seg, values, fmts, e)
                else:
                    if rctx.depth >= 2 and not seg_sharded and not (
                            batched and bctx.cshard > 1):
                        # traced outputs this run produced and still
                        # owns — donation candidates for their last
                        # consumer
                        rctx.owned.update(seg.output_uids)
                self.stats.executed += len(seg.instructions)
            for uid, val in zip(seg.output_uids, outs, strict=True):
                values[uid] = val
            if lhash is not None:
                # same estimated cost as the interpreter stores (see
                # _run_instructions) — keeps eviction mode-identical
                self.cache.put(lhash, values[last.out_id],
                               last.est_cost_s, gated=False)
                # the reuse cache now references this buffer: it must
                # never be donated out from under a future hit
                rctx.owned.discard(last.out_id)
            self._free(values, seg.frees)

    # ------------------------------------------------------------------
    @staticmethod
    def _seg_builder(seg, fmts: dict, bctx: Optional[_BatchCtx],
                     drop_output: Optional[int] = None, jmesh=None):
        """Deferred segment-closure builder (only called on a jit-cache
        miss): plain for invariant segments, vmap-wrapped for
        config-variant ones, shard_map-wrapped for mesh-lowered ones
        (with a local-equivalent fallback when the mesh is absent), and
        shard_map-over-config around the vmap for bucket-sharded
        batched segments."""
        from .segments import (build_batched_segment_fn,
                               build_config_sharded_segment_fn,
                               build_segment_fn, build_sharded_segment_fn)
        if getattr(seg, "sharded", False):
            if jmesh is not None:
                return lambda: build_sharded_segment_fn(
                    seg, fmts, jmesh, drop_output=drop_output)
            return lambda: build_segment_fn(
                seg, fmts, drop_output=drop_output, unshard=True)
        if bctx is None:
            return lambda: build_segment_fn(seg, fmts,
                                            drop_output=drop_output)
        if bctx.cshard > 1 and bctx.cmesh is not None:
            return lambda: build_config_sharded_segment_fn(
                seg, fmts, bctx.bvals, bctx.cmesh,
                drop_output=drop_output)
        return lambda: build_batched_segment_fn(seg, fmts, bctx.bvals,
                                                drop_output=drop_output)

    # ------------------------------------------------------------------
    def _meter_shard_segment(self, seg) -> None:
        """Account one mesh dispatch of a sharded segment into
        `stats.shard` — walked from the compile-time instruction stream,
        so the meter matches the cost model's collective formulas."""
        log = self.stats.shard
        log.sharded_segments += 1
        for ins in seg.instructions:
            op = ins.node.op
            if op == backend.RESHARD_OP:
                log.reshards += 1
                log.collective_bytes += costmodel.collective_bytes(
                    ins.node)
            elif op in backend.SHARD_REDUCE_OPS:
                log.collectives += 1
                log.collective_bytes += costmodel.collective_bytes(
                    ins.node)

    # ------------------------------------------------------------------
    @staticmethod
    def _donation_mask(seg, values: dict[int, Any], rctx: _RunCtx,
                       bctx: Optional[_BatchCtx]) -> tuple[int, ...]:
        """Argument positions safe to donate on this dispatch.

        Structural candidacy (`Segment.donatable_positions`: this
        segment frees the uid, i.e. nothing in the plan reads it
        afterwards) intersected with run-time ownership: the buffer
        must have been produced by traced execution THIS run
        (`_RunCtx.owned` — never a bound leaf, reuse-cache hit, or
        value the cache took a reference to) and be a plain dense
        array (BCOO pytrees and federated handles are never donated).
        Sharded dispatches are excluded wholesale — their buffers live
        on mesh-placed shardings XLA cannot alias into differently-
        placed outputs. Batched (vmap/serving) dispatches are excluded
        too: their donation mask would depend on per-request reuse-probe
        outcomes, and a mask flip changes the executable key — a
        retrace on a pinned serving hot path, which deploy warmup
        guarantees never happens."""
        if rctx.depth < 2 or bctx is not None \
                or getattr(seg, "sharded", False):
            return ()
        cand = seg.donatable_positions()
        if not cand:
            return ()
        return tuple(
            i for i in cand
            if seg.input_uids[i] in rctx.owned
            and not backend.is_sparse(values[seg.input_uids[i]]))

    # ------------------------------------------------------------------
    def _execute_cached(self, seg_key: str, build_fn, args, jcache,
                        rctx: Optional[_RunCtx] = None,
                        donate: tuple = ()):
        """Run one executable through the jit cache (lookup, compile on
        miss, execute), accounting trace/exec time.

        Pipeline depth 1 (or no `rctx`): block until every output is
        ready — the pre-pipeline behaviour, bitwise and meter
        identical. Depth >= 2: return the outputs as in-flight device
        arrays (XLA dispatches asynchronously); the sync happens at
        plan roots / probe materialization / host-op boundaries, and
        the dispatch cost is metered into `stats.pipeline`."""
        key, exe = jcache.lookup(seg_key, args)
        if exe is None:
            try:
                exe, dt_trace = jcache.compile(key, build_fn(), args,
                                               donate_argnums=donate)
            except Exception as e:
                # typed so the segment loop can take its degradation
                # ladder (interpreter fallback); with the policy off
                # compile errors propagate raw, as before
                if faults.policy_enabled():
                    raise CompileFailedError(seg_key, e) from e
                raise
            self.stats.trace_time += dt_trace
        else:
            self.stats.jit_cache_hits += 1
        t0 = time.perf_counter()
        outs = exe(*args)
        if rctx is None or rctx.depth < 2:
            for o in outs:
                backend.block_ready(o)
            self.stats.exec_time += time.perf_counter() - t0
        else:
            dt = time.perf_counter() - t0
            self.stats.exec_time += dt
            plog = self.stats.pipeline
            plog.async_segments += 1
            plog.dispatch_s += dt
        return outs

    # ------------------------------------------------------------------
    def _run_compensation(self, seg, seg_key: str, fmts: dict, args,
                          rest: tuple, probe_uid: int, jcache,
                          values: dict[int, Any],
                          bctx: Optional[_BatchCtx] = None,
                          jmesh=None,
                          rctx: Optional[_RunCtx] = None) -> None:
        """Execute a probe-hit segment's remaining outputs (the segment
        with the cached value dead-code eliminated); see
        `segments.build_segment_fn(drop_output=...)`. Never donates —
        the compensation key derives from the plain segment key."""
        try:
            outs = self._execute_cached(
                f"{seg_key}|comp",
                self._seg_builder(seg, fmts, bctx, drop_output=probe_uid,
                                  jmesh=jmesh),
                args, jcache, rctx=rctx)
        except CompileFailedError as e:
            e.args = (f"{e.args[0]} [{seg.summary()}]",)
            if bctx is not None or getattr(seg, "sharded", False):
                raise
            # eager fallback computes ALL segment outputs; deliver only
            # the non-probe ones (the hit already filled probe_uid)
            allouts = self._interpret_segment(seg, values, fmts, e)
            by_uid = dict(zip(seg.output_uids, allouts, strict=True))
            outs = tuple(by_uid[u] for u in rest)
        # interpreter-equivalent accounting: it would execute every
        # instruction except the one reused (DCE may drop more)
        self.stats.executed += len(seg.instructions) - 1
        for uid, val in zip(rest, outs, strict=True):
            values[uid] = val

    # ------------------------------------------------------------------
    def _interpret_segment(self, seg, values: dict[int, Any],
                           fmts: dict, err: CompileFailedError) -> tuple:
        """Graceful-degradation lane for a failed segment compile: run
        the segment's instructions eagerly through `_exec_one` — the
        SAME kernels the fuse=False interpreter dispatches, so the
        degraded result matches the fused executable to numerical
        round-off. Intermediates live in a private overlay; only the
        segment's declared outputs are returned."""
        flog = self.stats.faults
        flog.degradations += 1
        if isinstance(err.cause, faults.InjectedFault):
            flog.injected += 1
        env = dict(values)  # shallow overlay: refs only
        for ins in seg.instructions:
            env[ins.out_id] = self._exec_one(ins, env, fmts)
        return tuple(env[u] for u in seg.output_uids)

    # ------------------------------------------------------------------
    def _run_chunked_segment(self, plan: Plan, seg, seg_key: str,
                             fmts: dict, values: dict[int, Any],
                             lin: dict[int, str], lmemo: dict[int, str],
                             jcache,
                             rctx: Optional[_RunCtx] = None) -> None:
        """Streaming executor for a chunked-target segment (out-of-core
        execution, ROADMAP item 4).

        The segment's sliced inputs (`plan.chunk_sliced`) are visited in
        row buckets sized by `costmodel.chunk_rows` from the ACTUAL
        per-row payload (BCOO-formatted inputs charged at their sparse
        data+indices size), so one live chunk plus the running partial
        aggregates stay under `costmodel.CHUNK_MEM_BUDGET`. The bucket
        is a power of two independent of the total row count, so every
        full bucket shares ONE warm jit executable (the ragged tail
        compiles a second, once) and appending rows never shifts the
        earlier bucket boundaries.

        Reuse happens at two granularities:

          * full aggregates — each probe-flagged output's lineage hash
            is probed before any chunk is dispatched; when every output
            hits, the whole stream is skipped (the segment-final probe
            of ordinary segments, applied per output);
          * chunk level (incremental recompute) — each bucket's partial
            tuple is cached under a key of the segment structure, the
            row range, and content fingerprints of the bucket's slices
            (plus the replicated operands, which shift every bucket when
            they change). Appending or correcting rows recomputes ONLY
            the affected buckets; untouched ones hit.

        At pipeline depth >= 2 (`costmodel.prefetch_depth`) the stream
        is double-buffered: bucket fingerprints derive from the leaf's
        block-sum table (`dag._slice_fingerprint` — bitwise identical
        to hashing the slice, so the chunk cache is shared across
        depths) and a bounded single-worker thread slices/pads the NEXT
        miss bucket's arguments while the device computes the current
        one. Cache lookups, meter updates and accumulation stay on the
        main thread; the worker only does pure numpy prep. Worker
        errors propagate to the caller via `Future.result()` and the
        `finally` shutdown cancels queued preps so no thread outlives
        the stream. Depth 1 takes the pre-pipeline loop verbatim.
        """
        reuse = self.cache is not None
        log = self.stats.streaming
        out_set = set(seg.output_uids)
        out_ins = {ins.out_id: ins for ins in seg.instructions
                   if ins.out_id in out_set}
        # ---- full-aggregate probes (one per probe-flagged output, the
        # same set the fuse=False interpreter probes) ----
        lhashes: dict[int, str] = {}
        hits: dict[int, Any] = {}
        if reuse:
            for uid in seg.output_uids:
                if not out_ins[uid].probe:
                    continue
                lh = _lhash_rec(out_ins[uid].node, lin, lmemo)
                lhashes[uid] = lh
                got = self.cache.probe(lh)
                if got is not None:
                    hits[uid] = got
        # short-circuit iff every output is either a cache-hit partial
        # aggregate or a chunk-invariant generator (a target-neutral
        # literal that rode along) — escaping chunked-placement values
        # have inputs and always force the stream to run
        if hits and all(uid in hits or not out_ins[uid].node.inputs
                        for uid in seg.output_uids):
            for uid in seg.output_uids:
                if uid in hits:
                    values[uid] = _coerce_format(
                        hits[uid], fmts.get(uid, backend.DENSE))
                else:
                    values[uid] = backend.kernel_for_node(
                        out_ins[uid].node)()
            self.stats.reused += len(hits)
            self.stats.executed += len(seg.output_uids) - len(hits)
            log.full_hits += 1
            return

        sliced = [u for u in seg.input_uids if u in plan.chunk_sliced]
        if not sliced:  # defensive: nothing to stream over — the chunk
            # kernels ARE the base ops, so one whole-input dispatch is
            # exact
            outs = self._execute_cached(
                seg_key, self._seg_builder(seg, fmts, None),
                [values[u] for u in seg.input_uids], jcache)
            for uid, val in zip(seg.output_uids, outs, strict=True):
                values[uid] = val
            self.stats.executed += len(seg.instructions)
            return

        log.chunked_segments += 1
        host: dict[int, np.ndarray] = {}
        for u in sliced:
            a = values[u]
            if backend.is_sparse(a):
                # materialization fallback for a sparse interior value
                # entering the stream row-aligned (leaves are kept
                # host-dense by _bind_leaves; this is the rare rest)
                a = a.todense()
            host[u] = np.asarray(a)
        rows = host[sliced[0]].shape[0]
        for u in sliced[1:]:
            if host[u].shape[0] != rows:
                raise ValueError(
                    f"chunked segment {seg.index}: sliced inputs "
                    f"disagree on rows ({host[u].shape[0]} vs {rows})")
        row_bytes = 0.0
        for u in sliced:
            a = host[u]
            if fmts.get(u) == backend.BCOO:
                # BCOO slice payload: data + 2 int32 index columns,
                # charged at 2x for the nse power-of-two padding bucket
                # (see backend.sparsify) — the reuse.nbytes accounting
                nnz = int(np.count_nonzero(a))
                row_bytes += (2.0 * nnz / max(rows, 1)
                              * (a.dtype.itemsize + 8))
            else:
                row_bytes += a.nbytes / max(rows, 1)
        c = costmodel.chunk_rows(row_bytes)
        n_chunks = max(1, -(-rows // c))
        # replicated operands are fingerprinted once: they are part of
        # every chunk's identity (a changed mean shifts every bucket)
        rep_fp = ""
        if reuse:
            rep_fp = "|".join(
                _fingerprint(np.asarray(backend.densify(values[u])))
                for u in seg.input_uids if u not in host)
        cost_each = (sum(i.est_cost_s for i in out_ins.values())
                     / n_chunks)
        builder = self._seg_builder(seg, fmts, None)
        # per-output accumulation mode: chunk_* partials SUM across row
        # buckets; an escaping chunked-placement value (consumed by a
        # later scope through a local boundary) is materialized
        # piecewise — its buckets CONCAT back to the full rows; anything
        # else is a target-neutral generator that rode along and is
        # chunk-invariant — the first bucket's value stands
        modes = {}
        for uid in seg.output_uids:
            n = out_ins[uid].node
            if n.op.startswith("chunk_"):
                modes[uid] = "sum"
            elif n.placement == "chunked":
                modes[uid] = "concat"
            else:
                modes[uid] = "keep"
        accs: dict[int, Any] = {u: None for u in seg.output_uids}

        def _accumulate(parts, live: int) -> None:
            for uid, p in zip(seg.output_uids, parts, strict=True):
                prev = accs[uid]
                mode = modes[uid]
                if mode == "concat":
                    accs[uid] = [p] if prev is None else prev + [p]
                elif prev is None:
                    accs[uid] = p
                elif mode == "sum":
                    accs[uid] = prev + p
                    log.combines += 1
                # "keep": chunk-invariant — the first value stands
            acc_bytes = sum(_reuse_nbytes(v) for v in accs.values()
                            if v is not None)
            log.peak_live_bytes = max(log.peak_live_bytes,
                                      live + acc_bytes)

        pdepth = 1
        if rctx is not None and rctx.depth >= 2:
            pdepth = costmodel.prefetch_depth(row_bytes, n_chunks)
        if pdepth <= 1:
            # ---- synchronous loop (pre-pipeline behaviour, bitwise
            # and meter identical at REPRO_PIPELINE_DEPTH=1) ----
            for s in range(0, rows, c):
                e = min(s + c, rows)
                parts, ckey, live = None, None, 0
                if reuse:
                    fps = ",".join(_fingerprint(host[u][s:e])
                                   for u in sliced)
                    ckey = hashlib.sha1(
                        f"chunkpart|{seg_key}|{s}:{e}|{rep_fp}|{fps}"
                        .encode()).hexdigest()
                    parts = self.cache.probe(ckey)
                    if parts is not None:
                        log.chunks_reused += 1
                if parts is None:
                    args = []
                    for u in seg.input_uids:
                        if u in host:
                            a = host[u][s:e]
                            if fmts.get(u) == backend.BCOO:
                                a = backend.sparsify(a)
                            live += _reuse_nbytes(a)
                            args.append(a)
                        else:
                            args.append(values[u])
                    outs = self._execute_cached(seg_key, builder, args,
                                                jcache)
                    # partials densify to HOST arrays: their only
                    # consumer is the `combine` densify boundary, numpy
                    # accumulators add chunk-by-chunk regardless of the
                    # slice's format, and host adds skip the per-op
                    # device dispatch that would otherwise dominate
                    # warm (all-chunks-reused) runs
                    parts = tuple(np.asarray(backend.densify(o))
                                  for o in outs)
                    log.chunks += 1
                    log.bytes_streamed += live
                    if ckey is not None:
                        self.cache.put(ckey, parts, cost_each,
                                       gated=False)
                _accumulate(parts, live)
        else:
            self._run_chunked_pipelined(
                seg, seg_key, fmts, values, jcache, host, sliced,
                rows, c, reuse, rep_fp, cost_each, builder,
                _accumulate, row_bytes, rctx)
        for uid, m in modes.items():
            if m == "concat" and accs[uid] is not None:
                accs[uid] = np.concatenate(accs[uid], axis=0)
        # cached full aggregates win (identical values, mirrors the
        # interpreter's per-instruction hits); streamed accumulators
        # fill the rest and populate the cache
        for uid in seg.output_uids:
            if uid in hits:
                values[uid] = _coerce_format(
                    hits[uid], fmts.get(uid, backend.DENSE))
            else:
                values[uid] = accs[uid]
                if uid in lhashes:
                    self.cache.put(lhashes[uid], accs[uid],
                                   out_ins[uid].est_cost_s, gated=False)
        self.stats.reused += len(hits)
        self.stats.executed += len(seg.instructions) - len(hits)

    # ------------------------------------------------------------------
    def _run_chunked_pipelined(self, seg, seg_key: str, fmts: dict,
                               values: dict[int, Any], jcache,
                               host: dict[int, np.ndarray],
                               sliced: list, rows: int, c: int,
                               reuse: bool, rep_fp: str,
                               cost_each: float, builder,
                               accumulate, row_bytes: float,
                               rctx: _RunCtx) -> None:
        """Double-buffered bucket loop (pipeline depth >= 2).

        Division of labour, chosen so every shared structure stays
        single-threaded: the MAIN thread resolves each bucket's
        fingerprints (near-free via the leaf's block-sum table when the
        bucket is 4096-byte aligned, direct hashing otherwise — both
        bitwise identical to the synchronous loop's `_fingerprint`, so
        chunk-cache keys and hits are depth-invariant), probes the
        reuse cache, dispatches, accumulates and meters; the single
        WORKER thread only slices/sparsifies a MISS bucket's arguments
        (pure numpy on private data) while the device computes the
        previous bucket. Hit buckets never reach the worker — a warm
        append-retrain stream costs zero wasted copies.

        `peak_live_bytes` charges the consuming bucket's actual bytes
        PLUS the next in-flight miss bucket's estimated payload, so the
        meter honestly reflects two live buckets under
        `CHUNK_MEM_BUDGET` (chunk_rows sizes buckets with
        `CHUNK_LIVE_FACTOR` headroom for exactly this).

        A worker exception surfaces on the main thread at
        `Future.result()`; under the fault policy the stream degrades
        mid-flight to the synchronous chunk loop (injected or real
        worker death costs the pipeline, never the answer), with the
        policy off it propagates raw. Either way the `finally` cancels
        queued preps (counted as `prefetch_cancelled`) and joins the
        worker, so an error never leaves a hung thread or a
        silently-dropped bucket."""
        log = self.stats.streaming
        plog = self.stats.pipeline
        # block-sum tables are only valid when the bound value IS the
        # registered leaf buffer (np.asarray of the registry array is
        # identity); an override/densified copy falls back to hashing
        tables = {}
        for u in sliced:
            a = host[u]
            if (LEAVES.values.get(u) is a
                    and a.flags["C_CONTIGUOUS"] and a.ndim >= 1):
                tables[u] = LEAVES.fp_tables.get(u)
            else:
                tables[u] = None

        def _bucket_fp(u: int, s: int, e: int) -> str:
            sl = host[u][s:e]
            t = tables[u]
            if t is not None:
                fp = _slice_fingerprint(sl, t, s * host[u].strides[0])
                if fp is not None:
                    return fp
            return _fingerprint(sl)

        def _prep(s: int, e: int, probe_faults: bool = True):
            if probe_faults:
                # worker-side injection point: a chunk_io firing here
                # kills this prep — the consumer degrades the rest of
                # the stream to the synchronous loop. The degraded
                # (probe_faults=False) re-preps are injection-free so
                # recovery always completes.
                faults.io_entry("chunk_prefetch")
            t0 = time.perf_counter()
            args, live = [], 0
            for u in seg.input_uids:
                if u in host:
                    a = host[u][s:e]
                    if fmts.get(u) == backend.BCOO:
                        a = backend.sparsify(a)
                    live += _reuse_nbytes(a)
                    args.append(a)
                else:
                    args.append(values[u])
            return args, live, time.perf_counter() - t0

        spans = [(s, min(s + c, rows)) for s in range(0, rows, c)]
        pdepth = costmodel.prefetch_depth(row_bytes, len(spans))
        ex = ThreadPoolExecutor(max_workers=1,
                                thread_name_prefix="chunk-prefetch")
        inflight: deque = deque()  # (s, e, ckey, parts, fut)
        nxt = 0
        try:
            while inflight or nxt < len(spans):
                # keep pdepth buckets resolved/in-flight ahead of the
                # consumer; fingerprint+probe on the main thread, args
                # prep for misses on the worker
                while len(inflight) < pdepth and nxt < len(spans):
                    s, e = spans[nxt]
                    nxt += 1
                    ckey, parts, fut = None, None, None
                    if reuse:
                        fps = ",".join(_bucket_fp(u, s, e)
                                       for u in sliced)
                        ckey = hashlib.sha1(
                            f"chunkpart|{seg_key}|{s}:{e}|{rep_fp}|{fps}"
                            .encode()).hexdigest()
                        parts = self.cache.probe(ckey)
                        if parts is not None:
                            log.chunks_reused += 1
                    if parts is None:
                        fut = ex.submit(_prep, s, e)
                        plog.prefetch_issued += 1
                    inflight.append((s, e, ckey, parts, fut))
                s, e, ckey, parts, fut = inflight.popleft()
                live = 0
                if parts is None:
                    try:
                        args, live, dt_prep = fut.result()
                    except Exception as err:
                        if not faults.policy_enabled():
                            raise
                        # degradation ladder: the prefetch worker died
                        # mid-stream — reclaim this span plus every
                        # queued/unscheduled one and finish on the
                        # synchronous loop (the `finally` still joins
                        # the pool; the sync re-preps are
                        # injection-free, so recovery terminates)
                        flog = self.stats.faults
                        if isinstance(err, faults.InjectedFault):
                            flog.injected += 1
                        flog.degradations += 1
                        tail = [(s, e, ckey, None)]
                        while inflight:
                            s2, e2, ck2, p2, f2 = inflight.popleft()
                            if f2 is not None and f2.cancel():
                                plog.prefetch_cancelled += 1
                            tail.append((s2, e2, ck2, p2))
                        tail.extend((s3, e3, None, None)
                                    for s3, e3 in spans[nxt:])
                        nxt = len(spans)
                        for s2, e2, ck2, p2 in tail:
                            live2 = 0
                            if p2 is None and reuse and ck2 is None:
                                # spans the pipeline never resolved:
                                # probe the chunk cache like the sync
                                # loop would (same keys, same hits)
                                fps = ",".join(_bucket_fp(u, s2, e2)
                                               for u in sliced)
                                ck2 = hashlib.sha1(
                                    f"chunkpart|{seg_key}|{s2}:{e2}|"
                                    f"{rep_fp}|{fps}"
                                    .encode()).hexdigest()
                                p2 = self.cache.probe(ck2)
                                if p2 is not None:
                                    log.chunks_reused += 1
                            if p2 is None:
                                args2, live2, _ = _prep(
                                    s2, e2, probe_faults=False)
                                outs2 = self._execute_cached(
                                    seg_key, builder, args2, jcache)
                                p2 = tuple(
                                    np.asarray(backend.densify(o))
                                    for o in outs2)
                                log.chunks += 1
                                log.bytes_streamed += live2
                                if ck2 is not None:
                                    self.cache.put(ck2, p2, cost_each,
                                                   gated=False)
                            accumulate(p2, live2)
                        return
                    plog.prefetch_hits += 1
                    plog.prefetch_s += dt_prep
                    outs = self._execute_cached(seg_key, builder, args,
                                                jcache, rctx=rctx)
                    t0 = time.perf_counter()
                    parts = tuple(np.asarray(backend.densify(o))
                                  for o in outs)
                    plog.block_s += time.perf_counter() - t0
                    log.chunks += 1
                    log.bytes_streamed += live
                    if ckey is not None:
                        self.cache.put(ckey, parts, cost_each,
                                       gated=False)
                # charge the NEXT in-flight miss bucket alongside this
                # one: its args are (being) materialized concurrently
                nxt_live = 0
                if inflight and inflight[0][3] is None:
                    n_rows = inflight[0][1] - inflight[0][0]
                    nxt_live = int(n_rows * row_bytes)
                accumulate(parts, live + nxt_live)
        finally:
            while inflight:
                _, _, _, _, fut = inflight.popleft()
                if fut is not None and fut.cancel():
                    plog.prefetch_cancelled += 1
            ex.shutdown(wait=True)

    # ------------------------------------------------------------------
    def _exec_one(self, ins, values: dict[int, Any], fmts: dict,
                  bctx: Optional[_BatchCtx] = None):
        """Execute one instruction eagerly on concrete values — the
        single implementation shared by the interpreter loop and the
        segment executor's host path (non-traceable singleton
        segments), so cross-mode parity cannot erode: federated ops
        route to the site orchestrator, everything else runs its
        registry kernel with a device sync.

        `bctx` (batched plans) marks operands carrying the config axis:
        federated ops take their batched path (one exchange round for
        the whole grid), other host ops (quantile) loop over the batch
        on the host — they are order-statistics on concrete values and
        cannot vmap."""
        node = ins.node
        if node.op in backend.FED_OPS or node.op == backend.COLLECT_OP:
            return self._exec_federated(ins, values, bctx=bctx)
        kern = backend.kernel_for_node(
            node,
            in_fmts=tuple(fmts.get(u, backend.DENSE)
                          for u in ins.input_ids),
            out_fmt=fmts.get(ins.out_id, backend.DENSE),
            # eager execution holds GLOBAL arrays: shard-exec ops run
            # their local-equivalent base kernels (no collectives)
            unshard=(node.op in backend.SHARD_EXEC_OPS
                     or node.placement == "sharded"))
        if bctx is not None:
            import jax.numpy as jnp
            bpos = {i for i, u in enumerate(ins.input_ids)
                    if u in bctx.bvals}
            args = [values[u] for u in ins.input_ids]
            # host ops loop over the TRUE k, not the padded bucket —
            # padding configs duplicate the last one, so their result
            # is re-padded in, never recomputed
            rows = [kern(*[a[j] if i in bpos else a
                           for i, a in enumerate(args)])
                    for j in range(bctx.batch)]
            out = _pad_axis0(jnp.stack(rows, axis=0), bctx.bucket)
            backend.block_ready(out)
            return out
        out = kern(*[values[u] for u in ins.input_ids])
        backend.block_ready(out)
        return out

    # ------------------------------------------------------------------
    def _site_call(self, op: str, i: int, rpc, local=None):
        """One federated site RPC under the fault policy: per-site
        timeout + bounded exponential-backoff retry, then the
        degradation ladder. Returns ``(result, degraded)``.

        `rpc(stats)` performs the site call (stats is `self.stats` on
        the first attempt, None on re-attempts so retries cannot
        double-book jit-cache/trace meters); `local()` is the
        collect-and-recompute fallback run when every attempt failed
        but the site's DATA survives. In-process sites cannot be
        preempted, so the timeout binds at the attempt boundary: a
        call slower than `costmodel.fed_timeout_s()` has its (late)
        result discarded and is retried — sound because site kernels
        are pure, a recompute yields the same value. Latencies route
        through the `StepMonitor` straggler flagging and successful
        calls heartbeat the site. With the policy off this is a bare
        passthrough (raw error propagation)."""
        if not faults.policy_enabled():
            return rpc(self.stats), False
        flog = self.stats.faults
        timeout = costmodel.fed_timeout_s()
        last_err: Optional[BaseException] = None
        for attempt in range(costmodel.max_retries() + 1):
            if attempt:
                pause = costmodel.retry_backoff_s(attempt)
                flog.retries += 1
                flog.backoff_s += pause
                if pause > 0:
                    time.sleep(pause)
            t0 = time.perf_counter()
            try:
                out = rpc(self.stats if attempt == 0 else None)
            except Exception as e:
                flog.record_site(i, time.perf_counter() - t0, ok=False)
                if isinstance(e, faults.InjectedFault):
                    flog.injected += 1
                last_err = e
                continue
            dt = time.perf_counter() - t0
            flog.record_site(i, dt)
            if dt > timeout:
                flog.timeouts += 1
                last_err = TimeoutError(
                    f"site {i} exceeded {timeout}s during {op!r}")
                continue
            return out, False
        plan = faults.active_plan()
        if local is None or (plan is not None and plan.data_lost(i)):
            raise SiteFailedError(i, op, detail=str(last_err))
        flog.degradations += 1
        return local(), True

    def _recompute_local(self, s, i: int, op: str, args: tuple,
                         attrs: tuple, vmap_axes):
        """Degradation-ladder step for a dead site whose data survives:
        pull the partition to the master — metered as a collect
        (`add_in` + one round) — and run the site's work locally
        through the SAME jit-cached executable (`site=None` is never
        injected), so a degraded run is bitwise the clean run."""
        log = self.stats.exchange
        log.add_in(s.data, site=i)
        log.add_round(i)
        return s.execute(op, args, attrs=attrs, stats=self.stats,
                         vmap_axes=vmap_axes, site=None)

    @staticmethod
    def _data_plane_check(op: str, i: int) -> None:
        """Raise `SiteFailedError` when site `i`'s data plane is gone
        (`site_lost`) — guards pure data movement (collect) and the
        recompute ladder, which both read `site.data` directly."""
        if not faults.policy_enabled():
            return
        plan = faults.active_plan()
        if plan is not None and plan.data_lost(i):
            raise SiteFailedError(i, op)

    # ------------------------------------------------------------------
    def _exec_federated(self, ins, values: dict[int, Any],
                        bctx: Optional[_BatchCtx] = None):
        """Execute one federated instruction (or a `collect` boundary).

        Master-side orchestration: loop over sites, run each site's
        local work as a compiled sub-segment (`LocalSite.execute` — the
        kernel registry + process-wide jit cache, so per-site gram runs
        the same Pallas/BCOO kernels as local plans and repeated runs
        replay warm executables), and meter every byte crossing the
        federation boundary into `stats.exchange`, per site. Every
        (instruction, site) pair that actually exchanges bytes counts
        one *round* (`ExchangeLog.add_round`).

        With a `_BatchCtx`, batched *local* operands (fed operands are
        never batched — `batching.choose_mode` guarantees it) travel as
        ONE stacked payload per site and the site's work runs vmapped
        over the config axis: a k-configuration grid costs one round
        per site per instruction, not k.
        """
        node = ins.node
        op = node.op
        log = self.stats.exchange
        args = [values[u] for u in ins.input_ids]
        bpos = (frozenset(i for i, u in enumerate(ins.input_ids)
                          if u in bctx.bvals)
                if bctx is not None else frozenset())

        if op == backend.COLLECT_OP:
            fed = args[0]
            fed._require_sites(op)
            batched = getattr(fed, "batch", None) is not None
            parts = []
            for i, s in enumerate(fed.sites):
                # collect is pure data movement: only a lost DATA plane
                # can fail it (a dead compute plane still serves reads)
                self._data_plane_check(op, i)
                log.add_in(s.data, site=i)
                log.add_round(i)
                parts.append(np.asarray(s.data))
            # batched site layout is (k, rows_i, c): rows concat on axis 1
            out = np.concatenate(parts, axis=1 if batched else 0)
            return _pad_axis0(out, bctx.bucket) if batched else out

        if op == "fed_gram":
            fed = args[0]
            fed._require_sites(op)
            batched = getattr(fed, "batch", None) is not None
            vmap_axes = (0,) if batched else None
            out = None
            for i, s in enumerate(fed.sites):
                g, deg = self._site_call(
                    op, i,
                    lambda st, s=s, i=i: s.execute(
                        "gram", (s.data,), stats=st,
                        vmap_axes=vmap_axes, site=i),
                    local=lambda s=s, i=i: self._recompute_local(
                        s, i, "gram", (s.data,), (), vmap_axes))
                if not deg:  # exchange metered on success only
                    log.add_in(g, site=i)
                    log.add_round(i)
                out = g if out is None else out + g
            return _pad_axis0(out, bctx.bucket) if batched else out

        if op in ("fed_xtv", "fed_vm"):
            # x^T v with any subset of {x, v} federated: per-site
            # partial products summed at the master; row-aligned local
            # operands are sent sliced (only the relevant rows travel).
            # Batched local operands are sliced along the row axis of
            # each config: v[:, a:b] — one stacked send per site.
            fed_pos = set(node.attr("fed_args", (0,)))
            fed = args[min(fed_pos)]
            fed._require_sites(op)
            self._check_alignment(op, [args[p] for p in sorted(fed_pos)])
            # batched positions: local operands flagged by the plan plus
            # federated operands whose site layout carries a config axis
            # (stacked (k, rows_i, c) partitions from a batched fed_map)
            bat = set(bpos) | {p for p in fed_pos
                               if getattr(args[p], "batch", None)}
            # densify local operands once, outside the site loop; a
            # batched operand is sliced to the TRUE k before anything
            # crosses the wire — the bucket padding (duplicates of the
            # last config) exists only to stabilize executable shapes,
            # and must not inflate the exchange
            args = [v if pos in fed_pos else
                    (backend.densify(v)[:bctx.batch] if pos in bat
                     else backend.densify(v))
                    for pos, v in enumerate(args)]
            vmap_axes = (tuple(0 if pos in bat else None
                               for pos in range(len(args)))
                         if bat else None)
            out = None
            for i, (a, b) in enumerate(fed.ranges):
                site_args, sent = [], []
                for pos, v in enumerate(args):
                    if pos in fed_pos:
                        site_args.append(v.sites[i].data)
                    else:
                        sl = v[:, a:b] if pos in bat else v[a:b]
                        sent.append(sl)
                        site_args.append(sl)
                s = fed.sites[i]
                sa = tuple(site_args)
                r, deg = self._site_call(
                    op, i,
                    lambda st, s=s, i=i, sa=sa: s.execute(
                        "xtv", sa, stats=st,
                        vmap_axes=vmap_axes, site=i),
                    local=lambda s=s, i=i, sa=sa: self._recompute_local(
                        s, i, "xtv", sa, (), vmap_axes))
                if not deg:  # exchange metered on success only
                    for sl in sent:
                        log.add_out(sl, site=i)
                    log.add_in(r, site=i)
                    log.add_round(i)
                out = r if out is None else out + r
            return _pad_axis0(out, bctx.bucket) if bat else out

        if op == "fed_mv":
            fed, w = args
            fed._require_sites(op)
            w = backend.densify(w)
            fed_b = getattr(fed, "batch", None) is not None
            w_b = 1 in bpos
            batched = fed_b or w_b
            if w_b:  # send the true k configs, never the padding
                w = w[:bctx.batch]
            vmap_axes = ((0 if fed_b else None, 0 if w_b else None)
                         if batched else None)
            parts = []
            for i, s in enumerate(fed.sites):
                r, deg = self._site_call(
                    op, i,
                    lambda st, s=s, i=i: s.execute(
                        "matmul", (s.data, w), stats=st,
                        vmap_axes=vmap_axes, site=i),
                    local=lambda s=s, i=i: self._recompute_local(
                        s, i, "matmul", (s.data, w), (), vmap_axes))
                if not deg:
                    log.add_out(w, site=i)  # broadcast (whole grid)
                    log.add_in(r, site=i)   # rbind of per-site results
                    log.add_round(i)
                parts.append(np.asarray(r))
            # per-site results are (rows_i, n) — or (k, rows_i, n)
            # batched — so the row concat axis shifts with the batch
            out = np.concatenate(parts, axis=1 if batched else 0)
            return _pad_axis0(out, bctx.bucket) if batched else out

        if op == "fed_colsums":
            fed = args[0]
            fed._require_sites(op)
            batched = getattr(fed, "batch", None) is not None
            vmap_axes = (0,) if batched else None
            out = None
            for i, s in enumerate(fed.sites):
                r, deg = self._site_call(
                    op, i,
                    lambda st, s=s, i=i: s.execute(
                        "colSums", (s.data,), stats=st,
                        vmap_axes=vmap_axes, site=i),
                    local=lambda s=s, i=i: self._recompute_local(
                        s, i, "colSums", (s.data,), (), vmap_axes))
                if not deg:
                    log.add_in(r, site=i)
                    log.add_round(i)
                out = r if out is None else out + r
            return _pad_axis0(out, bctx.bucket) if batched else out

        if op == "fed_map":
            return self._exec_fed_map(node, args, log, bctx=bctx,
                                      bpos=bpos)

        raise NotImplementedError(f"federated op {op!r}")

    def _exec_fed_map(self, node, args: list, log: ExchangeLog,
                      bctx: Optional[_BatchCtx] = None,
                      bpos: frozenset = frozenset()) -> FederatedTensor:
        """Row-preserving op applied per site: the output is a new
        `FederatedTensor` over the same ranges — no aggregate exchange.
        Local operands travel by shape: scalars and `full` generators
        cost nothing (generated on site), broadcast rows go to every
        site, row-aligned matrices are sent sliced.

        Batched (`parfor`) operands — local values flagged by the plan
        (`bpos`) or federated operands already carrying the stacked
        layout — travel as ONE (k, …) payload per site and the site's
        work runs vmapped over the config axis; the output federated
        tensor then carries the stacked (k, rows_i, c) site layout
        (`FederatedTensor.batch`), which the other fed_* instructions'
        batched paths consume. Only the TRUE k crosses the wire."""
        inner = node.attr("inner")
        n_args = node.attr("n_args")
        fed_pos = set(node.attr("fed_args", ()))
        gens = {p: (v, k, dt) for p, v, k, dt in node.attr("gen_args", ())}
        iattrs = dict(node.attr("iattrs", ()))
        slot: dict[int, Any] = {}
        bslots: set[int] = set()  # inner positions carrying the config axis
        it = iter(enumerate(args))
        for pos in range(n_args):
            if pos not in gens:
                ai, v = next(it)
                if pos in fed_pos:
                    slot[pos] = v
                    if getattr(v, "batch", None) is not None:
                        bslots.add(pos)
                else:
                    # densify local operands once, outside the site
                    # loop; batched ones sliced to the TRUE k up front
                    v = backend.densify(v)
                    if ai in bpos:
                        v = v[:bctx.batch]
                        bslots.add(pos)
                    slot[pos] = v
        batched = bool(bslots)
        vmap_axes = (tuple(0 if pos in bslots else None
                           for pos in range(n_args))
                     if batched else None)
        feds = [slot[p] for p in sorted(fed_pos)]
        fed = feds[0]
        fed._require_sites("fed_map")
        self._check_alignment("fed_map", feds)
        new_sites = []
        for i, (a, b) in enumerate(fed.ranges):
            rows_i = b - a
            ia = dict(iattrs)
            if inner == "slice":
                # rebase the absolute row range onto this site's rows
                idx = list(ia["index"])
                idx[0] = (0, rows_i, 0)
                ia["index"] = tuple(idx)
            site_args, to_send = [], []
            for pos in range(n_args):
                if pos in gens:
                    val, k, dt = gens[pos]
                    site_args.append(
                        np.full((rows_i, int(k)), val, dtype=np.dtype(dt)))
                elif pos in fed_pos:
                    site_args.append(slot[pos].sites[i].data)
                else:
                    v = slot[pos]
                    shp = getattr(v, "shape", ())
                    # route by the per-config shape: a batched operand
                    # carries a leading (k, …) axis on top of it
                    ishp = shp[1:] if pos in bslots else shp
                    if ishp == () or ishp[0] == 1:
                        if ishp != () or pos in bslots:
                            to_send.append(v)  # broadcast payload
                        site_args.append(v)
                    else:
                        sl = (v[:, a:b] if pos in bslots else v[a:b])
                        to_send.append(sl)
                        site_args.append(sl)
            s = fed.sites[i]
            sa, attrs = tuple(site_args), tuple(sorted(ia.items()))
            out_i, deg = self._site_call(
                "fed_map", i,
                lambda st, s=s, i=i, sa=sa, attrs=attrs: s.execute(
                    inner, sa, attrs=attrs, stats=st,
                    vmap_axes=vmap_axes, site=i),
                local=lambda s=s, i=i, sa=sa, attrs=attrs:
                    self._recompute_local(s, i, inner, sa, attrs,
                                          vmap_axes))
            if not deg and to_send:
                # purely on-site fed_map work (generators, fed
                # operands) exchanges nothing and counts no round;
                # exchange is metered on success only
                for payload in to_send:
                    log.add_out(payload, site=i)
                log.add_round(i)
            new_sites.append(LocalSite(out_i))
        return FederatedTensor(sites=new_sites, ranges=list(fed.ranges),
                               ncols=node.shape[1],
                               batch=bctx.batch if batched else None)

    @staticmethod
    def _check_alignment(op: str, feds: list) -> None:
        ranges = feds[0].ranges
        for f in feds[1:]:
            if list(f.ranges) != list(ranges):
                raise ValueError(
                    f"{op}: federated operands are partitioned "
                    f"differently ({f.ranges} vs {ranges}); joint "
                    "federated execution requires aligned row ranges")

    @staticmethod
    def _free(values: dict[int, Any], uids: tuple[int, ...]):
        for uid in uids:
            values.pop(uid, None)


def _coerce_format(value: Any, fmt: str) -> Any:
    """Align a reuse-cache hit with the plan's assigned physical format.

    Lineage hashes identify *values*, not representations: a cache
    shared across runtimes (or sparse_inputs settings) can return a
    dense array where this plan assigned BCOO, or vice versa. Sparse
    kernels have no dense guard, so convert at the boundary.
    """
    if fmt == backend.BCOO and not backend.is_sparse(value):
        return backend.sparsify(np.asarray(value))
    if fmt == backend.DENSE and backend.is_sparse(value):
        return value.todense()
    return value


# ---------------------------------------------------------------------------
# Module-level convenience (a default runtime without reuse)
# ---------------------------------------------------------------------------

_default_runtime: Optional[LineageRuntime] = None


def get_runtime() -> LineageRuntime:
    global _default_runtime
    if _default_runtime is None:
        _default_runtime = LineageRuntime()
    return _default_runtime


def set_runtime(rt: LineageRuntime) -> None:
    global _default_runtime
    _default_runtime = rt


def evaluate(*outputs: LTensor, runtime: Optional[LineageRuntime] = None
             ) -> list[np.ndarray]:
    rt = runtime or get_runtime()
    return rt.evaluate(list(outputs))


def value(x: LTensor, runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    return evaluate(x, runtime=runtime)[0]


# ---------------------------------------------------------------------------
# PreparedScript (JMLC-style precompiled script, §3.1)
# ---------------------------------------------------------------------------

class PreparedScript:
    """Compile a DSL function once; execute repeatedly with new inputs."""

    def __init__(self, fn: Callable[..., Any],
                 arg_shapes: Sequence[tuple[int, ...]],
                 arg_dtypes: Optional[Sequence[Any]] = None,
                 runtime: Optional[LineageRuntime] = None,
                 arg_sparsities: Optional[Sequence[float]] = None):
        # arg_sparsities: declared density per argument (JMLC-style
        # metadata). The placeholder leaves are zeros, so without a
        # declaration the format-assignment pass would estimate every
        # leaf as empty and pin it to BCOO; default to dense (1.0) and
        # let callers declare what they will actually bind.
        self.runtime = runtime or get_runtime()
        self._fn = fn
        self._arg_shapes = [tuple(int(d) for d in s) for s in arg_shapes]
        self._arg_dtypes = [np.dtype(d) for d in (
            arg_dtypes or [np.float64] * len(arg_shapes))]
        self._arg_sparsities = list(
            arg_sparsities or [1.0] * len(arg_shapes))
        # shape-variation memo: bound-shapes tuple -> None (accepted) or
        # the rejection message (see _check_shapes)
        self._shape_verdicts: dict[tuple, Optional[str]] = {}
        self._leaves = [
            input_tensor(f"arg{i}", np.zeros(s, dtype=d), sparsity=sp)
            for i, (s, d, sp) in enumerate(
                zip(self._arg_shapes, self._arg_dtypes,
                    self._arg_sparsities, strict=True))]
        outs = fn(*self._leaves)
        if isinstance(outs, LTensor):
            outs = [outs]
        self._outputs = list(outs)
        self.plan = compile_plan(
            self._outputs, reuse_enabled=self.runtime.cache is not None,
            opt_level=self.runtime.opt_level)

    # ------------------------------------------------------------------
    def validate_args(self, arrays: Sequence[Any],
                      exact_shapes: bool = False) -> list[np.ndarray]:
        """Validate bindings against the declared `arg_shapes` /
        `arg_dtypes` — at bind time, with a clear `ValueError`, instead
        of a shape/dtype explosion deep inside segment execution.

        Dtypes: a binding whose dtype safe-casts to the declared one
        (int grids into a float plan) is converted; anything lossy
        (float into an int plan, complex into float) is an error.

        Shapes: a binding may deviate from the declared shape only
        along axes the plan never *constrains* — verified by re-tracing
        the script function at the bound shapes and requiring the same
        instruction stream (see `_check_shapes`); generators (`eye(n)`,
        `ones((m, 1))` intercepts), slice bounds, and shape-dependent
        rewrites all constrain their axes and reject the binding.
        `exact_shapes` (the serving path, which stacks requests into
        fixed buckets) skips the re-trace escape hatch entirely.
        """
        if len(arrays) != len(self._leaves):
            # a real error, not an assert: argument-count bugs must
            # surface under `python -O` too
            raise ValueError(
                f"PreparedScript expects {len(self._leaves)} argument(s), "
                f"got {len(arrays)}")
        out: list[np.ndarray] = []
        mismatch = False
        for i, (arr, shape, dtype) in enumerate(
                zip(arrays, self._arg_shapes, self._arg_dtypes)):
            arr = np.asarray(arr)
            if arr.dtype != dtype:
                if not np.can_cast(arr.dtype, dtype, casting="safe"):
                    raise ValueError(
                        f"PreparedScript arg{i}: bound dtype {arr.dtype} "
                        f"does not safe-cast to the declared {dtype}")
                arr = arr.astype(dtype)
            if arr.shape != shape:
                if exact_shapes or len(arr.shape) != len(shape):
                    raise ValueError(
                        f"PreparedScript arg{i}: bound shape {arr.shape} "
                        f"!= declared {shape}")
                mismatch = True
            out.append(arr)
        if mismatch:
            self._check_shapes(tuple(a.shape for a in out))
        return out

    def _check_shapes(self, shapes: tuple) -> None:
        """Accept deviating bound shapes iff the plan never constrains
        the deviating axes: re-trace the script function at the bound
        shapes and require an instruction stream identical up to leaf
        renaming — same ops, attrs, dtypes, connectivity, and (for
        zero-input generators, whose output shape is baked into their
        kernel) the same shapes. Interior value shapes may differ: they
        derive from the inputs, and every non-generator kernel is
        shape-polymorphic. Verdicts are memoized per shape tuple."""
        verdict = self._shape_verdicts.get(shapes)
        if verdict is None and shapes in self._shape_verdicts:
            return  # previously accepted
        if verdict is None:
            verdict = self._probe_shapes(shapes)
            self._shape_verdicts[shapes] = verdict
        if verdict is not None:
            raise ValueError(verdict)

    def _probe_shapes(self, shapes: tuple) -> Optional[str]:
        declared = tuple(self._arg_shapes)
        try:
            leaves = [
                input_tensor(f"arg{i}", np.zeros(s, dtype=d), sparsity=sp)
                for i, (s, d, sp) in enumerate(
                    zip(shapes, self._arg_dtypes, self._arg_sparsities))]
            outs = self._fn(*leaves)
            if isinstance(outs, LTensor):
                outs = [outs]
            probe = compile_plan(
                list(outs), reuse_enabled=self.runtime.cache is not None,
                opt_level=self.runtime.opt_level)
        except Exception as e:
            return (f"PreparedScript: bound shapes {shapes} != declared "
                    f"{declared} and re-tracing at the bound shapes "
                    f"failed ({type(e).__name__}: {e})")
        reject = (f"PreparedScript: bound shapes {shapes} deviate from "
                  f"the declared {declared} along axes the plan "
                  "constrains (generator shapes, slice bounds, or "
                  "shape-dependent rewrites differ)")
        a_ins, b_ins = self.plan.instructions, probe.instructions
        if len(a_ins) != len(b_ins):
            return reject
        # positional uid correspondence: declared-plan uid -> probe uid
        pair: dict[int, int] = {
            la.node.uid: lb.node.uid
            for la, lb in zip(self._leaves, leaves)}
        for ia, ib in zip(a_ins, b_ins):
            na, nb = ia.node, ib.node
            if (na.op != nb.op or na.attrs != nb.attrs
                    or na.dtype != nb.dtype
                    or len(ia.input_ids) != len(ib.input_ids)):
                return reject
            if not na.inputs and na.shape != nb.shape:
                return reject  # generator output shape is kernel-baked
            for ua, ub in zip(ia.input_ids, ib.input_ids):
                if pair.setdefault(ua, ub) != ub:
                    return reject
            if pair.setdefault(ia.out_id, ib.out_id) != ib.out_id:
                return reject
        for ua, ub in zip(self.plan.output_ids, probe.output_ids):
            if pair.get(ua) != ub:
                return reject
        return None

    def __call__(self, *arrays) -> list[np.ndarray]:
        arrays = self.validate_args(arrays)
        leaf_values: dict[int, Any] = {}
        leaf_lineage: dict[int, str] = {}
        # content fingerprints keep reuse sound across re-binds, but they
        # cost a hash pass per input — only lineage consumers (a reuse
        # cache) need them
        need_lineage = self.runtime.cache is not None
        for leaf, arr in zip(self._leaves, arrays):
            leaf_values[leaf.node.uid] = arr
            if need_lineage:
                leaf_lineage[leaf.node.uid] = \
                    f"{leaf.node.attr('name')}:{_fingerprint(arr)}"
        return self.runtime.run_plan(self.plan, leaf_values, leaf_lineage)

    # ------------------------------------------------------------------
    def prepare_batched(self):
        """Compile the serving form of this script: the same function
        traced over *batched* request leaves, returning a
        `batching.BatchedPlan` replayable at any batch size through
        `LineageRuntime.replay_batch`. This is the deploy-time entry
        `repro.serving.ModelServer` AOT-warms its power-of-two vmap
        buckets from — request compile cost moves fully off the
        request path."""
        from .batching import compile_serving
        return compile_serving(
            self._fn, self._arg_shapes, self._arg_dtypes,
            self._arg_sparsities,
            reuse_enabled=self.runtime.cache is not None,
            opt_level=self.runtime.opt_level)


# ---------------------------------------------------------------------------
# Lineage trace export (§4.1 — debugging / versioning over lineage)
# ---------------------------------------------------------------------------

def lineage_trace(x: LTensor) -> str:
    """Serialize the lineage DAG in a SystemDS-log-like text format."""
    lines: list[str] = []
    seen: dict[int, int] = {}

    def rec(n: Node) -> int:
        if n.uid in seen:
            return seen[n.uid]
        args = [rec(i) for i in n.inputs]
        idx = len(lines)
        seen[n.uid] = idx
        if n.op == "input":
            lid = LEAVES.lineage.get(n.uid, f"input:{n.attr('name')}")
            lines.append(f"({idx}) L·input {lid}")
        elif n.op == "literal":
            lines.append(f"({idx}) L·lit {n.attr('value')}")
        else:
            attrs = {k: v for k, v in n.attrs if k != "index"}
            ref = " ".join(f"({a})" for a in args)
            lines.append(f"({idx}) L·{n.op} {ref} {attrs or ''}".rstrip())
        return idx

    rec(x.node)
    return "\n".join(lines)
