"""Data cleaning / preparation builtins (SystemDS §4.2).

Vectorized implementations over the DSL: masking turns missing-value
imputation and outlier handling into sequences of full matrix operations
("masking allows data slicing and missing value imputation ... via
sequences of full matrix operations", §4.2), which keeps them inside the
compiler's optimization scope and trivially distributable.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import ops
from repro.core.dag import LTensor, input_tensor
from repro.core.runtime import LineageRuntime, get_runtime


def _rt(runtime):
    return runtime or get_runtime()


def isnan_mask(X: LTensor) -> LTensor:
    """1.0 where NaN (NaN != NaN)."""
    return X._bin(X, "ne")


def scale_matrix(X: LTensor, center: bool = True, scale: bool = True,
                 runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """z-score standardization (DML `scale`)."""
    out = X
    if center:
        out = out - ops.colMeans(out)
    if scale:
        out = out / ops.sqrt(ops.colVars(X))
    return _rt(runtime).evaluate([out])[0]


def impute_by_mean(X: LTensor, runtime: Optional[LineageRuntime] = None
                   ) -> np.ndarray:
    """Replace NaNs by per-column means of observed values (mask algebra)."""
    mask = isnan_mask(X)                      # 1 where missing
    x0 = ops.replace_nan(X, 0.0)
    obs = X.shape[0] - ops.colSums(mask)      # observed count per column
    mu = ops.colSums(x0) / ops.maximum(obs, 1.0)
    out = x0 + mask * mu
    return _rt(runtime).evaluate([out])[0]


def impute_by_median(X: LTensor, runtime: Optional[LineageRuntime] = None
                     ) -> np.ndarray:
    """Median imputation; order statistics run in the control program
    (host) like SystemDS's sort-based quantiles."""
    rt = _rt(runtime)
    x = rt.evaluate([X])[0]
    med = np.nanmedian(x, axis=0, keepdims=True)
    return np.where(np.isnan(x), med, x)


def mice_lite(X: LTensor, n_iter: int = 3, reg: float = 1e-3,
              runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """Chained-equation imputation (mice, §4.2 ref [71]) via mask algebra.

    Each round regresses every incomplete column on the others over the
    *observed* rows (row mask folded into the normal equations:
    gram(M⊙X) and (M⊙X)^T y — full matrix ops, no gather/scatter), then
    rewrites only the missing entries.
    """
    rt = _rt(runtime)
    x_np = rt.evaluate([X])[0] if isinstance(X, LTensor) else np.asarray(X)
    miss = np.isnan(x_np)
    # init: mean imputation
    mu = np.nanmean(x_np, axis=0, keepdims=True)
    cur = np.where(miss, mu, x_np)
    n, d = cur.shape
    for _ in range(n_iter):
        for j in range(d):
            mj = miss[:, j]
            if not mj.any() or mj.all():
                continue
            others = [k for k in range(d) if k != j]
            Xo = input_tensor("miceX", cur[:, others])
            yj = input_tensor("micey", cur[:, j:j + 1])
            w = input_tensor("micew", (~mj).astype(np.float64)[:, None])
            Xw = Xo * w                      # zero out unobserved rows
            yw = yj * w
            A = ops.gram(Xw) + reg * ops.eye(d - 1)
            b = ops.xtv(Xw, yw)
            beta_t = ops.solve(A, b)
            pred_t = Xo @ beta_t
            pred = rt.evaluate([pred_t])[0]
            cur[mj, j] = pred[mj, 0]
    return cur


def outlier_by_iqr(X: LTensor, k: float = 1.5, repair: str = "nan",
                   runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """Flag/repair values outside [Q1 - k·IQR, Q3 + k·IQR] per column."""
    rt = _rt(runtime)
    x = rt.evaluate([X])[0] if isinstance(X, LTensor) else np.asarray(X)
    q1 = np.nanquantile(x, 0.25, axis=0, keepdims=True)
    q3 = np.nanquantile(x, 0.75, axis=0, keepdims=True)
    iqr = q3 - q1
    lo, hi = q1 - k * iqr, q3 + k * iqr
    bad = (x < lo) | (x > hi)
    if repair == "nan":
        return np.where(bad, np.nan, x)
    if repair == "clip":
        return np.clip(x, lo, hi)
    return bad.astype(np.float64)  # repair == "flag"


def outlier_by_sd(X: LTensor, k: float = 3.0, repair: str = "nan",
                  runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """Flag/repair values beyond k standard deviations (DSL mask algebra)."""
    rt = _rt(runtime)
    mu = ops.colMeans(X)
    sd = ops.sqrt(ops.colVars(X))
    dev = ops.abs_(X - mu)
    bad = dev > (k * sd)
    x_np, bad_np = rt.evaluate([X, bad])
    if repair == "nan":
        return np.where(bad_np != 0, np.nan, x_np)
    if repair == "clip":
        mu_np, sd_np = rt.evaluate([mu, sd])
        return np.clip(x_np, mu_np - k * sd_np, mu_np + k * sd_np)
    return bad_np


def winsorize(X: LTensor, lower: float = 0.05, upper: float = 0.95,
              runtime: Optional[LineageRuntime] = None) -> np.ndarray:
    """Clamp each column to its [lower, upper] quantiles."""
    rt = _rt(runtime)
    x = rt.evaluate([X])[0] if isinstance(X, LTensor) else np.asarray(X)
    lo = np.nanquantile(x, lower, axis=0, keepdims=True)
    hi = np.nanquantile(x, upper, axis=0, keepdims=True)
    xt = input_tensor("winsX", x)
    out = ops.minimum(ops.maximum(xt, input_tensor("winsLo", lo)),
                      input_tensor("winsHi", hi))
    return rt.evaluate([out])[0]
