"""Pallas TPU kernel for gram (tsmm): G = X^T X, and fused X^T v.

This is the paper's single hottest operator (lmDS's X^T X / X^T y, §5.2).
TPU adaptation (DESIGN.md §2): SystemDS's JNI-BLAS dsyrk becomes an
MXU-tiled Pallas kernel:

  * grid = (n/bn, n/bn, m/bm); the k axis (rows of X) is the innermost
    reduction so the f32 output tile stays resident in VMEM across the
    sweep (block revisiting), accumulating in f32.
  * both operands are *column tiles of the same matrix* — two BlockSpecs
    index the same input with different maps, so X streams HBM→VMEM
    without ever materializing t(X).
  * only upper-triangle output tiles (j >= i) are computed (SystemML's
    tsmm trick); the wrapper mirrors them, halving MXU work.

Block sizes default to (bm, bn) = (512, 256): VMEM footprint =
2·bm·bn·2B (bf16 inputs) + bn·bn·4B (f32 acc) ≈ 780 KB « 16 MB VMEM,
and every matmul dim is a multiple of the 128×128 MXU tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 512
DEFAULT_BN = 256


def _gram_kernel(xi_ref, xj_ref, out_ref):
    """One (i, j, k) grid step: out += Xi^T @ Xj for upper-triangle tiles."""
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(j >= i)  # lower-triangle tiles are mirrored by the wrapper
    def _accum():
        out_ref[...] += jax.lax.dot_general(
            xi_ref[...], xj_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),  # contract over rows
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def gram_pallas(x: jnp.ndarray, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                interpret: bool = False) -> jnp.ndarray:
    """Upper-triangle gram via Pallas; caller mirrors (see ops.gram)."""
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    n_i = n // bn
    out = pl.pallas_call(
        _gram_kernel,
        grid=(n_i, n_i, m // bm),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, i)),  # Xi column tile
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),  # Xj column tile
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(x, x)
    return out


def _xtv_kernel(x_ref, v_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        x_ref[...], v_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def xtv_pallas(x: jnp.ndarray, v: jnp.ndarray, *, bm: int = DEFAULT_BM,
               bn: int = DEFAULT_BN, interpret: bool = False) -> jnp.ndarray:
    """X^T v (v may have multiple columns; pad columns to the lane width)."""
    m, n = x.shape
    mv, c = v.shape
    assert m == mv and m % bm == 0 and n % bn == 0, (x.shape, v.shape, bm, bn)
    out = pl.pallas_call(
        _xtv_kernel,
        grid=(n // bn, m // bm),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, k: (k, i)),
            pl.BlockSpec((bm, c), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bn, c), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.float32),
        interpret=interpret,
    )(x, v)
    return out
