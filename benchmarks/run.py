"""Benchmark driver. One module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  fig5_hpo_baseline_*   — Fig. 5(a,b): k lmDS models, dense/sparse, no reuse
  fig5c/fig5d_*         — Fig. 5(c,d) + Fig. 6: lineage reuse speedups
  fig7_cv_*             — Fig. 7: cross-validation partial reuse
  ex2_fed_*             — §4.3 Example 2: federated MV/VM/gram + lmDS
  gram_*                — §5.2 kernel trio (dense XLA / BLAS / sparse)
  roofline_*            — §Roofline cells from the dry-run sweep
  fused_vs_interpreted  — ISSUE 1: segment JIT engine vs per-op interpreter
                          (appends a BENCH_fusion.json trajectory entry)

``--smoke`` runs only the fusion benchmark at a reduced size (CI).
"""
import sys

sys.path.insert(0, "src")


def main() -> None:
    if "--smoke" in sys.argv:
        from benchmarks import fusion_bench
        print("name,us_per_call,derived")
        fusion_bench.main(rows=500, cols=32, calls=20, repeats=2)
        return
    from benchmarks import (cv_reuse, federated_bench, fusion_bench,
                            hpo_baseline, hpo_reuse, kernel_bench,
                            roofline_bench)
    quick = "--quick" in sys.argv
    ks = (1, 5, 10) if quick else (1, 5, 10, 20)
    print("name,us_per_call,derived")
    hpo_baseline.main(ks=ks)
    hpo_reuse.main(ks=ks)
    cv_reuse.main(folds=(4,) if quick else (4, 8))
    federated_bench.main()
    kernel_bench.main()
    roofline_bench.main()
    fusion_bench.main(calls=20 if quick else 50)


if __name__ == "__main__":
    main()
