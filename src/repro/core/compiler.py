"""Plan compiler: HOP DAG -> ordered runtime instructions (SystemDS §3.2).

Mirrors SystemDS's compilation chain at our scale: rewrites + size
propagation happen on the DAG (shapes/sparsity are attached at
construction), memory estimates pick an execution target per instruction
(local vs distributed — the analogue of CP vs Spark instructions), and
the result is a topologically ordered instruction sequence executed by
`repro.core.runtime.LineageRuntime`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .dag import LTensor, Node
from .rewrites import run_rewrites

# Default per-operation local memory budget: inputs+output of an op above
# this threshold are flagged for the distributed backend (pjit over the
# mesh) when one is attached. 2 GB mirrors a driver-heap style budget.
LOCAL_MEM_BUDGET = 2 << 30


@dataclass
class Instruction:
    node: Node
    out_id: int
    input_ids: tuple[int, ...]
    target: str  # 'local' | 'distributed'
    last_use_of: tuple[int, ...] = ()  # uids freed after this instruction


@dataclass
class Plan:
    instructions: list[Instruction]
    output_ids: list[int]
    roots: list[Node]
    est_bytes_peak: int = 0
    reuse_enabled: bool = False
    # segmentation memo: {reuse_active: [Segment, ...]}
    _segments: dict = field(default_factory=dict, repr=False)

    def count_ops(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for ins in self.instructions:
            out[ins.node.op] = out.get(ins.node.op, 0) + 1
        return out

    def segments_for(self, reuse_active: bool):
        """Fusable segments of this plan (lazily computed, memoized).

        With an active reuse cache every cacheable intermediate must stay
        observable, so segmentation degenerates to per-instruction; see
        `repro.core.segments`.
        """
        reuse_active = bool(reuse_active)
        got = self._segments.get(reuse_active)
        if got is None:
            from .segments import segment_plan
            got = segment_plan(self, reuse_active=reuse_active)
            self._segments[reuse_active] = got
        return got

    def _ins_line(self, ins: Instruction) -> str:
        args = ",".join(f"%{i}" for i in ins.input_ids)
        attrs = {k: v for k, v in ins.node.attrs if k != "index"}
        return (f"%{ins.out_id} = [{ins.target[0].upper()}] "
                f"{ins.node.op}({args}) {ins.node.shape} "
                f"sp={ins.node.sparsity:.3f} {attrs if attrs else ''}")

    def explain(self, segments: bool = True,
                reuse_active: Optional[bool] = None) -> str:
        """EXPLAIN-style plan dump (SystemDS -explain) with segment
        annotations showing how instructions fuse into jit executables.

        `reuse_active` defaults to the flag the plan was compiled with;
        pass the executing runtime's actual cache state (cache is not
        None) to see the segmentation that run will use.
        """
        if reuse_active is None:
            reuse_active = self.reuse_enabled
        lines = []
        if segments and self.instructions:
            for seg in self.segments_for(reuse_active):
                outs = ",".join(f"%{u}" for u in seg.output_uids)
                kind = "fused" if len(seg.instructions) > 1 else "single"
                lines.append(
                    f"-- segment {seg.index} [{seg.target}] {kind} "
                    f"{len(seg.instructions)} op(s) key={seg.key[:10]} "
                    f"-> {outs}")
                lines.extend(f"  {self._ins_line(ins)}"
                             for ins in seg.instructions)
        else:
            lines.extend(self._ins_line(ins) for ins in self.instructions)
        lines.append("outputs: " + ", ".join(f"%{i}" for i in self.output_ids))
        return "\n".join(lines)


def topo_order(roots: list[Node]) -> list[Node]:
    seen: set[int] = set()
    order: list[Node] = []

    def rec(n: Node):
        if n.uid in seen:
            return
        seen.add(n.uid)
        for i in n.inputs:
            rec(i)
        order.append(n)

    for r in roots:
        rec(r)
    return order


def compile_plan(outputs: list[LTensor], *, reuse_enabled: bool = False,
                 opt_level: int = 2,
                 local_budget: int = LOCAL_MEM_BUDGET) -> Plan:
    roots = [o.node for o in outputs]
    roots = run_rewrites(roots, reuse_enabled=reuse_enabled,
                         opt_level=opt_level)
    order = topo_order(roots)

    # liveness: last consumer of each node frees it (buffer-pool eviction)
    last_consumer: dict[int, int] = {}
    for idx, n in enumerate(order):
        for i in n.inputs:
            last_consumer[i.uid] = idx
    root_ids = {r.uid for r in roots}
    frees_at: dict[int, list[int]] = {}
    for uid, idx in last_consumer.items():
        if uid not in root_ids:
            frees_at.setdefault(idx, []).append(uid)

    instructions: list[Instruction] = []
    peak = 0
    live = 0
    live_sizes: dict[int, int] = {}  # uid -> bytes counted into `live`
    for idx, n in enumerate(order):
        if n.op == "input":
            continue
        op_bytes = n.est_bytes() + sum(i.est_bytes() for i in n.inputs)
        target = "distributed" if op_bytes > local_budget else "local"
        instructions.append(Instruction(
            node=n, out_id=n.uid,
            input_ids=tuple(i.uid for i in n.inputs),
            target=target,
            last_use_of=tuple(frees_at.get(idx, ()))))
        sz = n.est_bytes()
        live_sizes[n.uid] = sz
        live += sz
        peak = max(peak, live)
        for uid in frees_at.get(idx, ()):
            # frees of input leaves were never counted into `live`
            live -= live_sizes.pop(uid, 0)

    return Plan(instructions=instructions,
                output_ids=[r.uid for r in roots], roots=roots,
                est_bytes_peak=peak, reuse_enabled=reuse_enabled)
