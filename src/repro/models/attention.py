"""GQA attention with memory-safe chunked softmax + KV-cache decode.

Three execution paths for the core attention:
  * "chunked" — q-chunk unrolled / kv-chunk scanned online softmax
    (flash-attention algorithm in pure jnp; memory O(qc·kc); the CPU
    dry-run + training path — causal skips fully-masked kv blocks, so
    compiled FLOPs match flash semantics)
  * "pallas"  — the TPU flash kernel (repro.kernels.flash_attention)
  * "ref"     — full S² materialization (small shapes / oracle)

Decode attends over a padded KV cache with position masking; under
GSPMD a sequence-sharded cache turns the softmax reductions into
partial-reduce + all-reduce (sequence parallelism for long contexts).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.hints import axis_size, shard_hint

from . import layers
from .layers import Params, cdtype, dense_init, rmsnorm, rmsnorm_init, apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: (B, Sq, Hq, hd), k: (B, Sk, Hkv, hd) -> (B, Hkv, G, Sq, Sk)."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg, k)


def _gqa_out(probs, v):
    """probs: (B, Hkv, G, Sq, Sk), v: (B, Sk, Hkv, vd) -> (B, Sq, Hq, vd)."""
    B, Hkv, G, Sq, Sk = probs.shape
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, Hkv * G, v.shape[-1])


def ref_attention(q, k, v, *, causal: bool = True,
                  q_offset: int = 0) -> jnp.ndarray:
    scale = float(1.0 / np.sqrt(q.shape[-1]))
    s = _gqa_scores(q * scale, k).astype(jnp.float32)
    if causal:
        qpos = jnp.arange(q.shape[1]) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return _gqa_out(p, v)


def chunked_attention(q, k, v, *, causal: bool = True, q_chunk: int = 1024,
                      k_chunk: int = 1024) -> jnp.ndarray:
    """Online-softmax blockwise attention (flash algorithm, pure jnp).

    The q loop is python-unrolled so each q block's kv scan covers only
    the causally visible prefix — compiled FLOPs ≈ S²/2 like real flash.
    """
    B, Sq, Hq, hd = q.shape
    Sk = k.shape[1]
    vd = v.shape[-1]
    Hkv = k.shape[2]
    G = Hq // Hkv
    qc = min(q_chunk, Sq)
    kc = min(k_chunk, Sk)
    assert Sq % qc == 0 and Sk % kc == 0
    scale = float(1.0 / np.sqrt(hd))
    nq, nk = Sq // qc, Sk // kc

    # NOTE: no explicit hints inside the block loop — GSPMD propagates a
    # joint (Hkv, G) head sharding from the _qkv hints that PartitionSpec
    # cannot even express; hinting here was measured to cause
    # "involuntary full rematerialization" reshard copies (§Perf log).
    k_blocks = k.reshape(B, nk, kc, Hkv, hd).transpose(1, 0, 2, 3, 4)
    v_blocks = v.reshape(B, nk, kc, Hkv, vd).transpose(1, 0, 2, 3, 4)
    outs = []
    for i in range(nq):
        qi = q[:, i * qc:(i + 1) * qc] * scale          # (B, qc, Hq, hd)
        qg = qi.reshape(B, qc, Hkv, G, hd)
        if causal:
            n_vis = min(((i + 1) * qc + kc - 1) // kc, nk)
        else:
            n_vis = nk
        qpos = jnp.arange(i * qc, (i + 1) * qc)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def body(carry, blk):
            # checkpointed: backward recomputes scores per block instead
            # of saving the (qc, kc) probability tiles (flash semantics)
            m, denom, acc, j = carry
            kb, vb = blk                                 # (B, kc, Hkv, ·)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb).astype(jnp.float32)
            if causal:
                kpos = j * kc + jnp.arange(kc)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            denom = denom * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(q.dtype), vb)
            acc = acc * alpha[..., None].astype(q.dtype) + pv
            return (m_new, denom, acc, j + 1), None

        init = (jnp.full((B, Hkv, G, qc), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, qc), jnp.float32),
                jnp.zeros((B, Hkv, G, qc, vd), q.dtype),
                jnp.zeros((), jnp.int32))
        (m, denom, acc, _), _ = jax.lax.scan(
            body, init, (k_blocks[:n_vis], v_blocks[:n_vis]))
        out = acc / denom[..., None].astype(q.dtype)
        outs.append(out.transpose(0, 3, 1, 2, 4).reshape(B, qc, Hq, vd))
    return jnp.concatenate(outs, axis=1)


def decode_attention(q, k_cache, v_cache, cur_len) -> jnp.ndarray:
    """q: (B, 1, Hq, hd); caches: (B, S, Hkv, ·); cur_len: () int32.

    Full-cache masked attention; reductions over the (possibly
    sequence-sharded) cache axis compile to partial + all-reduce.
    """
    scale = float(1.0 / np.sqrt(q.shape[-1]))
    s = _gqa_scores(q * scale, k_cache).astype(jnp.float32)
    kpos = jnp.arange(k_cache.shape[1])
    mask = kpos[None, :] < cur_len                     # (1, Sk)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return _gqa_out(p, v_cache)


def attention_core(q, k, v, *, causal, cfg, impl: Optional[str] = None,
                   q_offset: int = 0):
    impl = impl or ("pallas" if cfg.use_pallas else "chunked")
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fops
        return fops.flash_attention(q, k, v, causal=causal)
    if impl == "chunked" and q.shape[1] > cfg.attn_chunk:
        return chunked_attention(q, k, v, causal=causal,
                                 q_chunk=cfg.attn_chunk,
                                 k_chunk=cfg.attn_chunk)
    return ref_attention(q, k, v, causal=causal, q_offset=q_offset)


# ---------------------------------------------------------------------------
# GQA attention layer (llama/phi/qwen/musicgen/jamba-attn)
# ---------------------------------------------------------------------------

def gqa_init(key, cfg) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, cfg.n_heads * hd),
         "wk": dense_init(ks[1], d, cfg.kv_heads * hd),
         "wv": dense_init(ks[2], d, cfg.kv_heads * hd),
         "wo": dense_init(ks[3], cfg.n_heads * hd, d)}
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def _qkv(p: Params, cfg, x, positions):
    B, S, D = x.shape
    hd = cfg.head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, cfg.kv_heads, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, cfg.kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # keep heads sharded on the model axis through attention (hint-gated;
    # auto-sharding measurably replicates score tiles otherwise). When
    # Hq doesn't divide the axis the hint degrades to the head-dim split.
    if cfg.n_heads % max(axis_size("model"), 1) == 0:
        q = shard_hint(q, "dp", None, "model", None)
    k = shard_hint(k, "dp", None, "model", None)
    v = shard_hint(v, "dp", None, "model", None)
    return q, k, v


def gqa_forward(p: Params, cfg, x, positions, impl: Optional[str] = None):
    """Training / prefill: returns (out, (k, v)) for cache construction."""
    q, k, v = _qkv(p, cfg, x, positions)
    out = attention_core(q, k, v, causal=True, cfg=cfg, impl=impl)
    return out.reshape(x.shape[0], x.shape[1], -1) @ p["wo"].astype(x.dtype), (k, v)


def gqa_decode(p: Params, cfg, x, cache: tuple, cur_len):
    """x: (B, 1, D); cache: (k (B,S,Hkv,hd), v); cur_len: scalar position."""
    B = x.shape[0]
    positions = jnp.full((B, 1), cur_len, dtype=jnp.int32)
    q, k_new, v_new = _qkv(p, cfg, x, positions)
    k_cache, v_cache = cache
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), cur_len, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), cur_len, axis=1)
    out = decode_attention(q, k_cache.astype(x.dtype),
                           v_cache.astype(x.dtype), cur_len + 1)
    out = out.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)
    return out, (k_cache, v_cache)


def gqa_cache_spec(cfg, batch: int, max_len: int):
    shape = (batch, max_len, cfg.kv_heads, cfg.head_dim)
    return (jax.ShapeDtypeStruct(shape, cdtype(cfg)),
            jax.ShapeDtypeStruct(shape, cdtype(cfg)))


# ---------------------------------------------------------------------------
# Cross-attention layer (llama-3.2-vision image layers)
# ---------------------------------------------------------------------------

def xattn_init(key, cfg) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 5)
    return {"wq": dense_init(ks[0], d, cfg.n_heads * hd),
            "wk": dense_init(ks[1], d, cfg.kv_heads * hd),
            "wv": dense_init(ks[2], d, cfg.kv_heads * hd),
            "wo": dense_init(ks[3], cfg.n_heads * hd, d),
            "gate": jnp.zeros((1,), dtype=jnp.float32)}


def xattn_forward(p: Params, cfg, x, image_embeds,
                  impl: Optional[str] = None):
    """Cross-attend text states to (precomputed) image embeddings."""
    B, S, D = x.shape
    hd = cfg.head_dim
    dt = x.dtype
    n_img = image_embeds.shape[1]
    q = (x @ p["wq"].astype(dt)).reshape(B, S, cfg.n_heads, hd)
    k = (image_embeds @ p["wk"].astype(dt)).reshape(B, n_img, cfg.kv_heads, hd)
    v = (image_embeds @ p["wv"].astype(dt)).reshape(B, n_img, cfg.kv_heads, hd)
    out = attention_core(q, k, v, causal=False, cfg=cfg, impl=impl)
    out = out.reshape(B, S, -1) @ p["wo"].astype(dt)
    return jnp.tanh(p["gate"]).astype(dt) * out
