"""Fault-tolerant training supervision.

`run_with_restarts` drives a step function with:
  * periodic atomic checkpoints (params, opt state, data-pipeline state),
  * resume-from-latest on (re)start,
  * SIGTERM/SIGINT preemption handling — checkpoint-and-exit with a
    distinct exit code so a cluster launcher reschedules,
  * optional fault injection for tests (fail at step k, prove the run
    produces bit-identical results to an uninterrupted one — the
    lineage-exactness property from §4.1).
"""
from __future__ import annotations

import signal
from dataclasses import dataclass
from typing import Any, Callable, Optional

from . import store

PREEMPTED_EXIT_CODE = 42


@dataclass
class TrainState:
    step: int
    params: Any
    opt_state: Any
    pipeline_state: dict


class Preemption(Exception):
    pass


def run_with_restarts(
        *, ckpt_dir: str, init_fn: Callable[[], TrainState],
        step_fn: Callable[[TrainState], TrainState],
        total_steps: int, ckpt_every: int = 50,
        fail_at: Optional[int] = None,
        install_signal_handlers: bool = False) -> TrainState:
    """Run to `total_steps`, resuming from the latest checkpoint."""
    preempted = {"flag": False}

    def _handler(signum, frame):
        preempted["flag"] = True

    if install_signal_handlers:
        signal.signal(signal.SIGTERM, _handler)
        signal.signal(signal.SIGINT, _handler)

    latest = store.latest_step(ckpt_dir)
    if latest is not None:
        template = init_fn()
        tree, manifest = store.restore(
            ckpt_dir, {"params": template.params,
                       "opt_state": template.opt_state})
        state = TrainState(step=manifest["step"], params=tree["params"],
                           opt_state=tree["opt_state"],
                           pipeline_state=manifest["lineage"].get(
                               "pipeline", template.pipeline_state))
    else:
        state = init_fn()

    while state.step < total_steps:
        if fail_at is not None and state.step == fail_at:
            raise Preemption(f"injected failure at step {fail_at}")
        if preempted["flag"]:
            store.save(ckpt_dir, state.step,
                       {"params": state.params,
                        "opt_state": state.opt_state},
                       lineage={"pipeline": state.pipeline_state,
                                "preempted": True})
            raise SystemExit(PREEMPTED_EXIT_CODE)
        state = step_fn(state)
        if state.step % ckpt_every == 0 or state.step == total_steps:
            store.save(ckpt_dir, state.step,
                       {"params": state.params,
                        "opt_state": state.opt_state},
                       lineage={"pipeline": state.pipeline_state})
    return state
