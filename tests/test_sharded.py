"""Sharded execution over the device mesh (shard_map-lowered segments).

Covers the `lower_distributed` placement pass and the shard-exec
runtime lane:

  * compile-time — shardable-leaf gating (size / divisibility / format),
    partial-reduction lowering (gram/xtv/colSums/sum -> shard_* + psum),
    explicit `reshard` boundaries for non-lowerable consumers and plan
    roots, `Plan.explain()` markers, variant-node refusal;
  * cost model — collective-byte formulas, shard-vs-reshard arbitration,
    jit-cache key separation across mesh shapes;
  * runtime — 3-way parity (sharded vs local-fused vs interpreter) on a
    forced 8-device host mesh for lmDS, PCA, and a k=8 grid (`parfor
    mode='shard'`), graceful unshard fallback when the mesh does not
    realize, collective-byte meter invariants, batched `fed_map`.

Multi-device cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
`tests/test_distributed.py` pattern); everything compile-time runs
in-process because `lower_distributed` is parameterized by an integer
device count, not by real devices.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import ops
from repro.core.compiler import compile_plan, lower_distributed
from repro.core.dag import input_tensor
from repro.core.runtime import LineageRuntime
from repro.distributed import MeshSpec, use_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=REPO, timeout=560)
    assert out.returncode == 0 and "OK" in out.stdout, \
        out.stdout[-2000:] + out.stderr[-4000:]
    return out.stdout


def _segs(plan):
    return plan.segments_for(False)


def _ops_in(plan) -> set:
    return {ins.node.op for seg in _segs(plan)
            for ins in seg.instructions}


def _big_x(name: str, rows: int = 4096, cols: int = 64):
    rng = np.random.default_rng(7)
    return input_tensor(name, rng.normal(size=(rows, cols)))


class TestMeshSpec:
    def test_shape_and_key_tag(self):
        ms = MeshSpec(data=8, config=2)
        assert ms.ndev == 16 and ms.shape == (8, 2)
        assert ms.key_tag() == "d8xc2"
        assert MeshSpec(data=4).key_tag() != ms.key_tag()

    def test_rejects_bad_axes(self):
        with pytest.raises(ValueError):
            MeshSpec(data=0)

    def test_unrealizable_mesh_resolves_none(self):
        # the test process exposes 1 CPU device: graceful degradation,
        # never an error
        assert MeshSpec(data=8).jax_mesh() is None


class TestLowerDistributed:
    def test_small_leaf_stays_local(self):
        # 64x16 f64 = 8KB < SHARD_MIN_LEAF_BYTES: dispatch overhead
        # would dominate, the pass must not touch the plan
        X = _big_x("sm_X", 64, 16)
        roots = [ops.gram(X).node]
        assert lower_distributed(roots, 8) is roots

    def test_nondivisible_rows_stay_local(self):
        X = _big_x("nd_X", 4100, 64)  # 2.1MB but 4100 % 8 != 0
        roots = [ops.gram(X).node]
        assert lower_distributed(roots, 8) is roots

    def test_sparse_leaf_stays_local(self):
        rng = np.random.default_rng(3)
        x = rng.normal(size=(4096, 64))
        x[x < 1.5] = 0.0  # ~93% zero -> format pass pins BCOO
        X = input_tensor("sp_X", x)
        roots = [ops.gram(X).node]
        assert lower_distributed(roots, 8) is roots

    def test_gram_lowers_to_shard_gram(self):
        X = _big_x("g_X")
        plan = compile_plan([ops.gram(X)], mesh=MeshSpec(data=8))
        assert "shard_gram" in _ops_in(plan)
        assert any(seg.sharded for seg in _segs(plan))
        assert "[sharded]" in plan.explain()

    def test_xtv_colsums_sum_lower(self):
        X = _big_x("r_X")
        y = _big_x("r_y", 4096, 1)
        plan = compile_plan(
            [ops.xtv(X, y), ops.colMeans(X), ops.sum_(X)],
            mesh=MeshSpec(data=8))
        got = _ops_in(plan)
        assert {"shard_xtv", "shard_colsums", "shard_sum"} <= got
        # colMeans/mean lower through the sharded sum plus a local
        # 1/m scale, never a distinct collective
        assert "colMeans" not in got and "sum" not in got

    def test_row_preserving_ops_keep_sharding(self):
        X = _big_x("m_X")
        w = input_tensor("m_w", np.random.default_rng(5).normal(
            size=(64, 1)))
        # matmul with replicated rhs + elementwise chain stays sharded
        # end-to-end: exactly one reduce collects the scalar
        resid = X @ w - 1.0
        plan = compile_plan([ops.sum_(resid * resid)],
                            mesh=MeshSpec(data=8))
        assert "shard_sum" in _ops_in(plan)
        sharded = [seg for seg in _segs(plan) if seg.sharded]
        assert sharded and any(seg.fused for seg in sharded)
        assert "reshard" not in _ops_in(plan)

    def test_nonlowerable_consumer_gets_reshard_boundary(self):
        X = _big_x("t_X")
        plan = compile_plan([ops.t(X)], mesh=MeshSpec(data=8))
        assert "reshard" in _ops_in(plan)
        assert "[reshard-boundary]" in plan.explain()

    def test_sharded_root_resharded_once(self):
        X = _big_x("ab_X")
        # |X| is row-preserving, but a plan output must be replicated:
        # one boundary, shared, surfaced by explain()
        plan = compile_plan([ops.abs_(X), ops.abs_(X) * 2.0],
                            mesh=MeshSpec(data=8))
        n_resh = sum(1 for seg in _segs(plan)
                     for ins in seg.instructions
                     if ins.node.op == "reshard")
        assert n_resh >= 1
        assert "[reshard-boundary]" in plan.explain()

    def test_no_mesh_means_no_sharding(self):
        X = _big_x("nm_X")
        plan = compile_plan([ops.gram(X)], mesh=MeshSpec(data=1))
        assert "shard_gram" not in _ops_in(plan)

    def test_plan_records_mesh_spec(self):
        X = _big_x("ms_X")
        ms = MeshSpec(data=8)
        plan = compile_plan([ops.gram(X)], mesh=ms)
        assert plan.mesh_spec is ms


class TestShardCostModel:
    def test_collective_byte_formulas(self):
        from repro.core.costmodel import (allgather_bytes, allreduce_bytes,
                                          collective_bytes)
        from repro.core.dag import make_node
        n = make_node("input", (), (128, 64), np.dtype(np.float64), 1.0,
                      name="cb_X")
        b = 128 * 64 * 8
        assert allreduce_bytes(n, 8) == 2 * b * 7
        assert allgather_bytes(n, 8) == b * 7
        r = make_node("reshard", (n,), n.shape, n.dtype, 1.0,
                      axis="data", n_dev=8, sin=("s",))
        assert collective_bytes(r) == allgather_bytes(n, 8)
        assert collective_bytes(n) == 0  # row-preserving: no collective

    def test_shard_gram_beats_reshard_then_local(self):
        # the arbitration the lowering gate applies: per-shard compute
        # + psum must beat all-gathering X and running gram locally
        from repro.core import costmodel
        X = _big_x("cg_X")
        g = ops.gram(X).node
        sg = [ins.node for seg in _segs(compile_plan(
            [ops.gram(X)], mesh=MeshSpec(data=8)))
            for ins in seg.instructions if ins.node.op == "shard_gram"][0]
        assert costmodel.est_cost_s(sg) <= (
            costmodel.reshard_cost_s(X.node, 8) + costmodel.est_cost_s(g))

    def test_mesh_key_tags_never_collide(self):
        from repro.core.jit_cache import mesh_key_tag
        a = mesh_key_tag("d8xc1", ("s", "r"), ("r",))
        b = mesh_key_tag("d4xc2", ("s", "r"), ("r",))
        c = mesh_key_tag("d8xc1", ("s", "s"), ("r",))
        assert len({a, b, c}) == 3
        assert "|mesh:d8xc1|in:sr|out:r" == a

    def test_structural_key_separates_shard_lane(self):
        # same body compiled with and without a mesh must not share an
        # executable: the '+sh' lane tag is baked into the segment key
        X1, X2 = _big_x("sk_a"), _big_x("sk_b")
        p_sh = compile_plan([ops.gram(X1)], mesh=MeshSpec(data=8))
        p_lo = compile_plan([ops.gram(X2)], mesh=MeshSpec(data=1))
        k_sh = {seg.key for seg in _segs(p_sh)}
        k_lo = {seg.key for seg in _segs(p_lo)}
        assert k_sh.isdisjoint(k_lo)


class TestUnshardFallback:
    """A sharded plan must stay executable when the mesh does not
    realize (1 visible device): local-equivalent kernels, zero meter."""

    def test_parity_and_zero_meter(self):
        rng = np.random.default_rng(11)
        xn = rng.normal(size=(4096, 64))
        yn = rng.normal(size=(4096, 1))

        def lmds(X, y):
            A = ops.gram(X) + 1e-3 * ops.eye(64)
            beta = ops.solve(A, ops.xtv(X, y))
            resid = y - X @ beta
            return beta, ops.sum_(resid * resid)

        ref = LineageRuntime().evaluate(
            list(lmds(input_tensor("fb_X", xn), input_tensor("fb_y", yn))))
        with use_mesh(data=8):
            plan = compile_plan(list(lmds(input_tensor("fb_X2", xn),
                                          input_tensor("fb_y2", yn))))
        assert any(seg.sharded for seg in _segs(plan))
        rt = LineageRuntime()
        out = rt.run_plan(plan)
        assert rt.stats.shard.total == 0  # fallback, not sharded exec
        for a, b in zip(out, ref):
            np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-12)
        # the interpreter (fuse=False) agrees too
        for a, b in zip(LineageRuntime(fuse=False).run_plan(plan), ref):
            np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-12)


class TestBatchedFedMap:
    def test_parfor_vmap_over_federated_map(self):
        from repro.core import FederatedTensor, federated_input
        from repro.lifecycle.validation import parfor
        rng = np.random.default_rng(2)
        xn = rng.normal(size=(300, 12))
        lams = [0.5, 1.5, 2.5, 3.5]

        X = federated_input("bfm_X", FederatedTensor.partition_rows(xn, 3))
        out = parfor(lams, lambda lam: ops.colSums(ops.abs_(X) * float(lam)),
                     runtime=LineageRuntime(), mode="vmap")
        for lam, (got,) in zip(lams, out):
            np.testing.assert_allclose(
                got, np.abs(xn).sum(axis=0, keepdims=True) * lam,
                rtol=1e-9, atol=1e-12)

    def test_batched_collect_of_fed_map(self):
        from repro.core import FederatedTensor, federated_input
        from repro.lifecycle.validation import parfor
        rng = np.random.default_rng(4)
        xn = rng.normal(size=(90, 6))
        X = federated_input("bfc_X", FederatedTensor.partition_rows(xn, 3))
        out = parfor([1.0, 2.0, 3.0], lambda s: X * float(s),
                     runtime=LineageRuntime(), mode="vmap")
        for s, (got,) in zip([1.0, 2.0, 3.0], out):
            np.testing.assert_allclose(got, xn * s, rtol=1e-12)


class TestEightDeviceMesh:
    """Real shard_map execution on a forced 8-device host mesh."""

    def test_lmds_three_way_parity_and_meter(self):
        _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.core import ops, input_tensor
from repro.core.runtime import LineageRuntime
from repro.core.compiler import compile_plan
from repro.core import backend, costmodel
from repro.distributed import use_mesh

rng = np.random.default_rng(0)
xn = rng.normal(size=(4096, 64)); yn = rng.normal(size=(4096, 1))

def lmds(X, y):
    A = ops.gram(X) + 1e-3 * ops.eye(64)
    beta = ops.solve(A, ops.xtv(X, y))
    resid = y - X @ beta
    return beta, ops.sum_(resid * resid)

ref = LineageRuntime().evaluate(
    list(lmds(input_tensor("X", xn), input_tensor("y", yn))))
with use_mesh(data=8):
    plan = compile_plan(list(lmds(input_tensor("X2", xn),
                                  input_tensor("y2", yn))))
    rt = LineageRuntime()
    out = rt.run_plan(plan)
    out_i = LineageRuntime(fuse=False).run_plan(plan)

segs = plan.segments_for(rt.cache is not None)
assert any(s.sharded for s in segs)
for a, b in zip(out, ref):
    np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-12)
for a, b in zip(out_i, ref):
    np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-12)

# meter invariant: one dispatch of each sharded segment, bytes match
# the compile-time formulas exactly
exp_coll = exp_bytes = exp_resh = 0
for seg in segs:
    if not seg.sharded:
        continue
    for ins in seg.instructions:
        if ins.node.op == backend.RESHARD_OP:
            exp_resh += 1
            exp_bytes += costmodel.collective_bytes(ins.node)
        elif ins.node.op in backend.SHARD_REDUCE_OPS:
            exp_coll += 1
            exp_bytes += costmodel.collective_bytes(ins.node)
sh = rt.stats.shard
assert sh.sharded_segments == sum(1 for s in segs if s.sharded)
assert sh.collectives == exp_coll and sh.reshards == exp_resh
assert sh.collective_bytes == exp_bytes and exp_bytes > 0
print("OK")
""")

    def test_pca_parity(self):
        _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.core import input_tensor
from repro.core.runtime import LineageRuntime
from repro.lifecycle.algorithms import pca
from repro.distributed import use_mesh

rng = np.random.default_rng(1)
xn = rng.normal(size=(4096, 48)) * rng.uniform(0.5, 4.0, size=48)

c_ref, p_ref = pca(input_tensor("X", xn), k=4,
                   runtime=LineageRuntime())
with use_mesh(data=8):
    rt = LineageRuntime()
    c_sh, p_sh = pca(input_tensor("X2", xn), k=4, runtime=rt)
    assert rt.stats.shard.sharded_segments > 0
np.testing.assert_allclose(c_sh, c_ref, rtol=1e-8, atol=1e-10)
np.testing.assert_allclose(p_sh, p_ref, rtol=1e-8, atol=1e-10)
print("OK")
""")

    def test_grid_config_shard_parity(self):
        _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.core import input_tensor
from repro.core.runtime import LineageRuntime
from repro.lifecycle.validation import grid_search_lm
from repro.distributed import use_mesh

rng = np.random.default_rng(1)
xn = rng.normal(size=(512, 16)); yn = rng.normal(size=(512, 1))
lams = [0.1 * (i + 1) for i in range(8)]

b_ref, l_ref = grid_search_lm(input_tensor("X", xn),
                              input_tensor("y", yn), lams,
                              runtime=LineageRuntime(), mode="vmap")
with use_mesh(data=1, config=8):
    rt = LineageRuntime()
    b_sh, l_sh = grid_search_lm(input_tensor("X2", xn),
                                input_tensor("y2", yn), lams,
                                runtime=rt, mode="shard")
    assert rt.stats.shard.config_sharded_segments > 0
np.testing.assert_allclose(b_sh, b_ref, rtol=1e-9)
np.testing.assert_allclose(l_sh, l_ref, rtol=1e-9)
print("OK")
""")

    def test_jit_cache_no_collision_across_mesh_shapes(self):
        _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.core import get_jit_cache, input_tensor, ops
from repro.core.runtime import LineageRuntime
from repro.core.compiler import compile_plan
from repro.distributed import use_mesh

rng = np.random.default_rng(0)
xn = rng.normal(size=(4096, 64))
ref = np.asarray(LineageRuntime().evaluate(
    [ops.gram(input_tensor("X", xn))])[0])

outs = []
for d in (8, 4, 2):
    with use_mesh(data=d):
        plan = compile_plan([ops.gram(input_tensor(f"X{d}", xn))])
        outs.append(np.asarray(LineageRuntime().run_plan(plan)[0]))
jc = get_jit_cache()
# three mesh shapes + the local reference: four distinct executables,
# zero cross-shape reuse of a shard_map closure
keys = {k[0] for k in jc._entries}
mesh_tags = {k.split("|mesh:")[1].split("|")[0]
             for k in keys if "|mesh:" in k}
assert mesh_tags == {"d8xc1", "d4xc1", "d2xc1"}, mesh_tags
for o in outs:
    np.testing.assert_allclose(o, ref, rtol=1e-9, atol=1e-12)
print("OK")
""")
