"""Fig. 5(c,d) + Fig. 6: HPO with lineage-based reuse of intermediates.

Measures end-to-end time with/without the reuse cache as k grows, and
the input-size sweep (Fig 5d): the larger X, the larger the speedup,
because the reused X^T X / X^T y are the only row-count-dependent ops.
"""
from __future__ import annotations

import numpy as np

from .common import COLS, ROWS, SPARSITY, emit, timed
from .hpo_baseline import run_hpo


def main(ks=(1, 5, 10, 20), rows=ROWS, cols=COLS) -> None:
    from repro.data.synthetic import gen_regression
    x, y, _ = gen_regression(rows, cols, sparsity=1.0, seed=7)

    base_times = {}
    for k in ks:
        t_no = timed(lambda: run_hpo(x, y, k, reuse=False), repeats=2,
                     warmup=1)
        t_yes = timed(lambda: run_hpo(x, y, k, reuse=True), repeats=2,
                      warmup=1)
        base_times[k] = (t_no, t_yes)
        emit(f"fig5c_hpo_reuse_k{k}", t_yes,
             f"no_reuse_us={t_no*1e6:.1f};speedup={t_no/t_yes:.2f}x")

    # Fig 5(d): size sweep at fixed k — speedup grows with rows
    k = max(ks)
    for r in (rows // 4, rows // 2, rows):
        xs, ys_, _ = gen_regression(r, cols, sparsity=SPARSITY, seed=8)
        t_no = timed(lambda: run_hpo(xs, ys_, k, reuse=False), repeats=2,
                     warmup=1)
        t_yes = timed(lambda: run_hpo(xs, ys_, k, reuse=True), repeats=2,
                      warmup=1)
        emit(f"fig5d_hpo_reuse_rows{r}", t_yes,
             f"no_reuse_us={t_no*1e6:.1f};speedup={t_no/t_yes:.2f}x")

    # correctness guard: reuse changes nothing numerically
    b_no = run_hpo(x, y, 4, reuse=False)["betas"]
    b_yes = run_hpo(x, y, 4, reuse=True)["betas"]
    assert np.allclose(b_no, b_yes, rtol=1e-8), "reuse changed results!"


if __name__ == "__main__":
    main()
