"""Layer blocks: per-kind init / train-forward / decode-step.

A `kind` string names one residual layer's composition:
  "attn"            self-attention + dense MLP        (dense/audio/vlm self)
  "attn+moe"        self-attention + MoE FFN          (deepseek, jamba-attn)
  "attn+mlp_first"  dense first layers of deepseek models
  "xattn"           cross-attention (image) + MLP     (llama-vision)
  "mamba"           mamba mixer + dense MLP           (jamba)
  "mamba+moe"       mamba mixer + MoE FFN             (jamba)
  "rwkv6"           rwkv6 time-mix + channel-mix      (finch)

All blocks are pre-norm residual. Decode carries a per-layer cache whose
pytree structure is fixed per kind (see `cache_spec`).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mamba as mamba_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import rwkv6 as rwkv_mod
from .layers import Params, cdtype, mlp, mlp_init, rmsnorm, rmsnorm_init


def _attn_init(key, cfg):
    if cfg.attn_type == "mla":
        return mla_mod.mla_init(key, cfg)
    return attn_mod.gqa_init(key, cfg)


def block_init(key, cfg, kind: str) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"norm1": rmsnorm_init(cfg.d_model),
                 "norm2": rmsnorm_init(cfg.d_model)}
    if kind == "rwkv6":
        p["rwkv"] = rwkv_mod.rwkv6_init(k1, cfg)
        return p
    if kind.startswith("attn"):
        p["attn"] = _attn_init(k1, cfg)
    elif kind == "xattn":
        p["xattn"] = attn_mod.xattn_init(k1, cfg)
    elif kind.startswith("mamba"):
        p["mamba"] = mamba_mod.mamba_init(k1, cfg)
    if kind.endswith("+moe"):
        p["moe"] = moe_mod.moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff)
    return p


# ---------------------------------------------------------------------------
# training / prefill forward
# ---------------------------------------------------------------------------

def block_forward(p: Params, cfg, kind: str, x, positions,
                  image_embeds=None, collect_cache: bool = False):
    """Returns (x, aux_loss, cache-or-None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if kind == "rwkv6":
        # token-shift state starts at zeros for a fresh sequence
        y, shift_tm, wkv_state = rwkv_mod.time_mix(
            p["rwkv"], cfg, rmsnorm(p["norm1"], x), None,
            jnp.zeros((x.shape[0], cfg.d_model // cfg.rwkv_head_dim,
                       cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32))
        x = x + y
        y, shift_cm = rwkv_mod.channel_mix(p["rwkv"],
                                           rmsnorm(p["norm2"], x), None)
        x = x + y
        if collect_cache:
            cache = {"wkv": wkv_state, "shift_tm": shift_tm.astype(cdtype(cfg)),
                     "shift_cm": shift_cm.astype(cdtype(cfg))}
        return x, aux, cache

    if kind.startswith("attn"):
        h = rmsnorm(p["norm1"], x)
        if cfg.attn_type == "mla":
            y, kv = mla_mod.mla_forward(p["attn"], cfg, h, positions)
        else:
            y, kv = attn_mod.gqa_forward(p["attn"], cfg, h, positions)
        x = x + y
        if collect_cache:
            cache = tuple(t.astype(cdtype(cfg)) for t in kv)
    elif kind == "xattn":
        h = rmsnorm(p["norm1"], x)
        y = attn_mod.xattn_forward(p["xattn"], cfg, h, image_embeds)
        x = x + y
        if collect_cache:
            # cache the image K/V so decode never re-encodes the image
            dt = x.dtype
            B, n_img = image_embeds.shape[:2]
            k = (image_embeds @ p["xattn"]["wk"].astype(dt)).reshape(
                B, n_img, cfg.kv_heads, cfg.head_dim)
            v = (image_embeds @ p["xattn"]["wv"].astype(dt)).reshape(
                B, n_img, cfg.kv_heads, cfg.head_dim)
            cache = (k.astype(cdtype(cfg)), v.astype(cdtype(cfg)))
    elif kind.startswith("mamba"):
        h = rmsnorm(p["norm1"], x)
        y, state = mamba_mod.mamba_forward(p["mamba"], cfg, h)
        x = x + y
        if collect_cache:
            cache = {"h": state["h"],
                     "conv": state["conv"].astype(cdtype(cfg))}

    h2 = rmsnorm(p["norm2"], x)
    if kind.endswith("+moe"):
        y, aux = moe_mod.moe_forward(p["moe"], cfg, h2)
    else:
        y = mlp(p["mlp"], h2)
    return x + y, aux, cache


# ---------------------------------------------------------------------------
# decode step (one token)
# ---------------------------------------------------------------------------

def block_decode(p: Params, cfg, kind: str, x, cache, cur_len):
    """x: (B, 1, D); returns (x, new_cache)."""
    if kind == "rwkv6":
        y, shift_tm, wkv_state = rwkv_mod.time_mix(
            p["rwkv"], cfg, rmsnorm(p["norm1"], x),
            cache["shift_tm"].astype(x.dtype), cache["wkv"], decode=True)
        x = x + y
        y, shift_cm = rwkv_mod.channel_mix(
            p["rwkv"], rmsnorm(p["norm2"], x),
            cache["shift_cm"].astype(x.dtype))
        x = x + y
        new_cache = {"wkv": wkv_state,
                     "shift_tm": shift_tm.astype(cache["shift_tm"].dtype),
                     "shift_cm": shift_cm.astype(cache["shift_cm"].dtype)}
        return x, new_cache

    if kind.startswith("attn"):
        h = rmsnorm(p["norm1"], x)
        if cfg.attn_type == "mla":
            y, new_cache = mla_mod.mla_decode(p["attn"], cfg, h, cache,
                                              cur_len)
        else:
            y, new_cache = attn_mod.gqa_decode(p["attn"], cfg, h, cache,
                                               cur_len)
        x = x + y
    elif kind == "xattn":
        h = rmsnorm(p["norm1"], x)
        k_img, v_img = cache
        B = x.shape[0]
        dt = x.dtype
        q = (h @ p["xattn"]["wq"].astype(dt)).reshape(
            B, 1, cfg.n_heads, cfg.head_dim)
        out = attn_mod.ref_attention(q, k_img.astype(dt), v_img.astype(dt),
                                     causal=False)
        y = out.reshape(B, 1, -1) @ p["xattn"]["wo"].astype(dt)
        x = x + jnp.tanh(p["xattn"]["gate"]).astype(dt) * y
        new_cache = cache
    elif kind.startswith("mamba"):
        h = rmsnorm(p["norm1"], x)
        state = {"h": cache["h"], "conv": cache["conv"].astype(x.dtype)}
        y, new_state = mamba_mod.mamba_forward(p["mamba"], cfg, h,
                                               state, decode=True)
        x = x + y
        new_cache = {"h": new_state["h"],
                     "conv": new_state["conv"].astype(cache["conv"].dtype)}

    h2 = rmsnorm(p["norm2"], x)
    if kind.endswith("+moe"):
        y, _ = moe_mod.moe_forward(p["moe"], cfg, h2)
    else:
        y = mlp(p["mlp"], h2)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------

def cache_spec(cfg, kind: str, batch: int, max_len: int):
    if kind == "rwkv6":
        return rwkv_mod.rwkv6_state_spec(cfg, batch)
    if kind.startswith("attn"):
        if cfg.attn_type == "mla":
            return mla_mod.mla_cache_spec(cfg, batch, max_len)
        return attn_mod.gqa_cache_spec(cfg, batch, max_len)
    if kind == "xattn":
        shape = (batch, cfg.n_image_tokens, cfg.kv_heads, cfg.head_dim)
        return (jax.ShapeDtypeStruct(shape, cdtype(cfg)),) * 2
    if kind.startswith("mamba"):
        return mamba_mod.mamba_state_spec(cfg, batch)
    raise ValueError(kind)
