"""Roofline table: reads the dry-run sweep JSON (results/dryrun*.json)
and emits one CSV row per (arch × shape × mesh) cell."""
from __future__ import annotations

import json
import os

from .common import emit

RESULTS = ("results/dryrun_hints.json", "results/dryrun_baseline.json")


def main() -> None:
    found = False
    for path in RESULTS:
        if not os.path.exists(path):
            continue
        found = True
        data = json.load(open(path))
        for c in data["cells"]:
            t_step = max(c["t_compute"], c["t_memory"], c["t_collective"])
            emit(f"roofline_{c['arch']}_{c['shape']}_{c['mesh']}", t_step,
                 f"bound={c['bottleneck']};comp_ms={c['t_compute']*1e3:.2f};"
                 f"mem_ms={c['t_memory']*1e3:.2f};"
                 f"coll_ms={c['t_collective']*1e3:.2f};"
                 f"model_hlo={c['flops_ratio']:.3f};"
                 f"roofline={c['roofline_fraction']*100:.1f}%")
        for s in data.get("skips", []):
            print(f"# SKIP {s['cell']}: {s['reason']}")
    if not found:
        print("# no dry-run results found — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all --hints "
              "--out results/dryrun_hints.json")


if __name__ == "__main__":
    main()
