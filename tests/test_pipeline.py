"""Async pipelined dispatch (PR 9): depth parity, donation safety,
prefetch error propagation, and continuous serving rebatching.

The contract under test:

  * `REPRO_PIPELINE_DEPTH=1` reproduces the synchronous runtime exactly
    — same results bitwise, zero pipeline counters, no `pipeline`
    section in `RuntimeStats.as_dict()`;
  * depth 2 matches depth 1 numerically (bitwise for single-row
    serving, 1e-10 for streamed lmDS/PCA) across fuse modes, and its
    chunk-cache keys are bitwise-compatible with depth 1's (a cache
    populated synchronously fully hits under the pipelined loop — the
    table-derived slice fingerprints are exact, not approximate);
  * buffer donation never claims a value the runtime doesn't own: leaf
    bindings, reuse-cache entries and probe-hit values survive any
    number of donating runs, and donated executables live under a
    separate `|don:`-suffixed jit-cache key;
  * a prefetch-worker error is absorbed by the fault policy — the
    worker is joined and the stream finishes on the synchronous chunk
    loop with the exact answer (`REPRO_FAULT_POLICY=off` restores raw
    propagation) — no hung threads, no silently dropped buckets; the
    serving completion worker keeps `QueueFullError` backpressure
    working while a batch is in flight.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import costmodel, ops, runtime as rt_mod
from repro.core.dag import input_tensor
from repro.core.jit_cache import get_jit_cache
from repro.core.reuse import ReuseCache
from repro.core.runtime import LineageRuntime, PreparedScript
from repro.lifecycle.algorithms import pca
from repro.lifecycle.regression import lmDS
from repro.serving import ModelServer, QueueFullError

BUDGET = 1 << 16


def _lm_ref(Xh, yh, reg=1e-3):
    return np.linalg.solve(Xh.T @ Xh + reg * np.eye(Xh.shape[1]),
                           Xh.T @ yh)


def _lm_run(rt, Xh, yh, reg=1e-3):
    X = input_tensor("X", Xh)
    y = input_tensor("y", yh)
    return np.asarray(lmDS(X, y, reg=reg, runtime=rt)).ravel()


def _no_prefetch_threads():
    return not [t for t in threading.enumerate()
                if t.name.startswith("chunk-prefetch") and t.is_alive()]


# ---------------------------------------------------------------------------
# depth-1 contract: the synchronous runtime, exactly
# ---------------------------------------------------------------------------

def test_depth1_has_no_pipeline_footprint(rng, monkeypatch):
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "1")
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", BUDGET)
    rt = LineageRuntime(cache=ReuseCache(), fuse=True)
    Xh, yh = rng.normal(size=(4096, 8)), rng.normal(size=(4096,))
    got = _lm_run(rt, Xh, yh)
    assert np.abs(got - _lm_ref(Xh, yh).ravel()).max() < 1e-10
    p = rt.stats.pipeline
    assert p.total == 0
    assert p.dispatch_s == p.block_s == p.prefetch_s == 0.0
    assert "pipeline" not in rt.stats.as_dict()
    assert rt.stats.streaming.chunks > 1  # the stream really ran


def test_depth_parity_streamed_lmds_across_fuse_modes(rng, monkeypatch):
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", BUDGET)
    Xh, yh = rng.normal(size=(4096, 8)), rng.normal(size=(4096,))
    got = {}
    for depth in ("1", "2"):
        monkeypatch.setenv("REPRO_PIPELINE_DEPTH", depth)
        for fuse in (True, False):
            rt = LineageRuntime(cache=ReuseCache(), fuse=fuse)
            got[(depth, fuse)] = _lm_run(rt, Xh, yh)
            if fuse and depth == "2":
                assert rt.stats.pipeline.total > 0
    ref = got[("1", True)]
    for k, v in got.items():
        assert np.abs(v - ref).max() < 1e-10, k
    # fused runs are bitwise across depths: same executables, same
    # accumulation order, only the sync points moved
    assert np.array_equal(got[("1", True)], got[("2", True)])


def test_depth_parity_streamed_pca(rng, monkeypatch):
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", BUDGET)
    Xh = rng.normal(size=(4096, 8))
    comps = {}
    for depth in ("1", "2"):
        monkeypatch.setenv("REPRO_PIPELINE_DEPTH", depth)
        rt = LineageRuntime(cache=ReuseCache(), fuse=True)
        c, _ = pca(input_tensor("X", Xh), 3, runtime=rt)
        comps[depth] = np.asarray(c)
        assert rt.stats.streaming.chunks > 1
    assert np.abs(comps["1"] - comps["2"]).max() < 1e-10


# ---------------------------------------------------------------------------
# chunk-cache key parity across depths (derived slice fingerprints)
# ---------------------------------------------------------------------------

def test_depth1_populated_chunk_cache_fully_hits_at_depth2(
        rng, monkeypatch):
    # 1 MiB budget over a 16 MiB matrix: bucket slices are > 64 KiB and
    # 4096-byte aligned, so the depth-2 loop takes the table-derived
    # fingerprint path — and must reproduce depth 1's sha1 keys bitwise
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", 1 << 20)
    Xh = rng.normal(size=(1 << 15, 64))
    yh = rng.normal(size=(1 << 15,))
    rt = LineageRuntime(cache=ReuseCache(), fuse=True)
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "1")
    cold = _lm_run(rt, Xh, yh)
    s = rt.stats.streaming
    base_chunks, base_reused = s.chunks, s.chunks_reused
    assert base_chunks > 1
    # correction: one changed cell re-dispatches exactly one bucket at
    # depth 2 — every untouched bucket's pipelined key HITS the
    # synchronously-written cache entries
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "2")
    Xc = Xh.copy()
    Xc[777, 3] = 42.0
    got = _lm_run(rt, Xc, yh)
    assert np.abs(got - _lm_ref(Xc, yh).ravel()).max() < 1e-10
    assert s.chunks - base_chunks == 1
    assert s.chunks_reused - base_reused == base_chunks - 1
    assert rt.stats.pipeline.prefetch_issued >= 1
    assert np.isfinite(cold).all()


def test_depth2_append_retrain_reuses_all_old_buckets(rng, monkeypatch):
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", BUDGET)
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "2")
    Xh, yh = rng.normal(size=(4096, 8)), rng.normal(size=(4096,))
    rt = LineageRuntime(cache=ReuseCache(), fuse=True)
    _lm_run(rt, Xh, yh)
    s = rt.stats.streaming
    base_chunks, base_reused = s.chunks, s.chunks_reused
    extra = 409
    Xa = np.vstack([Xh, rng.normal(size=(extra, 8))])
    ya = np.concatenate([yh, rng.normal(size=(extra,))])
    got = _lm_run(rt, Xa, ya)
    assert np.abs(got - _lm_ref(Xa, ya).ravel()).max() < 1e-10
    assert s.chunks_reused - base_reused == base_chunks
    assert s.chunks - base_chunks <= extra // 16 + 1


# ---------------------------------------------------------------------------
# memory bound with prefetch live
# ---------------------------------------------------------------------------

def test_peak_live_bytes_under_budget_with_prefetch(rng, monkeypatch):
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", BUDGET)
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "2")
    Xh, yh = rng.normal(size=(4096, 8)), rng.normal(size=(4096,))
    rt = LineageRuntime(cache=None, fuse=True)
    _lm_run(rt, Xh, yh)
    s = rt.stats.streaming
    assert s.chunks > 1
    assert rt.stats.pipeline.prefetch_issued > 1
    # the meter charges BOTH in-flight buckets, and still fits: the
    # bucket sizing keeps CHUNK_LIVE_FACTOR headroom per slice
    assert 0 < s.peak_live_bytes <= BUDGET
    assert _no_prefetch_threads()


# ---------------------------------------------------------------------------
# donation safety
# ---------------------------------------------------------------------------

def _donating_run(Xh, yh):
    """One fused lmDS run on a FRESH reuse-cache runtime: probe points
    split the plan into 4 segments, and the normal-equations combine
    frees a non-probe intermediate across a boundary — the depth-2
    executor donates it. Fresh cache per run keeps the probe outcomes
    (and therefore the donation masks and jit keys) deterministic."""
    rt = LineageRuntime(cache=ReuseCache(), fuse=True)
    return _lm_run(rt, Xh, yh), rt


def test_depth2_donates_and_keys_separate(rng, monkeypatch):
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", 1 << 30)
    Xh, yh = rng.normal(size=(2048, 16)), rng.normal(size=(2048,))
    jstats = get_jit_cache().stats
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "1")
    got1, rt1 = _donating_run(Xh, yh)
    assert rt1.stats.pipeline.donated_buffers == 0
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "2")
    miss0 = jstats.misses
    got2, rt2 = _donating_run(Xh, yh)
    p = rt2.stats.pipeline
    assert p.donated_buffers > 0
    assert p.donated_bytes > 0
    assert p.async_segments > 0
    # donated executables are NEW cache entries (the |don: key suffix):
    # they can never shadow or be served by the plain depth-1 programs
    assert jstats.misses > miss0
    assert np.array_equal(got1, got2)
    # replaying depth 2 on identical content: the donated executables
    # hit their own keys — not a single extra compile
    miss1 = jstats.misses
    got3, _ = _donating_run(Xh, yh)
    assert jstats.misses == miss1
    assert np.array_equal(got2, got3)


def test_donation_never_claims_reuse_cache_entries(rng, monkeypatch):
    # probe values enter the reuse cache as live references; a donated
    # buffer would be invalidated by the next dispatch and the warm-run
    # hit would hand back a dead array. Three runs on one cache: the
    # second hits the probes the first stored, the third proves the hit
    # values were never donated out from under the cache.
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", 1 << 30)
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "2")
    Xh, yh = rng.normal(size=(2048, 16)), rng.normal(size=(2048,))
    rt = LineageRuntime(cache=ReuseCache(), fuse=True)
    a = _lm_run(rt, Xh, yh)
    reused0 = rt.stats.reused
    b = _lm_run(rt, Xh, yh)
    assert rt.stats.reused > reused0  # warm run really hit the cache
    c = _lm_run(rt, Xh, yh)
    assert np.array_equal(a, b)
    assert np.array_equal(b, c)


def test_leaves_are_never_donated(rng, monkeypatch):
    # the same leaf arrays serve four plans back-to-back; if a leaf
    # buffer were ever donated, the later runs would read a deleted
    # array (jax raises) or corrupt results
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", 1 << 30)
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "2")
    Xh, yh = rng.normal(size=(1024, 8)), rng.normal(size=(1024,))
    runs = [_donating_run(Xh, yh)[0] for _ in range(4)]
    for r in runs[1:]:
        assert np.array_equal(runs[0], r)
    assert np.isfinite(runs[0]).all()


def test_batched_dispatches_never_donate(rng, monkeypatch):
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "2")
    rt = LineageRuntime(cache=ReuseCache(), fuse=True)

    def fn(X, y):
        return ops.solve(X.T @ X + 1e-3 * ops.eye(4), X.T @ y)

    Xh, yh = rng.normal(size=(256, 4)), rng.normal(size=(256,))
    script = PreparedScript(fn, [Xh.shape, yh.shape], runtime=rt)
    bplan = script.prepare_batched()
    stacked = [np.stack([Xh, Xh * 2.0]), np.stack([yh, yh * 0.5])]
    rt.replay_batch(bplan, stacked, 2)
    assert rt.stats.pipeline.donated_buffers == 0
    bplan.release_leaves()


# ---------------------------------------------------------------------------
# prefetch-worker error propagation
# ---------------------------------------------------------------------------

def _boom_on_prefetch(real):
    def boom(a):
        if threading.current_thread().name.startswith("chunk-prefetch"):
            raise RuntimeError("prefetch boom")
        return real(a)
    return boom


def test_prefetch_error_degrades_to_sync_tail(rng, monkeypatch):
    # Under the default fault policy a prefetch-worker crash is not
    # fatal: the runtime cancels queued preps, joins the worker, and
    # finishes the stream on the synchronous chunk loop (PR 10).
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", BUDGET)
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "2")
    real = rt_mod._reuse_nbytes
    monkeypatch.setattr(rt_mod, "_reuse_nbytes", _boom_on_prefetch(real))
    Xh, yh = rng.normal(size=(4096, 8)), rng.normal(size=(4096,))
    rt = LineageRuntime(cache=None, fuse=True)
    got = _lm_run(rt, Xh, yh)
    assert np.abs(got - _lm_ref(Xh, yh).ravel()).max() < 1e-10
    assert rt.stats.faults.degradations == 1
    # clean shutdown: queued preps cancelled, worker joined
    assert _no_prefetch_threads()
    # and the runtime is not poisoned: the next run (healthy worker)
    # streams pipelined again to the same answer
    monkeypatch.setattr(rt_mod, "_reuse_nbytes", real)
    again = _lm_run(rt, Xh, yh)
    assert np.abs(again - _lm_ref(Xh, yh).ravel()).max() < 1e-10
    assert rt.stats.faults.degradations == 1  # healthy run added none
    assert _no_prefetch_threads()


def test_prefetch_error_propagates_with_policy_off(rng, monkeypatch):
    # REPRO_FAULT_POLICY=off restores the PR-9 contract: the worker
    # error propagates to the caller and the worker is joined.
    monkeypatch.setenv("REPRO_FAULT_POLICY", "off")
    monkeypatch.setattr(costmodel, "CHUNK_MEM_BUDGET", BUDGET)
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "2")
    real = rt_mod._reuse_nbytes
    monkeypatch.setattr(rt_mod, "_reuse_nbytes", _boom_on_prefetch(real))
    Xh, yh = rng.normal(size=(4096, 8)), rng.normal(size=(4096,))
    rt = LineageRuntime(cache=None, fuse=True)
    with pytest.raises(RuntimeError, match="prefetch boom"):
        _lm_run(rt, Xh, yh)
    # clean shutdown: queued preps cancelled, worker joined
    assert _no_prefetch_threads()
    # and the runtime is not poisoned: the next run (healthy worker)
    # streams to the correct answer
    monkeypatch.setattr(rt_mod, "_reuse_nbytes", real)
    got = _lm_run(rt, Xh, yh)
    assert np.abs(got - _lm_ref(Xh, yh).ravel()).max() < 1e-10
    assert _no_prefetch_threads()


# ---------------------------------------------------------------------------
# serving: continuous rebatching
# ---------------------------------------------------------------------------

def _score_script(rng):
    rt = LineageRuntime(cache=None, fuse=True)

    def fn(x):
        return ops.matmul(x, x.T)

    return PreparedScript(fn, [(4, 4)], runtime=rt), rt


def test_serving_single_row_bitwise_parity_across_depths(
        rng, monkeypatch):
    x = rng.normal(size=(4, 4))
    got = {}
    for depth in ("1", "2"):
        monkeypatch.setenv("REPRO_PIPELINE_DEPTH", depth)
        script, _rt = _score_script(rng)
        with ModelServer(script, max_batch=4, max_wait_us=200.0) as srv:
            got[depth] = srv.score(x)[0]
        assert np.allclose(got[depth], x @ x.T, atol=1e-12)
    # the depth-2 issue/completion split replays the SAME executables:
    # single-row results are bitwise identical to the inline dispatcher
    assert np.array_equal(got["1"], got["2"])


def test_serving_rebatching_overlaps_inflight_batches(rng, monkeypatch):
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "2")
    script, rt = _score_script(rng)
    srv = ModelServer(script, max_batch=2, max_wait_us=100.0,
                      queue_limit=64)
    with srv:
        assert srv._pipelined
        gate = threading.Event()
        orig = rt.replay_batch

        def slow(*a, **k):
            gate.wait(5.0)
            return orig(*a, **k)

        monkeypatch.setattr(rt, "replay_batch", slow)
        xs = [rng.normal(size=(4, 4)) for _ in range(6)]
        futs = [srv.submit(x) for x in xs]
        time.sleep(0.05)  # let the coalescer stage batches behind the gate
        gate.set()
        outs = [f.result(timeout=10.0) for f in futs]
        srv.flush()
    for x, out in zip(xs, outs):
        assert np.allclose(out[0], x @ x.T, atol=1e-12)
    assert rt.stats.pipeline.rebatches >= 1
    assert rt.stats.serving.retraces == 0
    assert rt.stats.serving.busy_s > 0.0


def test_serving_error_delivery_and_queue_full_while_inflight(
        rng, monkeypatch):
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "2")
    script, rt = _score_script(rng)
    srv = ModelServer(script, max_batch=1, max_wait_us=50.0,
                      queue_limit=2)
    with srv:
        gate = threading.Event()
        orig = rt.replay_batch
        calls = []

        def failing(*a, **k):
            calls.append(1)
            gate.wait(5.0)
            if len(calls) == 1:
                raise ValueError("replay boom")
            return orig(*a, **k)

        monkeypatch.setattr(rt, "replay_batch", failing)
        x = rng.normal(size=(4, 4))
        f1 = srv.submit(x)           # in flight, will fail
        time.sleep(0.05)             # ensure it reached the worker
        f2 = srv.submit(x)           # staged behind it
        f3 = srv.submit(x)
        # bounded queue still applies while a batch is in flight
        with pytest.raises(QueueFullError):
            for _ in range(8):
                srv.submit(x)
        gate.set()
        with pytest.raises(ValueError, match="replay boom"):
            f1.result(timeout=10.0)
        # the failed batch didn't kill the pipeline: staged requests
        # complete and match the direct product
        for f in (f2, f3):
            assert np.allclose(f.result(timeout=10.0)[0], x @ x.T,
                               atol=1e-12)
        assert rt.stats.serving.rejected >= 1
    # shutdown joined both stages
    assert not [t for t in threading.enumerate()
                if t.name.startswith("repro-serving") and t.is_alive()]


def test_serving_depth1_keeps_inline_dispatch(rng, monkeypatch):
    monkeypatch.setenv("REPRO_PIPELINE_DEPTH", "1")
    script, rt = _score_script(rng)
    with ModelServer(script, max_batch=2, max_wait_us=50.0) as srv:
        assert not srv._pipelined
        assert srv._worker is None
        x = rng.normal(size=(4, 4))
        assert np.allclose(srv.score(x)[0], x @ x.T, atol=1e-12)
    assert rt.stats.pipeline.rebatches == 0
