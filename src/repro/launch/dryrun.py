import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks
# the device count on first init), so this module has no
# `from __future__ import annotations` and uses py3.9+ builtin generics.

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell:
  * build the model, ShapeDtypeStruct input specs, shardings,
  * jax.jit(step).lower(...).compile() on the production mesh,
  * print compiled.memory_analysis() (proves it fits 16 GB/chip) and
    cost_analysis(),
  * run the hlocost analyzer for trip-count-corrected FLOPs/bytes and
    collective wire bytes,
  * emit a RooflineCell JSON record (read by EXPERIMENTS.md §Roofline).

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ASSIGNED, get_config
from repro.distributed import sharding as shard_mod
from repro.launch import hlocost, roofline
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.shapes import SHAPES, cell_supported, input_specs
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step, train_state_shapes)
from repro.models import build_model


def lower_cell(arch: str, shape_name: str, mesh, *, verbose: bool = True,
               cfg_override=None, hints: bool = False):
    """Returns (lowered, compiled, cell) for one (arch, shape, mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.hints import disable_hints, enable_hints
    if hints:
        enable_hints(mesh)
    else:
        disable_hints()
    cfg = cfg_override or get_config(arch)
    model = build_model(cfg)
    specs = input_specs(cfg, shape_name, model)
    dax = data_axes(mesh)
    n_dev = mesh.devices.size

    def ns(spec_tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))

    t0 = time.time()
    if specs["kind"] == "train":
        params_s, opt_s = train_state_shapes(model)
        p_specs = shard_mod.param_specs(params_s, mesh)
        o_specs = shard_mod.param_specs(opt_s, mesh)
        b_specs = shard_mod.batch_specs(specs["batch"], mesh, dax)
        step = make_train_step(model)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(ns(p_specs), ns(o_specs), ns(b_specs)),
            ).lower(params_s, opt_s, specs["batch"])
    elif specs["kind"] == "prefill":
        params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_specs = shard_mod.param_specs(params_s, mesh)
        tok_spec = shard_mod.batch_specs({"t": specs["tokens"]}, mesh,
                                         dax)["t"]
        step = make_prefill_step(model, max_len=specs["S"])
        args = [params_s, specs["tokens"]]
        in_sh = [ns(p_specs), ns(tok_spec)]
        if "image_embeds" in specs:
            img_spec = shard_mod.batch_specs(
                {"i": specs["image_embeds"]}, mesh, dax)["i"]
            args.append(specs["image_embeds"])
            in_sh.append(ns(img_spec))
        # output caches MUST be sharded like the decode-step inputs —
        # unsharded KV outputs measured at 20 GiB/device (§Perf log)
        cache_sh = ns(shard_mod.cache_specs(
            model.cache_shapes(specs["B"], specs["S"]), mesh, specs["B"],
            dax))
        with mesh:
            lowered = jax.jit(step, in_shardings=tuple(in_sh),
                              out_shardings=(None, cache_sh)
                              ).lower(*args)
    else:  # decode
        params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_specs = shard_mod.param_specs(params_s, mesh)
        c_specs = shard_mod.cache_specs(specs["caches"], mesh, specs["B"],
                                        dax)
        tok_spec = shard_mod.batch_specs({"t": specs["token"]}, mesh,
                                         dax)["t"]
        step = make_decode_step(model)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(ns(p_specs), ns(tok_spec), ns(c_specs), None),
            ).lower(params_s, specs["token"], specs["caches"],
                    specs["cur_len"])

    compiled = lowered.compile()
    dt = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    mine = hlocost.analyze(txt, n_devices=n_dev)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    mf, tokens = roofline.model_flops_per_device(
        cfg, specs["kind"], specs["B"], specs["S"], n_dev)
    cell = roofline.RooflineCell(
        arch=cfg.name, shape=shape_name, mesh=mesh_name, n_devices=n_dev,
        kind=specs["kind"],
        hlo_flops=mine.flops, hlo_bytes=mine.bytes,
        coll_wire_bytes=mine.collective_wire_bytes,
        coll_raw_bytes=mine.collective_raw_bytes,
        per_collective=dict(mine.per_collective),
        by_group_size={str(k): v for k, v in mine.by_group_size.items()},
        unknown_trips=mine.unknown_trip_counts,
        xla_flops=float(ca.get("flops", 0.0)),
        xla_bytes=float(ca.get("bytes accessed", 0.0)),
        arg_bytes=ma.argument_size_in_bytes,
        out_bytes=ma.output_size_in_bytes,
        temp_bytes=ma.temp_size_in_bytes,
        model_flops=mf, tokens=tokens, compile_seconds=dt)
    if verbose:
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}"
              f"GiB out={ma.output_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB per device")
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e} (body-once)")
        print(f"  hlocost: flops={mine.flops:.3e} bytes={mine.bytes:.3e} "
              f"coll_wire={mine.collective_wire_bytes:.3e} "
              f"unknown_trips={mine.unknown_trip_counts}")
        print(f"  roofline: t_comp={cell.t_compute*1e3:.3f}ms "
              f"t_mem={cell.t_memory*1e3:.3f}ms "
              f"t_coll={cell.t_collective*1e3:.3f}ms "
              f"-> {cell.bottleneck}-bound "
              f"(MODEL/HLO={cell.flops_ratio:.3f}, "
              f"roofline={cell.roofline_fraction*100:.1f}%)  "
              f"[compile {dt:.1f}s]")
    return lowered, compiled, cell


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None, help="JSON results path")
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--hints", action="store_true",
                    help="enable sharding hints (optimized, non-baseline)")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    cells, failures, skips = [], [], []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "x".join(str(s) for s in mesh.devices.shape)
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                ok, why = cell_supported(cfg, shape_name)
                tag = f"{cfg.name} × {shape_name} × {mesh_name}"
                if not ok:
                    print(f"SKIP {tag}: {why}")
                    skips.append({"cell": tag, "reason": why})
                    continue
                print(f"DRYRUN {tag}")
                try:
                    _, _, cell = lower_cell(arch, shape_name, mesh,
                                            hints=args.hints)
                    if args.hints:
                        cell.mesh += "+hints"
                    cells.append(cell)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append({"cell": tag, "error": repr(e)[:500]})

    print()
    print(roofline.format_table(cells))
    print(f"\n{len(cells)} compiled, {len(skips)} skipped (documented), "
          f"{len(failures)} FAILED")
    for f in failures:
        print("  FAIL:", f["cell"], f["error"][:160])
    if args.out:
        existing = []
        if args.append and os.path.exists(args.out):
            existing = json.load(open(args.out))["cells"]
        with open(args.out, "w") as f:
            json.dump({"cells": existing + [c.to_dict() for c in cells],
                       "skips": skips, "failures": failures}, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
