"""ModelConfig: one dataclass describing every supported architecture.

Families:
  dense  — llama-style GQA transformer (llama3.2, phi3, qwen3)
  moe    — fine-grained MoE with shared experts (deepseek-moe/v2; v2 = MLA)
  ssm    — attention-free RWKV-6 (Finch)
  hybrid — jamba: mamba+attention 1:7 interleave, MoE every other layer
  audio  — musicgen: decoder-only over EnCodec tokens (4 codebooks, stub
           frontend)
  vlm    — llama-3.2-vision: self-attn layers + cross-attn image layers
           (stub vision encoder; precomputed patch embeddings)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    n_kv_heads: int = 0             # 0 -> = n_heads (MHA)
    d_head: int = 0                 # 0 -> d_model // n_heads

    # attention flavour
    attn_type: str = "gqa"          # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 500000.0

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0             # 0 -> d_head

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0               # fine-grained expert hidden size
    moe_layer_freq: int = 1         # every k-th layer is MoE
    first_dense_layers: int = 0
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_type: str = ""              # rwkv6 | mamba
    attn_layer_period: int = 0      # jamba: one attn layer per period
    d_state: int = 16
    expand: int = 2
    conv_kernel: int = 4
    rwkv_head_dim: int = 64

    # multimodal
    cross_attn_period: int = 0      # vlm: 1 cross-attn layer per period
    n_image_tokens: int = 1024      # stub frontend sequence length
    n_codebooks: int = 0            # musicgen

    # compute / distribution knobs
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    loss_chunk: int = 512           # chunked cross-entropy block
    attn_chunk: int = 1024          # kv-block size for chunked attention
    rwkv_chunk: int = 128
    use_pallas: bool = False        # TPU kernels (CPU container: off)
    fsdp_embed: bool = True

    # -- derived -------------------------------------------------------------
    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def vdim(self) -> int:
        return self.v_head_dim or self.head_dim

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def period(self) -> int:
        """Length of the repeating heterogeneous super-block."""
        if self.family == "hybrid":
            return self.attn_layer_period
        if self.family == "vlm":
            return self.cross_attn_period
        return 1

    def layer_kinds(self) -> list[str]:
        """Layer kinds within one period (scan unit)."""
        if self.family == "ssm":
            return ["rwkv6"]
        if self.family == "hybrid":
            # jamba period of 8: attn at index 4, mamba elsewhere;
            # MoE replaces the MLP on every second layer (odd indices)
            kinds = []
            for i in range(self.attn_layer_period):
                base = "attn" if i == self.attn_layer_period // 2 else "mamba"
                moe = "+moe" if (i % 2 == 1) and self.n_experts else ""
                kinds.append(base + moe)
            return kinds
        if self.family == "vlm":
            return ["attn"] * (self.cross_attn_period - 1) + ["xattn"]
        if self.family == "moe":
            return ["attn+moe"]
        return ["attn"]  # dense / audio

    def n_periods(self) -> int:
        assert self.n_scanned() % self.period == 0, \
            (self.name, self.n_layers, self.period)
        return self.n_scanned() // self.period

    def n_scanned(self) -> int:
        return self.n_layers - self.first_dense_layers

    # -- parameter counts (for roofline MODEL_FLOPS) --------------------------
    def param_counts(self) -> dict[str, int]:
        d, hd, vd = self.d_model, self.head_dim, self.vdim
        nh, nkv = self.n_heads, self.kv_heads
        counts: dict[str, int] = {}
        counts["embed"] = self.vocab_size * d * (
            self.n_codebooks or 1)
        counts["head"] = d * self.vocab_size * (self.n_codebooks or 1)
        attn = 0
        if self.attn_type == "mla":
            q_in = self.q_lora_rank or d
            attn += (d * self.q_lora_rank if self.q_lora_rank else 0)
            attn += q_in * nh * (hd + self.rope_head_dim)
            attn += d * (self.kv_lora_rank + self.rope_head_dim)
            attn += self.kv_lora_rank * nh * (hd + vd)
            attn += nh * vd * d
        else:
            attn += d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        mlp_dense = 3 * d * self.d_ff
        moe = 0
        if self.n_experts:
            de = self.d_expert or self.d_ff
            moe = self.n_experts * 3 * d * de \
                + self.n_shared_experts * 3 * d * de + d * self.n_experts
        mamba = 0
        if self.ssm_type == "mamba" or self.family == "hybrid":
            di, ds = self.d_inner, self.d_state
            mamba = (d * 2 * di + di * self.conv_kernel
                     + di * (2 * ds + 1) + di  # x_proj(B,C,dt) + dt rank 1
                     + di * d + di * ds)       # out proj + A
        rwkv = 0
        if self.ssm_type == "rwkv6":
            # time-mix (r,k,v,w,g + lora for w) + channel-mix
            rwkv = d * d * 5 + d * 64 * 2 + 2 * d * self.d_ff
        counts["attn_per_layer"] = attn
        counts["mlp_per_layer"] = mlp_dense
        counts["moe_per_layer"] = moe
        counts["mamba_per_layer"] = mamba
        counts["rwkv_per_layer"] = rwkv
        return counts

    def total_params(self) -> int:
        c = self.param_counts()
        kinds = self.layer_kinds() * self.n_periods()
        kinds = ["attn+mlp_first"] * self.first_dense_layers + kinds
        total = c["embed"] + c["head"]
        for k in kinds:
            if "rwkv" in k:
                total += c["rwkv_per_layer"]
                continue
            if "mamba" in k:
                total += c["mamba_per_layer"]
            if "attn" in k or "xattn" in k:
                total += c["attn_per_layer"]
            if "moe" in k and "mlp_first" not in k:
                total += c["moe_per_layer"]
            else:
                total += c["mlp_per_layer"]
        return total

    def active_params(self) -> int:
        """Activated params per token (MoE top-k instead of all experts)."""
        c = self.param_counts()
        if not self.n_experts:
            return self.total_params()
        de = self.d_expert or self.d_ff
        active_moe = (self.moe_top_k + self.n_shared_experts) * 3 * self.d_model * de \
            + self.d_model * self.n_experts
        kinds = self.layer_kinds() * self.n_periods()
        kinds = ["attn+mlp_first"] * self.first_dense_layers + kinds
        total = c["embed"] + c["head"]
        for k in kinds:
            if "mamba" in k:
                total += c["mamba_per_layer"]
            if "attn" in k or "xattn" in k:
                total += c["attn_per_layer"]
            if "moe" in k and "mlp_first" not in k:
                total += active_moe
            else:
                total += c["mlp_per_layer"]
        return total

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=max(self.period * (2 if self.first_dense_layers else 1),
                         2 * self.period) + self.first_dense_layers,
            d_model=128, n_heads=4, d_ff=256, vocab_size=512,
            n_kv_heads=min(self.kv_heads, 2) if self.n_kv_heads else 0,
            d_head=32, loss_chunk=64, attn_chunk=64, rwkv_chunk=16,
            rope_head_dim=16, v_head_dim=32 if self.v_head_dim else 0,
            scan_layers=True, dtype="float32")
        if self.attn_type == "mla":
            kw.update(kv_lora_rank=64, q_lora_rank=96)
        if self.n_experts:
            kw.update(n_experts=8, moe_top_k=min(self.moe_top_k, 2),
                      d_expert=64 if self.d_expert else 0,
                      n_shared_experts=min(self.n_shared_experts, 1))
        if self.family == "hybrid":
            kw.update(n_experts=4, moe_top_k=2, d_state=8, expand=2)
        if self.ssm_type == "rwkv6":
            kw.update(rwkv_head_dim=32)
        if self.first_dense_layers:
            kw.update(first_dense_layers=1)
        return self.with_(**kw)
