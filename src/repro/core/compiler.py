"""Plan compiler: HOP DAG -> ordered runtime instructions (SystemDS §3.2).

Mirrors SystemDS's compilation chain at our scale: rewrites + size
propagation happen on the DAG (shapes/sparsity are attached at
construction), memory estimates pick an execution target per instruction
(local vs distributed — the analogue of CP vs Spark instructions; plans
over `federated_input` leaves additionally get `federated`-target
`fed_*` instructions from the placement pass, see `lower_federated`),
and the result is a topologically ordered instruction sequence executed
by `repro.core.runtime.LineageRuntime`.

Three compile-time physical decisions ride on the propagated estimates:

  * format assignment (`assign_formats` / `Plan.formats_for`) — every
    value is pinned to `dense` or `bcoo` from its sparsity estimate, so
    kernel variants are selected at build time and sparse plans fuse;
  * probe-point selection (`Instruction.probe`) — only intermediates
    whose estimated cost clears the reuse cache's worth-keeping
    threshold become lineage-reuse probe points; segments stay maximal
    between probes instead of degenerating to one op per segment;
  * placement assignment (`lower_federated`) — placement propagates from
    federated input leaves; eligible patterns (gram, xtv, mv, vm,
    colSums/colMeans, row-preserving elementwise/structural ops) lower
    to `fed_*` instructions when the exchange-aware cost model says
    federation beats collecting, with explicit `collect` boundaries
    otherwise;
  * mesh placement (`lower_distributed`) — large row-shardable dense
    leaves propagate `placement='sharded'` over the device mesh's
    `data` axis; partial reductions lower to per-shard compute + psum
    (`shard_gram`, `shard_xtv`, ...) and row-preserving ops stay inside
    `shard_map`-lowered segments, with cost-gated `reshard`
    (all-gather) boundaries everywhere else;
  * chunked placement (`lower_chunked`) — row-partitionable reductions
    over leaves exceeding `costmodel.CHUNK_MEM_BUDGET` lower to
    streaming partial aggregates (`chunk_gram`, `chunk_xtv`,
    `chunk_colsums`, `chunk_sum`) closed by an explicit `combine`
    boundary; the row-preserving prefix (the same op class `fed_map`
    identifies) keeps `placement='chunked'` and fuses into the
    per-chunk jit segment the runtime streams row buckets through.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from dataclasses import replace as _dc_replace
from typing import Optional

from . import costmodel
from .dag import (ELEMENTWISE_BINARY, ELEMENTWISE_UNARY, SPARSE_THRESHOLD,
                  LTensor, Node, make_node)
from .rewrites import run_rewrites

# Default per-operation local memory budget: inputs+output of an op above
# this threshold are flagged for the distributed backend (pjit over the
# mesh) when one is attached. 2 GB mirrors a driver-heap style budget.
LOCAL_MEM_BUDGET = 2 << 30


@dataclass
class Instruction:
    node: Node
    out_id: int
    input_ids: tuple[int, ...]
    target: str  # 'local' | 'distributed' | 'federated' | 'chunked'
    last_use_of: tuple[int, ...] = ()  # uids freed after this instruction
    probe: bool = False   # lineage-reuse probe point (cost-gated)
    est_cost_s: float = 0.0  # compile-time cost estimate behind `probe`


@dataclass
class Plan:
    instructions: list[Instruction]
    output_ids: list[int]
    roots: list[Node]
    est_bytes_peak: int = 0
    reuse_enabled: bool = False
    # the device mesh the plan was compiled against (a
    # `repro.distributed.mesh.MeshSpec`, or None for local-only plans);
    # the runtime resolves it to a concrete jax Mesh lazily and falls
    # back to local-equivalent execution when devices are missing
    mesh_spec: Optional[object] = None
    # streaming metadata from `lower_chunked`: value uid -> total row
    # count, for every input the streaming executor row-slices per
    # chunk (chunked leaves plus row-aligned operands entering chunked
    # segments); empty for non-chunked plans
    chunk_sliced: dict = field(default_factory=dict)
    # segmentation memo: {reuse_active: [Segment, ...]}
    _segments: dict = field(default_factory=dict, repr=False)
    # format-assignment memo: {sparse_enabled: {uid: fmt}}
    _formats: dict = field(default_factory=dict, repr=False)

    def count_ops(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for ins in self.instructions:
            out[ins.node.op] = out.get(ins.node.op, 0) + 1
        return out

    def segments_for(self, reuse_active: bool):
        """Fusable segments of this plan (lazily computed, memoized).

        With an active reuse cache, cost-gated probe points
        (`Instruction.probe`) force segment boundaries so those
        intermediates stay observable; everything between probes still
        fuses. See `repro.core.segments`.
        """
        reuse_active = bool(reuse_active)
        got = self._segments.get(reuse_active)
        if got is None:
            from .segments import segment_plan
            got = segment_plan(self, reuse_active=reuse_active)
            self._segments[reuse_active] = got
        return got

    def formats_for(self, sparse: bool) -> dict[int, str]:
        """Compile-time physical format per value uid (lazily memoized).

        Only non-dense assignments are recorded — an all-dense plan maps
        to `{}` whether or not `sparse` is set, so identical plans share
        jit executables across `sparse_inputs` modes. Callers read
        `formats.get(uid, backend.DENSE)`.
        """
        sparse = bool(sparse)
        got = self._formats.get(sparse)
        if got is None:
            got = assign_formats(self, sparse)
            self._formats[sparse] = got
        return got

    def _ins_line(self, ins: Instruction, reuse_active: bool = False,
                  fmts: Optional[dict] = None) -> str:
        fmts = fmts or {}

        def ref(uid: int, node: Optional[Node] = None) -> str:
            if node is not None and node.placement == "federated":
                return f"%{uid}:fed"  # value lives row-partitioned on sites
            if node is not None and node.placement == "sharded":
                return f"%{uid}:sh"  # value lives row-sharded on the mesh
            if node is not None and node.placement == "chunked":
                return f"%{uid}:chunk"  # streamed one row bucket at a time
            f = fmts.get(uid, "dense")
            return f"%{uid}" if f == "dense" else f"%{uid}:{f}"

        args = ",".join(ref(u, nd)
                        for u, nd in zip(ins.input_ids, ins.node.inputs))
        attrs = {k: v for k, v in ins.node.attrs
                 if k not in ("index", "iattrs", "sin")}
        fmt = fmts.get(ins.out_id, "dense")
        tags = f" fmt={fmt}" if fmt != "dense" else ""
        if ins.node.placement == "federated":
            tags += " fed"
        if ins.node.placement == "sharded":
            tags += " sharded"
        if ins.node.placement == "chunked":
            tags += " chunked"
        if ins.node.op == "collect":
            tags += " [collect-boundary]"
        if ins.node.op == "reshard":
            tags += " [reshard-boundary]"
        if ins.node.op == "combine":
            tags += " [combine-boundary]"
        if reuse_active and ins.probe:
            tags += " [reuse-probe]"
        return (f"%{ins.out_id} = [{ins.target[0].upper()}] "
                f"{ins.node.op}({args}) {ins.node.shape} "
                f"sp={ins.node.sparsity:.3f}{tags} "
                f"{attrs if attrs else ''}").rstrip()

    def explain(self, segments: bool = True,
                reuse_active: Optional[bool] = None,
                sparse: bool = False) -> str:
        """EXPLAIN-style plan dump (SystemDS -explain) with segment
        annotations showing how instructions fuse into jit executables,
        the physical format assigned to each value (`fmt=bcoo`), and
        which instructions are cost-gated reuse-probe boundaries.

        `reuse_active` defaults to the flag the plan was compiled with;
        pass the executing runtime's actual cache state (cache is not
        None) to see the segmentation that run will use. `sparse`
        mirrors `LineageRuntime(sparse_inputs=...)`.
        """
        if reuse_active is None:
            reuse_active = self.reuse_enabled
        fmts = self.formats_for(sparse)
        lines = []
        if segments and self.instructions:
            for seg in self.segments_for(reuse_active):
                outs = ",".join(f"%{u}" for u in seg.output_uids)
                kind = "fused" if len(seg.instructions) > 1 else "single"
                if getattr(seg, "sharded", False):
                    kind += " [sharded]"
                if getattr(seg, "chunked", False):
                    kind += " [chunked]"
                lines.append(
                    f"-- segment {seg.index} [{seg.target}] {kind} "
                    f"{len(seg.instructions)} op(s) key={seg.key[:10]} "
                    f"-> {outs}")
                lines.extend(f"  {self._ins_line(ins, reuse_active, fmts)}"
                             for ins in seg.instructions)
        else:
            lines.extend(self._ins_line(ins, reuse_active, fmts)
                         for ins in self.instructions)
        lines.append("outputs: " + ", ".join(f"%{i}" for i in self.output_ids))
        return "\n".join(lines)


def assign_formats(plan: "Plan", sparse: bool) -> dict[int, str]:
    """Format-assignment pass: pin every value to `dense` or `bcoo`.

    A forward walk over the instruction stream using the sparsity
    estimates propagated on the DAG (SystemDS §3.2 size propagation):
    input leaves below the shared density threshold start as BCOO, and
    `backend.infer_format` decides per op whether the sparse structure
    survives (transpose, zero-preserving unaries, scalar scaling) or the
    value densifies (everything else). The executor selects kernel
    variants from this mapping at build time — no runtime `is_sparse`
    branches — which is what lets sparse plans run fused.
    """
    from . import backend
    fmt: dict[int, str] = {}
    if not sparse or not backend.HAS_SPARSE:
        return fmt  # empty mapping ≡ all dense
    seen_leaves: set[int] = set()
    for ins in plan.instructions:
        for inp in ins.node.inputs:
            if inp.op == "input" and inp.uid not in seen_leaves:
                seen_leaves.add(inp.uid)
                lf = backend.leaf_format(inp)
                if lf != backend.DENSE:
                    fmt[inp.uid] = lf
        in_fmts = tuple(fmt.get(u, backend.DENSE) for u in ins.input_ids)
        of = backend.infer_format(ins.node, in_fmts)
        if of != backend.DENSE:
            fmt[ins.out_id] = of
    return fmt


# ---------------------------------------------------------------------------
# Placement assignment (SystemDS §3.3/§4.3): federated as a compiler
# placement alongside local | distributed
# ---------------------------------------------------------------------------

# Elementwise / structural HOPs whose output keeps the row partitioning
# of their federated operand(s): they lower to `fed_map` (per-site
# execution, no aggregate exchange). `slice` qualifies only for full-row
# column slices; `cbind` only along axis 1 with row-aligned operands.
_FED_MAP_OPS = (ELEMENTWISE_BINARY | ELEMENTWISE_UNARY
                | {"replace_nan", "where", "slice", "cbind"})


def _site_count(n: Node, nsites: dict[int, int]) -> int:
    return int(nsites.get(n.uid, n.attr("n_sites", 1) or 1))


def lower_federated(roots: list[Node]) -> list[Node]:
    """Placement-assignment pass: propagate `placement='federated'` from
    federated input leaves over the DAG and lower eligible patterns into
    `fed_*` instructions; insert explicit, cost-modeled `collect`
    boundaries everywhere else.

    Runs after the algebraic rewrites — so `t(X) @ X` over a federated X
    has already been fused to `gram(X)` and lowers to `fed_gram`, the
    paper's Example 2 (fed instructions are *generated by the
    optimizer*, never hand-written). Each candidate lowering is gated by
    the cost model: the federated form (per-site compute + aggregate
    exchange) must beat collecting the operand and running locally
    (`costmodel.fed_cost_s` vs `costmodel.collect_cost_s`), so placement
    decisions are cost-based, not syntactic. A `collect` inserted for
    one consumer is shared by all of them.
    """
    # fast path: no federated leaves anywhere -> nothing to do
    seen: set[int] = set()
    stack = list(roots)
    has_fed = False
    while stack and not has_fed:
        n = stack.pop()
        if n.uid in seen:
            continue
        seen.add(n.uid)
        has_fed = n.placement == "federated"
        stack.extend(n.inputs)
    if not has_fed:
        return roots

    memo: dict[int, Node] = {}
    nsites: dict[int, int] = {}     # uid of federated value -> site count
    collected: dict[int, Node] = {}  # shared collect boundaries

    def is_fed(x: Node) -> bool:
        return x.placement == "federated"

    def collect_of(x: Node) -> Node:
        got = collected.get(x.uid)
        if got is None:
            got = make_node("collect", (x,), x.shape, x.dtype, x.sparsity,
                            n_sites=_site_count(x, nsites))
            collected[x.uid] = got
        return got

    def shared_sites(fed_inputs: list[Node]) -> Optional[int]:
        counts = {_site_count(x, nsites) for x in fed_inputs}
        return counts.pop() if len(counts) == 1 else None

    def try_lower(n: Node, ins: tuple[Node, ...]
                  ) -> Optional[tuple[Node, Node]]:
        """Return (replacement node, fed core used for the cost gate),
        or None when no federated lowering exists for this pattern."""
        op = n.op
        feds = [x for x in ins if is_fed(x)]
        sites = shared_sites(feds)
        if sites is None:  # partitionings disagree -> no joint lowering
            return None
        if op == "gram" and is_fed(ins[0]):
            core = make_node("fed_gram", ins, n.shape, n.dtype, n.sparsity,
                             n_sites=sites)
            return core, core
        if op == "xtv":
            fed_args = tuple(i for i, x in enumerate(ins) if is_fed(x))
            # v^T X (the vm pattern) when only the second operand is
            # federated; X^T v (xtv) otherwise — one runtime executor,
            # two instruction names so EXPLAIN reads like the paper's
            fed_op = "fed_vm" if fed_args == (1,) else "fed_xtv"
            core = make_node(fed_op, ins, n.shape, n.dtype, n.sparsity,
                             n_sites=sites, fed_args=fed_args)
            return core, core
        if op == "matmul" and is_fed(ins[0]) and not is_fed(ins[1]):
            core = make_node("fed_mv", ins, n.shape, n.dtype, n.sparsity,
                             n_sites=sites)
            return core, core
        if op in ("colSums", "colMeans") and is_fed(ins[0]):
            cs = make_node("fed_colsums", ins, (1, n.shape[-1]), n.dtype,
                           1.0, n_sites=sites)
            if op == "colSums":
                return cs, cs
            inv_m = make_node("literal", (), (), n.dtype, 1.0,
                              value=1.0 / ins[0].shape[0])
            return (make_node("mul", (cs, inv_m), n.shape, n.dtype, 1.0),
                    cs)
        if op in _FED_MAP_OPS:
            return _lower_fed_map(n, ins, sites)
        return None

    def _lower_fed_map(n: Node, ins: tuple[Node, ...], sites: int
                       ) -> Optional[tuple[Node, Node]]:
        m = next(x for x in ins if is_fed(x)).shape[0]
        if len(n.shape) != 2 or n.shape[0] != m:
            return None  # output must keep the row partitioning
        if n.op == "slice":
            idx = n.attr("index")
            if not idx or idx[0] != (0, m, 0):
                return None  # only full-row column slices stay federated
        if n.op == "cbind" and n.attr("axis") != 1:
            return None
        new_inputs: list[Node] = []
        fed_args: list[int] = []
        gen_args: list[tuple[int, float, int, str]] = []
        for pos, x in enumerate(ins):
            if is_fed(x):
                fed_args.append(pos)
                new_inputs.append(x)
            elif x.op == "full" and len(x.shape) == 2 and x.shape[0] == m:
                # row-aligned generator: produced per-site, never sent
                # (matches the eager intercept idiom of appending a ones
                # column at each site); dtype travels along so an f32
                # plan is not silently promoted by an f64 default
                gen_args.append((pos, float(x.attr("value")), x.shape[1],
                                 str(x.dtype)))
            elif x.shape == () or (len(x.shape) == 2
                                   and x.shape[0] in (1, m)):
                new_inputs.append(x)  # scalar / broadcast row / aligned
            else:
                return None
        iattrs = tuple(kv for kv in n.attrs)
        core = make_node("fed_map", tuple(new_inputs), n.shape, n.dtype,
                         n.sparsity, placement="federated", inner=n.op,
                         iattrs=iattrs, n_args=len(ins),
                         n_sites=sites, fed_args=tuple(fed_args),
                         gen_args=tuple(gen_args))
        return core, core

    def rec(n: Node) -> Node:
        got = memo.get(n.uid)
        if got is not None:
            return got
        if not n.inputs:
            if is_fed(n):
                nsites[n.uid] = _site_count(n, nsites)
            memo[n.uid] = n
            return n
        ins = tuple(rec(i) for i in n.inputs)
        fed_inputs = [x for x in ins if is_fed(x)]
        if not fed_inputs:
            if all(a is b for a, b in zip(ins, n.inputs)):
                out = n
            else:
                out = Node(op=n.op, inputs=ins, attrs=n.attrs, shape=n.shape,
                           dtype=n.dtype, sparsity=n.sparsity)
            memo[n.uid] = out
            return out
        cand = try_lower(n, ins)
        if cand is not None:
            out, core = cand
            # cost gate: federated execution vs collect-then-local
            collect_s = sum(
                0.0 if x.uid in collected else
                costmodel.collect_cost_s(x, _site_count(x, nsites))
                for x in fed_inputs) + costmodel.est_cost_s(n)
            if costmodel.est_cost_s(core) <= collect_s:
                if is_fed(out):
                    nsites[out.uid] = _site_count(core, nsites)
                memo[n.uid] = out
                return out
        # fallback: explicit collect boundary, then the op runs locally
        loc = tuple(collect_of(x) if is_fed(x) else x for x in ins)
        out = Node(op=n.op, inputs=loc, attrs=n.attrs, shape=n.shape,
                   dtype=n.dtype, sparsity=n.sparsity)
        memo[n.uid] = out
        return out

    new_roots = [rec(r) for r in roots]
    # plan outputs must be local: materialize federated roots
    return [collect_of(r) if is_fed(r) else r for r in new_roots]


# ---------------------------------------------------------------------------
# Sharded placement (SystemDS's distributed/Spark lane, here a device
# mesh): row-shard large dense leaves over the mesh's `data` axis and
# lower eligible patterns to shard_map-executed instructions
# ---------------------------------------------------------------------------

# Row-preserving HOPs that stay sharded under shard_map: the same op
# class `fed_map` computes, plus row aggregates (each shard owns whole
# rows, so rowSums needs no collective). `rbind` is excluded — per-shard
# concatenation would interleave the global row order.
_SHARD_MAP_OPS = (_FED_MAP_OPS | {"rowSums", "rowMeans"})

# name of the mesh's row axis; mirrors repro.distributed.mesh.DATA_AXIS
# (kept literal so the compiler does not import jax-touching modules)
_DATA_AXIS = "data"


def lower_distributed(roots: list[Node], d: int) -> list[Node]:
    """Placement-assignment pass for the device mesh: propagate
    `placement='sharded'` from large row-shardable dense input leaves
    over the DAG and lower eligible patterns to shard-exec instructions;
    insert explicit, cost-modeled `reshard` boundaries everywhere else.

    Mirrors `lower_federated` — the mesh's `data` axis plays the role of
    the federation's sites. Partial-reduction ops (gram, xtv, colSums,
    sum) lower to per-shard compute + `psum` (`shard_gram` etc.);
    row-preserving ops (`_SHARD_MAP_OPS`, matmul with a replicated rhs,
    row aggregates) keep the sharded placement and execute inside
    `shard_map` with per-input specs recorded in the `sin` attr ('s' =
    split on the data axis, 'r' = replicated). Each lowering is gated by
    the cost model: the sharded form (per-shard roofline + collective
    bytes over ICI) must beat resharding the operands and running
    locally (`costmodel.shard_cost_s` vs `costmodel.reshard_cost_s`).
    A `reshard` (all-gather back to a replicated value) inserted for one
    consumer is shared by all of them. Runs after `lower_federated`;
    federated subgraphs are left untouched (their local `collect`
    outputs may still feed sharded consumers as replicated operands).
    """
    from . import backend
    from repro.distributed.sharding import rows_shardable

    def leaf_shardable(n: Node) -> bool:
        return (n.op == "input" and n.placement == "local"
                and n.attr("batch") is None and len(n.shape) == 2
                and rows_shardable(n.shape, d)
                and backend.leaf_format(n) == backend.DENSE
                and costmodel._dense_bytes(n)
                >= costmodel.SHARD_MIN_LEAF_BYTES)

    # fast path: no shardable leaves anywhere -> nothing to do
    seen: set[int] = set()
    stack = list(roots)
    any_cand = False
    while stack and not any_cand:
        n = stack.pop()
        if n.uid in seen:
            continue
        seen.add(n.uid)
        any_cand = leaf_shardable(n)
        stack.extend(n.inputs)
    if not any_cand:
        return roots

    memo: dict[int, Node] = {}
    resharded: dict[int, Node] = {}  # shared reshard boundaries
    varmemo: dict[int, bool] = {}    # uid -> depends on a batched leaf

    def is_sh(x: Node) -> bool:
        return x.placement == "sharded"

    def is_var(n: Node) -> bool:
        got = varmemo.get(n.uid)
        if got is None:
            from .dag import is_batched_leaf
            got = is_batched_leaf(n) or any(is_var(i) for i in n.inputs)
            varmemo[n.uid] = got
        return got

    def maybe_bcoo(x: Node) -> bool:
        # conservatively refuse operands the format pass could pin to
        # BCOO — shard_map specs assume dense global arrays
        return (backend.HAS_SPARSE and len(x.shape) == 2
                and x.sparsity < SPARSE_THRESHOLD
                and x.numel >= backend.SPARSE_MIN_NUMEL)

    def reshard_of(x: Node) -> Node:
        got = resharded.get(x.uid)
        if got is None:
            got = make_node("reshard", (x,), x.shape, x.dtype, x.sparsity,
                            axis=_DATA_AXIS, n_dev=d, sin=("s",))
            resharded[x.uid] = got
        return got

    def classify(x: Node, m: int) -> Optional[str]:
        """shard_map in-spec tag for one operand of a row-preserving op:
        's' (split rows on the data axis) or 'r' (replicated)."""
        if is_sh(x):
            return "s" if x.shape[0] == m else None
        if x.shape == ():
            return "r"
        if len(x.shape) == 2 and x.shape[0] == 1:
            return "r"  # broadcast row, replicated on every shard
        if (len(x.shape) == 2 and x.shape[0] == m
                and x.shape[0] % d == 0 and not maybe_bcoo(x)):
            return "s"  # row-aligned local value: split by the in-spec
        return None

    def _lower_shard_map(n: Node, ins: tuple[Node, ...]
                         ) -> Optional[tuple[Node, Node]]:
        m = next(x for x in ins if is_sh(x)).shape[0]
        if len(n.shape) != 2 or n.shape[0] != m:
            return None  # output must keep the row partitioning
        if n.op == "slice":
            idx = n.attr("index")
            if not idx or idx[0] != (0, m, 0):
                return None  # only full-row column slices stay sharded
        if n.op == "cbind" and n.attr("axis") != 1:
            return None
        # note: non-scalar generators (`full` row columns etc.) keep
        # their local placement — segmentation puts them in a local
        # segment and the global array enters the sharded segment split
        # by its in-spec, so a shard_map body never builds a
        # global-shaped generator per shard
        sin = []
        for x in ins:
            tag = classify(x, m)
            if tag is None:
                return None
            sin.append(tag)
        extra = dict(n.attrs)
        extra.update(sin=tuple(sin), n_dev=d)
        core = make_node(n.op, ins, n.shape, n.dtype, n.sparsity,
                         placement="sharded", **extra)
        return core, core

    def try_lower(n: Node, ins: tuple[Node, ...]
                  ) -> Optional[tuple[Node, Node]]:
        """Return (replacement node, shard core used for the cost gate),
        or None when no sharded lowering exists for this pattern."""
        op = n.op
        if op == "gram" and is_sh(ins[0]):
            core = make_node("shard_gram", ins, n.shape, n.dtype,
                             n.sparsity, axis=_DATA_AXIS, n_dev=d,
                             sin=("s",))
            return core, core
        if op == "xtv":
            m = ins[0].shape[0]
            if all(classify(x, m) == "s" for x in ins):
                core = make_node("shard_xtv", ins, n.shape, n.dtype,
                                 n.sparsity, axis=_DATA_AXIS, n_dev=d,
                                 sin=("s", "s"))
                return core, core
            return None
        if (op == "matmul" and is_sh(ins[0]) and not is_sh(ins[1])
                and len(n.shape) == 2 and not maybe_bcoo(ins[1])):
            # (m,k) @ (k,p) with a replicated rhs is row-preserving
            core = make_node("matmul", ins, n.shape, n.dtype, n.sparsity,
                             placement="sharded", n_dev=d, sin=("s", "r"))
            return core, core
        if op in ("colSums", "colMeans") and is_sh(ins[0]):
            cs = make_node("shard_colsums", ins, (1, n.shape[-1]),
                           n.dtype, 1.0, axis=_DATA_AXIS, n_dev=d,
                           sin=("s",))
            if op == "colSums":
                return cs, cs
            inv_m = make_node("literal", (), (), n.dtype, 1.0,
                              value=1.0 / ins[0].shape[0])
            return (make_node("mul", (cs, inv_m), n.shape, n.dtype, 1.0),
                    cs)
        if op in ("sum", "mean") and is_sh(ins[0]):
            ss = make_node("shard_sum", ins, (), n.dtype, 1.0,
                           axis=_DATA_AXIS, n_dev=d, sin=("s",))
            if op == "sum":
                return ss, ss
            inv = make_node("literal", (), (), n.dtype, 1.0,
                            value=1.0 / max(1, ins[0].numel))
            return (make_node("mul", (ss, inv), n.shape, n.dtype, 1.0),
                    ss)
        if op in _SHARD_MAP_OPS:
            return _lower_shard_map(n, ins)
        return None

    def rec(n: Node) -> Node:
        got = memo.get(n.uid)
        if got is not None:
            return got
        if not n.inputs:
            if leaf_shardable(n):
                n = _dc_replace(n, placement="sharded")  # uid preserved:
                # the runtime's LEAVES binding is keyed by uid
            memo[n.uid] = n
            return n
        ins = tuple(rec(i) for i in n.inputs)
        sh_inputs = [x for x in ins if is_sh(x)]
        if not sh_inputs:
            if all(a is b for a, b in zip(ins, n.inputs)):
                out = n
            else:
                out = Node(op=n.op, inputs=ins, attrs=n.attrs,
                           shape=n.shape, dtype=n.dtype,
                           sparsity=n.sparsity)
            memo[n.uid] = out
            return out
        # config-variant nodes (downstream of a batched leaf) are the
        # `config` axis's business — keep data sharding to the invariant
        # prefix so a segment is never both vmapped and row-sharded
        cand = None if is_var(n) else try_lower(n, ins)
        if cand is not None:
            out, core = cand
            # cost gate: sharded execution vs reshard-then-local
            resh_s = sum(
                0.0 if x.uid in resharded else
                costmodel.reshard_cost_s(x, d)
                for x in sh_inputs) + costmodel.est_cost_s(n)
            if costmodel.est_cost_s(core) <= resh_s:
                memo[n.uid] = out
                return out
        # fallback: explicit reshard boundary, then the op runs locally
        loc = tuple(reshard_of(x) if is_sh(x) else x for x in ins)
        out = Node(op=n.op, inputs=loc, attrs=n.attrs, shape=n.shape,
                   dtype=n.dtype, sparsity=n.sparsity)
        memo[n.uid] = out
        return out

    new_roots = [rec(r) for r in roots]
    # plan outputs must be replicated/local: reshard sharded roots
    return [reshard_of(r) if is_sh(r) else r for r in new_roots]


# ---------------------------------------------------------------------------
# Chunked placement (out-of-core streaming, ROADMAP item 4): split
# row-partitionable reductions over budget-exceeding leaves into
# per-chunk partial aggregates with an explicit combine boundary
# ---------------------------------------------------------------------------

# Row-preserving HOPs that stay chunked (fuse into the per-chunk
# segment): exactly the op class `fed_map` identifies — each output row
# depends only on the matching input rows, so the op commutes with row
# chunking.
_CHUNK_MAP_OPS = _FED_MAP_OPS

# reduction op -> its streaming partial-aggregate instruction
_CHUNK_REDUCE_OPS = {
    "gram": "chunk_gram", "xtv": "chunk_xtv",
    "colSums": "chunk_colsums", "sum": "chunk_sum",
}


def lower_chunked(roots: list[Node]
                  ) -> tuple[list[Node], dict[int, int]]:
    """Placement-assignment pass for out-of-core streaming: when a
    row-partitionable reduction's leaves exceed `costmodel
    .CHUNK_MEM_BUDGET`, lower it to a per-chunk partial aggregate
    (`chunk_*`) closed by an explicit `combine` boundary, and mark the
    row-preserving prefix `placement='chunked'` so it fuses into the
    per-chunk jit segment the runtime streams row buckets through.

    Mirrors `lower_federated`/`lower_distributed` — chunks play the
    role of sites/shards, except they are *temporal* rather than
    spatial: one warm executable visits every row bucket in turn, so
    only partial aggregates (and one live chunk) are ever device-
    resident. The pass is dual-track: every node keeps its ordinary
    local form alongside an optional chunked form, and only a lowered
    reduction commits the chunked track into the plan — a consumer
    outside the row-decomposable class (`quantile`'s sort-based order
    statistics, row-shaped roots) simply keeps the local form, which is
    the materialization fallback. colMeans/mean lower through
    chunk_colsums/chunk_sum × 1/m, exactly like the fed/shard recipes,
    so zero rows in a ragged tail chunk can never skew a mean.

    Returns (new roots, sliced map): value uid -> total rows for every
    input the streaming executor must row-slice per chunk.
    """
    # fast path: no over-budget local leaves anywhere -> nothing to do
    seen: set[int] = set()
    stack = list(roots)
    any_cand = False
    while stack and not any_cand:
        n = stack.pop()
        if n.uid in seen:
            continue
        seen.add(n.uid)
        any_cand = costmodel.should_chunk(n)
        stack.extend(n.inputs)
    if not any_cand:
        return roots, {}

    # uid -> (local form, chunked form | None)
    memo: dict[int, tuple[Node, Optional[Node]]] = {}
    sliced: dict[int, int] = {}
    combined: dict[int, Node] = {}  # shared combine boundaries per core

    def is_chk(x: Optional[Node]) -> bool:
        # the chunked track is the non-None memo slot: an over-budget
        # leaf is its own chunked form (it keeps placement 'local' —
        # the uid keys its binding), interior forms carry
        # placement='chunked'
        return x is not None

    def combine_of(core: Node) -> Node:
        got = combined.get(core.uid)
        if got is None:
            got = make_node("combine", (core,), core.shape, core.dtype,
                            core.sparsity)
            combined[core.uid] = got
        return got

    def chunk_rows_of(x: Node) -> int:
        return sliced.get(x.uid, x.shape[0] if x.shape else 0)

    def chunk_operand(loc: Node, chk: Optional[Node], m: int
                      ) -> Optional[Node]:
        """Resolve one operand of a chunked op: the chunked form when it
        carries the same row partitioning, a row-sliced local value when
        row-aligned, a passthrough for scalars / broadcast rows —
        None when the operand cannot enter the per-chunk segment."""
        if is_chk(chk) and chk.shape and chk.shape[0] == m:
            # record the row count even for chunked forms: if the value
            # ends up crossing a streaming-scope boundary (consumed by a
            # later chunked segment through a local combine), the
            # runtime materializes it piecewise and re-slices it there
            sliced.setdefault(chk.uid, m)
            return chk
        if loc.shape == () or (len(loc.shape) == 2 and loc.shape[0] == 1):
            return loc  # scalar / broadcast row: replicated per chunk
        if (len(loc.shape) == 2 and loc.shape[0] == m) \
                or loc.shape == (m,):
            sliced[loc.uid] = m  # row-aligned: sliced per chunk
            return loc
        if len(loc.shape) == 1 and loc.shape[0] != m:
            return loc  # column-space vector, replicated
        return None

    def _lower_chunk_map(n: Node, pairs) -> Optional[Node]:
        m = next(chunk_rows_of(c) for _, c in pairs if is_chk(c))
        if len(n.shape) != 2 or n.shape[0] != m:
            return None  # output must keep the row partitioning
        if n.op == "slice":
            idx = n.attr("index")
            if not idx or idx[0] != (0, m, 0):
                return None  # only full-row column slices stay chunked
        if n.op == "cbind" and n.attr("axis") != 1:
            return None
        ops = [chunk_operand(loc, chk, m) for loc, chk in pairs]
        if any(o is None for o in ops):
            return None
        return make_node(n.op, tuple(ops), n.shape, n.dtype, n.sparsity,
                         placement="chunked", **dict(n.attrs))

    def try_lower(n: Node, pairs) -> Optional[Node]:
        """Return the local-valued replacement for a reduction over a
        chunked operand (combine of a streaming partial), or None."""
        op = n.op
        loc0, chk0 = pairs[0]
        if op in ("gram", "colSums", "colMeans", "sum", "mean") \
                and not is_chk(chk0):
            return None
        if op == "gram":
            core = make_node("chunk_gram", (chk0,), n.shape, n.dtype,
                             n.sparsity, placement="chunked")
            return combine_of(core)
        if op == "xtv":
            m = chunk_rows_of(chk0) if is_chk(chk0) else None
            if m is None:
                return None
            ops = [chunk_operand(loc, chk, m) for loc, chk in pairs]
            if any(o is None for o in ops):
                return None
            core = make_node("chunk_xtv", tuple(ops), n.shape, n.dtype,
                             n.sparsity, placement="chunked")
            return combine_of(core)
        if op == "matmul" and n.inputs[0].op == "t":
            # t(X) @ v with X on the chunked track: the unfused xtv
            # shape (fuse_tsmm declines 1-D v) streams identically —
            # X^T v = Σ_chunks X_i^T v_i
            xloc, xchk = memo.get(n.inputs[0].inputs[0].uid,
                                  (n.inputs[0].inputs[0], None))
            if not is_chk(xchk):
                return None
            m = chunk_rows_of(xchk)
            xop = chunk_operand(xloc, xchk, m)
            vop = chunk_operand(*pairs[1], m)
            if xop is None or vop is None:
                return None
            core = make_node("chunk_xtv", (xop, vop), n.shape, n.dtype,
                             n.sparsity, placement="chunked")
            return combine_of(core)
        if op in ("colSums", "colMeans"):
            cs = make_node("chunk_colsums", (chk0,), (1, n.shape[-1]),
                           n.dtype, 1.0, placement="chunked")
            comb = combine_of(cs)
            if op == "colSums":
                return comb
            inv_m = make_node("literal", (), (), n.dtype, 1.0,
                              value=1.0 / loc0.shape[0])
            return make_node("mul", (comb, inv_m), n.shape, n.dtype, 1.0)
        if op in ("sum", "mean"):
            ss = make_node("chunk_sum", (chk0,), (), n.dtype, 1.0,
                           placement="chunked")
            comb = combine_of(ss)
            if op == "sum":
                return comb
            inv = make_node("literal", (), (), n.dtype, 1.0,
                            value=1.0 / max(1, loc0.numel))
            return make_node("mul", (comb, inv), n.shape, n.dtype, 1.0)
        return None

    def rec(n: Node) -> tuple[Node, Optional[Node]]:
        got = memo.get(n.uid)
        if got is not None:
            return got
        if not n.inputs:
            chk = None
            if costmodel.should_chunk(n):
                chk = n  # leaf stays local-placed; uid keys its binding
                sliced[n.uid] = n.shape[0]
            memo[n.uid] = (n, chk)
            return memo[n.uid]
        pairs = [rec(i) for i in n.inputs]
        locs = tuple(p[0] for p in pairs)
        if all(a is b for a, b in zip(locs, n.inputs)):
            loc = n
        else:
            loc = Node(op=n.op, inputs=locs, attrs=n.attrs, shape=n.shape,
                       dtype=n.dtype, sparsity=n.sparsity)
        chk = None
        # the matmul(t(X), v) shape reaches its chunked operand through
        # the transpose, which carries no chunked track of its own
        through_t = (n.op == "matmul" and n.inputs[0].op == "t"
                     and is_chk(memo.get(
                         n.inputs[0].inputs[0].uid, (None, None))[1]))
        if any(is_chk(c) for _, c in pairs) or through_t:
            # streaming always beats materializing here: the reduction's
            # operand exceeds CHUNK_MEM_BUDGET by the leaf gate, so the
            # local form is exactly the blow-the-budget baseline
            lowered = try_lower(n, pairs)
            if lowered is not None:
                memo[n.uid] = (lowered, None)
                return memo[n.uid]
            if n.op in _CHUNK_MAP_OPS:
                chk = _lower_chunk_map(n, pairs)
                if chk is not None:
                    sliced_rows = next(chunk_rows_of(c)
                                       for _, c in pairs if is_chk(c))
                    sliced.setdefault(chk.uid, sliced_rows)
        memo[n.uid] = (loc, chk)
        return memo[n.uid]

    # roots must be local: the local track is the materialization
    # fallback for everything the reduction lowering did not commit
    new_roots = [rec(r)[0] for r in roots]
    live = {n.uid for n in topo_order(new_roots)}
    return new_roots, {u: m for u, m in sliced.items() if u in live}


def _chunk_exec(n: Node) -> bool:
    """True for instructions that execute on the streaming path."""
    return n.placement == "chunked" or n.op.startswith("chunk_")


def _cluster_chunked(order: list[Node]) -> list[Node]:
    """Dependency-preserving reorder that clusters chunked-target
    instructions into maximal runs, so one streaming pass computes every
    partial aggregate of a scope (lmDS's gram AND xtv) instead of
    re-reading the data per reduction. Plain Kahn scheduling with a
    two-level priority: stay in the current execution lane, break ties
    by original topological position — plans without chunked
    instructions never reach this (order is returned unchanged by the
    caller's gate), so existing segmentations are untouched.
    """
    import heapq
    pos = {n.uid: i for i, n in enumerate(order)}
    indeg = {n.uid: 0 for n in order}
    consumers: dict[int, list[Node]] = {n.uid: [] for n in order}
    for n in order:
        for i in n.inputs:
            if i.uid in pos:
                indeg[n.uid] += 1
                consumers[i.uid].append(n)
    heaps: dict[bool, list] = {True: [], False: []}
    for n in order:
        if indeg[n.uid] == 0:
            heapq.heappush(heaps[_chunk_exec(n)], (pos[n.uid], n))
    out: list[Node] = []
    lane = False
    while heaps[True] or heaps[False]:
        if not heaps[lane]:
            lane = not lane
        _, n = heapq.heappop(heaps[lane])
        out.append(n)
        for c in consumers[n.uid]:
            indeg[c.uid] -= 1
            if indeg[c.uid] == 0:
                heapq.heappush(heaps[_chunk_exec(c)], (pos[c.uid], c))
    return out


def topo_order(roots: list[Node]) -> list[Node]:
    seen: set[int] = set()
    order: list[Node] = []

    def rec(n: Node):
        if n.uid in seen:
            return
        seen.add(n.uid)
        for i in n.inputs:
            rec(i)
        order.append(n)

    for r in roots:
        rec(r)
    return order


def compile_plan(outputs: list[LTensor], *, reuse_enabled: bool = False,
                 opt_level: int = 2,
                 local_budget: int = LOCAL_MEM_BUDGET,
                 mesh: Optional[object] = None) -> Plan:
    roots = [o.node for o in outputs]
    roots = run_rewrites(roots, reuse_enabled=reuse_enabled,
                         opt_level=opt_level)
    # placement assignment runs after the rewrites so fused patterns
    # (t(X)@X -> gram) are visible to the federated lowering
    roots = lower_federated(roots)
    if mesh is None:
        from repro.distributed.mesh import get_mesh
        mesh = get_mesh()
    if mesh is not None and getattr(mesh, "data", 1) > 1:
        roots = lower_distributed(roots, int(mesh.data))
    # out-of-core streaming runs last: it only touches leaves the
    # federated/sharded passes left local, and its budget gate keeps it
    # inert for in-memory plans
    roots, chunk_sliced = lower_chunked(roots)
    order = topo_order(roots)
    if chunk_sliced:
        # cluster chunked instructions so one streaming pass serves
        # every partial aggregate of a scope (gram AND xtv share a read)
        order = _cluster_chunked(order)

    # liveness: last consumer of each node frees it (buffer-pool eviction)
    last_consumer: dict[int, int] = {}
    for idx, n in enumerate(order):
        for i in n.inputs:
            last_consumer[i.uid] = idx
    root_ids = {r.uid for r in roots}
    frees_at: dict[int, list[int]] = {}
    for uid, idx in last_consumer.items():
        if uid not in root_ids:
            frees_at.setdefault(idx, []).append(uid)

    instructions: list[Instruction] = []
    peak = 0
    live = 0
    live_sizes: dict[int, int] = {}  # uid -> bytes counted into `live`
    for idx, n in enumerate(order):
        if n.op == "input":
            continue
        op_bytes = n.est_bytes() + sum(i.est_bytes() for i in n.inputs)
        if n.op == "collect" or n.op.startswith("fed_"):
            target = "federated"
        elif (n.placement == "sharded" or n.op == "reshard"
                or n.op.startswith("shard_")):
            target = "distributed"  # shard-exec lane (mesh-lowered)
        elif _chunk_exec(n):
            target = "chunked"  # streaming lane (budget-lowered)
        else:
            target = "distributed" if op_bytes > local_budget else "local"
        cost = costmodel.est_cost_s(n)
        instructions.append(Instruction(
            node=n, out_id=n.uid,
            input_ids=tuple(i.uid for i in n.inputs),
            target=target,
            last_use_of=tuple(frees_at.get(idx, ())),
            # chunked-placement prefix values exist only one row bucket
            # at a time inside the streaming executor — they are never
            # materialized, so they can never be probed or cached. The
            # chunk_* partial aggregates (small, materialized segment
            # outputs) stay probe-eligible; the streaming executor
            # probes them before dispatching any chunk, so a warm cache
            # skips the whole stream.
            probe=(cost >= costmodel.PROBE_MIN_COST_S
                   and not (n.placement == "chunked"
                            and not n.op.startswith("chunk_"))),
            est_cost_s=cost))
        sz = n.est_bytes()
        live_sizes[n.uid] = sz
        live += sz
        peak = max(peak, live)
        for uid in frees_at.get(idx, ()):
            # frees of input leaves were never counted into `live`
            live -= live_sizes.pop(uid, 0)

    return Plan(instructions=instructions,
                output_ids=[r.uid for r in roots], roots=roots,
                est_bytes_peak=peak, reuse_enabled=reuse_enabled,
                mesh_spec=mesh, chunk_sliced=chunk_sliced)
