"""Example 2 (§4.3): federated MV/VM/gram — bytes exchanged vs
centralizing the data, plus federated lmDS end-to-end."""
from __future__ import annotations

import numpy as np

from .common import COLS, ROWS, emit, timed


def main(rows=ROWS, cols=COLS, n_sites=4) -> None:
    from repro.core.federated import FederatedTensor, federated_lmds
    from repro.data.synthetic import gen_regression
    x, y, _ = gen_regression(rows, cols, seed=13)
    data_bytes = x.nbytes

    f = FederatedTensor.partition_rows(x, n_sites)
    v = np.random.default_rng(0).normal(size=(cols, 1))
    t = timed(lambda: f.fed_mv(v))
    emit("ex2_fed_mv", t, f"exchanged={f.log.total}B")

    f = FederatedTensor.partition_rows(x, n_sites)
    vr = np.random.default_rng(0).normal(size=(rows, 1))
    t = timed(lambda: f.fed_vm(vr))
    emit("ex2_fed_vm", t, f"exchanged={f.log.total}B")

    f = FederatedTensor.partition_rows(x, n_sites)
    t = timed(lambda: f.fed_gram())
    emit("ex2_fed_gram", t,
         f"exchanged={f.log.total}B;centralize={data_bytes}B;"
         f"ratio={f.log.total/data_bytes:.4f}")

    f = FederatedTensor.partition_rows(x, n_sites)
    t = timed(lambda: federated_lmds(f, y))
    beta = federated_lmds(FederatedTensor.partition_rows(x, n_sites), y)
    ref = np.linalg.solve(x.T @ x + 1e-7 * np.eye(cols), x.T @ y)
    err = float(np.abs(beta - ref).max())
    emit("ex2_federated_lmds", t, f"max_err_vs_centralized={err:.2e}")


if __name__ == "__main__":
    main()
