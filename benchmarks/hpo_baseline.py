"""Fig. 5(a,b): HPO baseline — k lmDS models, dense and sparse, NO reuse.

The paper's workload: read CSV, train k regression models with different
λ, write models. X^T X / X^T y are recomputed per model (this is the
TF/Julia-equivalent baseline; Fig 5(c) adds reuse).
"""
from __future__ import annotations

import numpy as np

from .common import COLS, ROWS, SPARSITY, emit, gflop_per_model, timed


def run_hpo(x: np.ndarray, y: np.ndarray, k: int, reuse: bool) -> dict:
    from repro.core import LineageRuntime, ReuseCache, input_tensor
    from repro.lifecycle import grid_search_lm
    rt = LineageRuntime(cache=ReuseCache() if reuse else None)
    X = input_tensor("X", x)
    Y = input_tensor("y", y)
    lambdas = np.logspace(-2, 2, k).tolist()
    # mode='sequential' pins the Fig. 5 semantics (per-λ plans, reuse
    # cache as the only cross-λ sharing); the batched parfor path is
    # measured separately in benchmarks/parfor_bench.py
    betas, losses = grid_search_lm(X, Y, lambdas, runtime=rt,
                                   mode="sequential")
    return {"betas": betas, "stats": rt.stats, "cache": rt.cache}


def main(ks=(1, 5, 10, 20), rows=ROWS, cols=COLS) -> None:
    from repro.data.synthetic import gen_regression
    for sparse in (False, True):
        sp = SPARSITY if sparse else 1.0
        x, y, _ = gen_regression(rows, cols, sparsity=sp, seed=7)
        tag = "sparse" if sparse else "dense"
        for k in ks:
            t = timed(lambda: run_hpo(x, y, k, reuse=False), repeats=2,
                      warmup=1)
            emit(f"fig5_hpo_baseline_{tag}_k{k}", t,
                 f"gflop={k * gflop_per_model(rows, cols):.1f}")


if __name__ == "__main__":
    main()
