"""Federation as a compiler placement (ISSUE 4): `fed_*` plans compiled
through the DAG -> cost model -> fused-segment stack.

Covers the acceptance invariants:
  * placement compilation — `Plan.explain()` shows `fed_gram`/`fed_xtv`
    with `[F]` targets and explicit `collect` boundaries
  * parity — fused vs `fuse=False` vs the numpy `LocalSite` oracle on
    lmDS/steplm, and against the dense local solve
  * exchange accounting — bytes identical across fuse modes and exactly
    equal to the eager `federated_lmds` oracle, per site
  * reuse — hit parity across fuse modes on a federated HPO loop;
    warm per-site executables in the jit cache on repeated runs
  * validation — zero-site tensors, bad partitionings, misaligned
    federated operands raise clear errors
"""
import numpy as np
import pytest

from repro.core import (FederatedTensor, LineageRuntime, ReuseCache,
                        federated_input, get_jit_cache, input_tensor, ops)
from repro.core.compiler import compile_plan
from repro.core.federated import LocalSite, federated_lmds
from repro.lifecycle import lmDS_federated, steplm, steplm_federated


def _lmds_graph(X, Y, reg=1e-6):
    n = X.shape[1]
    return ops.solve(ops.gram(X) + reg * ops.eye(n), ops.xtv(X, Y))


@pytest.fixture
def data(rng):
    x = rng.normal(size=(211, 7))  # ragged row count across sites
    y = x @ rng.normal(size=(7, 1)) + 0.01 * rng.normal(size=(211, 1))
    return x, y


class TestPlacementCompilation:
    def test_explain_shows_fed_instructions(self, data):
        x, y = data
        fed = FederatedTensor.partition_rows(x, 3)
        plan = compile_plan([_lmds_graph(federated_input("X", fed),
                                         input_tensor("y", y))])
        txt = plan.explain()
        assert "fed_gram" in txt and "fed_xtv" in txt
        assert "[F]" in txt          # federated execution target
        assert ":fed" in txt         # federated value placement
        ops_seen = plan.count_ops()
        assert "gram" not in ops_seen and "xtv" not in ops_seen
        assert "collect" not in ops_seen  # lmDS federates end-to-end

    def test_non_lowerable_consumer_gets_collect_boundary(self, data):
        x, _ = data
        X = federated_input("X", FederatedTensor.partition_rows(x, 3))
        plan = compile_plan([ops.rowSums(X)])  # no federated lowering
        assert plan.count_ops().get("collect") == 1
        assert "[collect-boundary]" in plan.explain()

    def test_collect_shared_across_consumers(self, data):
        x, _ = data
        X = federated_input("X", FederatedTensor.partition_rows(x, 3))
        # two non-lowerable consumers -> one shared collect
        plan = compile_plan([ops.rowSums(X), ops.cumsum(X)])
        assert plan.count_ops().get("collect") == 1

    def test_row_preserving_chain_stays_federated(self, data):
        x, _ = data
        X = federated_input("X", FederatedTensor.partition_rows(x, 4))
        out = ops.colSums(ops.abs_(X) * 2.0)
        plan = compile_plan([out])
        counts = plan.count_ops()
        assert counts.get("fed_map", 0) == 2     # abs, scalar mul
        assert counts.get("fed_colsums") == 1
        assert "collect" not in counts           # nothing materializes

    def test_fed_instruction_targets_are_federated(self, data):
        x, y = data
        fed = FederatedTensor.partition_rows(x, 3)
        plan = compile_plan([_lmds_graph(federated_input("X", fed),
                                         input_tensor("y", y))])
        for ins in plan.instructions:
            is_fed_op = (ins.node.op.startswith("fed_")
                         or ins.node.op == "collect")
            assert (ins.target == "federated") == is_fed_op
        # federated instructions are single-op segments; local work fuses
        segs = plan.segments_for(False)
        for seg in segs:
            if seg.target == "federated":
                assert len(seg.instructions) == 1
        assert any(seg.fused for seg in segs)


class TestFederatedParity:
    def test_lmds_three_ways(self, data):
        """fused vs interpreter vs eager numpy oracle vs dense solve."""
        x, y = data
        ref = np.linalg.solve(x.T @ x + 1e-6 * np.eye(7), x.T @ y)
        oracle = federated_lmds(FederatedTensor.partition_rows(x, 3), y,
                                reg=1e-6)
        for fuse in (True, False):
            fed = FederatedTensor.partition_rows(x, 3)
            rt = LineageRuntime(fuse=fuse)
            b = lmDS_federated(fed, y, reg=1e-6, runtime=rt)
            np.testing.assert_allclose(b, ref, rtol=1e-8)
            np.testing.assert_allclose(b, oracle, rtol=1e-8)

    def test_lmds_intercept(self, data):
        x, y = data
        xi = np.concatenate([x, np.ones((x.shape[0], 1))], axis=1)
        ref = np.linalg.solve(xi.T @ xi + 1e-6 * np.eye(8), xi.T @ y)
        fed = FederatedTensor.partition_rows(x, 3)
        b = lmDS_federated(fed, y, reg=1e-6, intercept=True,
                           runtime=LineageRuntime())
        np.testing.assert_allclose(b, ref, rtol=1e-8)

    def test_steplm_matches_local(self, data):
        x, y = data
        rt_local = LineageRuntime()
        beta_l, sel_l = steplm(input_tensor("X", x), input_tensor("y", y),
                               max_features=3, runtime=rt_local)
        for fuse in (True, False):
            fed = FederatedTensor.partition_rows(x, 3)
            rt = LineageRuntime(fuse=fuse, cache=ReuseCache())
            beta_f, sel_f = steplm_federated(fed, y, max_features=3,
                                             runtime=rt)
            assert sel_f == sel_l
            np.testing.assert_allclose(beta_f, beta_l, rtol=1e-7)
            assert rt.cache.stats.hits > 0  # federated partial reuse

    def test_float32_plan_keeps_dtype(self, rng):
        """Per-site generated operands carry the generator's dtype — an
        f32 federated plan must not be silently promoted to f64 (parity
        with local execution and stable jit-cache signatures)."""
        x = rng.normal(size=(120, 5)).astype(np.float32)
        X = federated_input("f32X", FederatedTensor.partition_rows(x, 2))
        out = ops.gram(ops.cbind(ops.ones((120, 1), np.float32), X))
        g = LineageRuntime().evaluate([out])[0]
        assert g.dtype == np.float32
        xi = np.concatenate([np.ones((120, 1), np.float32), x], axis=1)
        np.testing.assert_allclose(g, xi.T @ xi, rtol=1e-4)

    def test_pca_federated(self, rng):
        from repro.lifecycle import pca
        x = rng.normal(size=(160, 5)) @ np.diag([4.0, 2.0, 1.0, 0.5, 0.1])
        comps_l, proj_l = pca(input_tensor("X", x), k=2,
                              runtime=LineageRuntime())
        fed = FederatedTensor.partition_rows(x, 4)
        comps_f, proj_f = pca(federated_input("Xf", fed), k=2,
                              runtime=LineageRuntime())
        np.testing.assert_allclose(np.abs(comps_f), np.abs(comps_l),
                                   rtol=1e-7, atol=1e-9)
        np.testing.assert_allclose(np.abs(proj_f), np.abs(proj_l),
                                   rtol=1e-6, atol=1e-8)


class TestExchangeAccounting:
    def _run(self, x, y, fuse, intercept=False):
        fed = FederatedTensor.partition_rows(x, 3)
        rt = LineageRuntime(fuse=fuse)
        lmDS_federated(fed, y, reg=1e-6, intercept=intercept, runtime=rt)
        return rt.stats.exchange.as_dict()

    def test_bytes_identical_across_fuse_modes(self, data):
        x, y = data
        assert self._run(x, y, True) == self._run(x, y, False)

    @pytest.mark.parametrize("intercept", [False, True])
    def test_bytes_match_eager_oracle_exactly(self, data, intercept):
        """The acceptance criterion: the compiled plan exchanges exactly
        the bytes the eager `federated_lmds` oracle does — per site."""
        x, y = data
        f = FederatedTensor.partition_rows(x, 3)
        federated_lmds(f, y, reg=1e-6, intercept=intercept)
        compiled = self._run(x, y, True, intercept=intercept)
        assert compiled == f.log.as_dict()

    def test_costmodel_fed_map_estimate_matches_runtime(self, data):
        """`fed_args`/`gen_args` index the inner argument list while the
        node's inputs are compacted — the compile-time exchange estimate
        must walk positions the way the executor does. Regression: a
        `full` generator *before* the federated operand used to make the
        estimate bill the whole partition as sent bytes."""
        from repro.core import costmodel
        x, _ = data
        X = federated_input("gX", FederatedTensor.partition_rows(x, 3))
        out = ops.colSums(ops.cbind(ops.ones((x.shape[0], 1)), X))
        plan = compile_plan([out])
        fm = next(i.node for i in plan.instructions
                  if i.node.op == "fed_map")
        assert costmodel.fed_exchange_bytes(fm) == (0.0, 0.0)
        rt = LineageRuntime()
        rt.evaluate([out])
        assert rt.stats.exchange.to_sites == 0  # ones generated on site

    def test_fed_map_exchanges_nothing_for_onsite_work(self, data):
        x, _ = data
        X = federated_input("X", FederatedTensor.partition_rows(x, 3))
        rt = LineageRuntime()
        rt.evaluate([ops.colSums(ops.abs_(X))])
        ex = rt.stats.exchange
        assert ex.to_sites == 0                 # nothing broadcast
        assert ex.from_sites == 3 * x.shape[1] * 8  # one row per site


class TestFederatedReuse:
    def _hpo(self, x, y, fuse):
        X = federated_input("hpoX", FederatedTensor.partition_rows(x, 3))
        Y = input_tensor("hpoy", y)
        rt = LineageRuntime(fuse=fuse, cache=ReuseCache())
        for lam in (0.1, 1.0, 10.0):
            rt.evaluate([_lmds_graph(X, Y, reg=lam)])
        return rt

    def test_hit_parity_across_fuse_modes(self, data):
        x, y = data
        rt_f, rt_i = self._hpo(x, y, True), self._hpo(x, y, False)
        sf, si = rt_f.cache.stats, rt_i.cache.stats
        assert (sf.probes, sf.hits, sf.misses) == \
            (si.probes, si.hits, si.misses)
        assert sf.hits >= 4  # fed_gram + fed_xtv reused for 2 lambdas

    def test_reuse_hit_skips_exchange(self, data):
        """A lineage hit on a federated intermediate skips the sites
        entirely — no recompute, no exchange, in both modes."""
        x, y = data
        for fuse in (True, False):
            rt = self._hpo(x, y, fuse)
            one = LineageRuntime(fuse=fuse)
            one.evaluate([_lmds_graph(
                federated_input("oX", FederatedTensor.partition_rows(x, 3)),
                input_tensor("oy", y), reg=0.1)])
            # 3 lambdas but fed_gram/fed_xtv executed once: exchange of
            # the whole HPO loop == exchange of a single solve
            assert rt.stats.exchange.as_dict() == \
                one.stats.exchange.as_dict()

    def test_per_site_work_hits_jit_cache_on_repeat(self, data):
        x, y = data
        X = federated_input("wX", FederatedTensor.partition_rows(x, 3))
        Y = input_tensor("wy", y)
        from repro.core import clear_jit_cache
        clear_jit_cache()          # deterministic cold start: the jit
        rt = LineageRuntime()      # cache is process-global by design
        plan = compile_plan([_lmds_graph(X, Y)])
        rt.run_plan(plan)          # trace + compile per-site segments
        assert rt.stats.trace_time > 0  # per-site compiles booked here
        st = get_jit_cache().stats
        before = st.hits
        hits_before = rt.stats.jit_cache_hits
        trace_before = rt.stats.trace_time
        rt.run_plan(plan)          # warm replay
        # >= 6 warm per-site lookups (gram + xtv on 3 sites) plus the
        # fused local segments
        assert st.hits - before >= 6
        assert rt.stats.jit_cache_hits - hits_before >= 6
        assert rt.stats.trace_time == trace_before  # nothing re-traced


class TestValidation:
    def test_zero_site_tensor_raises(self):
        f = FederatedTensor(sites=[], ranges=[], ncols=4)
        for op in (lambda: f.fed_colsums(), lambda: f.fed_vm(np.ones((4, 1))),
                   lambda: f.fed_xtv(np.ones((0, 1))), lambda: f.fed_gram(),
                   lambda: f.fed_mv(np.ones((4, 1))), lambda: f.collect()):
            with pytest.raises(ValueError, match="zero sites"):
                op()

    def test_partition_rows_validates_site_count(self, rng):
        x = rng.normal(size=(5, 3))
        with pytest.raises(ValueError, match="n_sites"):
            FederatedTensor.partition_rows(x, 6)  # n_sites > nrows
        with pytest.raises(ValueError, match="n_sites"):
            FederatedTensor.partition_rows(x, 0)
        with pytest.raises(ValueError, match="matrix"):
            FederatedTensor.partition_rows(np.ones(5), 2)

    def test_misaligned_federated_operands_raise(self, rng):
        x = rng.normal(size=(100, 4))
        f1 = FederatedTensor.partition_rows(x, 2)        # 50/50
        f2 = FederatedTensor(                            # 30/70
            sites=[LocalSite(x[:30]), LocalSite(x[30:])],
            ranges=[(0, 30), (30, 100)], ncols=4)
        out = federated_input("a", f1) * federated_input("b", f2)
        with pytest.raises(ValueError, match="aligned"):
            LineageRuntime().evaluate([ops.colSums(out)])

    def test_prepared_script_arity_error(self, rng):
        from repro.core import PreparedScript
        ps = PreparedScript(lambda a: a * 2.0, [(4, 4)])
        with pytest.raises(ValueError, match="argument"):
            ps(np.ones((4, 4)), np.ones((4, 4)))
