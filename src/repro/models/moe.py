"""Fine-grained Mixture-of-Experts (DeepSeekMoE / DeepSeek-V2 / Jamba).

Dropless sort-based dispatch:
  1. router top-k per token,
  2. tokens replicated k ways and sorted by expert id,
  3. grouped expert matmuls via `jax.lax.ragged_dot` (the TPU analogue of
     MegaBlocks' grouped GEMM — no (T, E, C) one-hot dispatch tensor),
  4. weighted scatter-add back to token order.

Shared experts (DeepSeek) run as a plain dense MLP on every token.
Expert weights are sharded on the `model` mesh axis (EP); token tensors
on `data` — GSPMD inserts the dispatch collectives, and the shard_map
all-to-all variant is a perf-iteration option (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Params, dense_init, mlp, mlp_init


def moe_init(key, cfg) -> Params:
    d = cfg.d_model
    de = cfg.d_expert or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / np.sqrt(d)
    p: Params = {
        "router": dense_init(ks[0], d, E, scale=0.02),
        "w_gate": jax.random.normal(ks[1], (E, d, de), jnp.float32) * scale,
        "w_up": jax.random.normal(ks[2], (E, d, de), jnp.float32) * scale,
        "w_down": jax.random.normal(ks[3], (E, de, d), jnp.float32)
        / np.sqrt(de),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, cfg.n_shared_experts * de)
    return p


def moe_forward(p: Params, cfg, x: jnp.ndarray
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss). Dispatches to the expert-parallel
    shard_map path on a distributed mesh, else the local sort path."""
    from repro.distributed.hints import _STATE, axis_size, hints_enabled
    dp_size = 1
    for a in _STATE["data_axes"]:
        dp_size *= _STATE["sizes"].get(a, 1)
    tokens = x.shape[0] * x.shape[1]
    if hints_enabled() and axis_size("model") > 1 and \
            cfg.n_experts % axis_size("model") == 0 and \
            tokens % max(dp_size, 1) == 0:
        return moe_forward_ep(p, cfg, x)
    return moe_forward_local(p, cfg, x)


def moe_forward_local(p: Params, cfg, x: jnp.ndarray
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-device dropless path (sort + ragged_dot): x: (B, S, D)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    dt = x.dtype
    T = B * S
    xf = x.reshape(T, D)

    logits = (xf @ p["router"].astype(dt)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)                  # (T, k)
    weights = top_vals / jnp.maximum(
        top_vals.sum(axis=-1, keepdims=True), 1e-9)

    # load-balance aux (Switch-style): E * Σ_e f_e · p̄_e
    f = jnp.zeros((E,), jnp.float32).at[top_idx.reshape(-1)].add(1.0) \
        / (T * k)
    aux = E * jnp.sum(f * probs.mean(axis=0))

    # sort token-replicas by expert
    flat_expert = top_idx.reshape(T * k)
    sort_idx = jnp.argsort(flat_expert)
    token_of = sort_idx // k
    xs = jnp.take(xf, token_of, axis=0)                          # (T·k, D)
    group_sizes = jnp.bincount(flat_expert, length=E).astype(jnp.int32)

    g = jax.lax.ragged_dot(xs, p["w_gate"].astype(dt), group_sizes)
    u = jax.lax.ragged_dot(xs, p["w_up"].astype(dt), group_sizes)
    h = jax.nn.silu(g) * u
    eo = jax.lax.ragged_dot(h, p["w_down"].astype(dt), group_sizes)

    w_sorted = weights.reshape(T * k)[sort_idx].astype(dt)
    out = jnp.zeros((T, D), dt).at[token_of].add(eo * w_sorted[:, None])

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xf)
    return out.reshape(B, S, D), aux


def moe_forward_ep(p: Params, cfg, x: jnp.ndarray
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE via shard_map over the `model` axis.

    Auto-GSPMD on the sort-based dispatch replicates token buffers and
    gathers expert weights (measured 2 TiB/device temp on
    deepseek-v2 × train_4k — §Perf log), so the distributed path is
    explicit: experts are sharded on `model`; every model rank holds its
    data shard's full token set, locally gathers the (capacity-bounded)
    slots routed to *its* experts, runs the expert FFNs, and the
    weighted partial outputs are psum'd over `model`. Capacity factor
    cfg.capacity_factor bounds memory (Switch-style token dropping,
    overflow slots masked).
    """
    try:
        from jax import shard_map
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.hints import _STATE, current_mesh

    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    dt = x.dtype
    mesh = current_mesh()
    n_ranks = _STATE["sizes"].get("model", 1)
    e_local = E // n_ranks
    dp = _STATE["data_axes"]
    dpa = dp if len(dp) > 1 else (dp[0] if dp else None)

    # cast OUTSIDE shard_map: the FSDP('data')->EP('model') reshard
    # all-gather then moves bf16, not f32 (halves gather traffic and
    # the transient gathered buffer)
    router = p["router"].astype(dt)
    experts = {kk: p[kk].astype(dt) for kk in ("w_gate", "w_up", "w_down")}

    def rank_fn(xf, router_w, w_gate, w_up, w_down):
        # xf: (T_loc, D) local tokens; expert weights: (e_local, ·, ·)
        T_loc = xf.shape[0]
        Tk = T_loc * k
        rank = jax.lax.axis_index("model")
        logits = (xf @ router_w).astype(jnp.float32)             # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_vals, top_idx = jax.lax.top_k(probs, k)
        weights = top_vals / jnp.maximum(
            top_vals.sum(axis=-1, keepdims=True), 1e-9)
        # globally exact load-balance aux: sum counts/probs over the data
        # axes BEFORE the nonlinear f·p̄ product (per-shard means differ)
        counts = jnp.zeros((E,), jnp.float32).at[top_idx.reshape(-1)].add(1.0)
        probs_sum = probs.sum(axis=0)
        t_glob = T_loc
        if dp:
            counts = jax.lax.psum(counts, tuple(dp))
            probs_sum = jax.lax.psum(probs_sum, tuple(dp))
            t_glob = T_loc * int(
                np.prod([_STATE["sizes"][a] for a in dp]))
        aux = E * jnp.sum((counts / (t_glob * k))
                          * (probs_sum / t_glob))

        flat_e = top_idx.reshape(Tk)
        flat_w = weights.reshape(Tk)
        tok_of = jnp.arange(Tk, dtype=jnp.int32) // k
        mine = (flat_e // e_local) == rank
        local_e = jnp.clip(flat_e - rank * e_local, 0, e_local - 1)
        # per-EXPERT capacity buffers -> dense batched matmuls with
        # ideal fwd AND bwd flops (ragged_dot's reference grad computes
        # every expert over the full buffer — measured 10× waste, §Perf)
        Ce = max(int(Tk / E * cfg.capacity_factor + 7) // 8 * 8, 8)
        onehot = (local_e[:, None] == jnp.arange(e_local)[None]) \
            & mine[:, None]                                      # (Tk, eL)
        pos = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
        pos_slot = jnp.take_along_axis(pos, local_e[:, None], axis=1)[:, 0]
        keep = mine & (pos_slot < Ce)
        # scatter slots into (e_local, Ce) index/weight buffers
        flat_idx = jnp.where(keep, local_e * (Ce + 1) + pos_slot,
                             e_local * (Ce + 1))
        buf_tok = jnp.full((e_local * (Ce + 1) + 1,), T_loc, jnp.int32
                           ).at[flat_idx].set(jnp.where(keep, tok_of, T_loc))
        buf_w = jnp.zeros((e_local * (Ce + 1) + 1,), jnp.float32
                          ).at[flat_idx].set(jnp.where(keep, flat_w, 0.0))
        buf_tok = buf_tok[:-1].reshape(e_local, Ce + 1)[:, :Ce]
        buf_w = buf_w[:-1].reshape(e_local, Ce + 1)[:, :Ce]

        xpad = jnp.concatenate([xf, jnp.zeros((1, D), dt)], axis=0)
        xs = jnp.take(xpad, buf_tok.reshape(-1), axis=0
                      ).reshape(e_local, Ce, D)
        g = jnp.einsum("ecd,edf->ecf", xs, w_gate)
        u = jnp.einsum("ecd,edf->ecf", xs, w_up)
        h = jax.nn.silu(g) * u
        eo = jnp.einsum("ecf,efd->ecd", h, w_down)
        out = jnp.zeros((T_loc + 1, D), dt).at[buf_tok.reshape(-1)].add(
            (eo * buf_w[..., None].astype(dt)).reshape(-1, D))[:T_loc]
        # NOTE: psum_scatter into the seq-parallel layout was tried and
        # REGRESSED (coll 39.5s -> 131.8s on deepseek-v2×train_4k): its
        # backward transposes to an all-gather per layer and the residual
        # stream resharding costs more than the (n-1)/n wire it saves.
        # §Perf iteration A7 (refuted). Plain psum kept.
        out = jax.lax.psum(out, "model")
        return out, aux

    xf = x.reshape(B * S, D)
    sm_kwargs = dict(
        mesh=mesh,
        in_specs=(P(dpa, None), P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(dpa, None), P()))
    try:
        wrapped = shard_map(rank_fn, check_vma=False, **sm_kwargs)
    except TypeError:  # older jax spelling
        wrapped = shard_map(rank_fn, check_rep=False, **sm_kwargs)
    out, aux = wrapped(xf, router, experts["w_gate"], experts["w_up"],
                       experts["w_down"])

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xf)
    return out.reshape(B, S, D), aux


def moe_forward_dense_fallback(p: Params, cfg, x: jnp.ndarray
                               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle: compute every expert densely, combine by router weights.

    O(E) compute — tests only. Must match `moe_forward` exactly (the
    dispatch path is dropless, so no capacity mismatch)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    dt = x.dtype
    xf = x.reshape(B * S, D)
    logits = (xf @ p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)
    weights = top_vals / jnp.maximum(
        top_vals.sum(axis=-1, keepdims=True), 1e-9)
    dense_w = jnp.zeros_like(probs).at[
        jnp.arange(xf.shape[0])[:, None], top_idx].set(weights)
    g = jnp.einsum("td,edf->tef", xf, p["w_gate"].astype(dt))
    u = jnp.einsum("td,edf->tef", xf, p["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    eo = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(dt))
    out = jnp.einsum("ted,te->td", eo, dense_w.astype(dt))
    f = jnp.zeros((E,), jnp.float32).at[top_idx.reshape(-1)].add(1.0) \
        / (xf.shape[0] * k)
    aux = E * jnp.sum(f * probs.mean(axis=0))
    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xf)
    return out.reshape(B, S, D), aux
