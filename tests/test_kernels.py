"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fops
from repro.kernels.flash_attention import ref as fref
from repro.kernels.gram import ops as gops
from repro.kernels.gram import ref as gref
from repro.kernels.rwkv6 import ops as rops
from repro.kernels.rwkv6 import ref as rref
from repro.kernels.ssd import ops as sops
from repro.kernels.ssd import ref as sref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


class TestGramKernel:
    @pytest.mark.parametrize("m,n,bm,bn", [
        (128, 32, 64, 32), (256, 96, 128, 32), (512, 128, 128, 64),
        (192, 64, 64, 64),  # m not multiple of bm -> padding path
        (250, 70, 64, 32),  # ragged both dims
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_gram_allclose(self, rng, m, n, bm, bn, dtype):
        x = jnp.asarray(rng.normal(size=(m, n)), dtype)
        got = gops.gram(x, interpret=True, bm=bm, bn=bn)
        want = gref.gram(x)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **_tol(dtype))

    def test_gram_symmetric(self, rng):
        x = jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)
        g = np.asarray(gops.gram(x, interpret=True, bm=128, bn=32))
        np.testing.assert_allclose(g, g.T, rtol=1e-6)

    @pytest.mark.parametrize("cols", [1, 3])
    def test_xtv_allclose(self, rng, cols):
        x = jnp.asarray(rng.normal(size=(256, 96)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(256, cols)), jnp.float32)
        got = gops.xtv(x, v, interpret=True, bm=128, bn=32)
        want = gref.xtv(x, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_gram_aug_fused_stats(self, rng):
        """gram([X|y]) carries X^TX, X^Ty, y^Ty in one pass."""
        x = jnp.asarray(rng.normal(size=(128, 30)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(128, 1)), jnp.float32)
        g = np.asarray(gops.gram_aug(x, y, interpret=True, bm=64, bn=32))
        np.testing.assert_allclose(g[:30, :30], np.asarray(x).T @ x,
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(g[:30, 30:], np.asarray(x).T @ y,
                                   rtol=1e-4, atol=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("S,Hq,Hkv,hd,bq,bk", [
        (128, 4, 4, 32, 64, 64),     # MHA
        (256, 8, 2, 64, 64, 64),     # GQA 4:1
        (256, 4, 1, 64, 128, 64),    # MQA
        (192, 2, 2, 32, 64, 64),     # ragged seq vs block
    ])
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose(self, rng, S, Hq, Hkv, hd, bq, bk, causal, dtype):
        if S % bq or S % bk:
            pytest.skip("kernel requires block-aligned seq (wrapper pads "
                        "in ops for production shapes)")
        B = 2
        q = jnp.asarray(rng.normal(size=(B, S, Hq, hd)), dtype)
        k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), dtype)
        v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), dtype)
        got = fops.flash_attention(q, k, v, causal=causal, interpret=True,
                                   bq=bq, bk=bk)
        want = fref.attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **_tol(dtype))

    def test_matches_chunked_model_path(self, rng):
        from repro.models.attention import chunked_attention
        B, S, H, hd = 2, 256, 4, 32
        q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
        a = chunked_attention(q, k, v, causal=True, q_chunk=64, k_chunk=64)
        b = fref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


class TestRwkv6Kernel:
    @pytest.mark.parametrize("S,H,dh,chunk", [
        (64, 2, 32, 32), (128, 3, 32, 64), (256, 2, 64, 64),
    ])
    def test_allclose(self, rng, S, H, dh, chunk):
        B = 2
        r = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
        lw = jnp.clip(-jnp.exp(jnp.asarray(
            rng.normal(size=(B, S, H, dh)) * 1.5, jnp.float32)), -5.0, -1e-4)
        u = jnp.asarray(rng.normal(size=(H, dh)) * 0.1, jnp.float32)
        s0 = jnp.asarray(rng.normal(size=(B, H, dh, dh)) * 0.1, jnp.float32)
        y_ref, s_ref = rref.wkv6(r, k, v, lw, u, s0)
        y_pl, s_pl = rops.wkv6(r, k, v, lw, u, s0, chunk=chunk,
                               interpret=True)
        scale = float(jnp.abs(y_ref).max()) + 1e-6
        assert float(jnp.abs(y_pl - y_ref).max()) / scale < 1e-4
        np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_extreme_decay_stable(self, rng):
        """Clamped maximal decay must not produce inf/nan."""
        B, S, H, dh = 1, 64, 1, 32
        r = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
        lw = jnp.full((B, S, H, dh), -5.0, jnp.float32)
        u = jnp.zeros((H, dh), jnp.float32)
        s0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        y_ref, _ = rref.wkv6(r, k, v, lw, u, s0)
        y_pl, _ = rops.wkv6(r, k, v, lw, u, s0, chunk=32, interpret=True)
        assert np.isfinite(np.asarray(y_pl)).all()
        np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_decode_step_matches_scan(self, rng):
        from repro.models.rwkv6 import wkv_step
        B, S, H, dh = 1, 8, 2, 16
        r, k, v = (jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
                   for _ in range(3))
        lw = jnp.clip(-jnp.exp(jnp.asarray(rng.normal(size=(B, S, H, dh)),
                                           jnp.float32)), -5.0, -1e-4)
        u = jnp.asarray(rng.normal(size=(H, dh)) * 0.1, jnp.float32)
        s = jnp.zeros((B, H, dh, dh), jnp.float32)
        y_ref, s_ref = rref.wkv6(r, k, v, lw, u, s)
        ys = []
        for t in range(S):
            y, s = wkv_step(r[:, t], k[:, t], v[:, t], lw[:, t], u, s)
            ys.append(y)
        y_steps = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                   rtol=1e-5, atol=1e-5)


class TestSsdKernel:
    @pytest.mark.parametrize("S,di,ds,bd,tc", [
        (64, 64, 8, 32, 16), (128, 32, 16, 32, 64), (96, 64, 4, 64, 32),
    ])
    def test_allclose(self, rng, S, di, ds, bd, tc):
        B = 2
        x = jnp.asarray(rng.normal(size=(B, S, di)), jnp.float32)
        dt = jnp.asarray(rng.random(size=(B, S, di)) * 0.2, jnp.float32)
        A = -jnp.exp(jnp.asarray(rng.normal(size=(di, ds)) * 0.3,
                                 jnp.float32))
        Bv = jnp.asarray(rng.normal(size=(B, S, ds)), jnp.float32)
        Cv = jnp.asarray(rng.normal(size=(B, S, ds)), jnp.float32)
        D = jnp.ones((di,), jnp.float32)
        h0 = jnp.zeros((B, di, ds), jnp.float32)
        y1, h1 = sref.ssm_scan(x, dt, A, Bv, Cv, D, h0)
        y2, h2 = sops.ssm_scan(x, dt, A, Bv, Cv, D, h0, interpret=True,
                               bd=bd, tc=tc)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h2), np.asarray(h1),
                                   rtol=1e-4, atol=1e-4)

    def test_chunked_model_path_matches_ref(self, rng):
        from repro.models.mamba import selective_scan
        B, S, di, ds = 2, 128, 16, 8
        x = jnp.asarray(rng.normal(size=(B, S, di)), jnp.float32)
        dt = jnp.asarray(rng.random(size=(B, S, di)) * 0.2, jnp.float32)
        A = -jnp.exp(jnp.asarray(rng.normal(size=(di, ds)) * 0.3,
                                 jnp.float32))
        Bv = jnp.asarray(rng.normal(size=(B, S, ds)), jnp.float32)
        Cv = jnp.asarray(rng.normal(size=(B, S, ds)), jnp.float32)
        D = jnp.ones((di,), jnp.float32)
        h0 = jnp.zeros((B, di, ds), jnp.float32)
        y1, h1 = sref.ssm_scan(x, dt, A, Bv, Cv, D, h0)
        y2, h2 = selective_scan(x, dt, A, Bv, Cv, D, h0, chunk=32)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                                   rtol=1e-4, atol=1e-4)
