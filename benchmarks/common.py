"""Shared benchmark utilities (timing, data, CSV emission)."""
from __future__ import annotations

import sys
import time
from typing import Callable

import numpy as np

sys.path.insert(0, "src")

# container-scale workload (paper: 100K×1K; see configs/paper_hpo.py —
# aspect ratio and GFLOP accounting preserved, rows scaled for 1 core)
ROWS, COLS = 20_000, 256
SPARSITY = 0.1


def timed(fn: Callable, repeats: int = 3, warmup: int = 0) -> float:
    """Median wall-clock seconds (paper reports mean of 3; median is
    steadier on a shared core)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds*1e6:.1f},{derived}")


def gflop_per_model(rows: int = ROWS, cols: int = COLS) -> float:
    """lmDS main computation: X^T X + X^T y (paper: 100.2 GFLOP)."""
    return (2 * rows * cols * cols + 2 * rows * cols) / 1e9
