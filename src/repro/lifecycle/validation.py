"""Model validation / selection builtins (paper §5 workloads).

`grid_search_lm` is the HPO workload of Fig. 5/6: train k lmDS models
with different regularization λ over the same X — X^T X and X^T y are
λ-independent, so a reuse-enabled runtime computes them once.

`cross_validate_lm` is the CV workload of Fig. 7: k-fold cross
validation where X_train = rbind(folds ∖ i); the compensation-plan
rewrite decomposes gram/xtv over the rbind so per-fold partial products
are computed once and summed per configuration ("multiplications of the
individual folds and element-wise addition", §5.4).

Both are built on `parfor` — the §5 task-parallel loop over independent
configurations. The declarative contract is that the *system* chooses
the parallelization: `parfor` merges the k per-config plans into one
batched template (`repro.core.batching`), and the cost model picks
between executing the whole grid as ONE vmapped fused-segment stack
(config-invariant prefix computed once, config-variant suffix mapped
over the batch axis) or the sequential per-config loop with lineage
reuse — structurally divergent configs always take the sequential path.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

from repro.core import ops
from repro.core.batching import BatchingError, choose_mode, compile_batched
from repro.core.dag import LTensor, input_tensor
from repro.core.runtime import LineageRuntime, get_runtime


def parfor(configs: Sequence, build_fn: Callable,
           runtime: Optional[LineageRuntime] = None,
           mode: str = "auto") -> list[list[np.ndarray]]:
    """Task-parallel loop over independent configurations (§5 `parfor`).

    `build_fn(config)` declares one configuration's outputs (an
    `LTensor` or a sequence of them); `parfor` returns one list of
    numpy outputs per configuration, in order.

    `mode` selects the execution strategy:
      * ``'auto'`` (default) — merge the k plans into one batched
        template and let `repro.core.batching.choose_mode` arbitrate
        vmapped-batched vs sequential-reuse execution; plans that
        cannot merge (structural divergence, unstackable leaves) fall
        back to the sequential loop;
      * ``'vmap'`` — force the batched path (raises `BatchingError`
        when no template exists);
      * ``'shard'`` — force the batched path AND split the bucket axis
        over the device mesh's `config` axis (one shard of the grid per
        device, vmapped locally); degrades to plain vmap at runtime
        when no realizable mesh is attached;
      * ``'sequential'`` — force the per-config loop (the PR-3 path:
        one plan per config, lineage reuse across them).
    """
    if mode not in ("auto", "vmap", "shard", "sequential"):
        raise ValueError(
            f"parfor mode {mode!r} not in auto|vmap|shard|sequential")
    rt = runtime or get_runtime()
    config_outputs: list[list[LTensor]] = []
    for cfg in configs:
        out = build_fn(cfg)
        config_outputs.append([out] if isinstance(out, LTensor)
                              else list(out))
    k = len(config_outputs)
    if k == 0:
        return []
    if mode in ("vmap", "shard") and k < 2:
        raise BatchingError("batching needs >= 2 configurations")
    if mode != "sequential" and k >= 2:
        try:
            bplan = compile_batched(
                config_outputs, reuse_enabled=rt.cache is not None,
                opt_level=rt.opt_level)
        except BatchingError:
            if mode in ("vmap", "shard"):
                raise
            bplan = None
        if bplan is not None:
            roots_list = [[o.node for o in outs]
                          for outs in config_outputs]
            bplan.mode = (mode if mode in ("vmap", "shard")
                          else choose_mode(
                bplan, roots_list, rt.cache is not None,
                rt.sparse_inputs))
            try:
                if bplan.mode in ("vmap", "shard"):
                    return rt.evaluate_batch(bplan)
            finally:
                # the hoisted (k, ...) stacks are parfor-internal:
                # unbind them so repeated calls don't grow the global
                # leaf registry without bound
                bplan.release_leaves()
    return [rt.evaluate(outs) for outs in config_outputs]


def grid_search_lm(X: LTensor, y: LTensor, lambdas: Sequence[float],
                   runtime: Optional[LineageRuntime] = None,
                   mode: str = "auto"
                   ) -> tuple[np.ndarray, list[float]]:
    """Train one lmDS model per λ; returns (betas [n, k], training losses).

    Declared once per λ through `parfor`: gram(X)/xtv(X, y) are
    λ-invariant, so the batched path computes them once and vmaps only
    the solve + loss suffix over the λ axis; the sequential fallback
    recovers them through the lineage reuse cache instead.
    """
    n = X.shape[1]

    def model(lam: float):
        A = ops.gram(X) + float(lam) * ops.eye(n)
        b = ops.xtv(X, y)
        beta_t = ops.solve(A, b)
        resid = y - X @ beta_t
        loss_t = ops.sum_(resid * resid)
        return beta_t, loss_t

    results = parfor(list(lambdas), model, runtime=runtime, mode=mode)
    betas = [beta for beta, _ in results]
    losses = [float(loss) for _, loss in results]
    return np.concatenate(betas, axis=1), losses


def make_folds(x: np.ndarray, y: np.ndarray, k: int, seed: int = 42
               ) -> tuple[list[LTensor], list[LTensor]]:
    """Split into k folds ONCE as leaf tensors — stable leaves are what
    make per-fold intermediates reusable across fold iterations."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(x.shape[0])
    idxs = np.array_split(perm, k)
    fx = [input_tensor(f"foldX{i}", x[idx]) for i, idx in enumerate(idxs)]
    fy = [input_tensor(f"foldY{i}", y[idx]) for i, idx in enumerate(idxs)]
    return fx, fy


def cross_validate_lm(folds_x: list[LTensor], folds_y: list[LTensor],
                      reg: float = 1e-7,
                      runtime: Optional[LineageRuntime] = None,
                      mode: str = "auto"
                      ) -> tuple[np.ndarray, list[float]]:
    """k-fold CV for lmDS; returns (betas [n, k], held-out MSEs).

    Fold i's training leaves differ per configuration, so the batched
    template stacks them into batched leaves (equal fold sizes
    permitting — `np.array_split` remainders force the sequential
    path, where the reuse rewrites still share per-fold grams).
    """
    k = len(folds_x)
    n = folds_x[0].shape[1]

    def model(i: int):
        tx = [f for j, f in enumerate(folds_x) if j != i]
        ty = [f for j, f in enumerate(folds_y) if j != i]
        X = ops.rbind(*tx)
        y = ops.rbind(*ty)
        A = ops.gram(X) + reg * ops.eye(n)
        b = ops.xtv(X, y)
        beta_t = ops.solve(A, b)
        resid = folds_y[i] - folds_x[i] @ beta_t
        mse_t = ops.mean_(resid * resid)
        return beta_t, mse_t

    results = parfor(list(range(k)), model, runtime=runtime, mode=mode)
    betas = [beta for beta, _ in results]
    errors = [float(mse) for _, mse in results]
    return np.concatenate(betas, axis=1), errors
