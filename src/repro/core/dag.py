"""HOP DAG: the declarative linear-algebra IR (SystemDS §3.2).

Every user-level operation builds a `Node` in a high-level-operator DAG.
Nodes carry shape/dtype/sparsity estimates (size propagation) and a
structural *lineage hash* (SystemDS §4.1) that identifies the value a node
computes, given the lineage of its leaf inputs.

The DAG is lazy: `LTensor` wraps a node; evaluation happens through
`repro.core.compiler.compile_plan` + `repro.core.runtime.LineageRuntime`.
"""
from __future__ import annotations

import hashlib
import itertools
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import numpy as np

# --------------------------------------------------------------------------
# Node
# --------------------------------------------------------------------------

_counter = itertools.count()

# Density below which a 2D matrix is worth a sparse physical
# representation. Single source of truth shared by the cost model
# (`Node.est_bytes`, `repro.core.costmodel`), the compile-time format
# assignment pass (`repro.core.compiler.assign_formats`), and the
# executor (`repro.core.backend`), so the compiler and the runtime
# always agree on when sparse pays off.
SPARSE_THRESHOLD = 0.3

# opcodes with their arity class; used for validation only
ELEMENTWISE_BINARY = {
    "add", "sub", "mul", "div", "pow", "min2", "max2",
    "gt", "lt", "ge", "le", "eq", "ne", "and", "or",
}
ELEMENTWISE_UNARY = {
    "neg", "exp", "log", "sqrt", "abs", "sign", "round", "floor", "ceil",
    "sigmoid", "not",
}
AGGREGATES = {"sum", "mean", "max", "min", "colSums", "rowSums", "colMeans",
              "rowMeans", "colMaxs", "colMins", "colVars", "trace", "nnz"}


@dataclass(frozen=True)
class Node:
    """One high-level operator (HOP)."""

    op: str
    inputs: tuple["Node", ...]
    attrs: tuple[tuple[str, Any], ...]  # sorted key/value pairs, hashable
    shape: tuple[int, ...]
    dtype: Any
    sparsity: float  # estimated nnz / numel in [0, 1]
    # Where the *value* lives: 'local' (master memory), 'federated'
    # (row-partitioned across sites, never materialized at the master),
    # 'sharded' (row-sharded over the device mesh's `data` axis,
    # resident as one global array with a NamedSharding), or 'chunked'
    # (row-chunked on host, streamed through device memory one bucket
    # at a time — only partial aggregates are ever resident).
    # Set on federated input leaves at construction and propagated by
    # the compiler's placement passes (`lower_federated` /
    # `lower_distributed` / `lower_chunked` in `repro.core.compiler`);
    # deliberately not part of the lineage hash — placement describes a
    # physical location, not a value.
    placement: str = "local"
    uid: int = field(default_factory=lambda: next(_counter))

    # -- helpers ----------------------------------------------------------
    def attr(self, key: str, default: Any = None) -> Any:
        for k, v in self.attrs:
            if k == key:
                return v
        return default

    @property
    def numel(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    def est_bytes(self) -> int:
        """Memory estimate in bytes (dense; sparse gets a CSR-like discount)."""
        itemsize = np.dtype(self.dtype).itemsize
        dense = self.numel * itemsize
        if self.sparsity < SPARSE_THRESHOLD and len(self.shape) == 2:
            # values + column idx + row ptr, MCSR-style estimate
            nnz = int(self.numel * self.sparsity)
            return nnz * (itemsize + 4) + 4 * (self.shape[0] + 1)
        return dense

    # -- lineage hash ------------------------------------------------------
    def lhash(self, leaf_lineage: dict[int, str]) -> str:
        """Lineage hash given leaf lineage ids (uid -> stable id).

        Matches SystemDS's lineage DAG semantics: the hash identifies the
        *value*, i.e. two structurally identical computations over inputs
        with identical lineage collide (enabling reuse), while different
        input data or literals produce different hashes.

        Uncached by design: a per-node memo keyed on id(environment) can
        alias a dead environment after GC and return a stale hash, and a
        content key costs O(env) to build per call. Batch callers (the
        runtime) share one memo across a whole plan via `_lhash_rec`.
        """
        return _lhash_rec(self, leaf_lineage, {})

    def __repr__(self) -> str:  # concise
        return f"Node#{self.uid}:{self.op}{self.shape}"


def _lhash_rec(node: Node, leaf_lineage: dict[int, str], memo: dict[int, str]) -> str:
    got = memo.get(node.uid)
    if got is not None:
        return got
    if node.op == "input":
        base = leaf_lineage.get(node.uid)
        if base is None:
            base = f"input:{node.attr('name')}:{node.uid}"
        payload = f"leaf|{base}|{node.shape}"
    elif node.op == "literal":
        payload = f"lit|{node.attr('value')!r}|{node.dtype}"
    else:
        child = ",".join(_lhash_rec(i, leaf_lineage, memo) for i in node.inputs)
        payload = f"{node.op}|{node.attrs!r}|{node.shape}|{node.dtype}|{child}"
    h = hashlib.sha1(payload.encode()).hexdigest()
    memo[node.uid] = h
    return h


def structural_key(node: Node, memo: dict[int, str]) -> str:
    """Structural hash used by CSE: identical subgraphs (same leaves by uid)."""
    got = memo.get(node.uid)
    if got is not None:
        return got
    if node.op in ("input",):
        key = f"leaf{node.uid}"
    else:
        child = ",".join(structural_key(i, memo) for i in node.inputs)
        key = hashlib.sha1(
            f"{node.op}|{node.attrs!r}|{node.shape}|{node.dtype}|{child}"
            .encode()).hexdigest()
    memo[node.uid] = key
    return key


# --------------------------------------------------------------------------
# Shape / sparsity propagation (SystemDS §3.2 size propagation)
# --------------------------------------------------------------------------

def _bshape(a: tuple[int, ...], b: tuple[int, ...]) -> tuple[int, ...]:
    try:
        return tuple(np.broadcast_shapes(a, b))
    except ValueError as e:
        raise ValueError(f"incompatible shapes {a} vs {b}") from e


def _sp_mult(a: float, b: float) -> float:
    a, b = min(max(a, 0.0), 1.0), min(max(b, 0.0), 1.0)
    return max(a * b, 1e-6)  # independence assumption


def _sp_add(a: float, b: float) -> float:
    return min(1.0, a + b - a * b)


def make_node(op: str, inputs: Sequence[Node], shape, dtype, sparsity,
              placement: str = "local", **attrs) -> Node:
    return Node(op=op, inputs=tuple(inputs),
                attrs=tuple(sorted(attrs.items())),
                shape=tuple(int(d) for d in shape), dtype=np.dtype(dtype),
                sparsity=min(max(float(sparsity), 0.0), 1.0),
                placement=placement)


# --------------------------------------------------------------------------
# LTensor: the user-facing lazy tensor
# --------------------------------------------------------------------------

class LTensor:
    """Lazy tensor handle over a HOP DAG node.

    Supports numpy-flavoured operator overloading; `repro.core.ops` provides
    the functional surface (t, matmul, rbind, ...).
    """

    __slots__ = ("node",)
    __array_priority__ = 100  # beat numpy operator dispatch

    def __init__(self, node: Node):
        self.node = node

    # -- metadata ----------------------------------------------------------
    @property
    def shape(self) -> tuple[int, ...]:
        return self.node.shape

    @property
    def ndim(self) -> int:
        return len(self.node.shape)

    @property
    def dtype(self):
        return self.node.dtype

    # -- arithmetic --------------------------------------------------------
    def _bin(self, other, op, reverse=False):
        other = as_ltensor(other, like=self)
        a, b = (other, self) if reverse else (self, other)
        shape = _bshape(a.shape, b.shape)
        dtype = np.result_type(a.dtype, b.dtype)
        if op in ("mul",):
            sp = _sp_mult(a.node.sparsity, b.node.sparsity)
        elif op in ("add", "sub"):
            sp = _sp_add(a.node.sparsity, b.node.sparsity)
        else:
            sp = 1.0
        if op in ("gt", "lt", "ge", "le", "eq", "ne", "and", "or"):
            dtype = np.dtype(np.float32)  # SystemDS semantics: 0/1 matrices
        return LTensor(make_node(op, (a.node, b.node), shape, dtype, sp))

    def __add__(self, o): return self._bin(o, "add")
    def __radd__(self, o): return self._bin(o, "add", True)
    def __sub__(self, o): return self._bin(o, "sub")
    def __rsub__(self, o): return self._bin(o, "sub", True)
    def __mul__(self, o): return self._bin(o, "mul")
    def __rmul__(self, o): return self._bin(o, "mul", True)
    def __truediv__(self, o): return self._bin(o, "div")
    def __rtruediv__(self, o): return self._bin(o, "div", True)
    def __pow__(self, o): return self._bin(o, "pow")
    def __gt__(self, o): return self._bin(o, "gt")
    def __lt__(self, o): return self._bin(o, "lt")
    def __ge__(self, o): return self._bin(o, "ge")
    def __le__(self, o): return self._bin(o, "le")
    def __neg__(self):
        return LTensor(make_node("neg", (self.node,), self.shape, self.dtype,
                                 self.node.sparsity))

    def __matmul__(self, other):
        other = as_ltensor(other, like=self)
        a, b = self.node, other.node
        if a.shape[-1] != b.shape[0]:
            raise ValueError(f"matmul shape mismatch {a.shape} @ {b.shape}")
        shape = a.shape[:-1] + b.shape[1:]
        # sparsity of product: 1 - (1 - sa*sb)^k, capped
        k = a.shape[-1]
        base = min(max(1.0 - _sp_mult(a.sparsity, b.sparsity), 0.0), 1.0)
        sp = min(1.0, max(1e-6, 1.0 - base ** min(k, 1024)))
        return LTensor(make_node("matmul", (a, b), shape,
                                 np.result_type(a.dtype, b.dtype), sp))

    # -- indexing ----------------------------------------------------------
    def __getitem__(self, idx):
        """Static (python int / slice) indexing only — keeps sizes known."""
        if not isinstance(idx, tuple):
            idx = (idx,)
        norm: list[tuple[int, int, int]] = []  # (start, stop, kind) kind:0=slice,1=int
        shape = []
        for axis, it in enumerate(idx):
            dim = self.shape[axis]
            if isinstance(it, int):
                it = dim + it if it < 0 else it
                norm.append((it, it + 1, 1))
            elif isinstance(it, slice):
                start, stop, step = it.indices(dim)
                if step != 1:
                    raise ValueError("only unit-step slices supported")
                norm.append((start, stop, 0))
                shape.append(stop - start)
            else:
                raise TypeError(f"unsupported index {it!r}")
        for axis in range(len(idx), self.ndim):
            norm.append((0, self.shape[axis], 0))
            shape.append(self.shape[axis])
        return LTensor(make_node("slice", (self.node,), tuple(shape),
                                 self.dtype, self.node.sparsity,
                                 index=tuple(norm)))

    def reshape(self, *shape):
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        shape = tuple(int(s) for s in shape)
        if -1 in shape:
            known = -int(np.prod([s for s in shape if s != -1]))
            shape = tuple(self.node.numel // known if s == -1 else s
                          for s in shape)
        if int(np.prod(shape)) != self.node.numel:
            raise ValueError(f"cannot reshape {self.shape} -> {shape}")
        return LTensor(make_node("reshape", (self.node,), shape, self.dtype,
                                 self.node.sparsity, newshape=shape))

    @property
    def T(self):
        if self.ndim != 2:
            raise ValueError("T requires a matrix")
        return LTensor(make_node("t", (self.node,),
                                 (self.shape[1], self.shape[0]),
                                 self.dtype, self.node.sparsity))

    def __repr__(self):
        return f"LTensor({self.node.op}, shape={self.shape}, dtype={self.dtype})"


def as_ltensor(x, like: Optional[LTensor] = None) -> LTensor:
    if isinstance(x, LTensor):
        return x
    if isinstance(x, (int, float, bool, np.integer, np.floating)):
        dtype = like.dtype if like is not None else np.dtype(np.float32)
        if isinstance(x, bool):
            dtype = np.dtype(np.float32)
        node = make_node("literal", (), (), dtype,
                         0.0 if x == 0 else 1.0, value=float(x))
        return LTensor(node)
    if isinstance(x, np.ndarray) or hasattr(x, "__array__"):
        return input_tensor(None, np.asarray(x))
    raise TypeError(f"cannot convert {type(x)} to LTensor")


# --------------------------------------------------------------------------
# Leaf construction & data binding
# --------------------------------------------------------------------------

class _LeafRegistry:
    """Maps leaf node uid -> (bound array, lineage id)."""

    def __init__(self):
        self.values: dict[int, Any] = {}
        self.lineage: dict[int, str] = {}
        # per-leaf 4 KiB block-sum tables retained from the bind-time
        # content fingerprint (~0.2% of the leaf) — the streaming
        # executor's prefetch path DERIVES aligned slice fingerprints
        # from them instead of re-scanning the slices (see
        # `_slice_fingerprint`). Same soundness contract as `lineage`:
        # valid until the leaf is re-bound.
        self.fp_tables: dict[int, np.ndarray] = {}

    def bind(self, node: Node, value, lineage_id: str):
        self.values[node.uid] = value
        self.lineage[node.uid] = lineage_id
        self.fp_tables.pop(node.uid, None)


LEAVES = _LeafRegistry()
_input_counter = itertools.count()


_FP_WEIGHTS: dict[int, np.ndarray] = {}


def _fp_weights(n: int) -> np.ndarray:
    """Deterministic odd uint64 multipliers for the content checksum,
    memoized per length (lengths are few: the streaming executor's
    power-of-two chunk buckets plus whole-leaf sizes)."""
    w = _FP_WEIGHTS.get(n)
    if w is None:
        w = np.random.default_rng(0x5EED).integers(
            0, 1 << 63, size=n, dtype=np.uint64) | np.uint64(1)
        _FP_WEIGHTS[n] = w
    return w


_FP_BLOCK = 512  # uint64 words per checksum block (4 KiB)


def _fingerprint_and_table(arr: np.ndarray
                           ) -> tuple[str, Optional[np.ndarray]]:
    """`_fingerprint` that also returns the per-4 KiB block-sum table
    the large-buffer path reduces over (None on the small/raw path).
    The table is a free by-product of the scan the fingerprint already
    does; retaining it at leaf-bind time lets aligned slice
    fingerprints be *derived* later without touching the slice payload
    again (`_slice_fingerprint` — the streaming prefetch fast path)."""
    a = np.ascontiguousarray(arr)
    h = hashlib.sha1()
    h.update(str(a.shape).encode())
    h.update(str(a.dtype).encode())
    raw = a.view(np.uint8).reshape(-1)
    if raw.size <= 65536:
        h.update(raw.tobytes())
        return h.hexdigest(), None
    head = raw.size - (raw.size % 8)
    u = raw[:head].view(np.uint64)
    nb = u.size // _FP_BLOCK
    table = None
    if nb:
        blocks = u[: nb * _FP_BLOCK].reshape(nb, _FP_BLOCK)
        table = blocks.sum(axis=1, dtype=np.uint64)
        acc = (table * _fp_weights(nb)).sum(dtype=np.uint64)
        h.update(int(acc).to_bytes(8, "little"))
        u = u[nb * _FP_BLOCK:]
    h.update(u.tobytes())
    h.update(raw[head:].tobytes())
    return h.hexdigest(), table


def _fingerprint(arr: np.ndarray) -> str:
    """Cheap, deterministic content fingerprint for input lineage.

    Large buffers reduce to position-weighted 4 KiB-block sums mod
    2**64 (odd multipliers): any SINGLE word change is guaranteed to
    alter the checksum (its block sum shifts by delta, and delta * odd
    is never 0 mod 2**64), so one corrected cell always re-keys its
    chunk on the streaming executor's reuse path — no sampling blind
    spots. The whole buffer is read but the hot loop is a SIMD block
    sum (~0.2ms / 2 MB). Known insensitivity: permuting words WITHIN
    one 4 KiB block preserves its sum — far below the granularity of
    any chunk or leaf this keys."""
    return _fingerprint_and_table(arr)[0]


_FP_BLOCK_BYTES = _FP_BLOCK * 8  # 4 KiB


def _slice_fingerprint(sl: np.ndarray, table: np.ndarray,
                       byte_offset: int) -> Optional[str]:
    """Derive `_fingerprint(sl)` from the parent buffer's block-sum
    table without re-scanning the slice's full 4 KiB blocks.

    `sl` must be a contiguous slice of the table's parent starting
    `byte_offset` bytes in. Derivation is exact — bitwise the same hex
    digest `_fingerprint(sl)` computes — because the weight sequence is
    prefix-stable across lengths (`_fp_weights(n)` draws the same
    stream for every n) and the slice's own 4 KiB blocking coincides
    with the parent's whenever `byte_offset` is 4 KiB-aligned. Returns
    None when not derivable (unaligned offset, or the slice takes the
    small raw-bytes path): callers fall back to `_fingerprint`.

    Only the residual words past the last full block (< 4 KiB) are
    read from the slice itself, so deriving a bucket fingerprint is
    O(table slice) instead of O(bucket bytes) — the host-prep scan the
    streaming pipeline removes.
    """
    if byte_offset % _FP_BLOCK_BYTES:
        return None
    raw_n = sl.nbytes
    if raw_n <= 65536:
        return None  # raw path hashes actual bytes — nothing to derive
    head = raw_n - (raw_n % 8)
    nb = (head // 8) // _FP_BLOCK
    first = byte_offset // _FP_BLOCK_BYTES
    if first + nb > table.size:
        return None  # slice's full blocks overrun the parent's table
    h = hashlib.sha1()
    h.update(str(sl.shape).encode())
    h.update(str(sl.dtype).encode())
    if nb:
        acc = (table[first:first + nb]
               * _fp_weights(nb)).sum(dtype=np.uint64)
        h.update(int(acc).to_bytes(8, "little"))
    raw = np.ascontiguousarray(sl).view(np.uint8).reshape(-1)
    h.update(raw[nb * _FP_BLOCK_BYTES:head].tobytes())
    h.update(raw[head:].tobytes())
    return h.hexdigest()


def batch_input(name: Optional[str], stacked,
                sparsity: Optional[float] = None,
                lineage_id: Optional[str] = None) -> LTensor:
    """Create a *batched* leaf: one template node standing for k
    per-configuration values (the `parfor` config axis, §5).

    The node's shape/dtype/sparsity describe ONE element — size
    propagation, rewrites, and the cost model see the per-config plan —
    while the bound value is the stacked ``(k,) + elem_shape`` array.
    The batch axis exists only in the execution layer: the batched
    compiler (`repro.core.batching`) marks every transitive consumer as
    config-variant and the runtime maps those segments over axis 0 with
    `jax.vmap`. Leaves carry ``batch=k`` in their attrs (still
    ``op == 'input'`` so leaf binding/lineage/rewrites need no special
    cases); `is_batched_leaf` is the single detection helper.
    """
    arr = np.asarray(stacked)
    if arr.ndim < 1 or arr.shape[0] < 1:
        raise ValueError(
            f"batch_input needs a stacked (k, ...) array, got {arr.shape}")
    k = int(arr.shape[0])
    if sparsity is None:
        if arr.size and np.issubdtype(arr.dtype, np.floating):
            sample = arr.ravel()[: 4096]
            sparsity = float(np.count_nonzero(sample)) / sample.size
        else:
            sparsity = 1.0
    name = name or f"cfg{next(_input_counter)}"
    node = make_node("input", (), arr.shape[1:], arr.dtype, sparsity,
                     name=name, batch=k)
    # lineage is content-only (no auto-generated name): re-hoisting the
    # same grid in a later parfor call yields the same lineage id, so
    # repeated identical grids hit the reuse cache across calls
    lid = lineage_id or f"batch:{_fingerprint(arr)}"
    LEAVES.bind(node, arr, lid)
    return LTensor(node)


def is_batched_leaf(node: Node) -> bool:
    """True for leaves created by `batch_input` (the hoisted config axis)."""
    return node.op == "input" and node.attr("batch") is not None


def input_tensor(name: Optional[str], value, sparsity: Optional[float] = None,
                 lineage_id: Optional[str] = None) -> LTensor:
    """Create a leaf bound to concrete data.

    Lineage of an input is its name + content fingerprint (SystemDS traces
    inputs "by name"; we add a fingerprint so re-bound different data never
    aliases in the reuse cache).
    """
    arr = np.asarray(value)
    if sparsity is None:
        if arr.size and np.issubdtype(arr.dtype, np.floating):
            sample = arr.ravel()[: 4096]
            sparsity = float(np.count_nonzero(sample)) / sample.size
        else:
            sparsity = 1.0
    name = name or f"in{next(_input_counter)}"
    node = make_node("input", (), arr.shape, arr.dtype, sparsity, name=name)
    table = None
    if lineage_id is None:
        fp, table = _fingerprint_and_table(arr)
        lid = f"{name}:{fp}"
    else:
        lid = lineage_id
    LEAVES.bind(node, arr, lid)
    if table is not None:
        # retained for slice-fingerprint derivation on the streaming
        # prefetch path — a free by-product of the scan above
        LEAVES.fp_tables[node.uid] = table
    return LTensor(node)
