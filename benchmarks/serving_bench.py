"""Low-latency scoring: coalesced serving vs sequential single-request.

ISSUE 7: a trained lmDS-style scoring plan deployed behind
`repro.serving.ModelServer`:

  * **closed-loop throughput** — 8 concurrent clients scoring through
    the server (requests coalesce onto warm vmapped buckets) vs the
    same request stream scored one-at-a-time through the solo
    `PreparedScript` path; the coalesced path must sustain >= 3x.
  * **open-loop latency** — a seeded Poisson arrival process at several
    offered rates; per-request p50/p99 latency and sustained QPS.

Asserts zero hot-path retraces after deploy-time warmup
(`RuntimeStats.serving.retraces`) and bitwise parity between coalesced
and sequential scoring (single-row requests — see tests/test_serving.py
for why single-row contractions are the bitwise-stable serving shape).

Appends a trajectory entry to ``benchmarks/BENCH_serving.json``.
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

from .common import COLS, emit

BENCH_JSON = os.path.join(os.path.dirname(__file__), "BENCH_serving.json")


def _make_script(d: int, rt, rng):
    from repro.core import input_tensor, ops
    from repro.core.runtime import PreparedScript

    beta = input_tensor("srv_beta", rng.normal(size=(d, 1)))

    def scoring(x):
        return ops.matmul(x, beta)

    return PreparedScript(scoring, [(1, d)], runtime=rt)


def _closed_loop(server, script, rows, concurrency: int) -> dict:
    """Closed-loop at offered concurrency `concurrency`: a pipelining
    client keeps that many requests in flight (`ModelServer.submit` /
    `ScoreFuture.result`, the event-loop client shape) vs the same
    stream scored one-at-a-time through the solo `PreparedScript`."""
    from collections import deque

    n = len(rows)
    # sequential baseline: one request at a time, no coalescing
    t0 = time.perf_counter()
    seq = [script(x) for x in rows]
    t_seq = time.perf_counter() - t0

    results: list = [None] * n
    outstanding: deque = deque()
    t0 = time.perf_counter()
    i = 0
    while i < n or outstanding:
        while i < n and len(outstanding) < concurrency:
            outstanding.append((i, server.submit(rows[i])))
            i += 1
        j, fut = outstanding.popleft()
        results[j] = fut.result()
    t_coal = time.perf_counter() - t0

    for got, ref in zip(results, seq):      # exact output parity
        for a, b in zip(got, ref):
            assert (a == b).all(), "coalesced != sequential scoring"
    return dict(n=n,
                sequential_qps=n / t_seq,
                coalesced_qps=n / t_coal,
                sequential_us_per_call=t_seq / n * 1e6,
                coalesced_us_per_call=t_coal / n * 1e6,
                speedup=t_seq / t_coal)


def _open_loop(server, d: int, rate_qps: float, n: int, seed: int) -> dict:
    """Seeded-Poisson open-loop load: one thread per request fires at
    its scheduled arrival regardless of completions (no coordinated
    omission); reports per-request latency percentiles, sustained QPS
    over the span from first arrival to last completion, and queue-idle
    time — span minus dispatch-stage busy seconds
    (`ServingLog.busy_s`), i.e. how much headroom the request path
    still has at this offered rate."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_qps, size=n)
    arrivals = np.cumsum(gaps)
    rows = [rng.normal(size=(1, d)) for _ in range(n)]
    lat_us = [0.0] * n
    done_at = [0.0] * n
    busy0 = server.runtime.stats.serving.busy_s
    start = time.perf_counter() + 0.05   # common epoch for all threads

    def fire(i):
        delay = start + arrivals[i] - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        t0 = time.perf_counter()
        server.score(rows[i])
        t1 = time.perf_counter()
        lat_us[i] = (t1 - t0) * 1e6
        done_at[i] = t1

    threads = [threading.Thread(target=fire, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    span = max(done_at) - (start + float(arrivals[0]))
    p50, p99 = np.percentile(lat_us, [50, 99])
    busy = server.runtime.stats.serving.busy_s - busy0
    idle = max(span - busy, 0.0)
    return dict(rate=rate_qps, n=n, p50_us=float(p50), p99_us=float(p99),
                qps=n / span, busy_s=float(busy),
                queue_idle_s=float(idle),
                idle_frac=float(idle / span) if span > 0 else 0.0)


def main(d: int = COLS, n: int = 512, concurrency: int = 16,
         max_batch: int = 16, rates=(500.0, 2000.0),
         openloop_n: int = 200) -> dict:
    from repro.core import LineageRuntime, clear_jit_cache
    from repro.serving import ModelServer

    clear_jit_cache()
    rng = np.random.default_rng(7)
    rt = LineageRuntime()
    script = _make_script(d, rt, rng)
    rows = [rng.normal(size=(1, d)) for _ in range(n)]

    server = ModelServer(script, runtime=rt, max_batch=max_batch,
                         max_wait_us=2000.0)
    t0 = time.perf_counter()
    server.deploy()
    t_deploy = time.perf_counter() - t0

    closed = _closed_loop(server, script, rows, concurrency)
    open_runs = [_open_loop(server, d, r, openloop_n, seed=int(r))
                 for r in rates]

    log = rt.stats.serving
    assert log.retraces == 0, \
        f"hot path recompiled {log.retraces}x after deploy warmup"
    assert closed["speedup"] >= 3.0, \
        f"coalesced throughput only {closed['speedup']:.2f}x sequential " \
        f"at concurrency {concurrency} (>= 3x required)"

    emit("serving_coalesced", closed["coalesced_us_per_call"] * 1e-6,
         f"seq_us={closed['sequential_us_per_call']:.1f};"
         f"conc={concurrency};speedup={closed['speedup']:.2f}x")
    for runm in open_runs:
        emit(f"serving_openloop_{int(runm['rate'])}qps",
             runm["p50_us"] * 1e-6,
             f"p99_us={runm['p99_us']:.0f};qps={runm['qps']:.0f};"
             f"idle_frac={runm['idle_frac']:.2f}")

    entry = dict(
        benchmark="serving_coalesce",
        workload=f"score (1x{d})@({d}x1), conc={concurrency}, "
                 f"max_batch={max_batch}",
        deploy_warmup_us_per_call=round(t_deploy * 1e6, 1),
        sequential_us_per_call=round(closed["sequential_us_per_call"], 1),
        coalesced_us_per_call=round(closed["coalesced_us_per_call"], 1),
        speedup=round(closed["speedup"], 2),
        sequential_qps=round(closed["sequential_qps"], 1),
        coalesced_qps=round(closed["coalesced_qps"], 1),
        retraces=int(log.retraces),
        mean_coalesce=round(log.requests / max(log.batches, 1), 2),
        parity="bitwise",
        ts=time.strftime("%Y-%m-%dT%H:%M:%S"),
    )
    for runm in open_runs:      # flattened latency columns (aggregate())
        tag = f"load{int(runm['rate'])}"
        entry[f"{tag}_p50_us"] = round(runm["p50_us"], 1)
        entry[f"{tag}_p99_us"] = round(runm["p99_us"], 1)
        entry[f"{tag}_qps"] = round(runm["qps"], 1)
        entry[f"{tag}_idle_frac"] = round(runm["idle_frac"], 3)

    server.shutdown()

    trajectory = []
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                trajectory = json.load(f)
        except Exception:
            trajectory = []
    trajectory.append(entry)
    with open(BENCH_JSON, "w") as f:
        json.dump(trajectory, f, indent=2)
    return entry


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    print("name,us_per_call,derived")
    if "--smoke" in sys.argv:
        out = main(d=64, n=256, concurrency=8, max_batch=8,
                   rates=(500.0, 1000.0), openloop_n=120)
    else:
        out = main()
    print(json.dumps(out, indent=2))
