"""deepseek-v2-236b — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].

60L d_model=5120 128H d_ff=1536 (expert size) vocab=102400, MoE 160e
top-6, first layer dense (d_ff 12288 dense MLP), q_lora_rank=1536.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,        # MLA: latent-shared; head count for Q
    d_head=128,            # qk_nope_head_dim
    d_ff=12288,            # dense first-layer MLP
    vocab_size=102400,
    attn_type="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    d_expert=1536,
    first_dense_layers=1,
    rope_theta=10000.0,
)
