"""Gradient compression with error feedback (cross-pod sync traffic).

int8 quantization with per-leaf scale + error-feedback residual: the
cross-pod exchange moves 1 byte/param instead of 4 (the all-reduce is
realized as all_gather-of-int8 + local dequant-mean, which is what makes
the wire format actually narrow). Error feedback keeps the long-run
update unbiased (residual carried to the next round).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def quantize_int8(g: jnp.ndarray, err: jnp.ndarray
                  ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q int8, scale f32 scalar, new_err)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, err_state: Any) -> tuple[Any, Any, Any]:
    """Quantize every leaf; returns (q_tree, scale_tree, new_err_state)."""
    flat, treedef = jax.tree_util.tree_flatten(grads)
    errs = treedef.flatten_up_to(err_state)
    qs, scales, new_errs = [], [], []
    for g, e in zip(flat, errs):
        q, s, ne = quantize_int8(g, e)
        qs.append(q)
        scales.append(s)
        new_errs.append(ne)
    return (treedef.unflatten(qs), treedef.unflatten(scales),
            treedef.unflatten(new_errs))


def init_error_state(grads_like: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def crosspod_mean_int8(q_tree: Any, scale_tree: Any, axis_name: str) -> Any:
    """Inside shard_map/pmap over `axis_name`: exchange int8 + scales,
    return the dequantized mean. Wire bytes = 1/4 of f32 all-reduce."""
    def combine(q, s):
        qs = jax.lax.all_gather(q, axis_name)          # int8 on the wire
        ss = jax.lax.all_gather(s, axis_name)
        deq = qs.astype(jnp.float32) * ss.reshape(
            (-1,) + (1,) * (qs.ndim - 1))
        return deq.mean(axis=0)

    return jax.tree_util.tree_map(combine, q_tree, scale_tree)


def compressed_bytes(grads: Any) -> tuple[int, int]:
    """(int8 wire bytes, f32 wire bytes) for reporting."""
    n = sum(int(jnp.size(l)) for l in jax.tree_util.tree_leaves(grads))
    return n + 4 * len(jax.tree_util.tree_leaves(grads)), 4 * n
