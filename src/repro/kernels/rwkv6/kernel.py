"""Pallas TPU kernel for chunked WKV6 (RWKV-6 time-mix recurrence).

Grid = (B·H, n_chunks), chunks innermost. The (dh, dh) state matrix
lives in f32 VMEM scratch and persists across the chunk sweep for each
(batch, head) cell — the TPU analogue of keeping the recurrent state in
registers/SRAM in the official CUDA kernel (DESIGN.md §2).

Intra-chunk coefficients exp(lw_ex[t] − lw[s]) are factored per
sub-block pair (b, a) around a boundary next to block a (GLA-style
secondary chunking), so every materialized exponent is bounded by
SUB·MAX_DECAY — numerically stable under maximal decays. The pair loop
is statically unrolled ((C/SUB)(C/SUB+1)/2 small matmuls).

VMEM per cell ≈ 4·C·dh·4 (r,k,v,lw) + dh²·4 (state) + C²·4 ≈ 0.2 MB at
C = 64, dh = 64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.models.rwkv6 import MAX_DECAY, SUB  # single source of truth


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return jax.ShapeDtypeStruct(shape, dtype)


def _dot(a, b, dims):
    return jax.lax.dot_general(a, b, (dims, ((), ())),
                               preferred_element_type=jnp.float32)


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                y_ref, sout_ref, state_scr, *, C: int, dh: int, n_c: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)                  # (C, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)                  # log-decay, < 0
    u = u_ref[0].astype(jnp.float32)                  # (dh,)

    lw = jnp.cumsum(w, axis=0)                        # inclusive
    lw_ex = lw - w                                    # exclusive

    # inter-chunk + bonus diagonal
    y = _dot(r * jnp.exp(lw_ex), state_scr[...], ((1,), (0,)))
    diag = jnp.sum(r * u * k, axis=1)                 # (C,)
    y = y + diag[:, None] * v

    # intra-chunk sub-block pairs (statically unrolled)
    nu = C // SUB
    strict = (jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 0)
              > jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 1))
    for b in range(nu):
        t0 = b * SUB
        rb = r[t0:t0 + SUB]
        lweb = lw_ex[t0:t0 + SUB]
        acc = jnp.zeros((SUB, dh), jnp.float32)
        for a in range(b + 1):
            s0 = a * SUB
            base = lw_ex[t0][None, :] if a == b \
                else lw[s0 + SUB - 1][None, :]
            left = rb * jnp.exp(lweb - base)
            right = k[s0:s0 + SUB] * jnp.exp(base - lw[s0:s0 + SUB])
            A = _dot(left, right, ((1,), (1,)))       # (SUB, SUB)
            if a == b:
                A = jnp.where(strict, A, 0.0)
            acc = acc + _dot(A, v[s0:s0 + SUB], ((1,), (0,)))
        y = jax.lax.dynamic_update_slice_in_dim(y, y[t0:t0 + SUB] + acc,
                                                t0, axis=0)

    y_ref[0] = y.astype(y_ref.dtype)

    # state update (all exponents <= 0)
    lw_last = lw[-1]                                  # (dh,)
    decay_rest = jnp.exp(lw_last[None, :] - lw)       # (C, dh)
    state_scr[...] = (jnp.exp(lw_last)[:, None] * state_scr[...]
                      + _dot(k * decay_rest, v, ((0,), (0,))))

    @pl.when(c == n_c - 1)
    def _flush():
        sout_ref[0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, logw, u, state, *, chunk: int = 64,
                interpret: bool = False):
    """r,k,v,logw: (BH, S, dh); u: (BH, dh); state: (BH, dh, dh)."""
    BH, S, dh = r.shape
    C = min(chunk, S)
    assert S % C == 0 and C % SUB == 0, (S, C, SUB)
    n_c = S // C
    grid = (BH, n_c)
    y, s_out = pl.pallas_call(
        functools.partial(_wkv_kernel, C=C, dh=dh, n_c=n_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, dh), lambda h, c: (h, c, 0)),  # r
            pl.BlockSpec((1, C, dh), lambda h, c: (h, c, 0)),  # k
            pl.BlockSpec((1, C, dh), lambda h, c: (h, c, 0)),  # v
            pl.BlockSpec((1, C, dh), lambda h, c: (h, c, 0)),  # logw
            pl.BlockSpec((1, dh), lambda h, c: (h, 0)),        # u
            pl.BlockSpec((1, dh, dh), lambda h, c: (h, 0, 0)),  # s0
        ],
        out_specs=[
            pl.BlockSpec((1, C, dh), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, dh, dh), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, dh), r.dtype),
            jax.ShapeDtypeStruct((BH, dh, dh), jnp.float32),
        ],
        scratch_shapes=[_vmem((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, state)
    return y, s_out
