"""Production mesh builders.

Functions (not module-level constants) so importing this module never
touches jax device state — required for the forced-512-device dry-run
to control initialization order.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 explicit-sharding API; older jax has no AxisType
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    _AxisType = None


def _mk(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    if _AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(_AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    return _mk(shape, axes)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
