"""Federated ML demo (paper §4.3): enterprise sites keep their data,
exchange only aggregates.

  1. federated lmDS *through the compiler* — the ordinary DSL program
     over a `federated_input` leaf: the placement pass lowers gram/xtv
     to fed_* instructions (see the EXPLAIN dump: `[F]` targets, `:fed`
     values), per-site work runs as compiled jit sub-segments, and the
     runtime meters every exchanged byte per site.
  2. the eager-numpy oracle (`federated_lmds`) for comparison — same
     answer, same bytes.
  3. FedAvg mini-batch training of a small LM head across 4 sites with
     int8-compressed parameter deltas (the cross-pod schedule of
     distributed/fedavg).

    PYTHONPATH=src python examples/federated_lm.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np


def main():
    import jax.numpy as jnp
    from repro.core import (FederatedTensor, LineageRuntime, ReuseCache,
                            federated_input, input_tensor, ops)
    from repro.core.compiler import compile_plan
    from repro.core.federated import federated_lmds
    from repro.data.synthetic import gen_regression
    from repro.distributed.fedavg import FedAvgTrainer

    # -- 1. federated lmDS through the DAG -> placement -> segment stack --
    x, y, beta_true = gen_regression(8000, 64, seed=1)
    fed = FederatedTensor.partition_rows(x, n_sites=4)
    X, Y = federated_input("X", fed), input_tensor("y", y)
    beta_t = ops.solve(ops.gram(X) + 1e-6 * ops.eye(64), ops.xtv(X, Y))
    plan = compile_plan([beta_t])
    print("== EXPLAIN (federated placement) ==")
    print(plan.explain())

    rt = LineageRuntime(cache=ReuseCache())
    beta = rt.run_plan(plan)[0]
    ref = np.linalg.solve(x.T @ x + 1e-6 * np.eye(64), x.T @ y)
    print(f"\ncompiled federated lmDS: max err vs centralized = "
          f"{np.abs(beta - ref).max():.2e}")
    print(f"  exchange: {rt.stats.exchange.as_dict()}")
    rt.run_plan(plan)  # warm: lineage hits skip the sites entirely
    print(f"  repeat solve: reuse hits={rt.cache.stats.hits}, "
          f"exchange unchanged={rt.stats.exchange.total:,}B")

    # -- 2. the eager numpy oracle: same answer, same bytes ---------------
    fed2 = FederatedTensor.partition_rows(x, n_sites=4)
    beta2 = federated_lmds(fed2, y, reg=1e-6)
    print(f"eager oracle: max err vs compiled = "
          f"{np.abs(beta2 - beta).max():.2e}; bytes exchanged "
          f"{fed2.log.total:,} (compiled moved {rt.stats.exchange.total:,};"
          f" centralizing would move {x.nbytes:,})")

    # -- 3. FedAvg with relaxed sync + int8 compression -------------------
    w_true = np.random.default_rng(0).normal(size=(64, 1))

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    def site_batch(site, step):
        r = np.random.default_rng(1000 * site + step)
        xs = r.normal(size=(128, 64))
        return {"x": jnp.asarray(xs),
                "y": jnp.asarray(xs @ w_true
                                 + 0.05 * r.normal(size=(128, 1)))}

    for compress in (False, True):
        tr = FedAvgTrainer(loss_fn=loss_fn, n_sites=4, sync_every=8,
                           lr=5e-2, compress_int8=compress)
        tr.init({"w": jnp.zeros((64, 1))})
        for step in range(120):
            for s in range(4):
                tr.local_step(s, site_batch(s, step))
            tr.maybe_sync()
        err = float(np.abs(np.asarray(tr.anchor["w"]) - w_true).max())
        print(f"FedAvg (int8={compress}): max err={err:.3f}, "
              f"wire bytes={tr.bytes_exchanged:,}")


if __name__ == "__main__":
    main()
