"""Declarative lifecycle builtins (SystemDS Fig. 1 stack / Fig. 2 example).

DML-bodied builtin analogues, written on the lineage-traced DSL so the
compiler rewrites + reuse cache optimize across lifecycle tasks."""
from .regression import (lm, lmCG, lmDS, lmDS_federated,  # noqa: F401
                         steplm, steplm_federated)
from .validation import (cross_validate_lm, grid_search_lm,  # noqa: F401
                         parfor)
from .cleaning import (impute_by_mean, impute_by_median, mice_lite,  # noqa: F401
                       outlier_by_iqr, outlier_by_sd, scale_matrix,
                       winsorize)
from .algorithms import kmeans, l2svm, mlogreg, pca  # noqa: F401
