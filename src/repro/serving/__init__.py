"""repro.serving — low-latency scoring of compiled lifecycle plans.

The deployment end of the SystemDS lifecycle (§2: "model deployment
and scoring" as a first-class lifecycle stage, JMLC-style embedded
scoring): a `PreparedScript` is AOT-compiled at *deploy* time — every
power-of-two vmap bucket of its batched serving plan is warmed and
pinned in the jit cache — and live requests are coalesced onto those
warm bucketed executables with zero compiles on the request path.

Not to be confused with `repro.launch.serve`, the transformer
prefill/decode text-generation driver for the LM model zoo; this
package serves *plans* (lmDS scoring, pipelines), not token loops.

    server = ModelServer(script, max_batch=16, max_wait_us=2000)
    server.deploy()                  # compile + warm + pin, off-path
    yhat, = server.score(x)          # thread-safe, coalesced
    server.shutdown()
"""
from .server import ModelServer, QueueFullError, ScoreFuture  # noqa: F401

__all__ = ["ModelServer", "QueueFullError", "ScoreFuture"]
