"""Fused-segment JIT engine: segmentation structure, executable cache,
and `fuse=True` vs `fuse=False` parity (numerical results + reuse-cache
hit counts) across representative plans."""
import numpy as np
import pytest

from repro.core import (LineageRuntime, PreparedScript, ReuseCache,
                        clear_jit_cache, input_tensor, ops)
from repro.core.compiler import compile_plan


def _ridge(x, y, lam=0.1):
    n = x.shape[1]
    return ops.solve(ops.gram(x) + lam * ops.eye(n), ops.xtv(x, y))


def _pipeline(x, w):
    """Scoring-style chain: matmul + elementwise + aggregate + concat."""
    z = x @ w
    p = ops.sigmoid(z)
    err = p - 0.5
    g = ops.xtv(x, err * 2.0) + 1e-3 * w
    loss = ops.sum_(err * err)
    stats = ops.cbind(ops.colSums(err), ops.colMaxs(err))
    return loss, g, stats


class TestSegmentation:
    def test_fusion_produces_multi_op_segments(self, rng):
        x = input_tensor("X", rng.normal(size=(60, 8)))
        y = input_tensor("y", rng.normal(size=(60, 1)))
        plan = compile_plan([_ridge(x, y)])
        segs = plan.segments_for(False)
        assert sum(len(s.instructions) for s in segs) == \
            len(plan.instructions)
        assert len(segs) < len(plan.instructions)
        assert any(s.fused for s in segs)

    def test_reuse_active_segments_break_at_probe_points(self, rng):
        x = input_tensor("X", rng.normal(size=(60, 8)))
        y = input_tensor("y", rng.normal(size=(60, 1)))
        plan = compile_plan([_ridge(x, y)], reuse_enabled=True)
        segs = plan.segments_for(True)
        # cost-gated probing: segments stay maximal between probe
        # points instead of degenerating to one instruction each
        assert sum(len(s.instructions) for s in segs) == \
            len(plan.instructions)
        assert len(segs) < len(plan.instructions)
        assert any(s.fused for s in segs)
        # heavy ops are probe points; trivial generators are not
        probes = {ins.node.op for ins in plan.instructions if ins.probe}
        assert {"gram", "xtv", "solve"} <= probes
        assert "literal" not in probes and "eye" not in probes
        for s in segs:
            for pos, ins in enumerate(s.instructions):
                if ins.probe:
                    # a probe is always segment-final and observable
                    assert pos == len(s.instructions) - 1
                    assert ins.out_id in s.output_uids

    def test_reuse_probe_annotated_in_explain(self, rng):
        x = input_tensor("X", rng.normal(size=(60, 8)))
        y = input_tensor("y", rng.normal(size=(60, 1)))
        plan = compile_plan([_ridge(x, y)], reuse_enabled=True)
        txt = plan.explain(reuse_active=True)
        assert "[reuse-probe]" in txt
        # without a cache the marker disappears
        assert "[reuse-probe]" not in plan.explain(reuse_active=False)

    def test_target_change_breaks_segment(self, rng):
        x = input_tensor("X", rng.normal(size=(64, 64)))
        y = input_tensor("y", rng.normal(size=(4, 4)))
        expr = ops.sum_(ops.gram(x)) + ops.sum_(y)
        plan = compile_plan([expr], local_budget=1 << 14)
        targets = {ins.target for ins in plan.instructions}
        assert targets == {"local", "distributed"}  # plan really splits
        segs = plan.segments_for(False)
        assert len(segs) >= 2
        for s in segs:  # no segment mixes heavy local and distributed ops
            heavy = {ins.target for ins in s.instructions
                     if ins.input_ids or ins.node.shape != ()}
            assert len(heavy) <= 1

    def test_scalar_literals_do_not_break_segments(self, rng):
        # a literal is target-neutral: gram [distributed] + 1.0 [local
        # scalar] must still fuse into a single segment
        x = input_tensor("X", rng.normal(size=(64, 64)))
        plan = compile_plan([ops.gram(x) + 1.0], local_budget=1 << 10)
        segs = plan.segments_for(False)
        assert len(segs) == 1 and segs[0].fused

    def test_segment_keys_are_uid_independent(self, rng):
        xn = rng.normal(size=(40, 6))
        yn = rng.normal(size=(40, 1))
        p1 = compile_plan(
            [_ridge(input_tensor("A", xn), input_tensor("b", yn))])
        p2 = compile_plan(
            [_ridge(input_tensor("C", xn + 1.0), input_tensor("d", yn))])
        keys1 = [s.key for s in p1.segments_for(False)]
        keys2 = [s.key for s in p2.segments_for(False)]
        assert keys1 == keys2  # same computation, different uids/data

    def test_same_body_different_outputs_distinct_keys(self, rng):
        # identical instruction bodies but different exported sets must
        # not collide in the process-wide executable cache
        clear_jit_cache()
        x1 = input_tensor("X1", rng.normal(size=(16, 4)))
        x2n = rng.normal(size=(16, 4))
        x2 = input_tensor("X2", x2n)
        rt = LineageRuntime(fuse=True)
        rt.evaluate([ops.gram(x1) + ops.eye(4)])          # one output
        g, ge = rt.evaluate([ops.gram(x2),                # two outputs
                             ops.gram(x2) + ops.eye(4)])
        np.testing.assert_allclose(g, x2n.T @ x2n, rtol=1e-10)
        np.testing.assert_allclose(ge, x2n.T @ x2n + np.eye(4), rtol=1e-10)

    def test_explain_annotates_segments(self, rng):
        x = input_tensor("X", rng.normal(size=(30, 5)))
        txt = compile_plan([x.T @ x]).explain()
        assert "-- segment 0" in txt
        assert "gram" in txt and "outputs:" in txt


class TestParity:
    def test_lifecycle_regression_parity(self, rng):
        from repro.lifecycle.regression import lmDS
        x = input_tensor("X", rng.normal(size=(120, 10)))
        y = input_tensor("y", rng.normal(size=(120, 1)))
        b_fused = lmDS(x, y, runtime=LineageRuntime(fuse=True))
        b_interp = lmDS(x, y, runtime=LineageRuntime(fuse=False))
        np.testing.assert_allclose(b_fused, b_interp, rtol=1e-10,
                                   atol=1e-12)

    def test_mixed_pipeline_parity(self, rng):
        x = input_tensor("X", rng.normal(size=(50, 12)))
        w = input_tensor("w", rng.normal(size=(12, 1)))
        outs_f = LineageRuntime(fuse=True).evaluate(list(_pipeline(x, w)))
        outs_i = LineageRuntime(fuse=False).evaluate(list(_pipeline(x, w)))
        for a, b in zip(outs_f, outs_i):
            np.testing.assert_allclose(a, b, rtol=1e-10, atol=1e-12)

    def test_generators_and_slicing_parity(self, rng):
        x = input_tensor("X", rng.normal(size=(20, 8)))
        expr = (x[2:12, 1:5] * ops.rand((10, 4), seed=3)
                + ops.seq(0, 9) @ ops.ones((1, 4)))
        expr = ops.where(expr > 0.0, ops.sqrt(ops.abs_(expr)), expr)
        a = LineageRuntime(fuse=True).evaluate([expr])[0]
        b = LineageRuntime(fuse=False).evaluate([expr])[0]
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12)

    def test_cv_reuse_hits_and_values_match(self, rng):
        from repro.lifecycle.validation import cross_validate_lm, make_folds
        x = rng.normal(size=(160, 6))
        y = rng.normal(size=(160, 1))
        results, stats = {}, {}
        for fuse in (True, False):
            rt = LineageRuntime(cache=ReuseCache(), fuse=fuse)
            fx, fy = make_folds(x, y, 4, seed=5)
            results[fuse], _ = cross_validate_lm(fx, fy, runtime=rt)
            stats[fuse] = (rt.cache.stats.probes, rt.cache.stats.hits,
                           rt.cache.stats.misses)
        np.testing.assert_allclose(results[True], results[False],
                                   rtol=1e-9, atol=1e-10)
        assert stats[True] == stats[False]  # identical reuse behaviour

    def test_grid_search_reuse_hits_match(self, rng):
        xn = rng.normal(size=(100, 8))
        yn = rng.normal(size=(100, 1))
        hits = {}
        for fuse in (True, False):
            rt = LineageRuntime(cache=ReuseCache(), fuse=fuse)
            x, y = input_tensor("X", xn), input_tensor("y", yn)
            for lam in (0.1, 1.0, 10.0):
                rt.evaluate([_ridge(x, y, lam)])
            hits[fuse] = (rt.cache.stats.probes, rt.cache.stats.hits)
            assert rt.cache.stats.hits >= 4  # gram+xtv reused per extra lam
        assert hits[True] == hits[False]

    def test_multi_output_probe_segment_compensation(self, rng):
        # in the ridge plan xtv's segment also exports the add result;
        # a cache hit on xtv must still produce the add value (the
        # compensation executable re-runs the segment minus the cached
        # op), count as reused, and match the uncached answer
        xn = rng.normal(size=(200, 16))
        yn = rng.normal(size=(200, 1))
        x, y = input_tensor("X", xn), input_tensor("y", yn)
        rt = LineageRuntime(cache=ReuseCache(), fuse=True)
        rt.evaluate([_ridge(x, y, 0.1)])
        reused0 = rt.stats.reused
        out = rt.evaluate([_ridge(x, y, 0.5)])[0]  # gram + xtv hit
        assert rt.stats.reused >= reused0 + 2
        ref = np.linalg.solve(xn.T @ xn + 0.5 * np.eye(16), xn.T @ yn)
        np.testing.assert_allclose(out, ref, rtol=1e-8, atol=1e-10)

    def test_reuse_hits_match_under_eviction_pressure(self, rng):
        # entry costs are the compile-time estimates in both modes, so
        # eviction ordering — and therefore hits — cannot diverge even
        # when the pool churns
        xs = [rng.normal(size=(200, 32)) for _ in range(6)]
        stats = {}
        for fuse in (True, False):
            rt = LineageRuntime(cache=ReuseCache(budget_bytes=1 << 14),
                                fuse=fuse)
            tensors = [input_tensor(f"E{i}", x)
                       for i, x in enumerate(xs)]
            for t in tensors + tensors:
                rt.evaluate([ops.gram(t)])
            stats[fuse] = (rt.cache.stats.probes, rt.cache.stats.hits,
                           rt.cache.stats.misses,
                           rt.cache.stats.evictions)
        assert stats[True] == stats[False]
        assert stats[True][3] > 0  # evictions actually happened

    def test_prepared_script_parity(self, rng):
        def fn(a, b):
            return _ridge(a, b, 0.05)
        ps_f = PreparedScript(fn, [(64, 6), (64, 1)],
                              runtime=LineageRuntime(fuse=True))
        ps_i = PreparedScript(fn, [(64, 6), (64, 1)],
                              runtime=LineageRuntime(fuse=False))
        for seed in range(3):
            r = np.random.default_rng(seed)
            an, bn = r.normal(size=(64, 6)), r.normal(size=(64, 1))
            np.testing.assert_allclose(ps_f(an, bn)[0], ps_i(an, bn)[0],
                                       rtol=1e-10, atol=1e-12)


class TestJitExecutableCache:
    def test_prepared_script_warm_replay(self, rng):
        clear_jit_cache()
        rt = LineageRuntime(fuse=True)
        ps = PreparedScript(lambda a, b: _ridge(a, b), [(80, 5), (80, 1)],
                            runtime=rt)
        r = np.random.default_rng(1)
        ps(r.normal(size=(80, 5)), r.normal(size=(80, 1)))
        assert rt.stats.segments >= 1
        assert rt.stats.trace_time > 0.0  # first call traced
        hits_before = rt.stats.jit_cache_hits
        trace_before = rt.stats.trace_time
        ps(r.normal(size=(80, 5)), r.normal(size=(80, 1)))
        assert rt.stats.jit_cache_hits > hits_before  # warm executables
        assert rt.stats.trace_time == trace_before   # no re-trace

    def test_structurally_identical_scripts_share_executables(self, rng):
        clear_jit_cache()
        def fn(a, b):
            return _ridge(a, b, 0.3)
        rt1 = LineageRuntime(fuse=True)
        PreparedScript(fn, [(48, 4), (48, 1)], runtime=rt1)(
            rng.normal(size=(48, 4)), rng.normal(size=(48, 1)))
        rt2 = LineageRuntime(fuse=True)
        PreparedScript(fn, [(48, 4), (48, 1)], runtime=rt2)(
            rng.normal(size=(48, 4)), rng.normal(size=(48, 1)))
        # second script re-traced nothing: same structural keys + shapes
        assert rt2.stats.trace_time == 0.0
        assert rt2.stats.jit_cache_hits >= rt2.stats.segments

    def test_stats_accounting(self, rng):
        rt = LineageRuntime(fuse=True)
        x = input_tensor("X", rng.normal(size=(30, 6)))
        rt.evaluate([ops.gram(x) + ops.eye(6)])
        assert rt.stats.segments >= 1
        assert rt.stats.instructions == rt.stats.executed > 0
