"""Runtime operation library (the TensorBlock operation layer, §3.2/§3.3).

Executes single HOP instructions over concrete arrays. Two physical
representations are supported, mirroring SystemDS's dense/sparse blocks:

  * dense  — jnp arrays (fp64 default on the lifecycle path, like SystemDS)
  * sparse — jax.experimental.sparse.BCOO for 2D matrices below a density
             threshold; matmul/gram/xtv stay sparse, everything else
             densifies (TPU adaptation note in DESIGN.md §2a: sparsity
             exploitation is block-level on TPU, value-level on CPU).

The `gram` op routes through `repro.kernels.gram.ops` which picks the
Pallas TPU kernel on TPU and the jnp path elsewhere.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

try:  # BCOO sparse support (available on CPU)
    from jax.experimental import sparse as jsparse
    HAS_SPARSE = True
except Exception:  # pragma: no cover
    jsparse = None
    HAS_SPARSE = False

SPARSE_THRESHOLD = 0.3


def is_sparse(x) -> bool:
    return HAS_SPARSE and isinstance(x, jsparse.BCOO)


def densify(x):
    return x.todense() if is_sparse(x) else x


def maybe_sparsify(arr, sparsity_est: float):
    """Convert a 2D array to BCOO when the estimate says it pays off."""
    if (HAS_SPARSE and sparsity_est < SPARSE_THRESHOLD
            and getattr(arr, "ndim", 0) == 2 and arr.size > 1 << 16):
        return jsparse.BCOO.fromdense(arr)
    return arr


# ---------------------------------------------------------------------------
# op implementations
# ---------------------------------------------------------------------------

def _gram(x):
    if is_sparse(x):
        # sparse-dense: flops ∝ nnz·n (sparse-sparse lowering is slow)
        return densify(x.T @ x.todense())
    from repro.kernels.gram import ops as gram_ops
    return gram_ops.gram(x)


def _xtv(x, v):
    if is_sparse(x):
        out = x.T @ densify(v)
        return densify(out)
    from repro.kernels.gram import ops as gram_ops
    return gram_ops.xtv(x, v)


def _matmul(a, b):
    if is_sparse(a) or is_sparse(b):
        out = a @ b
        return densify(out)
    return a @ b


def _solve(a, b):
    a = densify(a).astype(jnp.float64)
    b = densify(b).astype(jnp.float64)
    # SPD fast path (normal equations): cholesky solve, else generic
    return jax.scipy.linalg.solve(a, b, assume_a="pos") \
        if a.shape[0] == a.shape[1] else jnp.linalg.lstsq(a, b)[0]


def _slice(x, index):
    x = densify(x)
    idx = []
    for (start, stop, kind) in index:
        idx.append(start if kind == 1 else slice(start, stop))
    return x[tuple(idx)]


def _colvars(x):
    x = densify(x)
    return jnp.var(x, axis=0, keepdims=True, ddof=1)


_BINARY = {
    "add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply,
    "div": jnp.divide, "pow": jnp.power,
    "min2": jnp.minimum, "max2": jnp.maximum,
    "gt": lambda a, b: (a > b).astype(jnp.float32),
    "lt": lambda a, b: (a < b).astype(jnp.float32),
    "ge": lambda a, b: (a >= b).astype(jnp.float32),
    "le": lambda a, b: (a <= b).astype(jnp.float32),
    "eq": lambda a, b: (a == b).astype(jnp.float32),
    "ne": lambda a, b: (a != b).astype(jnp.float32),
    "and": lambda a, b: jnp.logical_and(a != 0, b != 0).astype(jnp.float32),
    "or": lambda a, b: jnp.logical_or(a != 0, b != 0).astype(jnp.float32),
}

_UNARY = {
    "neg": jnp.negative, "exp": jnp.exp, "log": jnp.log, "sqrt": jnp.sqrt,
    "abs": jnp.abs, "sign": jnp.sign, "round": jnp.round,
    "floor": jnp.floor, "ceil": jnp.ceil, "sigmoid": jax.nn.sigmoid,
    "not": lambda x: (x == 0).astype(jnp.float32),
}

_AGG = {
    "sum": jnp.sum, "mean": jnp.mean, "max": jnp.max, "min": jnp.min,
    "trace": jnp.trace,
    "nnz": lambda x: jnp.count_nonzero(x).astype(jnp.float64),
    "colSums": partial(jnp.sum, axis=0, keepdims=True),
    "rowSums": partial(jnp.sum, axis=1, keepdims=True),
    "colMeans": partial(jnp.mean, axis=0, keepdims=True),
    "rowMeans": partial(jnp.mean, axis=1, keepdims=True),
    "colMaxs": partial(jnp.max, axis=0, keepdims=True),
    "colMins": partial(jnp.min, axis=0, keepdims=True),
    "colVars": _colvars,
}


def execute_op(op: str, attrs: dict[str, Any], inputs: list) -> Any:
    """Execute one instruction; inputs are jnp arrays (or BCOO)."""
    if op in _BINARY:
        a, b = (densify(x) for x in inputs)
        return _BINARY[op](a, b)
    if op in _UNARY:
        return _UNARY[op](densify(inputs[0]))
    if op in _AGG:
        x = densify(inputs[0])
        return _AGG[op](x)
    if op == "matmul":
        return _matmul(inputs[0], inputs[1])
    if op == "gram":
        return _gram(inputs[0])
    if op == "xtv":
        return _xtv(inputs[0], inputs[1])
    if op == "t":
        x = inputs[0]
        return x.T if is_sparse(x) else jnp.transpose(densify(x))
    if op == "solve":
        return _solve(inputs[0], inputs[1])
    if op == "cholesky":
        return jnp.linalg.cholesky(densify(inputs[0]).astype(jnp.float64))
    if op == "inv":
        return jnp.linalg.inv(densify(inputs[0]).astype(jnp.float64))
    if op == "diag":
        return jnp.diagonal(densify(inputs[0]))[:, None]
    if op == "diagm":
        return jnp.diag(densify(inputs[0])[:, 0])
    if op == "slice":
        return _slice(inputs[0], attrs["index"])
    if op == "reshape":
        return jnp.reshape(densify(inputs[0]), attrs["newshape"])
    if op in ("rbind", "cbind"):
        return jnp.concatenate([densify(x) for x in inputs],
                               axis=attrs["axis"])
    if op == "where":
        c, a, b = (densify(x) for x in inputs)
        return jnp.where(c != 0, a, b)
    if op == "replace_nan":
        return jnp.nan_to_num(densify(inputs[0]), nan=attrs["value"])
    if op == "cumsum":
        return jnp.cumsum(densify(inputs[0]), axis=0)
    if op == "literal":
        return jnp.asarray(attrs["value"])
    if op == "full":
        return jnp.full(attrs.get("_shape", ()), attrs["value"])
    if op == "eye":
        return jnp.eye(attrs["_shape"][0])
    if op == "seq":
        n = attrs["_shape"][0]
        return (attrs["start"]
                + attrs["step"] * jnp.arange(n, dtype=jnp.float64))[:, None]
    if op == "rand":
        key = jax.random.PRNGKey(attrs["seed"])
        shape = attrs["_shape"]
        if attrs.get("dist") == "normal":
            out = jax.random.normal(key, shape, dtype=jnp.float64)
        else:
            out = jax.random.uniform(key, shape, dtype=jnp.float64)
        sp = attrs.get("sparsity_gen", 1.0)
        if sp < 1.0:
            key2 = jax.random.PRNGKey(attrs["seed"] + 0x9E3779B9)
            mask = jax.random.uniform(key2, shape) < sp
            out = jnp.where(mask, out, 0.0)
        return out
    raise NotImplementedError(f"op {op!r}")


def to_numpy(x) -> np.ndarray:
    return np.asarray(densify(x))
