"""Task-parallel batched grid execution vs the sequential-reuse loop.

ISSUE 5: the §5 `parfor` HPO workload (k lmDS models over one X,
varying λ) executed two ways:

  * **batched** — `grid_search_lm(mode='vmap')`: ONE compiled plan, the
    λ-invariant gram/xtv prefix computed once, the solve+loss suffix
    vmapped over the (power-of-two bucketed) λ axis;
  * **sequential-reuse** — the PR-3 path: one plan per λ with the
    lineage reuse cache serving gram/xtv after the first config.

Asserts `allclose` parity on betas and losses, and — on a federated
grid — that the batched path performs exactly one exchange round per
site per federated instruction *independent of k*, with the same total
payload k sequential rounds would carry.

Appends a trajectory entry to ``benchmarks/BENCH_parfor.json``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from .common import COLS, ROWS, emit, timed

BENCH_JSON = os.path.join(os.path.dirname(__file__), "BENCH_parfor.json")


def _grid(rt, xn, yn, lambdas, mode):
    from repro.core import input_tensor
    from repro.lifecycle.validation import grid_search_lm
    X = input_tensor("pfX", xn)
    y = input_tensor("pfy", yn)
    return grid_search_lm(X, y, lambdas, runtime=rt, mode=mode)


def _federated_rounds(xn, yn, lambdas) -> dict:
    """Batched federated grid: per-site exchange rounds must not scale
    with k, and one batched exchange must carry exactly the payload of
    k sequential single-λ exchanges (k a power of two, so the batch
    bucket is exact)."""
    from repro.core import LineageRuntime, ReuseCache, input_tensor
    from repro.core.federated import FederatedTensor, federated_input
    from repro.lifecycle.validation import grid_search_lm

    n_sites = 3
    k = len(lambdas)
    assert k & (k - 1) == 0, "use a power-of-two k for exact buckets"

    def run(lams, mode, cache=None):
        fed = FederatedTensor.partition_rows(xn, n_sites)
        rt = LineageRuntime(cache=cache)
        X = federated_input("pfedX", fed)
        y = input_tensor("pfedy", yn)
        betas, losses = grid_search_lm(X, y, lams, runtime=rt, mode=mode)
        return betas, losses, rt.stats.exchange

    b_bat, l_bat, ex_bat = run(lambdas, "vmap")
    _, _, ex_one = run(lambdas[:1], "sequential")
    b_seq, l_seq, ex_seq = run(lambdas, "sequential", cache=ReuseCache())
    np.testing.assert_allclose(b_bat, b_seq, rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(l_bat, l_seq, rtol=1e-8)
    # one round per site per federated instruction, independent of k:
    # the k-λ batched grid touches each site exactly as often as a
    # single-λ run (fed_gram + fed_xtv + fed_mv = 3 rounds per site)
    rps = ex_bat.rounds_per_site
    assert rps == ex_one.rounds_per_site, \
        f"batched rounds grew with k: {rps} vs {ex_one.rounds_per_site}"
    assert ex_seq.rounds > ex_bat.rounds, \
        f"sequential should pay more rounds: {ex_seq.rounds} " \
        f"vs {ex_bat.rounds}"
    # payload parity: the single batched fed_mv exchange carries exactly
    # what the k sequential fed_mv rounds carry (the λ-invariant
    # gram/xtv prefix is exchanged once on BOTH paths — reuse serves it
    # sequentially, invariant hoisting serves it batched)
    assert ex_bat.total == ex_seq.total, \
        f"batched payload {ex_bat.total}B != k sequential rounds' " \
        f"{ex_seq.total}B"
    return dict(
        batched_rounds_per_site={int(s): int(r) for s, r in sorted(
            rps.items())},
        sequential_rounds=int(ex_seq.rounds),
        batched_rounds=int(ex_bat.rounds),
        batched_exchange_bytes=int(ex_bat.total),
        sequential_exchange_bytes=int(ex_seq.total),
        single_config_exchange_bytes=int(ex_one.total),
    )


def main(rows: int = ROWS, cols: int = COLS, k: int = 16,
         repeats: int = 3, fed_rows: int = 4096, fed_cols: int = 64
         ) -> dict:
    from repro.core import LineageRuntime, ReuseCache, clear_jit_cache

    rng = np.random.default_rng(11)
    xn = rng.normal(size=(rows, cols))
    yn = rng.normal(size=(rows, 1))
    lambdas = [float(10.0 ** (i / 4 - 2)) for i in range(k)]

    clear_jit_cache()

    def batched():
        return _grid(LineageRuntime(), xn, yn, lambdas, "vmap")

    def sequential():
        return _grid(LineageRuntime(cache=ReuseCache()), xn, yn,
                     lambdas, "sequential")

    t_bat = timed(batched, repeats=repeats, warmup=1)
    t_seq = timed(sequential, repeats=repeats, warmup=1)

    b_bat, l_bat = batched()
    b_seq, l_seq = sequential()
    np.testing.assert_allclose(b_bat, b_seq, rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(l_bat, l_seq, rtol=1e-8)
    parity = float(np.max(np.abs(b_bat - b_seq)))

    # cost-model sanity: auto mode must pick the batched path here
    rt_auto = LineageRuntime(cache=ReuseCache())
    _grid(rt_auto, xn, yn, lambdas, "auto")
    auto_batched = rt_auto.stats.batched_segments > 0

    fed = _federated_rounds(
        rng.normal(size=(fed_rows, fed_cols)),
        rng.normal(size=(fed_rows, 1)),
        [float(10.0 ** (i / 4 - 2)) for i in range(8)])

    speedup = t_seq / max(t_bat, 1e-12)
    emit("parfor_batched_grid", t_bat,
         f"seq_reuse_us={t_seq * 1e6:.1f};k={k};speedup={speedup:.2f}x")

    entry = dict(
        benchmark="parfor_batched_grid",
        workload=f"grid_search_lm({rows}x{cols}, k={k})",
        batched_us_per_call=round(t_bat * 1e6, 1),
        sequential_reuse_us_per_call=round(t_seq * 1e6, 1),
        speedup=round(speedup, 2),
        parity_max_abs_err=parity,
        auto_mode_picked_batched=bool(auto_batched),
        federated=fed,
        ts=time.strftime("%Y-%m-%dT%H:%M:%S"),
    )
    trajectory = []
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                trajectory = json.load(f)
        except Exception:
            trajectory = []
    trajectory.append(entry)
    with open(BENCH_JSON, "w") as f:
        json.dump(trajectory, f, indent=2)
    return entry


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    print("name,us_per_call,derived")
    print(json.dumps(main(), indent=2))
