"""Mamba-1 block (selective SSM) for the Jamba hybrid (arXiv:2403.19887).

The selective scan runs as a `lax.scan` over time in the pure-JAX path
(compile-light; the state never materializes per-step in HBM beyond the
carry) — the Pallas kernel (repro.kernels.ssd) is the TPU
hardware-aware-scan analogue: state resident in VMEM, time loop inside
the kernel, channels across the grid.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Params, dense_init


def dt_rank(cfg) -> int:
    return max(1, cfg.d_model // 16)


def mamba_init(key, cfg) -> Params:
    d, di, ds, ck = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.conv_kernel
    r = dt_rank(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (di, 1, ck), jnp.float32)
        / np.sqrt(ck),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], di, r + 2 * ds),
        "dt_proj": dense_init(ks[3], r, di, scale=r ** -0.5),
        "dt_bias": jnp.log(jnp.exp(
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32)
                    * (np.log(0.1) - np.log(0.001)) + np.log(0.001))) - 1.0
            + 1e-9),
        "A_log": jnp.log(A),
        "D_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d),
    }


def _causal_conv(p: Params, xin: jnp.ndarray,
                 conv_state: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Depthwise causal conv1d; xin (B, S, di).

    Written as shift-multiply (Σ_j w_j ⊙ shift(x, j)) instead of
    lax.conv: XLA's gradient for grouped convolutions materializes the
    full (di, di, k) cross-channel filter grad — measured 4.5e15 flops
    and a 1 GiB temp *per layer* on jamba×train_4k (§Perf log). The
    shift form is exact, O(k·B·S·di), and differentiates elementwise.
    """
    ck = p["conv_w"].shape[-1]
    w = p["conv_w"][:, 0, :].astype(xin.dtype)        # (di, ck)
    if conv_state is not None:                        # decode: prepend
        x_full = jnp.concatenate([conv_state.swapaxes(1, 2), xin], axis=1)
    else:
        x_full = jnp.pad(xin, ((0, 0), (ck - 1, 0), (0, 0)))
    S_out = x_full.shape[1] - (ck - 1)
    out = 0.0
    for j in range(ck):
        # tap j multiplies inputs delayed by (ck - 1 - j)
        out = out + x_full[:, j:j + S_out] * w[None, None, :, j]
    return out + p["conv_b"].astype(out.dtype)[None, None, :]


def selective_scan(xin, dt, A, Bv, Cv, D_skip, h0, chunk: int = 256):
    """xin,dt: (B,S,di); A: (di,ds); Bv,Cv: (B,S,ds); h0: (B,di,ds).

    Two-level scan: outer scan over time-chunks (carries = chunk-boundary
    states only), inner per-step scan inside a jax.checkpoint — backward
    recomputes per-step states within one chunk instead of saving all S
    of them (the memory property that makes mamba trainable at 4k+)."""
    f32 = jnp.float32
    B, S, di = xin.shape
    tc = min(chunk, S)
    assert S % tc == 0
    nc = S // tc

    def to_chunks(t):
        return t.astype(f32).reshape(B, nc, tc, -1).swapaxes(0, 1)

    xs, dts, Bs, Cs = (to_chunks(t) for t in (xin, dt, Bv, Cv))

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_body(h, blk):
        x_c, dt_c, B_c, C_c = blk                     # (B, tc, ·)

        def step(h, t):
            x_t, dt_t, B_t, C_t = (x_c[:, t], dt_c[:, t], B_c[:, t],
                                   C_c[:, t])
            dA = jnp.exp(dt_t[..., None] * A[None].astype(f32))
            h = dA * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
            y = jnp.einsum("bds,bs->bd", h, C_t)
            return h, y

        h, ys = jax.lax.scan(step, h, jnp.arange(tc))
        return h, ys.swapaxes(0, 1)                   # (B, tc, di)

    h, ys = jax.lax.scan(chunk_body, h0.astype(f32), (xs, dts, Bs, Cs))
    y = ys.swapaxes(0, 1).reshape(B, S, di)
    return y + xin.astype(f32) * D_skip[None, None], h


def mamba_forward(p: Params, cfg, x, state: Optional[dict] = None,
                  decode: bool = False):
    """x: (B, S, D). state: {'h': (B,di,ds), 'conv': (B,di,ck-1)} for
    decode. Returns (out, new_state)."""
    B, S, D = x.shape
    di, ds = cfg.d_inner, cfg.d_state
    ck = cfg.conv_kernel
    r = dt_rank(cfg)
    dt_ = x.dtype

    xz = x @ p["in_proj"].astype(dt_)
    xin, z = jnp.split(xz, 2, axis=-1)

    conv_state = state["conv"] if decode else None
    conv_out = _causal_conv(p, xin, conv_state)
    if decode:
        new_conv = jnp.concatenate(
            [conv_state[:, :, 1:], xin.swapaxes(1, 2)], axis=2)
        conv_out = conv_out[:, -1:]                   # last position only
    else:
        new_conv = xin.swapaxes(1, 2)[:, :, -(ck - 1):]
    xin_c = jax.nn.silu(conv_out)

    dbc = xin_c @ p["x_proj"].astype(dt_)
    dt_raw, Bv, Cv = jnp.split(dbc, [r, r + ds], axis=-1)
    dt = jax.nn.softplus(
        (dt_raw @ p["dt_proj"].astype(dt_)).astype(jnp.float32)
        + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])

    h0 = state["h"] if decode else jnp.zeros((B, di, ds), jnp.float32)
    if cfg.use_pallas and not decode:
        from repro.kernels.ssd import ops as sops
        y, h = sops.ssm_scan(xin_c, dt, A, Bv, Cv, p["D_skip"], h0)
    else:
        y, h = selective_scan(xin_c, dt, A, Bv, Cv, p["D_skip"], h0)
    out = (y.astype(dt_) * jax.nn.silu(z)) @ p["out_proj"].astype(dt_)
    return out, {"h": h, "conv": new_conv}


def mamba_state_spec(cfg, batch: int):
    di, ds, ck = cfg.d_inner, cfg.d_state, cfg.conv_kernel
    return {"h": jax.ShapeDtypeStruct((batch, di, ds), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, di, ck - 1),
                                         jnp.dtype(cfg.dtype))}
