"""Pallas TPU kernels for the framework's compute hot-spots.

  gram            — fused X^T[X|y] (the paper's lmDS hot op; MXU-tiled)
  flash_attention — causal GQA attention (prefill/train)
  rwkv6           — chunked WKV6 recurrence (Finch time-mix)
  ssd             — mamba selective-scan (hardware-aware scan in VMEM)

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (dispatching
jit wrapper with interpret fallback), ref.py (pure-jnp oracle).
"""
