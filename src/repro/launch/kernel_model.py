"""Kernel-effective roofline substitution (EXPERIMENTS §Perf B3/C2).

This container cannot lower Pallas kernels for TPU, so the dry-run's
recurrent paths (WKV6, mamba selective scan) compile as XLA `scan`
fallbacks whose per-step carry traffic round-trips HBM. The real TPU
kernels (repro.kernels.{rwkv6,ssd} — validated against the oracles in
interpret mode) keep the state in VMEM, so their HBM traffic is just
kernel I/O.

This module makes the substitution reproducible:
  1. measure the scan-region bytes of a compiled cell — the hlocost walk
     restricted to while-loops nested at depth >= 2 (the layer scan is
     depth 1; the inner time/chunk scans are the kernel-replaceable
     region),
  2. compute the kernel's analytic I/O bytes for the same work,
  3. report the substituted memory term.

Usage:
  PYTHONPATH=src python -m repro.launch.kernel_model rwkv6-3b train_4k
"""
import os

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import re
import sys

from repro.launch import hlocost, roofline


def scan_region_bytes(an: "hlocost.HloCostAnalyzer") -> tuple[float, float]:
    """Returns (total_bytes, bytes inside depth>=2 while bodies)."""
    totals = {"all": 0.0, "inner": 0.0}

    def walk(comp_name, mult, depth):
        c = an.comps.get(comp_name)
        if c is None:
            return
        for i in c.order:
            ins = c.instrs[i]
            if ins.opcode == "while":
                m = re.search(r'known_trip_count...?.?"n":"(\d+)"', ins.raw)
                trips = int(m.group(1)) if m else 1
                body = (hlocost._attr(ins.raw, "body") or "").strip("%")
                walk(body, mult * trips, depth + 1)
                continue
            ct = an._instr_cost(c, ins)
            totals["all"] += ct.bytes * mult
            if depth >= 2:
                totals["inner"] += ct.bytes * mult

    walk("__entry__", 1, 0)
    return totals["all"], totals["inner"]


def wkv6_kernel_io_bytes(cfg, batch_per_dev: int, seq: int,
                         passes: float = 3.0) -> float:
    """Per-device HBM I/O of the WKV6 kernel across all layers:
    r,k,v,logw in + y out (+state), bf16, heads sharded /16 on model."""
    d_sharded = cfg.d_model / 16
    per_layer = 5 * batch_per_dev * seq * d_sharded * 2
    H = cfg.d_model // cfg.rwkv_head_dim
    state = batch_per_dev * (H / 16) * cfg.rwkv_head_dim ** 2 * 4 \
        * (seq // cfg.rwkv_chunk)
    return (per_layer + state) * cfg.n_layers * passes


def ssd_kernel_io_bytes(cfg, batch_per_dev: int, seq: int,
                        passes: float = 3.0) -> float:
    """Per-device HBM I/O of the mamba scan kernel across mamba layers:
    x, dt in/out + B, C + y, f32, channels sharded /16 on model."""
    di_sharded = cfg.d_inner / 16
    n_mamba = sum(1 for k in cfg.layer_kinds() if k.startswith("mamba")) \
        * cfg.n_periods()
    per_layer = batch_per_dev * seq * (3 * di_sharded + 2 * cfg.d_state) * 4
    return per_layer * n_mamba * passes


def main():
    from repro.configs import get_config
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES

    arch = sys.argv[1] if len(sys.argv) > 1 else "rwkv6-3b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
    mesh = make_production_mesh(multi_pod=False)
    _, comp, cell = lower_cell(arch, shape, mesh, verbose=False, hints=True)
    an = hlocost.HloCostAnalyzer(comp.as_text(), 256)
    total, inner = scan_region_bytes(an)
    cfg = get_config(arch)
    B_dev = SHAPES[shape]["batch"] // 16
    S = SHAPES[shape]["seq_len"]
    if cfg.ssm_type == "rwkv6":
        k_io = wkv6_kernel_io_bytes(cfg, B_dev, S)
        kname = "rwkv6 (chunked WKV, state in VMEM)"
    else:
        k_io = ssd_kernel_io_bytes(cfg, B_dev, S)
        kname = "ssd (mamba scan, state in VMEM)"
    substituted = total - inner + k_io
    print(f"cell: {arch} × {shape} (per device)")
    print(f"  measured bytes total      : {total:.3e}  "
          f"(t_mem {total/roofline.HBM_BW*1e3:9.1f} ms)")
    print(f"  inner-scan region bytes   : {inner:.3e}  "
          f"({inner/total*100:.1f}% of total)")
    print(f"  kernel I/O replacement    : {k_io:.3e}   [{kname}]")
    print(f"  SUBSTITUTED bytes         : {substituted:.3e}  "
          f"(t_mem {substituted/roofline.HBM_BW*1e3:9.1f} ms)")
    print(f"  memory-term improvement   : "
          f"{total/max(substituted,1):.1f}x")


if __name__ == "__main__":
    main()
