"""repro.core — the paper's contribution: declarative LA DSL with lineage
tracing, lineage-based reuse, an optimizing compiler, heterogeneous
tensors, and federated tensors (SystemDS, CIDR 2020)."""
import jax as _jax

# SystemDS's numeric lifecycle semantics are double-precision; the LM
# model zoo uses explicit f32/bf16 dtypes and is unaffected.
_jax.config.update("jax_enable_x64", True)

from . import ops  # noqa: F401,E402
from .batching import (BatchedPlan, BatchingError,  # noqa: F401
                       compile_batched)
from .compiler import Plan, compile_plan  # noqa: F401
from .dag import LTensor, batch_input, input_tensor  # noqa: F401
from .federated import (FederatedTensor, LocalSite,  # noqa: F401
                        federated_input)
from .jit_cache import clear_jit_cache, get_jit_cache  # noqa: F401
from .reuse import ReuseCache  # noqa: F401
from .runtime import (LineageRuntime, PreparedScript, evaluate,  # noqa: F401
                      get_runtime, lineage_trace, set_runtime, value)
from .segments import Segment  # noqa: F401
