"""Multi-device sharded execution vs single-device local execution.

ISSUE 6: the SystemDS distributed backend as a compiler placement —
`lower_distributed` shards large row-partitionable leaves over the
mesh's `data` axis and lowers partial reductions to per-shard compute
+ `psum` inside `shard_map`-compiled segments; `parfor(mode='shard')`
splits the HPO grid's bucket axis over the `config` axis.

Two measurements, both against the same fused local baseline:

  * **lmDS data-parallel** — one lmDS plan on an 8-device host mesh
    (`use_mesh(data=8)`) vs the local plan;
  * **grid config-parallel** — `grid_search_lm(mode='shard')` on
    `use_mesh(config=8)` vs the single-device vmapped grid.

`allclose` parity against the local path is asserted for both — the
hard invariant. Wall-clock speedup is recorded honestly: on a
single-core container the 8 "devices" share one core, so the
interesting signal is parity + collective accounting, not throughput
(real meshes get real scaling; the cost model's ICI terms are what
the compiler arbitrates with).

The measurement runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so it works
no matter how the parent process initialized jax. Appends a trajectory
entry to ``benchmarks/BENCH_distributed.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(__file__),
                          "BENCH_distributed.json")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEVICES = 8
_MARK = "RESULT "


def _child(rows: int, cols: int, k: int, repeats: int) -> None:
    """Runs inside the forced-8-device subprocess; prints one marked
    JSON line with the raw measurements."""
    import numpy as np

    from repro.core import LineageRuntime, clear_jit_cache, input_tensor, ops
    from repro.core.compiler import compile_plan
    from repro.distributed import use_mesh
    from repro.lifecycle.validation import grid_search_lm

    from .common import timed

    import jax
    assert jax.device_count() >= DEVICES, jax.device_count()

    rng = np.random.default_rng(17)
    xn = rng.normal(size=(rows, cols))
    yn = rng.normal(size=(rows, 1))

    def lmds(X, y):
        A = ops.gram(X) + 1e-3 * ops.eye(cols)
        beta = ops.solve(A, ops.xtv(X, y))
        resid = y - X @ beta
        return beta, ops.sum_(resid * resid)

    # --- lmDS: local fused baseline vs data-sharded -------------------
    clear_jit_cache()
    plan_lo = compile_plan(list(lmds(input_tensor("dbX", xn),
                                     input_tensor("dby", yn))))
    with use_mesh(data=DEVICES):
        plan_sh = compile_plan(list(lmds(input_tensor("dbX2", xn),
                                         input_tensor("dby2", yn))))
    rt_lo, rt_sh = LineageRuntime(), LineageRuntime()
    out_lo = rt_lo.run_plan(plan_lo)
    out_sh = rt_sh.run_plan(plan_sh)
    assert rt_sh.stats.shard.sharded_segments > 0, "plan did not shard"
    parity = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                 for a, b in zip(out_sh, out_lo))
    for a, b in zip(out_sh, out_lo):
        np.testing.assert_allclose(a, b, rtol=1e-8, atol=1e-10)
    t_lo = timed(lambda: rt_lo.run_plan(plan_lo), repeats=repeats,
                 warmup=1)
    t_sh = timed(lambda: rt_sh.run_plan(plan_sh), repeats=repeats,
                 warmup=1)

    # --- grid: single-device vmap vs config-sharded -------------------
    lambdas = [float(10.0 ** (i / 4 - 2)) for i in range(k)]

    def grid(mode):
        rt = LineageRuntime()
        X = input_tensor(f"dbgX_{mode}", xn)
        y = input_tensor(f"dbgy_{mode}", yn)
        out = grid_search_lm(X, y, lambdas, runtime=rt, mode=mode)
        return out, rt

    (b_v, l_v), _ = grid("vmap")
    with use_mesh(data=1, config=DEVICES):
        (b_c, l_c), rt_c = grid("shard")
        assert rt_c.stats.shard.config_sharded_segments > 0
        t_grid_sh = timed(lambda: grid("shard"), repeats=repeats)
    np.testing.assert_allclose(b_c, b_v, rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(l_c, l_v, rtol=1e-8)
    t_grid_v = timed(lambda: grid("vmap"), repeats=repeats)
    grid_parity = float(np.max(np.abs(b_c - b_v)))

    print(_MARK + json.dumps(dict(
        devices=DEVICES,
        local_s=t_lo, sharded_s=t_sh,
        parity_max_abs_err=parity,
        shard_meter=rt_sh.stats.shard.as_dict(),
        grid_vmap_s=t_grid_v, grid_shard_s=t_grid_sh,
        grid_parity_max_abs_err=grid_parity,
    )))


def main(rows: int = 32768, cols: int = 128, k: int = 16,
         repeats: int = 3) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={DEVICES}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.distributed_bench",
         "--child", str(rows), str(cols), str(k), str(repeats)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560)
    if proc.returncode != 0:
        raise RuntimeError(
            f"distributed bench child failed:\n{proc.stdout[-2000:]}"
            f"\n{proc.stderr[-4000:]}")
    raw = next(ln for ln in proc.stdout.splitlines()
               if ln.startswith(_MARK))
    m = json.loads(raw[len(_MARK):])

    speedup = m["local_s"] / max(m["sharded_s"], 1e-12)
    grid_speedup = m["grid_vmap_s"] / max(m["grid_shard_s"], 1e-12)
    emit("distributed_lmds_sharded", m["sharded_s"],
         f"local_us={m['local_s'] * 1e6:.1f};devices={m['devices']};"
         f"speedup={speedup:.2f}x")
    emit("distributed_grid_config_shard", m["grid_shard_s"],
         f"vmap_us={m['grid_vmap_s'] * 1e6:.1f};k={k};"
         f"speedup={grid_speedup:.2f}x")

    entry = dict(
        benchmark="distributed_shard_map",
        workload=f"lmDS({rows}x{cols}) + grid(k={k})",
        devices=m["devices"],
        local_us_per_call=round(m["local_s"] * 1e6, 1),
        sharded_us_per_call=round(m["sharded_s"] * 1e6, 1),
        speedup=round(speedup, 2),
        grid_vmap_us_per_call=round(m["grid_vmap_s"] * 1e6, 1),
        grid_shard_us_per_call=round(m["grid_shard_s"] * 1e6, 1),
        grid_speedup=round(grid_speedup, 2),
        parity_max_abs_err=max(m["parity_max_abs_err"],
                               m["grid_parity_max_abs_err"]),
        shard_meter=m["shard_meter"],
        ts=time.strftime("%Y-%m-%dT%H:%M:%S"),
    )
    trajectory = []
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                trajectory = json.load(f)
        except Exception:
            trajectory = []
    trajectory.append(entry)
    with open(BENCH_JSON, "w") as f:
        json.dump(trajectory, f, indent=2)
    return entry


if __name__ == "__main__":
    if "--child" in sys.argv:
        i = sys.argv.index("--child")
        _child(*(int(a) for a in sys.argv[i + 1:i + 5]))
    else:
        sys.path.insert(0, "src")
        print("name,us_per_call,derived")
        args = {}
        if "--smoke" in sys.argv:
            args = dict(rows=8192, cols=64, k=8, repeats=2)
        print(json.dumps(main(**args), indent=2))
