"""End-to-end LM training driver: trains the ~100M-param `lm-100m`
config with the full stack (data pipeline, AdamW, checkpoints, fault
monitor). A full run is
    PYTHONPATH=src python examples/train_lm.py --steps 300
(slow on 1 CPU core); `--smoke` trains a reduced model for 30 steps and
asserts the loss actually drops.
"""
import sys

sys.path.insert(0, "src")

import numpy as np


def main():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.data.tokens import TokenPipeline
    from repro.launch.steps import init_train_state, make_train_step
    from repro.models import build_model

    smoke = "--smoke" in sys.argv
    steps = 30 if smoke else next(
        (int(sys.argv[i + 1]) for i, a in enumerate(sys.argv)
         if a == "--steps"), 300)

    if smoke:
        cfg = get_config("lm-100m").with_(
            n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
            vocab_size=512, loss_chunk=32, attn_chunk=64)
        batch, seq = 4, 64
    else:
        cfg = get_config("lm-100m")
        batch, seq = 8, 256

    model = build_model(cfg)
    print(f"training {cfg.name}: {model.n_params()/1e6:.1f}M params, "
          f"{steps} steps, batch={batch} seq={seq}")
    pipe = TokenPipeline(vocab=cfg.vocab_size, batch=batch, seq_len=seq,
                         seed=0)
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, lr=3e-4))

    losses = []
    import time
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        t0 = time.time()
        params, opt, m = step_fn(params, opt, b)
        losses.append(float(m["loss"]))
        if s % max(steps // 10, 1) == 0 or s == steps - 1:
            print(f"step {s:4d} loss={losses[-1]:.4f} "
                  f"({time.time()-t0:.2f}s/step)")
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"loss {first:.4f} -> {last:.4f}")
    assert last < first, "training did not reduce loss"
    print("OK: loss decreased")


if __name__ == "__main__":
    main()
