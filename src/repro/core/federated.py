"""Federated tensors and federated instructions (SystemDS §3.3, §4.3).

A `FederatedTensor` is a metadata object holding references to per-site
partitions covering disjoint row ranges. Federated instructions push
computation to the sites and exchange only the minimal aggregates
(paper Example 2):

  fed_mv   : broadcast v -> local X_i @ v       -> rbind of results
  fed_vm   : send v slice -> local v_i^T @ X_i  -> elementwise sum
  fed_gram : local X_i^T X_i                    -> sum (n² exchange only)
  fed_xtv  : local X_i^T y_i                    -> sum

Every exchange is metered (`ExchangeLog`, with per-site byte counters) —
the paper's "exchange constraints" become an auditable byte budget per
site.

Two execution paths share these instruction semantics:

  * the **compiler placement path** — `federated_input` creates a DAG
    leaf with `placement='federated'`; `repro.core.compiler
    .lower_federated` lowers eligible HOPs into `fed_*` instructions and
    `repro.core.runtime.LineageRuntime` executes them, running each
    site's local work as compiled jit segments through `LocalSite
    .execute` (the plan-executing worker: kernel registry + process-wide
    jit cache, so per-site gram runs the Pallas/BCOO kernels and
    repeated runs replay warm executables);
  * the **eager numpy methods** on `FederatedTensor` (`fed_mv`,
    `fed_gram`, ...) — the in-process oracle used by tests and the
    eager-numpy baseline in `benchmarks/federated_bench.py`.

The multi-pod mesh backend lives in `repro.distributed.fedavg`: sites =
slices along the `pod` mesh axis, instructions lower to shard_map
programs with psum/all_gather on that axis only.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np


@dataclass
class ExchangeLog:
    """Byte meter for master<->site traffic, with per-site attribution.

    Besides bytes, *exchange rounds* are counted: one round per
    (federated instruction, site) — the latency unit of the federation
    boundary. A task-parallel grid executed batched performs ONE round
    per site per federated instruction regardless of the grid size k
    (the stacked operand travels in one payload), where the sequential
    loop performs k; `rounds_per_site` is how tests assert that.
    """

    to_sites: int = 0      # bytes master -> workers
    from_sites: int = 0    # bytes workers -> master
    per_site: dict = field(default_factory=dict)  # site idx -> total bytes
    rounds: int = 0        # (instruction, site) exchange round trips
    rounds_per_site: dict = field(default_factory=dict)

    def add_out(self, arr, site: Optional[int] = None):
        nb = int(np.asarray(arr).nbytes)
        self.to_sites += nb
        if site is not None:
            self.per_site[site] = self.per_site.get(site, 0) + nb

    def add_in(self, arr, site: Optional[int] = None):
        nb = int(np.asarray(arr).nbytes)
        self.from_sites += nb
        if site is not None:
            self.per_site[site] = self.per_site.get(site, 0) + nb

    def add_round(self, site: int):
        self.rounds += 1
        self.rounds_per_site[site] = self.rounds_per_site.get(site, 0) + 1

    @property
    def total(self) -> int:
        return self.to_sites + self.from_sites

    def as_dict(self) -> dict:
        return dict(to_sites=self.to_sites, from_sites=self.from_sites,
                    total=self.total, rounds=self.rounds,
                    per_site={int(k): int(v)
                              for k, v in sorted(self.per_site.items())},
                    rounds_per_site={int(k): int(v) for k, v in
                                     sorted(self.rounds_per_site.items())})


@dataclass
class LocalSite:
    """An in-process 'remote worker' owning one partition.

    Two faces:

      * `execute(op, args, attrs)` — the plan-executing worker: builds
        the kernel from the `repro.core.backend` registry and runs it as
        a compiled executable through the process-wide jit cache
        (`repro.core.jit_cache`), so per-site work compiles once and
        replays warm across federated plan executions. This is the path
        the compiler-placed `fed_*` instructions use.
      * the eager numpy methods (`mv`, `vm`, `gram`, `xtv`, `colsums`)
        — the pure-numpy oracle for tests and the eager baseline.
    """

    data: Any  # np.ndarray or device array; rows × ncols partition

    def execute(self, op: str, args: tuple, attrs: tuple = (), stats=None,
                vmap_axes: Optional[tuple] = None,
                site: Optional[int] = None):
        """Run one op over this site's data as a compiled segment.

        `args` is the *full* kernel argument tuple (the caller places
        `self.data` at the right position); `attrs` are the op's static
        attributes as a sorted key/value tuple (part of the executable
        cache key). Per-site sub-segments share warm executables across
        sites/runs whenever (op, attrs, arg signature) match. `stats`
        (a `RuntimeStats`) receives the same accounting the fused
        segment executor books: compile seconds into `trace_time`, warm
        lookups into `jit_cache_hits`.

        `vmap_axes` (batched `parfor` grids) maps the kernel over a
        leading config axis of the flagged operands (`jax.vmap`
        in_axes) — the site runs its local work for the WHOLE grid in
        one compiled dispatch, so a k-configuration grid still touches
        the site once per federated instruction.

        `site` is this site's index in the owning `FederatedTensor` —
        the identity the seeded fault registry keys on. `site=None`
        marks a master-side execution (the degradation ladder's
        collect-and-recompute), which is never injected: recovery runs
        the SAME cached executable on the surviving data, so a degraded
        run is bitwise the clean run.
        """
        import jax

        from . import backend, faults
        faults.site_entry(site, op)
        from .jit_cache import get_jit_cache
        cache = get_jit_cache()
        seg_key = f"fedsite|{op}|{attrs!r}"
        if vmap_axes is not None:
            seg_key += f"|vmap:{vmap_axes!r}"
        key, exe = cache.lookup(seg_key, args)
        if exe is None:
            kern = backend.get_kernel(op, dict(attrs))
            fn = lambda *xs: (kern(*xs),)  # noqa: E731
            if vmap_axes is not None:
                fn = jax.vmap(fn, in_axes=vmap_axes, out_axes=0)
            exe, dt = cache.compile(key, fn, args)
            if stats is not None:
                stats.trace_time += dt
        elif stats is not None:
            stats.jit_cache_hits += 1
        out = exe(*args)[0]
        backend.block_ready(out)
        return out

    # -- eager numpy oracle -------------------------------------------------
    def mv(self, v):           # X_i @ v
        return np.asarray(self.data) @ v

    def vm(self, v_slice):     # v_i^T @ X_i
        return v_slice.T @ np.asarray(self.data)

    def gram(self):            # X_i^T X_i
        d = np.asarray(self.data)
        return d.T @ d

    def xtv(self, y_i):        # X_i^T y_i
        return np.asarray(self.data).T @ y_i

    def colsums(self):
        return np.asarray(self.data).sum(axis=0, keepdims=True)

    def rows(self):
        return self.data.shape[0]


@dataclass
class FederatedTensor:
    """Row-partitioned federated matrix: sites cover disjoint row ranges."""

    sites: list[LocalSite]
    ranges: list[tuple[int, int]]  # [start, stop) per site
    ncols: int
    log: ExchangeLog = field(default_factory=ExchangeLog)
    # batched (`parfor`) site layout: when set, every site's partition
    # carries a leading config axis — data is (k, rows_i, ncols) for the
    # k stacked grid configurations. Produced by batched `fed_map`
    # execution; consumed by the batched paths of the other fed_*
    # instructions (vmap over axis 0 at each site) and by `collect`.
    batch: Optional[int] = None

    @classmethod
    def partition_rows(cls, x: np.ndarray, n_sites: int) -> "FederatedTensor":
        x = np.asarray(x)
        if x.ndim != 2:
            raise ValueError(
                f"partition_rows requires a matrix, got shape {x.shape}")
        if not 1 <= n_sites <= x.shape[0]:
            raise ValueError(
                f"n_sites must be in [1, {x.shape[0]}] (one non-empty row "
                f"range per site), got {n_sites}")
        splits = np.array_split(np.arange(x.shape[0]), n_sites)
        sites, ranges = [], []
        for idx in splits:
            sites.append(LocalSite(x[idx]))
            ranges.append((int(idx[0]), int(idx[-1]) + 1))
        return cls(sites=sites, ranges=ranges, ncols=x.shape[1])

    def _require_sites(self, op: str) -> None:
        if not self.sites:
            raise ValueError(
                f"{op} over a federated tensor with zero sites — "
                "partition data with FederatedTensor.partition_rows first")

    @property
    def nrows(self) -> int:
        return sum(s.rows() for s in self.sites)

    @property
    def shape(self) -> tuple[int, int]:
        return (self.nrows, self.ncols)

    # -- eager federated instructions (Example 2; the numpy oracle) ---------
    def fed_mv(self, v: np.ndarray) -> np.ndarray:
        """X @ v: broadcast v, local MV, rbind results."""
        self._require_sites("fed_mv")
        parts = []
        for i, s in enumerate(self.sites):
            self.log.add_out(v, site=i)          # broadcast
            r = s.mv(v)
            self.log.add_in(r, site=i)           # collect
            self.log.add_round(i)
            parts.append(r)
        return np.concatenate(parts, axis=0)

    def fed_vm(self, v: np.ndarray) -> np.ndarray:
        """v^T @ X: send only the relevant slice of v, add local results."""
        self._require_sites("fed_vm")
        out = None
        for i, (s, (a, b)) in enumerate(zip(self.sites, self.ranges)):
            vs = v[a:b]
            self.log.add_out(vs, site=i)
            r = s.vm(vs)
            self.log.add_in(r, site=i)
            self.log.add_round(i)
            out = r if out is None else out + r
        return out

    def fed_gram(self) -> np.ndarray:
        """X^T X with only n×n bytes exchanged per site (data never moves).
        This is the same fold decomposition the reuse rewrites exploit —
        federated learning and CV partial reuse share one algebraic core."""
        self._require_sites("fed_gram")
        out = None
        for i, s in enumerate(self.sites):
            g = s.gram()
            self.log.add_in(g, site=i)
            self.log.add_round(i)
            out = g if out is None else out + g
        return out

    def fed_xtv(self, y: np.ndarray) -> np.ndarray:
        self._require_sites("fed_xtv")
        out = None
        for i, (s, (a, b)) in enumerate(zip(self.sites, self.ranges)):
            ys = y[a:b]
            self.log.add_out(ys, site=i)
            r = s.xtv(ys)
            self.log.add_in(r, site=i)
            self.log.add_round(i)
            out = r if out is None else out + r
        return out

    def fed_colsums(self) -> np.ndarray:
        self._require_sites("fed_colsums")
        out = None
        for i, s in enumerate(self.sites):
            r = s.colsums()
            self.log.add_in(r, site=i)
            self.log.add_round(i)
            out = r if out is None else out + r
        return out

    def collect(self) -> np.ndarray:
        """Materialize (breaks federation — for tests/debug only)."""
        self._require_sites("collect")
        return np.concatenate([np.asarray(s.data) for s in self.sites],
                              axis=0)


# ---------------------------------------------------------------------------
# Compiler integration: federated DAG leaves (§3.3 — fed_* instructions
# are generated by the optimizer, not hand-written by users)
# ---------------------------------------------------------------------------

def site_fingerprints(fed: FederatedTensor) -> str:
    """Stable identity of a federated tensor's *data*: one content
    fingerprint per site plus the row partitioning. Lineage hashes over
    federated inputs derive from this, so reuse of federated
    intermediates is sound — re-partitioned or re-bound data never
    aliases a cached value."""
    from .dag import _fingerprint
    h = hashlib.sha1()
    for s, (a, b) in zip(fed.sites, fed.ranges):
        h.update(f"{a}:{b}:".encode())
        h.update(_fingerprint(np.asarray(s.data)).encode())
    return h.hexdigest()


def federated_input(name: Optional[str], fed: FederatedTensor,
                    sparsity: float = 1.0):
    """Create a DAG leaf bound to a `FederatedTensor`.

    The leaf carries `placement='federated'`; the compiler's placement
    pass (`repro.core.compiler.lower_federated`) propagates placement
    over the DAG and lowers eligible patterns into `fed_*` instructions.
    Its lineage id hashes the per-site data fingerprints, so lineage
    reuse works on federated intermediates exactly like local ones.
    """
    from .dag import LEAVES, LTensor, make_node
    fed._require_sites("federated_input")
    name = name or "fed"
    dtype = np.result_type(*(np.asarray(s.data).dtype for s in fed.sites))
    node = make_node("input", (), fed.shape, dtype, sparsity,
                     placement="federated", name=name,
                     n_sites=len(fed.sites))
    LEAVES.bind(node, fed, f"fed:{name}:{site_fingerprints(fed)}")
    return LTensor(node)


# ---------------------------------------------------------------------------
# Federated closed-form regression (the §4.3 enterprise use-case)
# ---------------------------------------------------------------------------

def federated_lmds(fx: FederatedTensor, y: np.ndarray, reg: float = 1e-7,
                   intercept: bool = False) -> np.ndarray:
    """lmDS over a federated X: only gram-sized aggregates leave sites.

    Eager numpy oracle. The compiled equivalent is
    `repro.lifecycle.regression.lmDS` over a `federated_input` leaf,
    which routes the same exchange pattern through the DAG -> cost model
    -> fused-segment stack (see `tests/test_fed_placement.py` for the
    exchange-byte parity invariants).
    """
    if intercept:
        fx = FederatedTensor(
            sites=[LocalSite(np.concatenate(
                [np.asarray(s.data), np.ones((s.rows(), 1))], axis=1))
                for s in fx.sites],
            ranges=fx.ranges, ncols=fx.ncols + 1, log=fx.log)
    a = fx.fed_gram() + reg * np.eye(fx.ncols)
    b = fx.fed_xtv(y)
    return np.linalg.solve(a, b)
