"""Benchmark driver. One module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  fig5_hpo_baseline_*   — Fig. 5(a,b): k lmDS models, dense/sparse, no reuse
  fig5c/fig5d_*         — Fig. 5(c,d) + Fig. 6: lineage reuse speedups
  fig7_cv_*             — Fig. 7: cross-validation partial reuse
  ex2_fed_*             — §4.3 Example 2: federated MV/VM/gram + lmDS
  gram_*                — §5.2 kernel trio (dense XLA / BLAS / sparse)
  roofline_*            — §Roofline cells from the dry-run sweep
"""
import sys

sys.path.insert(0, "src")


def main() -> None:
    from benchmarks import (cv_reuse, federated_bench, hpo_baseline,
                            hpo_reuse, kernel_bench, roofline_bench)
    quick = "--quick" in sys.argv
    ks = (1, 5, 10) if quick else (1, 5, 10, 20)
    print("name,us_per_call,derived")
    hpo_baseline.main(ks=ks)
    hpo_reuse.main(ks=ks)
    cv_reuse.main(folds=(4,) if quick else (4, 8))
    federated_bench.main()
    kernel_bench.main()
    roofline_bench.main()


if __name__ == "__main__":
    main()
