"""Benchmark driver. One module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  fig5_hpo_baseline_*   — Fig. 5(a,b): k lmDS models, dense/sparse, no reuse
  fig5c/fig5d_*         — Fig. 5(c,d) + Fig. 6: lineage reuse speedups
  fig7_cv_*             — Fig. 7: cross-validation partial reuse
  ex2_fed_*             — §4.3 Example 2: federated MV/VM/gram + lmDS
  fed_compiled_vs_eager — ISSUE 4: federated plans through the compiler
                          (placement pass + per-site fused segments +
                          lineage reuse) vs the eager-numpy federated
                          island (BENCH_federated.json)
  gram_*                — §5.2 kernel trio (dense XLA / BLAS / sparse)
  roofline_*            — §Roofline cells from the dry-run sweep
  fused_vs_interpreted  — ISSUE 1: segment JIT engine vs per-op interpreter
                          (appends a BENCH_fusion.json trajectory entry)
  sparse_*              — ISSUE 3: sparsity-aware fused execution +
                          cost-gated reuse probes (BENCH_sparse.json)

Every run ends with a summary table aggregating the latest entry of all
``BENCH_*.json`` trajectories.

``--smoke`` runs the fusion + sparse + federated benchmarks at reduced
sizes (CI).
"""
import glob
import json
import os
import sys

sys.path.insert(0, "src")

BENCH_DIR = os.path.dirname(os.path.abspath(__file__))


def aggregate() -> None:
    """Print one summary row per BENCH_*.json (latest trajectory entry)."""
    paths = sorted(glob.glob(os.path.join(BENCH_DIR, "BENCH_*.json")))
    if not paths:
        return
    rows = []
    for path in paths:
        try:
            with open(path) as f:
                trajectory = json.load(f)
            entry = trajectory[-1]
        except Exception as e:
            print(f"!! {os.path.basename(path)}: unreadable trajectory "
                  f"({type(e).__name__}: {e})")
            continue
        metrics = "; ".join(
            f"{k.replace('_us_per_call', '')}={v}us" if
            k.endswith("_us_per_call") else f"{k}={v}"
            for k, v in entry.items()
            if k.endswith("_us_per_call") or k.startswith("speedup"))
        rows.append((os.path.basename(path),
                     str(entry.get("benchmark", "?")),
                     str(entry.get("workload", ""))[:46],
                     metrics))
    if not rows:
        return
    headers = ("trajectory", "benchmark", "workload", "metrics")
    widths = [max(len(headers[i]), *(len(r[i]) for r in rows))
              for i in range(3)]
    print("\n== benchmark summary (latest entry per trajectory) ==")
    print("  ".join(h.ljust(w) for h, w in zip(headers[:3], widths))
          + "  " + headers[3])
    for r in rows:
        print("  ".join(c.ljust(w) for c, w in zip(r[:3], widths))
              + "  " + r[3])


def main() -> None:
    if "--smoke" in sys.argv:
        from benchmarks import federated_bench, fusion_bench, sparse_bench
        print("name,us_per_call,derived")
        fusion_bench.main(rows=500, cols=32, calls=20, repeats=2)
        sparse_bench.main(rows=512, cols=64, calls=10, repeats=2)
        # large enough that per-site gram dominates the eager baseline
        # (at toy sizes fixed plan/probe overhead hides the reuse win)
        federated_bench.main(rows=4096, cols=96, n_sites=3, repeats=3,
                             eager_layer=False)
        aggregate()
        return
    from benchmarks import (cv_reuse, federated_bench, fusion_bench,
                            hpo_baseline, hpo_reuse, kernel_bench,
                            roofline_bench, sparse_bench)
    quick = "--quick" in sys.argv
    ks = (1, 5, 10) if quick else (1, 5, 10, 20)
    print("name,us_per_call,derived")
    hpo_baseline.main(ks=ks)
    hpo_reuse.main(ks=ks)
    cv_reuse.main(folds=(4,) if quick else (4, 8))
    federated_bench.main()
    kernel_bench.main()
    roofline_bench.main()
    fusion_bench.main(calls=20 if quick else 50)
    sparse_bench.main(calls=10 if quick else 20)
    aggregate()


if __name__ == "__main__":
    main()
