"""Fig. 7: k-fold cross-validation with full+partial reuse.

Reuse relies on rewriting gram(rbind(folds∖i)) into per-fold grams and
element-wise additions during compilation — the per-fold pieces are then
cache hits across the k configurations (paper §5.4).
"""
from __future__ import annotations

import numpy as np

from .common import COLS, ROWS, SPARSITY, emit, timed


def run_cv(x, y, k, reuse):
    from repro.core import LineageRuntime, ReuseCache
    from repro.lifecycle import cross_validate_lm
    from repro.lifecycle.validation import make_folds
    rt = LineageRuntime(cache=ReuseCache() if reuse else None)
    fx, fy = make_folds(x, y, k, seed=11)
    # mode='sequential' pins the Fig. 7 semantics (per-fold plans, the
    # distribute-for-reuse rewrite sharing fold grams through the
    # cache); the batched path is measured in benchmarks/parfor_bench.py
    return cross_validate_lm(fx, fy, runtime=rt,
                             mode="sequential"), rt


def main(rows=ROWS, cols=COLS, folds=(4, 8)) -> None:
    from repro.data.synthetic import gen_regression
    for sparse in (False, True):
        sp = SPARSITY if sparse else 1.0
        tag = "sparse" if sparse else "dense"
        x, y, _ = gen_regression(rows, cols, sparsity=sp, seed=9)
        for k in folds:
            t_no = timed(lambda: run_cv(x, y, k, False), repeats=2,
                         warmup=1)
            t_yes = timed(lambda: run_cv(x, y, k, True), repeats=2,
                          warmup=1)
            emit(f"fig7_cv_{tag}_k{k}", t_yes,
                 f"no_reuse_us={t_no*1e6:.1f};speedup={t_no/t_yes:.2f}x")

    # exactness
    x, y, _ = gen_regression(rows // 4, cols, seed=9)
    (b1, e1), _ = run_cv(x, y, 5, True)
    (b2, e2), _ = run_cv(x, y, 5, False)
    assert np.allclose(b1, b2, rtol=1e-7), "CV reuse changed results!"


if __name__ == "__main__":
    main()
