"""Lineage-based reuse cache (SystemDS §4.1, "Reuse of Intermediates").

Intermediates are identified by their lineage hash (hash of the lineage
DAG). Before executing an instruction, the runtime probes the cache for
*full reuse*; *partial reuse* is realized by the compensation-plan
rewrites in `repro.core.rewrites.distribute_for_reuse`, which decompose
operators (gram/xtv over rbind/cbind) so their pieces become cache hits.

Eviction follows SystemDS's cost-and-size heuristic: keep entries with
high (compute-cost / byte), weighted by recency (LRU decay).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

# Below this compute cost (seconds) an intermediate is not worth caching.
# The runtime applies this gate at *compile time* via the cost model
# (`repro.core.costmodel.PROBE_MIN_COST_S`) and calls `put(gated=False)`;
# the measured-cost check below only applies to external callers using
# the cache standalone.
MIN_CACHE_COST_S = 20e-6
# Standalone-caller admission only: below this size a measured-cheap
# value is kept anyway (scalars/metadata cost nothing to hold). The
# runtime's compile-time probe gate does not consult this — sub-threshold
# intermediates are fused through, not cached.
ALWAYS_CACHE_BYTES = 1 << 12


def nbytes(value) -> int:
    """True byte size of a cached value.

    Sparse (BCOO) entries are accounted at their sparse size —
    data + indices buffers — checked *before* the generic `.nbytes`
    attribute so wrappers exposing a dense-shaped `nbytes` don't
    overcharge, and so entries lacking `.nbytes` entirely don't fall
    through to a stub size that would break eviction pressure.
    """
    if isinstance(value, (tuple, list)):
        # chunk-level partial-aggregate entries (see the streaming
        # executor) cache one tuple per row bucket
        return sum(nbytes(v) for v in value)
    sites = getattr(value, "sites", None)  # FederatedTensor intermediates
    if sites is not None:
        return sum(nbytes(getattr(s, "data", s)) for s in sites)
    data = getattr(value, "data", None)  # BCOO and friends
    indices = getattr(value, "indices", None)
    if data is not None and indices is not None:
        total = 0
        for buf in (data, indices):
            nb = getattr(buf, "nbytes", None)
            if nb is None:
                nb = int(np.size(buf)) * np.dtype(buf.dtype).itemsize
            total += int(nb)
        return total
    if hasattr(value, "nbytes"):
        return int(value.nbytes)
    size, dtype = getattr(value, "size", None), getattr(value, "dtype", None)
    if size is not None and dtype is not None:
        return int(size) * np.dtype(dtype).itemsize
    return 64


@dataclass
class CacheEntry:
    value: Any
    size: int
    cost: float          # seconds it took to compute
    last_used: float
    hits: int = 0


@dataclass
class ReuseStats:
    probes: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_cached: int = 0
    time_saved: float = 0.0   # Σ cost of hit entries

    def as_dict(self) -> dict:
        return dict(probes=self.probes, hits=self.hits, misses=self.misses,
                    evictions=self.evictions, bytes=self.bytes_cached,
                    time_saved_s=round(self.time_saved, 6))


class ReuseCache:
    """Lineage-hash keyed intermediate cache with cost/size eviction."""

    def __init__(self, budget_bytes: int = 4 << 30,
                 policy: str = "costsize"):
        assert policy in ("costsize", "lru")
        self.budget = int(budget_bytes)
        self.policy = policy
        self.entries: dict[str, CacheEntry] = {}
        self.stats = ReuseStats()

    # -- interface ----------------------------------------------------------
    def probe(self, lhash: str) -> Optional[Any]:
        self.stats.probes += 1
        e = self.entries.get(lhash)
        if e is None:
            self.stats.misses += 1
            return None
        e.hits += 1
        e.last_used = time.perf_counter()
        self.stats.hits += 1
        self.stats.time_saved += e.cost
        return e.value

    def put(self, lhash: str, value: Any, cost: float,
            gated: bool = True) -> None:
        """Insert an entry. `gated=False` skips the measured-cost
        worth-keeping check — used by the runtime, whose compile-time
        cost model already admitted the value as a probe point (keeps
        admission identical across interpreter and fused modes)."""
        size = nbytes(value)
        if gated and cost < MIN_CACHE_COST_S and size > ALWAYS_CACHE_BYTES:
            return  # not worth the pool space
        if size > self.budget:
            return
        if lhash in self.entries:
            return
        self._make_room(size)
        self.entries[lhash] = CacheEntry(value=value, size=size, cost=cost,
                                         last_used=time.perf_counter())
        self.stats.bytes_cached += size

    def clear(self) -> None:
        self.entries.clear()
        self.stats.bytes_cached = 0

    # -- eviction -------------------------------------------------------------
    def _score(self, e: CacheEntry, now: float) -> float:
        if self.policy == "lru":
            return -(now - e.last_used)
        # costsize: value density (seconds saved per byte), light recency decay
        age = now - e.last_used
        return (e.cost / max(e.size, 1)) / (1.0 + 0.01 * age)

    def _make_room(self, need: int) -> None:
        if self.stats.bytes_cached + need <= self.budget:
            return
        now = time.perf_counter()
        victims = sorted(self.entries.items(),
                         key=lambda kv: self._score(kv[1], now))
        for key, e in victims:
            if self.stats.bytes_cached + need <= self.budget:
                break
            del self.entries[key]
            self.stats.bytes_cached -= e.size
            self.stats.evictions += 1
