from .sharding import (batch_specs, cache_specs, param_specs,  # noqa: F401
                       safe_spec)
