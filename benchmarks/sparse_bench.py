"""Sparsity- and reuse-aware fused execution benchmark (ISSUE 3).

Two paper-headline workloads that used to defeat the segment engine:

  * sparse lmDS — ridge regression over a density-0.05 design matrix.
    With compile-time format assignment the whole plan (BCOO gram/xtv +
    dense solve) traces into fused jit segments; compared against the
    per-instruction interpreter on the same BCOO kernels, and against
    the dense fused path.
  * reuse-enabled HPO — a lambda grid with an active `ReuseCache`.
    Cost-gated probe points keep segments multi-instruction (the Fig. 7
    scenario finally fuses) while reuse hit counts stay identical to
    the interpreter.

Appends a trajectory entry to ``benchmarks/BENCH_sparse.json``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from .common import emit, timed

BENCH_JSON = os.path.join(os.path.dirname(__file__), "BENCH_sparse.json")

DENSITY = 0.05


def _ridge(x, y, lam=0.1):
    from repro.core import ops
    n = x.shape[1]
    return ops.solve(ops.gram(x) + float(lam) * ops.eye(n), ops.xtv(x, y))


def _sparse_pipeline(a, b):
    """Sparse lmDS with a sparsity-preserving feature transform and
    training diagnostics: the transform chain stays BCOO end-to-end
    (format propagation), gram/xtv run the sparse kernels, and the tail
    is dense — one fused plan instead of a dozen eager BCOO dispatches.
    """
    from repro.core import ops
    xt = ops.sqrt(ops.abs_(a)) * 0.5
    n = xt.shape[1]
    beta = ops.solve(ops.gram(xt) + 0.1 * ops.eye(n), ops.xtv(xt, b))
    err = xt @ beta - b
    return beta, ops.sum_(err * err), \
        ops.cbind(ops.colSums(err), ops.colMaxs(err))


def _sparse_data(rows, cols, rng):
    x = rng.normal(size=(rows, cols)) * (rng.random((rows, cols)) < DENSITY)
    y = rng.normal(size=(rows, 1))
    return x, y


def _run_mode(fuse: bool, sparse: bool, xn, yn, calls: int):
    from repro.core import LineageRuntime, PreparedScript
    rt = LineageRuntime(fuse=fuse, sparse_inputs=sparse)
    ps = PreparedScript(_sparse_pipeline, [xn.shape, yn.shape], runtime=rt,
                        arg_sparsities=[DENSITY, 1.0])
    ps(xn, yn)  # warm: trace/compile outside the timed loop
    def loop():
        out = None
        for _ in range(calls):
            out = ps(xn, yn)
        return out
    return ps, loop


def _reuse_fusion(fuse: bool, xn, yn, lambdas):
    """Grid-search HPO with an active cache; returns (stats, cache stats,
    per-plan segmentation shape)."""
    from repro.core import LineageRuntime, ReuseCache, input_tensor
    from repro.core.compiler import compile_plan
    rt = LineageRuntime(cache=ReuseCache(), fuse=fuse)
    x, y = input_tensor("sbX", xn), input_tensor("sby", yn)
    for lam in lambdas:
        rt.evaluate([_ridge(x, y, lam)])
    plan = compile_plan([_ridge(x, y, lambdas[0])], reuse_enabled=True)
    segs = plan.segments_for(True)
    seg_shape = dict(
        instruction_count=len(plan.instructions),
        segment_count=len(segs),
        multi_instruction_segments=sum(1 for s in segs if s.fused),
        max_segment_ops=max(len(s.instructions) for s in segs))
    return rt.stats.as_dict(), rt.cache.stats.as_dict(), seg_shape


def main(rows: int = 1024, cols: int = 64, calls: int = 20,
         repeats: int = 3) -> dict:
    rng = np.random.default_rng(11)
    xn, yn = _sparse_data(rows, cols, rng)

    ps_fused, loop_fused = _run_mode(True, True, xn, yn, calls)
    ps_interp, loop_interp = _run_mode(False, True, xn, yn, calls)
    ps_dense, loop_dense = _run_mode(True, False, xn, yn, calls)

    t_fused = timed(loop_fused, repeats=repeats)
    t_interp = timed(loop_interp, repeats=repeats)
    t_dense = timed(loop_dense, repeats=repeats)

    out_f = ps_fused(xn, yn)
    out_i = ps_interp(xn, yn)
    out_d = ps_dense(xn, yn)  # dense fused path is the reference
    parity = max(float(np.max(np.abs(a - d)))
                 for outs in (out_f, out_i)
                 for a, d in zip(outs, out_d))
    # f64 XLA kernels off-TPU; the TPU Pallas paths (dense gram AND
    # block-sparse spmm) accumulate in f32 with different block orders
    import jax
    tol = 1e-4 if jax.default_backend() == "tpu" else 1e-8
    assert parity < tol, f"sparse paths diverge (max abs err {parity})"

    speedup_vs_interp = t_interp / max(t_fused, 1e-12)
    speedup_vs_dense = t_dense / max(t_fused, 1e-12)
    emit("sparse_fused_vs_interpreted", t_fused / calls,
         f"interp_us={t_interp / calls * 1e6:.1f};"
         f"speedup={speedup_vs_interp:.2f}x;"
         f"vs_dense={speedup_vs_dense:.2f}x")

    # reuse-enabled HPO: fused must keep multi-instruction segments and
    # the interpreter's exact hit behaviour
    lambdas = (0.1, 1.0, 10.0)
    rs_f, rc_f, shape = _reuse_fusion(True, xn, yn, lambdas)
    rs_i, rc_i, _ = _reuse_fusion(False, xn, yn, lambdas)
    hits_f = (rc_f["probes"], rc_f["hits"], rc_f["misses"])
    hits_i = (rc_i["probes"], rc_i["hits"], rc_i["misses"])
    assert hits_f == hits_i, \
        f"fused reuse diverged from interpreter: {hits_f} vs {hits_i}"
    assert shape["instruction_count"] > 2 * shape["segment_count"], \
        f"reuse-active plan failed to fuse: {shape}"
    emit("sparse_reuse_fusion",
         rs_f["exec_time_s"] / max(rs_f["segments"], 1),
         f"instr={shape['instruction_count']};"
         f"segments={shape['segment_count']};hits={rc_f['hits']}")

    entry = dict(
        benchmark="sparse_fused_vs_interpreted",
        workload=f"sparse_lmDS_pipeline({rows}x{cols}, density={DENSITY}, "
                 f"{calls} calls)",
        fused_sparse_us_per_call=round(t_fused / calls * 1e6, 1),
        interpreted_sparse_us_per_call=round(t_interp / calls * 1e6, 1),
        dense_fused_us_per_call=round(t_dense / calls * 1e6, 1),
        speedup_fused_vs_interpreted=round(speedup_vs_interp, 2),
        speedup_fused_vs_dense=round(speedup_vs_dense, 2),
        parity_max_abs_err=parity,
        reuse_fusion=dict(
            **shape,
            probes_hits_misses_fused=list(hits_f),
            probes_hits_misses_interpreted=list(hits_i),
            runtime_stats_fused=rs_f),
        ts=time.strftime("%Y-%m-%dT%H:%M:%S"),
    )
    trajectory = []
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                trajectory = json.load(f)
        except Exception:
            trajectory = []
    trajectory.append(entry)
    with open(BENCH_JSON, "w") as f:
        json.dump(trajectory, f, indent=2)
    return entry


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    print("name,us_per_call,derived")
    print(json.dumps(main(), indent=2))
