"""Process-wide cache of compiled segment executables.

Keyed by (segment canonical structural key, concrete input signature
(shapes + dtypes)), so structurally identical segments compiled from
*different* plans — HPO loops, CV folds, repeated `PreparedScript`
construction — share one XLA executable and replay without re-tracing.

On a miss the segment closure is lowered ahead-of-time
(`jax.jit(fn).lower(*args).compile()`) so trace+compile cost is measured
explicitly and replay calls skip dispatch-time signature checks; if AOT
lowering is unavailable for some input combination we fall back to the
plain `jax.jit` wrapper (which still caches by aval internally).
"""
from __future__ import annotations

import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax

try:
    from jax.experimental.sparse import BCOO as _BCOO
except Exception:  # pragma: no cover
    _BCOO = ()


@dataclass
class JitCacheStats:
    hits: int = 0
    misses: int = 0
    trace_time: float = 0.0   # cumulative lower+compile seconds
    aot_fallbacks: int = 0    # segments served by plain jit (AOT failed)

    def as_dict(self) -> dict:
        return dict(hits=self.hits, misses=self.misses,
                    trace_time_s=round(self.trace_time, 6),
                    aot_fallbacks=self.aot_fallbacks)


def arg_signature(args) -> tuple:
    """Shape/dtype(/weak-type) signature of concrete call arguments.

    weak_type matters: AOT-compiled executables reject aval mismatches,
    and a weak-typed jax scalar (e.g. a literal crossing a segment
    boundary) has a different aval than a strong-typed array of the same
    shape/dtype. BCOO arguments additionally carry their nse (buffer
    size) — two sparse matrices of equal shape but different nnz have
    different avals and need separate executables.
    """
    out = []
    for a in args:
        if _BCOO and isinstance(a, _BCOO):
            # pytree flags are part of the aval too: an executable
            # compiled for unique_indices=True rejects a False-flagged
            # BCOO of identical shape/dtype/nse
            out.append(("bcoo", tuple(a.shape), str(a.dtype), int(a.nse),
                        bool(a.unique_indices), bool(a.indices_sorted)))
        else:
            out.append(
                (tuple(getattr(a, "shape", ())),
                 str(getattr(a, "dtype", type(a).__name__)),
                 bool(getattr(a, "weak_type", False))))
    return tuple(out)


class JitProgramCache:
    """LRU cache: (segment key, input signature) -> compiled executable."""

    def __init__(self, capacity: int = 512):
        self.capacity = int(capacity)
        self._entries: "OrderedDict[tuple, Callable]" = OrderedDict()
        self.stats = JitCacheStats()

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, seg_key: str, args) -> tuple[tuple, Optional[Callable]]:
        """Return (full key, executable-or-None); counts hit/miss."""
        key = (seg_key, arg_signature(args))
        exe = self._entries.get(key)
        if exe is not None:
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return key, exe
        self.stats.misses += 1
        return key, None

    def compile(self, key: tuple, fn: Callable, args
                ) -> tuple[Callable, float]:
        """Compile `fn` for `args`, store under `key`; returns
        (executable, trace_seconds)."""
        t0 = time.perf_counter()
        jitted = jax.jit(fn)
        if hasattr(jitted, "lower"):
            # Genuine trace/compile errors propagate immediately — masking
            # them here would cache a broken wrapper that re-raises on
            # every subsequent run with a misleading 'fallback' stat.
            exe: Any = jitted.lower(*args).compile()
        else:  # pragma: no cover - AOT API unavailable on this jax
            warnings.warn("jax.jit(...).lower unavailable; segment will "
                          "use dispatch-path jit", RuntimeWarning,
                          stacklevel=2)
            self.stats.aot_fallbacks += 1
            exe = jitted
        dt = time.perf_counter() - t0
        self.stats.trace_time += dt
        self._entries[key] = exe
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
        return exe, dt

    def clear(self) -> None:
        self._entries.clear()


_global_cache: Optional[JitProgramCache] = None


def get_jit_cache() -> JitProgramCache:
    global _global_cache
    if _global_cache is None:
        _global_cache = JitProgramCache()
    return _global_cache


def clear_jit_cache() -> None:
    """Drop all compiled executables (tests / memory pressure)."""
    if _global_cache is not None:
        _global_cache.clear()
