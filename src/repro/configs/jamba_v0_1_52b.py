"""jamba-v0.1-52b — Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Period of 8: attention at offset 4, mamba elsewhere; MoE every 2nd layer.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    moe_top_k=2,
    attn_layer_period=8,
    ssm_type="mamba",
    d_state=16,
    expand=2,
    conv_kernel=4,
    rope_theta=10000.0,
)
