"""Deterministic fault injection + the runtime fault policy (ISSUE 10).

SystemDS targets federated sites, streamed out-of-core data, and a
serving front door — exactly the places real deployments see timeouts,
stragglers, dead workers, and partial failures. This module provides
both halves of making that survivable:

  * a **seeded fault-injection registry** (`FaultPlan` / `inject()` /
    env ``REPRO_FAULT_SPEC``): every instrumented call site
    (`LocalSite.execute` site RPCs, `read_csv_chunks` byte-window
    reads, the chunk-prefetch worker, `jit_cache.compile`, the serving
    coalescer) asks the active plan whether to fail THIS call. Firing
    decisions key on ``(fault kind, per-kind call index, seed)`` via a
    sha1 draw, so a given spec reproduces the exact same fault
    sequence on every run — tests assert exact injection/recovery
    counters and bit-level result parity against clean runs;

  * the **fault policy meters** (`FaultLog`, surfaced as
    `RuntimeStats.faults`): injections observed, retries, timeouts,
    backoff seconds slept, degradations taken, requests shed — plus
    the rescued `repro.distributed.fault` control plane: per-site and
    per-dispatch latencies route through `StepMonitor` (median + k·MAD
    straggler flagging) and sites heartbeat into a `HeartbeatTracker`
    whose dead-host state shows up in ``as_dict()``.

The policy itself (retry/backoff/degradation ladders) lives at the
call sites in `repro.core.runtime`, `repro.serving.server` and
`repro.data.csv_io`; this module only decides *whether a call fails*
and *counts what the policy did about it*. ``REPRO_FAULT_POLICY=off``
is the kill switch: injection entries and policy wrappers become
no-ops and every error propagates raw (the pre-ISSUE-10 behaviour).

Spec format (env ``REPRO_FAULT_SPEC`` or `inject()` argument)::

    seed=42;site_rpc@1,3;site_slow:p=0.1:delay=0.02;site_dead:site=2

``;``-separated rules, an optional leading ``seed=N``. Each rule is
``kind[@i,j,...][:key=val]*``: explicit call indices (``@1,3`` fires on
the 2nd and 4th call of that kind), a seeded probability (``p=0.1``),
or both (indices win when given). Kinds:

  site_rpc    transient site-RPC failure (InjectedFault from
              `LocalSite.execute`; retried with backoff)
  site_slow   straggler: sleep ``delay`` seconds inside the site call
              (trips the per-site timeout -> discard + retry)
  site_dead   persistent compute failure of site ``site=K`` — every
              RPC to that site fails; the runtime degrades to
              collect-and-recompute from the site's surviving data
  site_lost   site ``site=K``'s data plane is gone too: degradation is
              impossible and the run fails with `SiteFailedError`
  chunk_io    IO error in `read_csv_chunks` / the chunk-prefetch
              worker (read retried; a dead worker degrades the stream
              to the synchronous chunk loop)
  compile     `jit_cache.compile` failure — the segment falls back to
              the fuse=False interpreter (parity by construction)
  serving_dispatch  coalescer crash between pop and dispatch — the
              supervisor restarts the loop and fails only the popped
              batch
"""
from __future__ import annotations

import hashlib
import os
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Optional

from repro.distributed.fault import HeartbeatTracker, StepMonitor

# Fault kinds with per-kind call-index counters. `site_dead`/`site_lost`
# are *stateful* (keyed on the site id, not a call index) and listed for
# spec validation only.
KINDS = frozenset({
    "site_rpc", "site_slow", "site_dead", "site_lost",
    "chunk_io", "compile", "serving_dispatch",
})


class InjectedFault(RuntimeError):
    """A failure triggered by the active `FaultPlan`. Policy layers
    catch this (and real exceptions) and run their recovery ladder;
    with the policy off it propagates like any other error."""


class SiteFailedError(RuntimeError):
    """A federated site is permanently unavailable — compute AND data
    plane — so no degradation is semantically sound. Names the site and
    the instruction so operators know exactly what died where."""

    def __init__(self, site: int, instruction: str, detail: str = ""):
        self.site = int(site)
        self.instruction = str(instruction)
        msg = (f"federated site {site} failed permanently during "
               f"{instruction!r} and its data is unreachable — "
               "cannot degrade to collect-and-recompute")
        if detail:
            msg += f" ({detail})"
        super().__init__(msg)


class CompileFailedError(RuntimeError):
    """jit compilation of one segment failed. The segment executor
    catches this and falls back to the fuse=False interpreter for that
    segment; batched/sharded segments (no eager equivalent of the same
    executable) re-raise."""

    def __init__(self, seg_key: str, cause: BaseException):
        self.seg_key = seg_key
        self.cause = cause
        super().__init__(
            f"jit compile failed for segment {seg_key!r}: "
            f"{type(cause).__name__}: {cause}")


class DeadlineExceededError(RuntimeError):
    """A serving request's per-request deadline expired while it was
    still queued. Shed *before* dispatch, never after — a request that
    reached the device always delivers its (late) result."""


class ServerClosedError(RuntimeError):
    """The serving dispatcher is gone (shutdown, or the thread died
    unrecoverably) — raised to queued/waiting futures instead of
    letting them hang forever."""


# ---------------------------------------------------------------------------
# The registry: seeded, deterministic firing decisions
# ---------------------------------------------------------------------------

def _draw(seed: int, kind: str, idx: int) -> float:
    """Uniform [0, 1) from (seed, kind, call index) — sha1-based, NOT
    python's salted `hash()`, so the sequence is identical across
    processes/reruns (chaos CI fixes three seeds and asserts exact
    counters)."""
    h = hashlib.sha1(f"{seed}|{kind}|{idx}".encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


@dataclass
class FaultRule:
    kind: str
    at: Optional[frozenset] = None   # explicit call indices (win over p)
    p: float = 0.0                   # seeded per-call probability
    params: dict = field(default_factory=dict)  # delay=, site=, ...

    def matches(self, seed: int, idx: int, **ctx: Any) -> bool:
        site = self.params.get("site")
        if site is not None and ctx.get("site") != int(site):
            return False
        if self.kind in ("site_dead", "site_lost"):
            return True  # stateful: every call to that site fails
        if self.at is not None:
            return idx in self.at
        if self.p > 0.0:
            return _draw(seed, self.kind, idx) < self.p
        return False


class FaultPlan:
    """Active fault schedule: seed + rules + per-kind call counters.

    Thread-safe (the chunk-prefetch worker and serving threads fire
    entries concurrently with the main thread); `fired` counts every
    triggered injection per kind — the injection-side ground truth
    tests assert against the policy-side `FaultLog` counters."""

    def __init__(self, rules: list[FaultRule], seed: int = 0):
        self.rules = list(rules)
        self.seed = int(seed)
        self.calls: dict[str, int] = {}
        self.fired: dict[str, int] = {}
        self._lock = threading.Lock()
        self._kinds = frozenset(r.kind for r in self.rules)

    def check(self, kind: str, **ctx: Any) -> Optional[FaultRule]:
        """Advance `kind`'s call counter and return the matching rule,
        if any. Stateful kinds (site_dead/site_lost) do not consume
        call indices — they key purely on the site id."""
        if kind not in self._kinds:
            # still advance the index for index-addressable kinds so
            # specs mixing rules see stable indices per kind
            if kind in ("site_dead", "site_lost"):
                return None
            with self._lock:
                self.calls[kind] = self.calls.get(kind, 0) + 1
            return None
        with self._lock:
            if kind in ("site_dead", "site_lost"):
                idx = -1
            else:
                idx = self.calls.get(kind, 0)
                self.calls[kind] = idx + 1
            for r in self.rules:
                if r.kind == kind and r.matches(self.seed, idx, **ctx):
                    self.fired[kind] = self.fired.get(kind, 0) + 1
                    return r
        return None

    def site_is_dead(self, site: int) -> bool:
        return any(r.kind in ("site_dead", "site_lost")
                   and int(r.params.get("site", -1)) == int(site)
                   for r in self.rules)

    def data_lost(self, site: int) -> bool:
        """True when `site`'s DATA plane is gone too — degradation by
        collect-and-recompute is impossible."""
        return any(r.kind == "site_lost"
                   and int(r.params.get("site", -1)) == int(site)
                   for r in self.rules)


def parse_spec(spec: str) -> FaultPlan:
    """Parse a ``REPRO_FAULT_SPEC`` string into a `FaultPlan`."""
    rules: list[FaultRule] = []
    seed = 0
    for raw in spec.split(";"):
        raw = raw.strip()
        if not raw:
            continue
        if raw.startswith("seed="):
            seed = int(raw[5:])
            continue
        head, *kvs = raw.split(":")
        at: Optional[frozenset] = None
        if "@" in head:
            kind, idxs = head.split("@", 1)
            at = frozenset(int(i) for i in idxs.split(",") if i)
        else:
            kind = head
        kind = kind.strip()
        if kind not in KINDS:
            raise ValueError(
                f"unknown fault kind {kind!r} in REPRO_FAULT_SPEC "
                f"(valid: {sorted(KINDS)})")
        p = 0.0
        params: dict = {}
        for kv in kvs:
            k, _, v = kv.partition("=")
            if k == "p":
                p = float(v)
            elif k in ("delay",):
                params[k] = float(v)
            elif k in ("site",):
                params[k] = int(v)
            else:
                raise ValueError(
                    f"unknown fault rule parameter {k!r} in {raw!r}")
        if kind in ("site_dead", "site_lost") and "site" not in params:
            raise ValueError(f"{kind} rule requires site=K ({raw!r})")
        rules.append(FaultRule(kind=kind, at=at, p=p, params=params))
    return FaultPlan(rules, seed=seed)


# ---------------------------------------------------------------------------
# Plan activation: inject() context > env REPRO_FAULT_SPEC
# ---------------------------------------------------------------------------

_stack: list[Optional[FaultPlan]] = []
_env_cache: tuple[Optional[str], Optional[FaultPlan]] = (None, None)
_env_lock = threading.Lock()


def active_plan() -> Optional[FaultPlan]:
    """The fault plan in effect: the innermost `inject()` context wins
    (an explicit ``inject(None)`` masks the env spec — tests that need
    a clean run inside chaos CI use that), else the env spec. The
    parsed env plan is cached per spec string so the no-fault fast
    path costs one dict lookup."""
    if _stack:
        return _stack[-1]
    global _env_cache
    spec = os.environ.get("REPRO_FAULT_SPEC") or None
    cached_spec, cached_plan = _env_cache
    if spec == cached_spec:
        return cached_plan
    with _env_lock:
        plan = parse_spec(spec) if spec else None
        _env_cache = (spec, plan)
    return plan


@contextmanager
def inject(spec: Any = None):
    """Activate a fault plan for the dynamic extent of the block.

    `spec` is a spec string, a ready `FaultPlan`, or None (explicitly
    NO faults — overrides the env spec). Yields the plan so tests can
    assert `plan.fired` afterwards."""
    plan = spec if isinstance(spec, (FaultPlan, type(None))) \
        else parse_spec(str(spec))
    _stack.append(plan)
    try:
        yield plan
    finally:
        _stack.pop()


def policy_enabled() -> bool:
    """Kill switch: ``REPRO_FAULT_POLICY=off`` disables BOTH injection
    and the recovery policy (raw pre-ISSUE-10 error propagation). Read
    per call, like the other runtime knobs, so one process can compare
    both modes (the fault benchmark does exactly that)."""
    return os.environ.get("REPRO_FAULT_POLICY", "").lower() != "off"


# ---------------------------------------------------------------------------
# Instrumented call-site entries (no-ops without an active plan)
# ---------------------------------------------------------------------------

def site_entry(site: Optional[int], op: str = "") -> None:
    """Injection point at the top of `LocalSite.execute`.

    `site=None` means a master-side (recovery/local) execution — never
    injected, which is what makes the degradation ladder's recompute
    deterministic. May sleep (site_slow) or raise `InjectedFault`
    (site_rpc / site_dead / site_lost)."""
    if site is None or not policy_enabled():
        return
    plan = active_plan()
    if plan is None:
        return
    r = plan.check("site_slow", site=site)
    if r is not None:
        time.sleep(float(r.params.get("delay", 0.05)))
    for kind in ("site_rpc", "site_dead", "site_lost"):
        r = plan.check(kind, site=site)
        if r is not None:
            raise InjectedFault(
                f"injected {kind} at site {site} during {op!r}")


def io_entry(what: str = "read") -> None:
    """Injection point for chunked IO: `read_csv_chunks` byte-window
    reads and the streaming chunk-prefetch worker."""
    if not policy_enabled():
        return
    plan = active_plan()
    if plan is None:
        return
    if plan.check("chunk_io") is not None:
        raise InjectedFault(f"injected chunk_io during {what!r}")


def compile_entry(key: Any = None) -> None:
    """Injection point at the top of `JitProgramCache.compile`."""
    if not policy_enabled():
        return
    plan = active_plan()
    if plan is None:
        return
    if plan.check("compile") is not None:
        raise InjectedFault(f"injected compile failure for {key!r}")


def dispatch_entry() -> None:
    """Injection point in the serving coalescer, between batch pop and
    dispatch — the window the supervisor's restart ladder covers."""
    if not policy_enabled():
        return
    plan = active_plan()
    if plan is None:
        return
    if plan.check("serving_dispatch") is not None:
        raise InjectedFault("injected serving_dispatch crash")


# ---------------------------------------------------------------------------
# The policy meter: RuntimeStats.faults
# ---------------------------------------------------------------------------

@dataclass
class FaultLog:
    """What the fault policy observed and did, plus the rescued
    control-plane instruments.

    Counter semantics (tests assert these exactly):

      injected      `InjectedFault`s caught by a policy layer (site_rpc
                    / site_dead / site_lost / chunk_io / compile /
                    serving_dispatch firings; site_slow manifests as
                    `timeouts` + `stragglers` instead — the plan's own
                    `fired` dict carries the injection-side count)
      retries       recovery re-attempts taken (site RPC + chunk IO)
      timeouts      site calls whose wall time exceeded
                    `costmodel.fed_timeout_s()` (result discarded,
                    call retried — in-process sites cannot be
                    preempted, so the timeout binds at the attempt
                    boundary)
      backoff_s     total exponential-backoff seconds slept
      degradations  ladder steps taken: dead-site collect-and-
                    recompute, compile -> interpreter fallback,
                    prefetch-worker death -> synchronous chunk loop
      shed          serving requests expired before dispatch
                    (`DeadlineExceededError`)
      restarts      coalescer supervisor restarts
      stragglers    site/dispatch latencies flagged by the median+k·MAD
                    monitor
    """

    injected: int = 0
    retries: int = 0
    timeouts: int = 0
    backoff_s: float = 0.0
    degradations: int = 0
    shed: int = 0
    restarts: int = 0
    stragglers: int = 0
    # rescued control plane (repro.distributed.fault): per-site RPC
    # latencies and per-dispatch serving latencies through the robust
    # straggler monitor; sites heartbeat on every successful RPC
    site_monitor: StepMonitor = field(default_factory=StepMonitor)
    dispatch_monitor: StepMonitor = field(default_factory=StepMonitor)
    heartbeats: HeartbeatTracker = field(default_factory=HeartbeatTracker)

    def record_site(self, site: int, seconds: float,
                    ok: bool = True) -> bool:
        """Route one site-RPC latency through the straggler monitor;
        successful calls heartbeat the site. Returns the straggler
        flag."""
        slow = self.site_monitor.record(site, seconds)
        if slow:
            self.stragglers += 1
        if ok:
            self.heartbeats.beat(f"site{site}")
        return slow

    def record_dispatch(self, batch_idx: int, seconds: float) -> bool:
        slow = self.dispatch_monitor.record(batch_idx, seconds)
        if slow:
            self.stragglers += 1
        return slow

    @property
    def total(self) -> int:
        """Incident count — nonzero iff anything fault-related
        happened (gates the `as_dict` section like the other logs)."""
        return (self.injected + self.retries + self.timeouts
                + self.degradations + self.shed + self.restarts
                + self.stragglers)

    def as_dict(self) -> dict:
        p50, p99 = self.site_monitor.p50_p99()
        out = dict(injected=self.injected, retries=self.retries,
                   timeouts=self.timeouts,
                   backoff_s=round(self.backoff_s, 6),
                   degradations=self.degradations, shed=self.shed,
                   restarts=self.restarts, stragglers=self.stragglers,
                   incidents=self.total,
                   site_p50_us=round(p50 * 1e6, 1),
                   site_p99_us=round(p99 * 1e6, 1))
        if self.dispatch_monitor.times:
            dp50, dp99 = self.dispatch_monitor.p50_p99()
            out["dispatch_p50_us"] = round(dp50 * 1e6, 1)
            out["dispatch_p99_us"] = round(dp99 * 1e6, 1)
        if self.heartbeats.last_seen:
            out["sites_seen"] = len(self.heartbeats.last_seen)
            out["dead_sites"] = sorted(self.heartbeats.dead_hosts())
        return out
