"""Plan compiler: HOP DAG -> ordered runtime instructions (SystemDS §3.2).

Mirrors SystemDS's compilation chain at our scale: rewrites + size
propagation happen on the DAG (shapes/sparsity are attached at
construction), memory estimates pick an execution target per instruction
(local vs distributed — the analogue of CP vs Spark instructions), and
the result is a topologically ordered instruction sequence executed by
`repro.core.runtime.LineageRuntime`.

Two compile-time physical decisions ride on the propagated estimates:

  * format assignment (`assign_formats` / `Plan.formats_for`) — every
    value is pinned to `dense` or `bcoo` from its sparsity estimate, so
    kernel variants are selected at build time and sparse plans fuse;
  * probe-point selection (`Instruction.probe`) — only intermediates
    whose estimated cost clears the reuse cache's worth-keeping
    threshold become lineage-reuse probe points; segments stay maximal
    between probes instead of degenerating to one op per segment.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from . import costmodel
from .dag import LTensor, Node
from .rewrites import run_rewrites

# Default per-operation local memory budget: inputs+output of an op above
# this threshold are flagged for the distributed backend (pjit over the
# mesh) when one is attached. 2 GB mirrors a driver-heap style budget.
LOCAL_MEM_BUDGET = 2 << 30


@dataclass
class Instruction:
    node: Node
    out_id: int
    input_ids: tuple[int, ...]
    target: str  # 'local' | 'distributed'
    last_use_of: tuple[int, ...] = ()  # uids freed after this instruction
    probe: bool = False   # lineage-reuse probe point (cost-gated)
    est_cost_s: float = 0.0  # compile-time cost estimate behind `probe`


@dataclass
class Plan:
    instructions: list[Instruction]
    output_ids: list[int]
    roots: list[Node]
    est_bytes_peak: int = 0
    reuse_enabled: bool = False
    # segmentation memo: {reuse_active: [Segment, ...]}
    _segments: dict = field(default_factory=dict, repr=False)
    # format-assignment memo: {sparse_enabled: {uid: fmt}}
    _formats: dict = field(default_factory=dict, repr=False)

    def count_ops(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for ins in self.instructions:
            out[ins.node.op] = out.get(ins.node.op, 0) + 1
        return out

    def segments_for(self, reuse_active: bool):
        """Fusable segments of this plan (lazily computed, memoized).

        With an active reuse cache, cost-gated probe points
        (`Instruction.probe`) force segment boundaries so those
        intermediates stay observable; everything between probes still
        fuses. See `repro.core.segments`.
        """
        reuse_active = bool(reuse_active)
        got = self._segments.get(reuse_active)
        if got is None:
            from .segments import segment_plan
            got = segment_plan(self, reuse_active=reuse_active)
            self._segments[reuse_active] = got
        return got

    def formats_for(self, sparse: bool) -> dict[int, str]:
        """Compile-time physical format per value uid (lazily memoized).

        Only non-dense assignments are recorded — an all-dense plan maps
        to `{}` whether or not `sparse` is set, so identical plans share
        jit executables across `sparse_inputs` modes. Callers read
        `formats.get(uid, backend.DENSE)`.
        """
        sparse = bool(sparse)
        got = self._formats.get(sparse)
        if got is None:
            got = assign_formats(self, sparse)
            self._formats[sparse] = got
        return got

    def _ins_line(self, ins: Instruction, reuse_active: bool = False,
                  fmts: Optional[dict] = None) -> str:
        fmts = fmts or {}

        def ref(uid: int) -> str:
            f = fmts.get(uid, "dense")
            return f"%{uid}" if f == "dense" else f"%{uid}:{f}"

        args = ",".join(ref(i) for i in ins.input_ids)
        attrs = {k: v for k, v in ins.node.attrs if k != "index"}
        fmt = fmts.get(ins.out_id, "dense")
        tags = f" fmt={fmt}" if fmt != "dense" else ""
        if reuse_active and ins.probe:
            tags += " [reuse-probe]"
        return (f"%{ins.out_id} = [{ins.target[0].upper()}] "
                f"{ins.node.op}({args}) {ins.node.shape} "
                f"sp={ins.node.sparsity:.3f}{tags} "
                f"{attrs if attrs else ''}").rstrip()

    def explain(self, segments: bool = True,
                reuse_active: Optional[bool] = None,
                sparse: bool = False) -> str:
        """EXPLAIN-style plan dump (SystemDS -explain) with segment
        annotations showing how instructions fuse into jit executables,
        the physical format assigned to each value (`fmt=bcoo`), and
        which instructions are cost-gated reuse-probe boundaries.

        `reuse_active` defaults to the flag the plan was compiled with;
        pass the executing runtime's actual cache state (cache is not
        None) to see the segmentation that run will use. `sparse`
        mirrors `LineageRuntime(sparse_inputs=...)`.
        """
        if reuse_active is None:
            reuse_active = self.reuse_enabled
        fmts = self.formats_for(sparse)
        lines = []
        if segments and self.instructions:
            for seg in self.segments_for(reuse_active):
                outs = ",".join(f"%{u}" for u in seg.output_uids)
                kind = "fused" if len(seg.instructions) > 1 else "single"
                lines.append(
                    f"-- segment {seg.index} [{seg.target}] {kind} "
                    f"{len(seg.instructions)} op(s) key={seg.key[:10]} "
                    f"-> {outs}")
                lines.extend(f"  {self._ins_line(ins, reuse_active, fmts)}"
                             for ins in seg.instructions)
        else:
            lines.extend(self._ins_line(ins, reuse_active, fmts)
                         for ins in self.instructions)
        lines.append("outputs: " + ", ".join(f"%{i}" for i in self.output_ids))
        return "\n".join(lines)


def assign_formats(plan: "Plan", sparse: bool) -> dict[int, str]:
    """Format-assignment pass: pin every value to `dense` or `bcoo`.

    A forward walk over the instruction stream using the sparsity
    estimates propagated on the DAG (SystemDS §3.2 size propagation):
    input leaves below the shared density threshold start as BCOO, and
    `backend.infer_format` decides per op whether the sparse structure
    survives (transpose, zero-preserving unaries, scalar scaling) or the
    value densifies (everything else). The executor selects kernel
    variants from this mapping at build time — no runtime `is_sparse`
    branches — which is what lets sparse plans run fused.
    """
    from . import backend
    fmt: dict[int, str] = {}
    if not sparse or not backend.HAS_SPARSE:
        return fmt  # empty mapping ≡ all dense
    seen_leaves: set[int] = set()
    for ins in plan.instructions:
        for inp in ins.node.inputs:
            if inp.op == "input" and inp.uid not in seen_leaves:
                seen_leaves.add(inp.uid)
                lf = backend.leaf_format(inp)
                if lf != backend.DENSE:
                    fmt[inp.uid] = lf
        in_fmts = tuple(fmt.get(u, backend.DENSE) for u in ins.input_ids)
        of = backend.infer_format(ins.node, in_fmts)
        if of != backend.DENSE:
            fmt[ins.out_id] = of
    return fmt


def topo_order(roots: list[Node]) -> list[Node]:
    seen: set[int] = set()
    order: list[Node] = []

    def rec(n: Node):
        if n.uid in seen:
            return
        seen.add(n.uid)
        for i in n.inputs:
            rec(i)
        order.append(n)

    for r in roots:
        rec(r)
    return order


def compile_plan(outputs: list[LTensor], *, reuse_enabled: bool = False,
                 opt_level: int = 2,
                 local_budget: int = LOCAL_MEM_BUDGET) -> Plan:
    roots = [o.node for o in outputs]
    roots = run_rewrites(roots, reuse_enabled=reuse_enabled,
                         opt_level=opt_level)
    order = topo_order(roots)

    # liveness: last consumer of each node frees it (buffer-pool eviction)
    last_consumer: dict[int, int] = {}
    for idx, n in enumerate(order):
        for i in n.inputs:
            last_consumer[i.uid] = idx
    root_ids = {r.uid for r in roots}
    frees_at: dict[int, list[int]] = {}
    for uid, idx in last_consumer.items():
        if uid not in root_ids:
            frees_at.setdefault(idx, []).append(uid)

    instructions: list[Instruction] = []
    peak = 0
    live = 0
    live_sizes: dict[int, int] = {}  # uid -> bytes counted into `live`
    for idx, n in enumerate(order):
        if n.op == "input":
            continue
        op_bytes = n.est_bytes() + sum(i.est_bytes() for i in n.inputs)
        target = "distributed" if op_bytes > local_budget else "local"
        cost = costmodel.est_cost_s(n)
        instructions.append(Instruction(
            node=n, out_id=n.uid,
            input_ids=tuple(i.uid for i in n.inputs),
            target=target,
            last_use_of=tuple(frees_at.get(idx, ())),
            probe=cost >= costmodel.PROBE_MIN_COST_S,
            est_cost_s=cost))
        sz = n.est_bytes()
        live_sizes[n.uid] = sz
        live += sz
        peak = max(peak, live)
        for uid in frees_at.get(idx, ()):
            # frees of input leaves were never counted into `live`
            live -= live_sizes.pop(uid, 0)

    return Plan(instructions=instructions,
                output_ids=[r.uid for r in roots], roots=roots,
                est_bytes_peak=peak, reuse_enabled=reuse_enabled)
