"""Straggler / failure handling harness (host-side control plane).

At 1000+ nodes the control plane must notice slow or dead workers. This
module provides the pieces the launcher composes:

  * StepMonitor — per-step timing stats, flags stragglers beyond a
    robust threshold (median + k·MAD), keeps an incident log.
  * HeartbeatTracker — host heartbeats with a dead-man switch.
  * simulate_failures — deterministic failure injection for tests
    (used with checkpoint.restart to prove exact-replay recovery).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class StepMonitor:
    window: int = 64
    mad_k: float = 5.0
    # history bound: long-lived consumers (the runtime's per-site RPC
    # monitor, the serving dispatch monitor — see RuntimeStats.faults)
    # record forever; percentiles cover recent history, memory stays flat
    max_history: int = 4096
    times: list = field(default_factory=list)
    incidents: list = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler.

        Hot path — called once per serving dispatch / site RPC, so the
        common (non-straggler) case is a slice + append + `sum()` over
        the <=64-entry window; the median/MAD pair only runs when the
        sample already clears the cheap mean guard (a straggler is
        several sigma out, so it clears any reasonable mean too)."""
        hist = self.times[-self.window:]
        self.times.append(seconds)
        if len(self.times) >= 2 * self.max_history:
            del self.times[:-self.max_history]
        if len(self.incidents) >= 2 * self.max_history:
            del self.incidents[:-self.max_history]
        n = len(hist)
        if n < 8:
            return False
        if seconds <= 1.2 * (sum(hist) / n):
            return False
        srt = sorted(hist)
        mid = n // 2
        med = srt[mid] if n % 2 else 0.5 * (srt[mid - 1] + srt[mid])
        dev = sorted(abs(t - med) for t in hist)
        mad = (dev[mid] if n % 2 else 0.5 * (dev[mid - 1] + dev[mid])) \
            or 1e-9
        if seconds > med + self.mad_k * mad and seconds > 1.2 * med:
            self.incidents.append(
                {"step": step, "seconds": seconds, "median": med})
            return True
        return False

    def p50_p99(self) -> tuple[float, float]:
        arr = np.array(self.times or [0.0])
        return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))


@dataclass
class HeartbeatTracker:
    timeout_s: float = 60.0
    last_seen: dict = field(default_factory=dict)

    def beat(self, host: str, now: Optional[float] = None) -> None:
        self.last_seen[host] = now if now is not None else time.monotonic()

    def dead_hosts(self, now: Optional[float] = None) -> list[str]:
        now = now if now is not None else time.monotonic()
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout_s]


def simulate_failures(total_steps: int, fail_steps: tuple, run_fn,
                      resume_fn):
    """Drive run_fn until each injected failure, then resume_fn; returns
    the final state. Used by tests to prove restart exactness."""
    state = None
    for fs in sorted(fail_steps):
        state = run_fn(until=fs, state=state)
        state = resume_fn(state)
    return run_fn(until=total_steps, state=state)
