"""Sharding helpers shared by the DSL's mesh placement and the launch
layer.

The DSL's sharded execution is a *compiler* placement: `repro.core
.compiler.lower_distributed` propagates a row-sharded placement over
the HOP DAG against the mesh axes of `repro.distributed.mesh`
(``data`` shards rows, ``config`` shards the parfor bucket axis), and
the runtime lowers sharded segments through `jax.shard_map`. What this
module contributes to that path is the *graceful degradation* contract:

  * `safe_spec` — drop any spec axis that does not divide the
    corresponding dimension (replicate instead of erroring);
  * `rows_shardable` — the compile-time form of the same rule used by
    `lower_distributed` to decide whether a leaf's row count divides
    the ``data`` axis (a non-dividing leaf stays local/replicated).

The transformer-era regex rule table (embed/attn/moe path patterns)
that used to live here reaches nothing in the DSL; it is quarantined in
`repro.distributed.legacy_rules` for the launch-layer dry-run tooling
and re-exported below for backward compatibility.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def safe_spec(shape: tuple, spec: P, mesh: Mesh) -> P:
    """Drop spec axes that don't divide the dim (graceful degradation)."""
    out = []
    for i, names in enumerate(spec):
        if names is None or i >= len(shape):
            out.append(None)
            continue
        group = names if isinstance(names, tuple) else (names,)
        size = int(np.prod([mesh.shape[n] for n in group]))
        out.append(names if shape[i] % size == 0 else None)
    return P(*out)


def rows_shardable(shape: tuple, d: int) -> bool:
    """Compile-time `safe_spec` for the row axis: True iff sharding
    axis 0 over `d` devices divides evenly. A False answer means the
    value replicates (stays local) — it never errors."""
    return d > 1 and len(shape) >= 1 and shape[0] % d == 0 \
        and shape[0] >= d


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# Backward-compatible re-exports of the quarantined transformer-era
# builders (consumed by repro.launch.dryrun only).
from .legacy_rules import (batch_specs, cache_specs,  # noqa: E402,F401
                           param_specs)
