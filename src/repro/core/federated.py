"""Federated tensors and federated instructions (SystemDS §3.3, §4.3).

A `FederatedTensor` is a metadata object holding references to per-site
partitions covering disjoint row (or column) ranges. Instructions push
computation to the sites and exchange only the minimal aggregates
(paper Example 2):

  fed_mv   : broadcast v -> local X_i @ v       -> rbind of results
  fed_vm   : send v slice -> local v_i^T @ X_i  -> elementwise sum
  fed_gram : local X_i^T X_i                    -> sum (n² exchange only)
  fed_xtv  : local X_i^T y_i                    -> sum

Every exchange is metered (`ExchangeLog`) — the paper's "exchange
constraints" become an auditable byte budget per site.

Two backends:
  * `LocalSite` — in-process numpy workers (this container; also the
    unit-test oracle).
  * the multi-pod mesh backend lives in `repro.distributed.fedavg`:
    sites = slices along the `pod` mesh axis, instructions lower to
    shard_map programs with psum/all_gather on that axis only.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np


@dataclass
class ExchangeLog:
    to_sites: int = 0      # bytes master -> workers
    from_sites: int = 0    # bytes workers -> master

    def add_out(self, arr):
        self.to_sites += int(np.asarray(arr).nbytes)

    def add_in(self, arr):
        self.from_sites += int(np.asarray(arr).nbytes)

    @property
    def total(self) -> int:
        return self.to_sites + self.from_sites


@dataclass
class LocalSite:
    """An in-process 'remote worker' owning one partition."""
    data: np.ndarray

    def mv(self, v):           # X_i @ v
        return self.data @ v

    def vm(self, v_slice):     # v_i^T @ X_i
        return v_slice.T @ self.data

    def gram(self):            # X_i^T X_i
        return self.data.T @ self.data

    def xtv(self, y_i):        # X_i^T y_i
        return self.data.T @ y_i

    def colsums(self):
        return self.data.sum(axis=0, keepdims=True)

    def rows(self):
        return self.data.shape[0]


@dataclass
class FederatedTensor:
    """Row-partitioned federated matrix: sites cover disjoint row ranges."""

    sites: list[LocalSite]
    ranges: list[tuple[int, int]]  # [start, stop) per site
    ncols: int
    log: ExchangeLog = field(default_factory=ExchangeLog)

    @classmethod
    def partition_rows(cls, x: np.ndarray, n_sites: int) -> "FederatedTensor":
        splits = np.array_split(np.arange(x.shape[0]), n_sites)
        sites, ranges = [], []
        for idx in splits:
            sites.append(LocalSite(x[idx]))
            ranges.append((int(idx[0]), int(idx[-1]) + 1))
        return cls(sites=sites, ranges=ranges, ncols=x.shape[1])

    @property
    def nrows(self) -> int:
        return sum(s.rows() for s in self.sites)

    @property
    def shape(self) -> tuple[int, int]:
        return (self.nrows, self.ncols)

    # -- federated instructions (Example 2) ---------------------------------
    def fed_mv(self, v: np.ndarray) -> np.ndarray:
        """X @ v: broadcast v, local MV, rbind results."""
        parts = []
        for s in self.sites:
            self.log.add_out(v)          # broadcast
            r = s.mv(v)
            self.log.add_in(r)           # collect
            parts.append(r)
        return np.concatenate(parts, axis=0)

    def fed_vm(self, v: np.ndarray) -> np.ndarray:
        """v^T @ X: send only the relevant slice of v, add local results."""
        out = None
        for s, (a, b) in zip(self.sites, self.ranges):
            vs = v[a:b]
            self.log.add_out(vs)
            r = s.vm(vs)
            self.log.add_in(r)
            out = r if out is None else out + r
        return out

    def fed_gram(self) -> np.ndarray:
        """X^T X with only n×n bytes exchanged per site (data never moves).
        This is the same fold decomposition the reuse rewrites exploit —
        federated learning and CV partial reuse share one algebraic core."""
        out = None
        for s in self.sites:
            g = s.gram()
            self.log.add_in(g)
            out = g if out is None else out + g
        return out

    def fed_xtv(self, y: np.ndarray) -> np.ndarray:
        out = None
        for s, (a, b) in zip(self.sites, self.ranges):
            ys = y[a:b]
            self.log.add_out(ys)
            r = s.xtv(ys)
            self.log.add_in(r)
            out = r if out is None else out + r
        return out

    def fed_colsums(self) -> np.ndarray:
        out = None
        for s in self.sites:
            r = s.colsums()
            self.log.add_in(r)
            out = r if out is None else out + r
        return out

    def collect(self) -> np.ndarray:
        """Materialize (breaks federation — for tests/debug only)."""
        return np.concatenate([s.data for s in self.sites], axis=0)


# ---------------------------------------------------------------------------
# Federated closed-form regression (the §4.3 enterprise use-case)
# ---------------------------------------------------------------------------

def federated_lmds(fx: FederatedTensor, y: np.ndarray, reg: float = 1e-7,
                   intercept: bool = False) -> np.ndarray:
    """lmDS over a federated X: only gram-sized aggregates leave sites."""
    if intercept:
        fx = FederatedTensor(
            sites=[LocalSite(np.concatenate(
                [s.data, np.ones((s.rows(), 1))], axis=1))
                for s in fx.sites],
            ranges=fx.ranges, ncols=fx.ncols + 1, log=fx.log)
    a = fx.fed_gram() + reg * np.eye(fx.ncols)
    b = fx.fed_xtv(y)
    return np.linalg.solve(a, b)
